"""Docs CI: execute the README quickstart and link-check the docs.

Two guarantees, so the documentation can't rot silently:

1. the FIRST ```python fence in README.md is extracted verbatim and run
   under the same interpreter/PYTHONPATH as the tests — a README
   quickstart that no longer imports or asserts is a CI failure, not a
   user bug report;
2. every relative markdown link in README.md and docs/*.md must point
   at an existing file (http(s) and pure-anchor links are skipped —
   this is a repo-consistency check, not a crawler).

Usage: python tools/docs_check.py   (from the repo root; sets
PYTHONPATH=src for the quickstart subprocess itself)
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images is not needed (repo has none), but
# ignore in-code spans by only scanning outside fenced blocks
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```")


def first_python_fence(md_path: str) -> str:
    """The first ```python code block's body, verbatim."""
    lines = open(md_path).read().splitlines()
    body: list[str] = []
    in_fence = False
    for line in lines:
        if not in_fence and line.strip().startswith("```python"):
            in_fence = True
            continue
        if in_fence:
            if line.strip().startswith("```"):
                return "\n".join(body) + "\n"
            body.append(line)
    raise SystemExit(f"{md_path}: no ```python fence found")


def run_quickstart(md_path: str) -> None:
    code = first_python_fence(md_path)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    print(f"-- running quickstart from {os.path.relpath(md_path, REPO)} "
          f"({len(code.splitlines())} lines)")
    proc = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO)
    if proc.returncode != 0:
        raise SystemExit(f"README quickstart failed "
                         f"(exit {proc.returncode})")


def check_links(md_path: str) -> list[str]:
    """Relative links in ``md_path`` that don't resolve to a file."""
    bad = []
    in_fence = False
    for line in open(md_path).read().splitlines():
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in _LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md_path), path))
            if not os.path.exists(resolved):
                bad.append(f"{os.path.relpath(md_path, REPO)}: "
                           f"broken link -> {target}")
    return bad


def main() -> int:
    readme = os.path.join(REPO, "README.md")
    docs_dir = os.path.join(REPO, "docs")
    md_files = [readme] + sorted(
        os.path.join(docs_dir, f) for f in os.listdir(docs_dir)
        if f.endswith(".md"))
    bad = []
    for md in md_files:
        bad += check_links(md)
    for b in bad:
        print(f"FAIL {b}")
    run_quickstart(readme)
    if bad:
        print(f"docs check: {len(bad)} broken link(s)")
        return 1
    print(f"docs check: OK ({len(md_files)} files, quickstart ran)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
