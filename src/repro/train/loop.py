"""Fault-tolerant training loop.

Run fragment: checkpoint-every-N with commit markers, resume-from-latest
on (re)start, straggler monitor fed by per-step wall clock, watchdog-
triggered restart path, deterministic data (step-keyed) so a resumed run
bit-matches an uninterrupted one. ``run()`` is what examples/train_lm.py
and launch/train.py call; crash injection in tests exercises the resume.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data.tokens import TokenPipeline
from repro.dist.straggler import StragglerMonitor, StepWatchdog
from repro.train.step import TrainConfig, init_state, make_train_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    step_timeout_s: float = 3600.0
    keep_metrics: bool = True


def run(cfg, tcfg: TrainConfig, loop: LoopConfig, pipeline: TokenPipeline,
        seed: int = 0, on_step: Optional[Callable] = None,
        crash_at: Optional[int] = None):
    """Train cfg (an LMConfig) until loop.total_steps. Returns (state,
    metrics history). ``crash_at`` raises at that step (tests exercise
    restart); resume picks up from the last committed checkpoint."""
    train_step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    state = None
    start_step = 0
    if loop.ckpt_dir:
        template_state = init_state(cfg, tcfg, jax.random.PRNGKey(seed))
        restored, step = restore_checkpoint(loop.ckpt_dir, template_state)
        if restored is not None:
            state = jax.tree.map(jax.numpy.asarray, restored)
            start_step = int(step)
            log.info("resumed from step %d", start_step)
        else:
            state = template_state
    else:
        state = init_state(cfg, tcfg, jax.random.PRNGKey(seed))

    monitor = StragglerMonitor(n_hosts=1)
    watchdog = StepWatchdog(loop.step_timeout_s)
    history = []
    for step in range(start_step, loop.total_steps):
        if crash_at is not None and step == crash_at:
            raise RuntimeError(f"injected crash at step {step}")
        batch = pipeline.batch_at(step)
        watchdog.start()
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])          # blocks: true step time
        dt = time.perf_counter() - t0
        monitor.record(0, dt)
        if watchdog.expired():
            log.warning("watchdog expired at step %d (%.1fs)", step, dt)
        if loop.keep_metrics:
            history.append({"step": step, "loss": loss,
                            "sec": dt,
                            "grad_norm": float(metrics["grad_norm"])})
        if loop.log_every and step % loop.log_every == 0:
            log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
        if on_step:
            on_step(step, state, metrics)
        if (loop.ckpt_dir and loop.ckpt_every
                and (step + 1) % loop.ckpt_every == 0):
            save_checkpoint(loop.ckpt_dir, step + 1, state)
    return state, history
