"""train_step / serve_step factories — the functions the dry-run lowers
and the training loop runs.

train_step: CE loss (+MoE aux +MTP) -> grads -> AdamW update, with
per-layer remat (scan body checkpointing), optional grad accumulation
(scan over microbatches, accumulating in f32), bf16 params / f32 moments.

Shardings are produced by dist.sharding from the models' logical axes;
GSPMD inserts the collectives (all-reduce over (pod, data) for grads,
all-gathers around TP) — the dry-run's collective schedule is read from
the compiled HLO.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import param_logical_axes, param_shapes
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_with_warmup


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    remat: bool = True
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000


def make_train_step(cfg: lm.LMConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {params, opt, step}; batch = {tokens|embeds, labels[, ctx]}.
    """
    mcfg = dataclasses.replace(cfg, remat=tcfg.remat)

    def loss_fn(params, batch):
        return lm.lm_loss(
            params, mcfg,
            tokens=batch.get("tokens"), labels=batch["labels"],
            embeds=batch.get("embeds"), ctx=batch.get("ctx"))

    def train_step(state, batch):
        params = state["params"]
        if tcfg.grad_accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((tcfg.grad_accum,
                                     x.shape[0] // tcfg.grad_accum)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        lr = cosine_with_warmup(state["step"], peak_lr=tcfg.peak_lr,
                                warmup=tcfg.warmup, total=tcfg.total_steps)
        new_params, new_opt = adamw_update(params, grads, state["opt"],
                                           tcfg.opt, lr)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(g.astype(jnp.float32) ** 2)
                       for g in jax.tree.leaves(grads)))}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def make_serve_prefill(cfg: lm.LMConfig, max_seq: int):
    def prefill_step(params, batch, caches):
        return lm.prefill(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), ctx=batch.get("ctx"),
                          caches=caches, max_seq=max_seq)
    return prefill_step


def make_serve_decode(cfg: lm.LMConfig):
    def decode(params, token, caches, ctx=None):
        return lm.decode_step(params, cfg, token, caches, ctx=ctx)
    return decode


def init_state(cfg: lm.LMConfig, tcfg: TrainConfig, key):
    from repro.models.common import init_params
    params = init_params(lm.lm_specs(cfg), key)
    return {"params": params, "opt": adamw_init(params, tcfg.opt),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes(cfg: lm.LMConfig, tcfg: TrainConfig):
    """ShapeDtypeStructs + logical axes for the dry-run (no allocation)."""
    specs = lm.lm_specs(cfg)
    p_shapes = param_shapes(specs)
    p_axes = param_logical_axes(specs)

    def mom_shapes(sds):
        if tcfg.opt.int8_moments:
            return {"m": jax.ShapeDtypeStruct(sds.shape, jnp.int8),
                    "ms": jax.ShapeDtypeStruct((), jnp.float32),
                    "v": jax.ShapeDtypeStruct(sds.shape, jnp.int8),
                    "vs": jax.ShapeDtypeStruct((), jnp.float32)}
        return {"m": jax.ShapeDtypeStruct(sds.shape, jnp.float32),
                "v": jax.ShapeDtypeStruct(sds.shape, jnp.float32)}

    def mom_axes(ax):
        if tcfg.opt.int8_moments:
            return {"m": ax, "ms": (), "v": ax, "vs": ()}
        return {"m": ax, "v": ax}

    is_ax = lambda x: isinstance(x, tuple)
    state_sh = {
        "params": p_shapes,
        "opt": {"mu": jax.tree.map(mom_shapes, p_shapes),
                "count": jax.ShapeDtypeStruct((), jnp.int32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_ax = {
        "params": p_axes,
        "opt": {"mu": jax.tree.map(mom_axes, p_axes, is_leaf=is_ax),
                "count": ()},
        "step": (),
    }
    return state_sh, state_ax
