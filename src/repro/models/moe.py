"""Mixture-of-Experts FFN (phi3.5-moe: 16e top-2; deepseek-v3: 1 shared +
256 routed top-8 with aux-free sigmoid routing).

Expert-parallel formulation: experts are a leading param axis (logical
axis "experts" -> mesh "model"), and dispatch is dense one-hot einsum over
a capacity-bounded buffer — the standard TPU MoE layout (GShard/Switch):
no dynamic shapes, the all-to-all materializes as einsum contractions that
GSPMD lowers onto the expert axis.

Routing styles:
  "softmax_topk"  — softmax over router logits then top-k renormalized
                    (phi/mixtral style)
  "sigmoid_topk"  — deepseek-v3: sigmoid affinities + per-expert bias for
                    aux-free load balance; weights renormalized over top-k.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    n_shared: int = 0              # shared (always-on) experts
    d_ff_shared: int = 0           # hidden of the fused shared expert
    routing: str = "softmax_topk"  # or "sigmoid_topk"
    capacity_factor: float = 1.25
    router_dtype: object = jnp.float32
    dispatch_groups: int = 16      # GShard groups (-> data axis); auto-
    # reduced to the largest power of two dividing the token count


def moe_specs(cfg: MoEConfig, dtype=jnp.bfloat16):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s = {
        "router": ParamSpec((D, E), ("embed", None), jnp.float32,
                            init_scale=0.02),
        "wi_gate": ParamSpec((E, D, F), ("experts", "embed", "mlp"), dtype),
        "wi_up": ParamSpec((E, D, F), ("experts", "embed", "mlp"), dtype),
        "wo": ParamSpec((E, F, D), ("experts", "mlp", "embed"), dtype),
    }
    if cfg.routing == "sigmoid_topk":
        s["router_bias"] = ParamSpec((E,), (None,), jnp.float32, "zeros")
    if cfg.n_shared > 0:
        Fs = cfg.d_ff_shared or cfg.n_shared * F
        s["shared_wi_gate"] = ParamSpec((D, Fs), ("embed", "mlp"), dtype)
        s["shared_wi_up"] = ParamSpec((D, Fs), ("embed", "mlp"), dtype)
        s["shared_wo"] = ParamSpec((Fs, D), ("mlp", "embed"), dtype)
    return s


def _route(params, cfg: MoEConfig, x_flat):
    """x_flat (N, D) -> (weights (N, k) f32, idx (N, k) i32, aux_loss)."""
    logits = (x_flat.astype(cfg.router_dtype)
              @ params["router"].astype(cfg.router_dtype))     # (N, E)
    if cfg.routing == "sigmoid_topk":
        affin = jax.nn.sigmoid(logits)
        biased = affin + params["router_bias"][None, :]
        _, idx = jax.lax.top_k(biased, cfg.top_k)             # bias picks...
        w = jnp.take_along_axis(affin, idx, axis=1)           # ...affin pays
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        aux = jnp.zeros((), jnp.float32)                      # aux-free
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        # Switch-style load-balance loss: E * sum_e f_e * p_e
        me = probs.mean(axis=0)
        one_hot = jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32)
        ce = one_hot.mean(axis=0)
        aux = cfg.n_experts * jnp.sum(me * ce)
    return w.astype(jnp.float32), idx, aux


def _capacity(cfg: MoEConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)


def _n_groups(cfg: MoEConfig, N: int) -> int:
    """Dispatch groups (GShard-style). Groups map onto the data axis so
    slot assignment (a cumsum) and the dispatch scatter stay shard-local;
    the expert einsum then carries a ("batch", "experts") layout that
    GSPMD turns into the canonical MoE all-to-all instead of replicating
    the expert GEMMs (the 256x compute blow-up the baseline §Perf row
    measured)."""
    g = cfg.dispatch_groups
    while g > 1 and N % g != 0:
        g //= 2
    return max(g, 1)


def _dispatch_group(xg, idxg, wg, C: int, E: int, top_k: int):
    """One group's dispatch. xg (n, D), idxg/wg (n, k).
    Returns (disp (E, C, D), e_flat, s_flat, w_masked)."""
    n, D = xg.shape
    onehot = jax.nn.one_hot(idxg, E, dtype=jnp.int32)         # (n, k, E)
    flat = onehot.reshape(n * top_k, E)
    pos_in_e = (jnp.cumsum(flat, axis=0) - 1) * flat          # (n*k, E)
    slot = pos_in_e.max(axis=1).reshape(n, top_k)             # (n, k)
    keep = slot < C
    w = jnp.where(keep, wg, 0.0)
    e_flat = idxg.reshape(-1)
    s_flat = jnp.where(keep, slot, C).reshape(-1)
    tok = jnp.repeat(jnp.arange(n), top_k)
    disp = jnp.zeros((E, C, D), xg.dtype)
    disp = disp.at[e_flat, jnp.minimum(s_flat, C - 1)].add(
        jnp.where((s_flat < C)[:, None], xg[tok], 0).astype(xg.dtype))
    return disp, e_flat, s_flat, w


def _combine_group(eo, e_flat, s_flat, w, C: int, top_k: int):
    """eo (E, C, D) -> (n, D) weighted combine.

    The elementwise weighting casts back to eo's dtype immediately: the
    gather partials cross the model axis (an all-reduce), and an f32
    promotion here doubles that collective's bytes — §Perf iteration 3
    measured exactly that before this cast."""
    out_k = eo[e_flat, jnp.minimum(s_flat, C - 1)]            # (n*k, D)
    out_k = (out_k.astype(jnp.float32)
             * w.reshape(-1, 1)).astype(eo.dtype)
    n = w.shape[0]
    return out_k.reshape(n, top_k, eo.shape[2]).sum(axis=1)


def moe_ffn(params, cfg: MoEConfig, x):
    """x (B, T, D) -> (out (B, T, D), aux_loss).

    Grouped dense-dispatch EP MoE: tokens split into G groups (logical
    axis "batch" -> data), experts stay a leading axis (logical
    "experts" -> model). Slot assignment + scatter vmap over groups
    (shard-local); the expert SwiGLU runs as (G, E, C, D) einsums whose
    (data, model) layout yields the all-to-all dispatch schedule."""
    from repro.dist.sharding import constrain
    B, T, D = x.shape
    N = B * T
    xf = x.reshape(N, D)
    w, idx, aux = _route(params, cfg, xf)
    E = cfg.n_experts
    G = _n_groups(cfg, N)
    n_g = N // G
    C = _capacity(cfg, n_g)

    xg = xf.reshape(G, n_g, D)
    idx_g = idx.reshape(G, n_g, cfg.top_k)
    w_g = w.reshape(G, n_g, cfg.top_k)
    disp, e_flat, s_flat, w_m = jax.vmap(
        lambda xx, ii, ww: _dispatch_group(xx, ii, ww, C, E, cfg.top_k)
    )(xg, idx_g, w_g)                                          # (G, E, C, D)
    disp = constrain(disp, ("batch", "experts", None, "embed"))

    # Expert einsums emit the model dtype: with preferred f32 outputs the
    # *backward cotangents* of disp/h are f32 and the dispatch/combine
    # cross-shard reductions double in bytes (§Perf iteration 4; on TPU
    # the MXU still accumulates in f32 internally).
    g = jnp.einsum("gecd,edf->gecf", disp, params["wi_gate"],
                   preferred_element_type=x.dtype)
    u = jnp.einsum("gecd,edf->gecf", disp, params["wi_up"],
                   preferred_element_type=x.dtype)
    h = (jax.nn.silu(g.astype(jnp.float32))
         * u.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, ("batch", "experts", None, "mlp"))
    eo = jnp.einsum("gecf,efd->gecd", h, params["wo"],
                    preferred_element_type=x.dtype)
    eo = constrain(eo, ("batch", "experts", None, "embed"))

    out = jax.vmap(
        lambda ee, ef, sf, ww: _combine_group(ee, ef, sf, ww, C, cfg.top_k)
    )(eo, e_flat, s_flat, w_m)                                 # (G, n_g, D)
    # remat save-point: the combine output's cross-shard all-reduce is the
    # layer's dominant collective — recomputing it in the backward pass
    # would double it (see EXPERIMENTS.md §Perf iteration 2).
    from jax.ad_checkpoint import checkpoint_name
    out = checkpoint_name(out, "moe_combine")
    out = out.reshape(N, D)

    if cfg.n_shared > 0:
        gs = xf @ params["shared_wi_gate"]
        us = xf @ params["shared_wi_up"]
        out = out + (jax.nn.silu(gs.astype(jnp.float32)) *
                     us.astype(jnp.float32)).astype(x.dtype) \
            @ params["shared_wo"]
    return out.reshape(B, T, D).astype(x.dtype), aux
