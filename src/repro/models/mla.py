"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437 §2.1).

Q path:  x -> q_a (q_lora_rank) -> norm -> q_b -> heads×(nope ++ rope)
KV path: x -> kv_a (kv_lora_rank ++ k_rope shared) -> norm(latent)
         latent -> kv_b -> heads×(k_nope ++ v)

The decode cache stores only (c_kv latent, k_rope): per token
kv_lora_rank + rope_dim = 512 + 64 floats versus 128 heads × 2 × 128 for
plain MHA — the 57x cache compression that makes 32k/500k decode shapes
feasible; this is the serving-memory stressor among the assigned archs.

Decode recomputes K/V from the cached latent (the paper's "naive"
formulation, which XLA fuses into two extra GEMMs; the absorbed-weights
trick is a serving optimization we note in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention
from repro.models.common import ParamSpec, apply_rope, rms_norm


def v_pad_to_qk(v, cfg):
    """Pad V's head dim up to qk_head_dim so the blockwise kernel's PV
    matmul shape matches QK (sliced back by the caller)."""
    pad = cfg.qk_head_dim - cfg.v_head_dim
    if pad <= 0:
        return v
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.nope_head_dim + self.rope_head_dim


def mla_specs(cfg: MLAConfig, dtype=jnp.bfloat16):
    return {
        "wq_a": ParamSpec((cfg.d_model, cfg.q_lora_rank),
                          ("embed", None), dtype),
        "q_norm": ParamSpec((cfg.q_lora_rank,), (None,), dtype, "zeros"),
        "wq_b": ParamSpec((cfg.q_lora_rank, cfg.n_heads, cfg.qk_head_dim),
                          (None, "heads", "head_dim"), dtype),
        "wkv_a": ParamSpec((cfg.d_model, cfg.kv_lora_rank + cfg.rope_head_dim),
                           ("embed", None), dtype),
        "kv_norm": ParamSpec((cfg.kv_lora_rank,), (None,), dtype, "zeros"),
        "wkv_b": ParamSpec(
            (cfg.kv_lora_rank, cfg.n_heads,
             cfg.nope_head_dim + cfg.v_head_dim),
            (None, "heads", "head_dim"), dtype),
        "wo": ParamSpec((cfg.n_heads, cfg.v_head_dim, cfg.d_model),
                        ("heads", "head_dim", "embed"), dtype),
    }


def _project(params, cfg: MLAConfig, x, positions):
    """Returns (q (B,T,H,qk), c_kv (B,T,r), k_rope (B,T,1,rope))."""
    q_lat = jnp.einsum("btd,dr->btr", x, params["wq_a"])
    q_lat = rms_norm(q_lat, params["q_norm"])
    q = jnp.einsum("btr,rhk->bthk", q_lat, params["wq_b"])
    q_nope, q_rope = jnp.split(q, [cfg.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv_a = jnp.einsum("btd,dr->btr", x, params["wkv_a"])
    c_kv, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions)   # 1 shared head
    return q, c_kv, k_rope


def _expand_kv(params, cfg: MLAConfig, c_kv):
    """latent -> (k_nope (B,S,H,nope), v (B,S,H,v))."""
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"])
    return jnp.split(kv, [cfg.nope_head_dim], axis=-1)


def _mla_sdpa(cfg: MLAConfig, q, k_nope, k_rope, v, mask):
    scale = 1.0 / math.sqrt(cfg.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.nope_head_dim], axis=-1)
    logits = jnp.einsum("bthk,bshk->bhts", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
    logits += jnp.einsum("bthk,bsuk->bhts", q_rope,
                         jnp.broadcast_to(
                             k_rope, k_rope.shape[:2] + (1,) + k_rope.shape[3:]),
                         preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhts,bshk->bthk", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return out


def mla_attention(params, cfg: MLAConfig, x, positions,
                  cache: Optional[dict] = None):
    """Returns (out (B,T,D), new_cache). Cache holds the *latent* stream."""
    q, c_kv, k_rope = _project(params, cfg, x, positions)
    B, T = x.shape[0], x.shape[1]

    if cache is None:
        k_nope, v = _expand_kv(params, cfg, c_kv)
        if T >= attention.BLOCKWISE_THRESHOLD:
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(
                    k_rope, k_rope.shape[:2] + (cfg.n_heads,
                                                cfg.rope_head_dim))],
                axis=-1)
            scale = 1.0 / math.sqrt(cfg.qk_head_dim)
            out = attention._sdpa_blockwise(
                q, k_full, v_pad_to_qk(v, cfg), positions, positions,
                None, scale)[..., :cfg.v_head_dim]
        else:
            mask = positions[:, None, :] <= positions[:, :, None]
            out = _mla_sdpa(cfg, q, k_nope, k_rope, v, mask)
        new_cache = None
    else:
        idx = cache["index"]
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv, idx, axis=1)
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, idx, axis=1)
        S = ckv.shape[1]
        k_nope, v = _expand_kv(params, cfg, ckv)
        kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = (kv_pos[:, None, :] <= positions[:, :, None]) & \
               (kv_pos[:, None, :] < idx + T)
        out = _mla_sdpa(cfg, q, k_nope, ckr, v, mask)
        new_cache = {"c_kv": ckv, "k_rope": ckr, "index": idx + T}

    return jnp.einsum("bthk,hkd->btd", out, params["wo"]), new_cache


def init_cache(cfg: MLAConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, 1, cfg.rope_head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes(cfg: MLAConfig) -> dict:
    return {
        "c_kv": ("batch", "cache_seq", None),
        "k_rope": ("batch", "cache_seq", None, None),
        "index": (),
    }
