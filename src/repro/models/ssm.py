"""Mamba-2 block via the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060 §6; "attn-free" assigned arch mamba2-1.3b).

SSD computes y = SSM(A, B, C)(x) for scalar-per-head decay A_t by
splitting the sequence into chunks: intra-chunk terms are a masked
matmul (the "quadratic/attention" dual form, MXU-friendly), inter-chunk
terms propagate a per-chunk state h (the "linear/recurrent" form) through
an associative scan. This is the TPU-native formulation: all heavy math
is (chunk x chunk) or (chunk x state) matmuls.

Decode keeps a constant-size recurrent state (B*H, P, S_state) + the conv
tail — the reason this arch runs long_500k.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64             # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1              # B/C shared across heads per group

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_specs(cfg: SSMConfig, dtype=jnp.bfloat16):
    D, Din, H, S = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.d_state
    G = cfg.n_groups
    d_in_proj = 2 * Din + 2 * G * S + H
    return {
        "in_proj": ParamSpec((D, d_in_proj), ("embed", "mlp"), dtype),
        "conv_w": ParamSpec((cfg.conv_width, Din + 2 * G * S),
                            (None, "mlp"), dtype, init_scale=0.5),
        "conv_b": ParamSpec((Din + 2 * G * S,), ("mlp",), dtype, "zeros"),
        "A_log": ParamSpec((H,), ("heads",), jnp.float32, "zeros"),
        "dt_bias": ParamSpec((H,), ("heads",), jnp.float32, "zeros"),
        "D_skip": ParamSpec((H,), ("heads",), jnp.float32, "ones"),
        "norm": ParamSpec((Din,), ("mlp",), dtype, "zeros"),
        "out_proj": ParamSpec((Din, D), ("mlp", "embed"), dtype),
    }


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """SSD scan. x (b, T, H, P); dt (b, T, H) >=0; A (H,) <0 decay rates;
    Bm/Cm (b, T, G, S). Returns (y (b, T, H, P), h_last (b, H, P, S))."""
    b, T, H, P = x.shape
    G, S = Bm.shape[2], Bm.shape[3]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rep = H // G

    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = jnp.repeat(Bm.reshape(b, nc, chunk, G, S), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(b, nc, chunk, G, S), rep, axis=3)

    dA = dtc * A[None, None, None, :]                  # (b, nc, c, H) <= 0
    seg = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    total = seg[:, :, -1, :]                           # (b, nc, H)

    # --- intra-chunk (dual quadratic form) ---
    # L[i,j] = exp(seg_i - seg_j) for i >= j
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]     # (b,nc,c,c,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bnihs,bnjhs->bnijh", Cc, Bc,
                        preferred_element_type=jnp.float32)
    M = scores * L * dtc[:, :, None, :, :]                 # dt enters via B
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", M.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # --- chunk states: h_n = sum_j exp(total - seg_j) * dt_j B_j x_j^T ---
    decay_to_end = jnp.exp(total[:, :, None, :] - seg)     # (b,nc,c,H)
    w = (decay_to_end * dtc).astype(x.dtype)
    states = jnp.einsum("bnjh,bnjhs,bnjhp->bnhps", w, Bc, xc,
                        preferred_element_type=jnp.float32)  # (b,nc,H,P,S)

    # --- inter-chunk recurrence over nc (associative scan) ---
    decay_chunk = jnp.exp(total)                           # (b, nc, H)

    def combine(a, c):
        da, ha = a
        dc, hc = c
        return da * dc, hc + dc[..., None, None] * ha

    dch = decay_chunk.transpose(1, 0, 2)                   # (nc, b, H)
    sth = states.transpose(1, 0, 2, 3, 4)                  # (nc, b, H, P, S)
    _, hcum = jax.lax.associative_scan(combine, (dch, sth), axis=0)
    # h_prev for chunk n = state after chunks < n (+ carried h0)
    h_after = hcum.transpose(1, 0, 2, 3, 4)                # (b, nc, H, P, S)
    zero = jnp.zeros_like(h_after[:, :1])
    h_prev = jnp.concatenate([zero, h_after[:, :-1]], axis=1)
    if h0 is not None:
        # prepend carried state decayed into every chunk
        cumdec = jnp.exp(jnp.cumsum(
            jnp.concatenate([jnp.zeros_like(total[:, :1]), total[:, :-1]],
                            axis=1), axis=1))              # (b, nc, H)
        h_prev = h_prev + cumdec[..., None, None] * h0[:, None]
        h_last = h_after[:, -1] + jnp.exp(total.sum(axis=1))[..., None, None] * h0
    else:
        h_last = h_after[:, -1]

    # --- inter-chunk output: C_i exp(seg_i) h_prev ---
    din = jnp.exp(seg).astype(x.dtype)                     # (b, nc, c, H)
    y_inter = jnp.einsum("bnihs,bnih,bnhps->bnihp",
                         Cc, din, h_prev.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, T, H, P)
    return y.astype(x.dtype), h_last.astype(jnp.float32)


def ssm_block(params, cfg: SSMConfig, x, cache: Optional[dict] = None):
    """x (b, T, D) -> (y (b, T, D), new_cache). Cache = {conv, h, index}."""
    b, T, D = x.shape
    Din, G, S, H, P = (cfg.d_inner, cfg.n_groups, cfg.d_state,
                       cfg.n_heads, cfg.head_dim)
    proj = jnp.einsum("btd,de->bte", x, params["in_proj"])   # (b,T,dproj)
    z = proj[..., :Din]
    xBC = proj[..., Din:2 * Din + 2 * G * S]
    dt_raw = proj[..., 2 * Din + 2 * G * S:]

    # causal depthwise conv over xBC
    W = cfg.conv_width
    if cache is None:
        pad = jnp.zeros((b, W - 1, xBC.shape[-1]), xBC.dtype)
        xin = jnp.concatenate([pad, xBC], axis=1)
        new_conv = xin[:, -(W - 1):] if W > 1 else None
    else:
        xin = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
        new_conv = xin[:, -(W - 1):] if W > 1 else None
    idxs = jnp.arange(T)[:, None] + jnp.arange(W)[None, :]
    windows = xin[:, idxs]                                  # (b, T, W, ch)
    xBC = jnp.einsum("btwc,wc->btc", windows, params["conv_w"]) \
        + params["conv_b"]
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)

    xs = xBC[..., :Din].reshape(b, T, H, P)
    Bm = xBC[..., Din:Din + G * S].reshape(b, T, G, S)
    Cm = xBC[..., Din + G * S:].reshape(b, T, G, S)
    A = -jnp.exp(params["A_log"])                            # (H,) < 0
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                # (b, T, H) > 0

    h0 = cache["h"] if cache is not None else None
    if T % cfg.chunk == 0 and T > 1:
        y, h_last = _ssd_chunked(xs, dt, A, Bm, Cm, cfg.chunk, h0)
    else:
        # short/decode path: plain scan over T (T=1 at decode)
        def step(h, inp):
            xt, dtt, Bt, Ct = inp
            dA = jnp.exp(dtt * A)                            # (b, H)
            Bh = jnp.repeat(Bt, H // G, axis=1)              # (b, H, S)
            Ch = jnp.repeat(Ct, H // G, axis=1)
            upd = jnp.einsum("bh,bhs,bhp->bhps", dtt, Bh, xt.astype(jnp.float32))
            h = dA[..., None, None] * h + upd
            yt = jnp.einsum("bhs,bhps->bhp", Ch, h)
            return h, yt
        h0v = h0 if h0 is not None else jnp.zeros((b, H, P, S), jnp.float32)
        xsw = xs.transpose(1, 0, 2, 3)
        dtw = dt.transpose(1, 0, 2)
        Bw = Bm.transpose(1, 0, 2, 3).astype(jnp.float32)
        Cw = Cm.transpose(1, 0, 2, 3).astype(jnp.float32)
        h_last, ys = jax.lax.scan(step, h0v, (xsw, dtw, Bw, Cw))
        y = ys.transpose(1, 0, 2, 3).astype(x.dtype)

    y = y + params["D_skip"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(b, T, Din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"])
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(jnp.bfloat16), "h": h_last,
                     "index": cache["index"] + T}
    return out, new_cache


def init_cache(cfg: SSMConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.n_groups * cfg.d_state),
                          dtype),
        "h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                       jnp.float32),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes(cfg: SSMConfig) -> dict:
    return {
        "conv": ("batch", None, "mlp"),
        "h": ("batch", "heads", None, None),
        "index": (),
    }
