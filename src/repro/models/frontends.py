"""Stub modality frontends (per instructions: [audio]/[vlm] archs specify
the transformer BACKBONE; frontends provide precomputed embeddings).

These produce deterministic random embeddings with the right shapes for
smoke tests and examples; ``configs.registry.input_specs`` produces the
matching ShapeDtypeStructs for the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def encodec_frames(key, batch: int, n_frames: int, d_model: int,
                   dtype=jnp.bfloat16):
    """MusicGen stub: EnCodec latent frames already projected to d_model
    (the real frontend sums 4 codebook embeddings per frame)."""
    return (jax.random.normal(key, (batch, n_frames, d_model), jnp.float32)
            * 0.02).astype(dtype)


def vision_patches(key, batch: int, n_patches: int, d_ctx: int,
                   dtype=jnp.bfloat16):
    """Llama-3.2-Vision stub: ViT patch embeddings after the projector
    (cross-attention context). n_patches ~ (448/14)^2 = 1024 per tile."""
    return (jax.random.normal(key, (batch, n_patches, d_ctx), jnp.float32)
            * 0.02).astype(dtype)
