"""Param-spec machinery + shared layers (RMSNorm, RoPE, embeddings).

ParamSpec carries (shape, dtype, logical_axes, init). Modules build a
nested dict of specs; ``init_params`` materializes arrays,
``param_shapes`` gives ShapeDtypeStructs for the dry-run (no allocation),
and ``repro.dist.sharding.shardings_for`` maps logical axes -> mesh
shardings. Logical axis names used across the stack:

  "embed"     d_model                 "vocab"    vocabulary
  "heads"     attention query heads   "kv_heads" KV heads
  "head_dim"  per-head dim            "mlp"      FFN hidden
  "experts"   MoE expert count        "layers"   stacked-scan leading axis
  "ssm_state" SSM state dim           "rnn"      RG-LRU recurrent width
  (None in a position = replicated on that dim)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical_axes: tuple          # same length as shape; entries str | None
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"         # normal | zeros | ones | embed_normal
    init_scale: Optional[float] = None   # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), \
            (self.shape, self.logical_axes)


def _fan_in(shape: tuple) -> int:
    return shape[-2] if len(shape) >= 2 else max(shape[-1], 1)


def _materialize(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    scale = spec.init_scale
    if scale is None:
        scale = 1.0 / math.sqrt(_fan_in(spec.shape))
    if spec.init == "embed_normal":
        # 1/sqrt(d_model): keeps tied-head logits O(1) at init
        scale = 1.0 / math.sqrt(spec.shape[-1])
    x = jax.random.normal(key, spec.shape, jnp.float32) * scale
    return x.astype(spec.dtype)


def _tree_map_with_key(fn, specs, key):
    flat, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(flat))
    return jax.tree.unflatten(treedef, [fn(s, k) for s, k in zip(flat, keys)])


def init_params(specs, key):
    """Materialize a spec tree into a param tree."""
    return _tree_map_with_key(_materialize, specs, key)


def param_shapes(specs):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_logical_axes(specs):
    """Tree of logical-axis tuples, parallel to the param tree."""
    return jax.tree.map(
        lambda s: s.logical_axes, specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(specs) -> int:
    flat, _ = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(s.shape) for s in flat))


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Prepend a stacked leading axis (for scan-over-layers params)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.logical_axes,
                            s.dtype, s.init, s.init_scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# shared layer math (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., None, :]                     # (..., s, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def embed_specs(vocab: int, d_model: int, dtype=jnp.bfloat16):
    return {"embedding": ParamSpec((vocab, d_model), ("vocab", "embed"),
                                   dtype, "embed_normal")}


def embed_lookup(params, tokens):
    return params["embedding"][tokens]


def unembed(params, x):
    """Tied output head: (..., d) @ (vocab, d)^T in f32 for stable CE."""
    w = params["embedding"].astype(jnp.float32)
    return jax.lax.dot_general(
        x.astype(jnp.float32), w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def dense_specs(d_in: int, d_out: int, ax_in, ax_out, dtype=jnp.bfloat16,
                bias: bool = False, name: str = "w"):
    out = {name: ParamSpec((d_in, d_out), (ax_in, ax_out), dtype)}
    if bias:
        out[name + "_b"] = ParamSpec((d_out,), (ax_out,), dtype, "zeros")
    return out


def dense(params, x, name: str = "w"):
    y = jax.lax.dot_general(
        x, params[name], (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    if name + "_b" in params:
        y = y + params[name + "_b"].astype(y.dtype)
    return y


def softmax_cross_entropy(logits, labels, mask=None):
    """logits (..., V) f32, labels (...) i32; mean over mask."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
