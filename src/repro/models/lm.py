"""Unified decoder LM covering all assigned architectures.

A model is a cyclic ``pattern`` of LayerSpecs (plus an optional
non-cyclic ``prefix``, e.g. deepseek's 3 dense layers). Consecutive
identical specs are grouped into *runs*; each run's params are stacked on
a leading "layers" axis and applied with ``lax.scan`` — one compiled body
per distinct spec regardless of depth (the compile-time lever that makes
the 512-device dry-run tractable on a single-core host).

Layer kinds: "attn" (GQA, optional sliding window / qkv-bias /
cross-attn sublayer), "mla" (deepseek), "ssm" (mamba2 SSD), "rglru"
(recurrentgemma). FFN kinds: "dense" (SwiGLU), "moe", "none".

Inputs are token ids, or precomputed frontend embeddings for the
[audio]/[vlm] stub frontends (paper scope: backbone only).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamSpec, dense, embed_lookup, rms_norm,
                                 softmax_cross_entropy, stack_specs, unembed)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                     # attn | mla | ssm | rglru
    ffn: str = "dense"            # dense | moe | none
    window: Optional[int] = None  # sliding-window width for attn layers
    cross_attn: bool = False      # vision cross-attn sublayer


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_ff: int = 0
    pattern: tuple = (LayerSpec("attn"),)
    prefix: tuple = ()
    # sub-configs (present only where the pattern needs them)
    attn: Optional[attn_mod.AttnConfig] = None
    mla: Optional[mla_mod.MLAConfig] = None
    moe: Optional[moe_mod.MoEConfig] = None
    ssm: Optional[ssm_mod.SSMConfig] = None
    rglru: Optional[rglru_mod.RGLRUConfig] = None
    d_ctx: int = 0                # cross-attn context width (0 = none)
    n_ctx_tokens: int = 0         # stub frontend tokens (vlm)
    embed_inputs: bool = True     # False: frontend embeddings are the input
    tie_embeddings: bool = True
    mtp_depth: int = 0            # deepseek multi-token prediction heads
    logit_softcap: float = 0.0    # gemma-style final-logit soft cap
    dtype: object = jnp.bfloat16
    remat: bool = False           # activation checkpointing per layer
    unroll: bool = False          # unroll layer scans (roofline accounting:
    # XLA cost_analysis counts while bodies ONCE; unrolled graphs count
    # exactly — see launch/roofline.py's differential method)

    def layer_list(self) -> list:
        layers = list(self.prefix)
        i = 0
        while len(layers) < self.n_layers:
            layers.append(self.pattern[i % len(self.pattern)])
            i += 1
        return layers[:self.n_layers]

    def runs(self) -> list:
        """[(spec, count), ...] — consecutive identical layer specs."""
        out = []
        for spec in self.layer_list():
            if out and out[-1][0] == spec:
                out[-1] = (spec, out[-1][1] + 1)
            else:
                out.append((spec, 1))
        return out


# ---------------------------------------------------------------------------
# per-layer specs / apply
# ---------------------------------------------------------------------------

def _ffn_specs(cfg: LMConfig, spec: LayerSpec, dtype):
    if spec.ffn == "dense":
        return {
            "wi_gate": ParamSpec((cfg.d_model, cfg.d_ff), ("embed", "mlp"),
                                 dtype),
            "wi_up": ParamSpec((cfg.d_model, cfg.d_ff), ("embed", "mlp"),
                               dtype),
            "wo_ffn": ParamSpec((cfg.d_ff, cfg.d_model), ("mlp", "embed"),
                                dtype),
            "norm_ffn": ParamSpec((cfg.d_model,), ("embed",), dtype, "zeros"),
        }
    if spec.ffn == "moe":
        s = {"moe": moe_mod.moe_specs(cfg.moe, dtype)}
        s["norm_ffn"] = ParamSpec((cfg.d_model,), ("embed",), dtype, "zeros")
        return s
    return {}


def _layer_specs(cfg: LMConfig, spec: LayerSpec, dtype):
    s = {"norm_in": ParamSpec((cfg.d_model,), ("embed",), dtype, "zeros")}
    if spec.kind == "attn":
        acfg = dataclasses.replace(cfg.attn, window=spec.window)
        s["attn"] = attn_mod.attn_specs(acfg, dtype)
        if spec.cross_attn:
            s["xattn"] = attn_mod.cross_attn_specs(cfg.attn, cfg.d_ctx, dtype)
            s["norm_x"] = ParamSpec((cfg.d_model,), ("embed",), dtype,
                                    "zeros")
    elif spec.kind == "mla":
        s["mla"] = mla_mod.mla_specs(cfg.mla, dtype)
    elif spec.kind == "ssm":
        s["ssm"] = ssm_mod.ssm_specs(cfg.ssm, dtype)
    elif spec.kind == "rglru":
        s["rglru"] = rglru_mod.rglru_specs(cfg.rglru, dtype)
    else:
        raise ValueError(spec.kind)
    s.update(_ffn_specs(cfg, spec, dtype))
    return s


def _apply_ffn(cfg: LMConfig, spec: LayerSpec, lp, h):
    if spec.ffn == "none":
        return h, jnp.zeros((), jnp.float32)
    hn = rms_norm(h, lp["norm_ffn"])
    if spec.ffn == "dense":
        g = dense(lp, hn, "wi_gate")
        u = dense(lp, hn, "wi_up")
        y = (jax.nn.silu(g.astype(jnp.float32)) *
             u.astype(jnp.float32)).astype(h.dtype)
        return h + dense({"w": lp["wo_ffn"]}, y, "w"), \
            jnp.zeros((), jnp.float32)
    y, aux = moe_mod.moe_ffn(lp["moe"], cfg.moe, hn)
    return h + y, aux


def _apply_layer(cfg: LMConfig, spec: LayerSpec, lp, h, positions,
                 ctx=None, cache=None):
    """One decoder layer. Returns (h, new_cache, aux)."""
    hn = rms_norm(h, lp["norm_in"])
    if spec.kind == "attn":
        acfg = dataclasses.replace(cfg.attn, window=spec.window)
        y, new_cache = attn_mod.attention(lp["attn"], acfg, hn, positions,
                                          cache)
        h = h + y
        if spec.cross_attn:
            hx = rms_norm(h, lp["norm_x"])
            h = h + attn_mod.cross_attention(lp["xattn"], cfg.attn, hx, ctx)
    elif spec.kind == "mla":
        y, new_cache = mla_mod.mla_attention(lp["mla"], cfg.mla, hn,
                                             positions, cache)
        h = h + y
    elif spec.kind == "ssm":
        y, new_cache = ssm_mod.ssm_block(lp["ssm"], cfg.ssm, hn, cache)
        h = h + y
    elif spec.kind == "rglru":
        y, new_cache = rglru_mod.rglru_block(lp["rglru"], cfg.rglru, hn,
                                             cache)
        h = h + y
    else:
        raise ValueError(spec.kind)
    h, aux = _apply_ffn(cfg, spec, lp, h)
    return h, new_cache, aux


def _layer_cache(cfg: LMConfig, spec: LayerSpec, batch: int, max_seq: int):
    if spec.kind == "attn":
        acfg = dataclasses.replace(cfg.attn, window=spec.window)
        return attn_mod.init_cache(acfg, batch, max_seq, cfg.dtype)
    if spec.kind == "mla":
        return mla_mod.init_cache(cfg.mla, batch, max_seq, cfg.dtype)
    if spec.kind == "ssm":
        return ssm_mod.init_cache(cfg.ssm, batch, cfg.dtype)
    if spec.kind == "rglru":
        return rglru_mod.init_cache(cfg.rglru, batch, cfg.dtype)
    raise ValueError(spec.kind)


def _layer_cache_axes(cfg: LMConfig, spec: LayerSpec):
    if spec.kind == "attn":
        acfg = dataclasses.replace(cfg.attn, window=spec.window)
        return attn_mod.cache_logical_axes(acfg)
    if spec.kind == "mla":
        return mla_mod.cache_logical_axes(cfg.mla)
    if spec.kind == "ssm":
        return ssm_mod.cache_logical_axes(cfg.ssm)
    if spec.kind == "rglru":
        return rglru_mod.cache_logical_axes(cfg.rglru)
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# whole-model specs / forward
# ---------------------------------------------------------------------------

def lm_specs(cfg: LMConfig):
    dtype = cfg.dtype
    s = {
        "embed": {"embedding": ParamSpec((cfg.vocab, cfg.d_model),
                                         ("vocab", "embed"), dtype,
                                         "embed_normal")},
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), dtype, "zeros"),
        "runs": [stack_specs(_layer_specs(cfg, spec, dtype), count)
                 for spec, count in cfg.runs()],
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                 ("embed", "vocab"), dtype)
    if cfg.mtp_depth > 0:
        # deepseek MTP: per-depth projection + one extra layer (same spec
        # as the cyclic pattern's last layer), embedding shared.
        spec = cfg.pattern[-1]
        s["mtp"] = [{
            "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                              ("embed", None), dtype),
            "norm_h": ParamSpec((cfg.d_model,), ("embed",), dtype, "zeros"),
            "norm_e": ParamSpec((cfg.d_model,), ("embed",), dtype, "zeros"),
            "layer": _layer_specs(cfg, spec, dtype),
        } for _ in range(cfg.mtp_depth)]
    return s


def _run_scan(cfg: LMConfig, spec: LayerSpec, run_params, h, positions,
              ctx=None, caches=None):
    """Apply `count` stacked layers with lax.scan. caches: stacked pytree
    (leading axis = layer) or None. Returns (h, new_caches, aux_sum)."""
    if caches is None:
        def body(carry, lp):
            hh, aux = carry
            hh, _, a = _apply_layer(cfg, spec, lp, hh, positions, ctx, None)
            return (hh, aux + a), None
        if cfg.remat:
            policy = jax.checkpoint_policies.save_from_both_policies(
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                jax.checkpoint_policies.save_only_these_names(
                    "moe_combine"))
            body = jax.checkpoint(body, policy=policy)
        n_in_run = jax.tree.leaves(run_params)[0].shape[0]
        (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                   run_params,
                                   unroll=n_in_run if cfg.unroll else 1)
        return h, None, aux

    def body(carry, xs):
        hh, aux = carry
        lp, cache = xs
        hh, new_cache, a = _apply_layer(cfg, spec, lp, hh, positions, ctx,
                                        cache)
        return (hh, aux + a), new_cache
    (h, aux), new_caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (run_params, caches))
    return h, new_caches, aux


def forward(params, cfg: LMConfig, tokens=None, embeds=None, positions=None,
            ctx=None, caches=None):
    """Backbone forward. Returns (hidden (B,T,D), new_caches, aux)."""
    if cfg.embed_inputs:
        h = embed_lookup(params["embed"], tokens)
        if cfg.name.startswith("gemma"):
            h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    else:
        h = embeds.astype(cfg.dtype)
    B, T = h.shape[0], h.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    new_caches = [] if caches is not None else None
    aux = jnp.zeros((), jnp.float32)
    for i, (spec, count) in enumerate(cfg.runs()):
        c = caches[i] if caches is not None else None
        h, nc, a = _run_scan(cfg, spec, params["runs"][i], h, positions,
                             ctx, c)
        aux += a
        if caches is not None:
            new_caches.append(nc)
    h = rms_norm(h, params["final_norm"])
    return h, new_caches, aux


def logits_of(params, cfg: LMConfig, h):
    if cfg.tie_embeddings:
        lg = unembed(params["embed"], h)
    else:
        lg = jax.lax.dot_general(
            h.astype(jnp.float32), params["lm_head"].astype(jnp.float32),
            (((h.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0:
        lg = cfg.logit_softcap * jnp.tanh(lg / cfg.logit_softcap)
    return lg


def lm_loss(params, cfg: LMConfig, tokens=None, labels=None, embeds=None,
            ctx=None, aux_weight: float = 0.01):
    """Next-token CE (+ MoE aux + MTP losses). labels (B, T) with -1 pad."""
    h, _, aux = forward(params, cfg, tokens=tokens, embeds=embeds, ctx=ctx)
    lg = logits_of(params, cfg, h)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    loss = softmax_cross_entropy(lg, safe, mask)

    if cfg.mtp_depth > 0 and tokens is not None:
        # MTP depth d predicts token t+1+d from h_t combined with the
        # embedding of token t+d (teacher-forced chain).
        spec = cfg.pattern[-1]
        hk = h
        for d, mp in enumerate(params["mtp"], start=1):
            emb_next = embed_lookup(params["embed"],
                                    jnp.roll(tokens, -d, axis=1))
            mix = jnp.concatenate(
                [rms_norm(hk, mp["norm_h"]),
                 rms_norm(emb_next, mp["norm_e"])], axis=-1)
            hk = jax.lax.dot_general(
                mix, mp["proj"], (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(h.dtype)
            hk, _, a2 = _apply_layer(cfg, spec, mp["layer"], hk,
                                     jnp.broadcast_to(
                                         jnp.arange(hk.shape[1],
                                                    dtype=jnp.int32),
                                         hk.shape[:2]))
            aux += a2
            lgd = logits_of(params, cfg, hk)
            lbl_d = jnp.roll(labels, -d, axis=1)
            m_d = mask & (jnp.arange(hk.shape[1]) < hk.shape[1] - d)
            loss += 0.1 * softmax_cross_entropy(lgd, jnp.maximum(lbl_d, 0),
                                                m_d)

    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill / decode caches
# ---------------------------------------------------------------------------

def init_caches(cfg: LMConfig, batch: int, max_seq: int):
    """Stacked per-run caches (leading axis = layers in run)."""
    out = []
    for spec, count in cfg.runs():
        one = _layer_cache(cfg, spec, batch, max_seq)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
    return out


def cache_logical_axes(cfg: LMConfig):
    out = []
    for spec, count in cfg.runs():
        axes = _layer_cache_axes(cfg, spec)
        out.append(jax.tree.map(
            lambda a: ("layers",) + tuple(a), axes,
            is_leaf=lambda x: isinstance(x, tuple)))
    return out


def prefill(params, cfg: LMConfig, tokens=None, embeds=None, ctx=None,
            caches=None, max_seq: int = 0):
    """Run the prompt through the model, filling caches. Returns
    (last-position logits (B, V), caches)."""
    B = (tokens if tokens is not None else embeds).shape[0]
    T = (tokens if tokens is not None else embeds).shape[1]
    if caches is None:
        caches = init_caches(cfg, B, max_seq or T)
    h, caches, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                           ctx=ctx, caches=caches)
    return logits_of(params, cfg, h[:, -1:, :])[:, 0, :], caches


def decode_step(params, cfg: LMConfig, token, caches, ctx=None):
    """One decode step. token (B, 1) i32 (or (B, 1, D) embeds). Returns
    (logits (B, V), caches)."""
    B = token.shape[0]
    # positions for the new token(s): every run tracks "index"; use run 0
    idx0 = caches[0]["index"][0]
    T = token.shape[1]
    positions = idx0 + jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                        (B, T))
    if cfg.embed_inputs:
        h, caches, _ = forward(params, cfg, tokens=token,
                               positions=positions, ctx=ctx, caches=caches)
    else:
        h, caches, _ = forward(params, cfg, embeds=token,
                               positions=positions, ctx=ctx, caches=caches)
    return logits_of(params, cfg, h[:, -1:, :])[:, 0, :], caches
