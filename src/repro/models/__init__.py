"""Model stack: the assigned-architecture workload side of the framework.

Pure-pytree parameter handling (no flax): every module is a pair of
functions — ``*_specs(cfg) -> {name: ParamSpec}`` describing shapes,
dtypes, logical sharding axes and initializers, and an ``apply``-style
function taking the materialized param dict. ``repro.dist.sharding``
turns logical axes into mesh shardings for pjit / the dry-run.
"""

from repro.models.common import ParamSpec, init_params, param_shapes  # noqa: F401
