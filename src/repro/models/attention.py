"""Attention blocks: GQA (MHA/MQA as special cases), sliding-window,
cross-attention — with a decode-time KV cache.

All functions are pure over param dicts built from ParamSpecs. Shapes:
  x          (B, T, D)
  k/v cache  (B, S_max, n_kv, d_head)   (seq-major for clean SP sharding)
Masks are computed from positions, so prefill/decode share one kernel
path. Softmax in f32.

Sharding intent (logical axes; see dist/sharding.py):
  wq (embed, heads*d_head->"q_proj" dim tagged "heads")
  cache ("batch", "cache_seq", "kv_heads", "head_dim") — long_500k shards
  "cache_seq" over the data axis (sequence parallelism for decode).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_rope


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False            # qwen1.5
    window: Optional[int] = None      # sliding-window layers (gemma3, rg)
    causal: bool = True
    softmax_scale: Optional[float] = None

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head


def attn_specs(cfg: AttnConfig, dtype=jnp.bfloat16):
    s = {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, cfg.d_head),
                        ("embed", "heads", "head_dim"), dtype),
        "wk": ParamSpec((cfg.d_model, cfg.n_kv_heads, cfg.d_head),
                        ("embed", "kv_heads", "head_dim"), dtype),
        "wv": ParamSpec((cfg.d_model, cfg.n_kv_heads, cfg.d_head),
                        ("embed", "kv_heads", "head_dim"), dtype),
        "wo": ParamSpec((cfg.n_heads, cfg.d_head, cfg.d_model),
                        ("heads", "head_dim", "embed"), dtype),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((cfg.n_heads, cfg.d_head),
                            ("heads", "head_dim"), dtype, "zeros")
        s["bk"] = ParamSpec((cfg.n_kv_heads, cfg.d_head),
                            ("kv_heads", "head_dim"), dtype, "zeros")
        s["bv"] = ParamSpec((cfg.n_kv_heads, cfg.d_head),
                            ("kv_heads", "head_dim"), dtype, "zeros")
    return s


def _proj_qkv(params, cfg: AttnConfig, x, positions):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q (B,T,H,dh), k/v (B,S,Hkv,dh) with H = G*Hkv; mask (B,T,S) bool."""
    B, T, H, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, dh)
    logits = jnp.einsum("bthgk,bshk->bhgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgts,bshk->bthgk", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, H, dh).astype(q.dtype)


BLOCKWISE_THRESHOLD = 4096     # direct sdpa below this many q rows
_BLK_Q = 512
_BLK_K = 512

# Roofline accounting sets this to unroll the KV scan: XLA cost analysis
# counts while bodies once, so the production scan form undercounts
# attention FLOPs by n_kv_blocks (launch/roofline.py).
UNROLL_SCANS = False


def _sdpa_blockwise(q, k, v, q_pos, kv_pos, window, scale,
                    blk_q: int = _BLK_Q, blk_k: int = _BLK_K):
    """Memory-efficient attention: lazy (online) softmax over KV blocks,
    never materializing the (T, S) score matrix. Pure JAX — the LM side
    needs no Pallas per the scope rules; the O(T*blk) working set is what
    lets prefill_32k / long-context shapes fit HBM.

    Causality is enforced by per-block masks from positions; fully-masked
    (future) blocks still execute — a deliberate baseline inefficiency
    (upper-triangle waste ~2x on causal prefill) that EXPERIMENTS.md §Perf
    removes in an iteration (diagonal band scheduling).
    """
    B, T, H, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    Tp = ((T + blk_q - 1) // blk_q) * blk_q
    Sp = ((S + blk_k - 1) // blk_k) * blk_k
    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, ((0, 0), (0, Tp - T)), constant_values=-1)
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kpos = jnp.pad(kv_pos, ((0, 0), (0, Sp - S)),
                   constant_values=jnp.iinfo(jnp.int32).max)

    nq, nk = Tp // blk_q, Sp // blk_k
    qb = qp.reshape(B, nq, blk_q, Hkv, G, dh)
    qposb = qpos.reshape(B, nq, blk_q)
    kb = kp.reshape(B, nk, blk_k, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, blk_k, Hkv, dh).transpose(1, 0, 2, 3, 4)
    kposb = kpos.reshape(B, nk, blk_k).transpose(1, 0, 2)

    def kv_step(carry, inp):
        m, l, acc = carry                     # (B,nq,bq,Hkv,G) / ... / +dh
        kj, vj, kpj = inp                     # (B,bk,Hkv,dh), (B,bk)
        logits = jnp.einsum("bnqhgk,bshk->bnqhgs", qb, kj,
                            preferred_element_type=jnp.float32) * scale
        mask = kpj[:, None, None, :] <= qposb[:, :, :, None]
        if window is not None:
            mask &= kpj[:, None, None, :] > (qposb[:, :, :, None] - window)
        logits = jnp.where(mask[:, :, :, None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bnqhgs,bshk->bnqhgk", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, blk_q, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nq, blk_q, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, nq, blk_q, Hkv, G, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kposb),
                                  unroll=nk if UNROLL_SCANS else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, Tp, H, dh)[:, :T]
    return out.astype(q.dtype)


def _causal_mask(q_pos, kv_pos, window: Optional[int], kv_valid=None):
    """(B, T, S) bool: kv visible to q. positions (B,T)/(B,S)."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    if kv_valid is not None:
        m &= kv_valid[:, None, :]
    return m


def attention(params, cfg: AttnConfig, x, positions,
              cache: Optional[dict] = None):
    """Self-attention. Without cache: full (prefill/train). With cache:
    append this step's K/V at ``cache["index"]`` and attend over the cache
    (decode). Returns (out (B,T,D), new_cache)."""
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.d_head))
    q, k, v = _proj_qkv(params, cfg, x, positions)

    if cache is None:
        if x.shape[1] >= BLOCKWISE_THRESHOLD:
            out = _sdpa_blockwise(q, k, v, positions, positions,
                                  cfg.window, scale)
        else:
            mask = _causal_mask(positions, positions,
                                cfg.window if cfg.causal else None)
            if not cfg.causal:
                mask = jnp.ones_like(mask)
            out = _sdpa(q, k, v, mask, scale)
        new_cache = None
    else:
        # Ring-buffer cache: slot = position % S. For full-attention
        # layers S = max_seq so the ring never wraps; for sliding-window
        # layers S = window, which is exactly why their long-context
        # memory stays O(window). ``pos`` tracks each slot's token
        # position (-1 = empty) so masking is order-independent.
        idx = cache["index"]                       # scalar i32: write offset
        T = x.shape[1]
        B = x.shape[0]
        S = cache["k"].shape[1]
        keep = min(T, S)                           # only the tail can matter
        k_t, v_t = k[:, -keep:], v[:, -keep:]
        p_t = positions[:, -keep:]
        slots = p_t % S                            # (B, keep)
        rows = jnp.arange(B, dtype=jnp.int32)[:, None]
        ck = cache["k"].at[rows, slots].set(k_t)
        cv = cache["v"].at[rows, slots].set(v_t)
        # Pin the updated cache to its logical layout on DECODE steps:
        # left alone, GSPMD may reshard the ring-buffer scatter over seq
        # and then all-gather the WHOLE cache for attention every step —
        # §Perf iteration 5 measured 86GB/step of exactly that on
        # vision-11b decode_32k. On prefill (T>1) the same pin doubles
        # the bulk-write collectives (iteration 5b), so it's T==1 only.
        if T == 1:
            from repro.dist.sharding import constrain
            cache_axes = ("batch", "cache_seq", "kv_heads", "head_dim")
            ck = constrain(ck, cache_axes)
            cv = constrain(cv, cache_axes)
        cpos = cache["pos"].at[rows, slots].set(p_t)
        mask = _causal_mask(positions, cpos, cfg.window, cpos >= 0)
        out = _sdpa(q, ck, cv, mask, scale)
        new_cache = {"k": ck, "v": cv, "pos": cpos, "index": idx + T}

    return jnp.einsum("bthk,hkd->btd", out, params["wo"]), new_cache


def init_cache(cfg: AttnConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache. Sliding-window layers only need window-sized caches
    (this is what makes gemma3/recurrentgemma long_500k sub-quadratic in
    memory for 5 of 6 layers)."""
    S = max_seq if cfg.window is None else min(max_seq, cfg.window)
    return {
        "k": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.d_head), dtype),
        "pos": jnp.full((batch, S), -1, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes(cfg: AttnConfig) -> dict:
    return {
        "k": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "pos": ("batch", "cache_seq"),
        "index": (),
    }


# ---------------------------------------------------------------------------
# cross-attention (llama-3.2-vision style image layers)
# ---------------------------------------------------------------------------

def cross_attn_specs(cfg: AttnConfig, d_ctx: int, dtype=jnp.bfloat16):
    s = {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, cfg.d_head),
                        ("embed", "heads", "head_dim"), dtype),
        "wk": ParamSpec((d_ctx, cfg.n_kv_heads, cfg.d_head),
                        ("embed", "kv_heads", "head_dim"), dtype),
        "wv": ParamSpec((d_ctx, cfg.n_kv_heads, cfg.d_head),
                        ("embed", "kv_heads", "head_dim"), dtype),
        "wo": ParamSpec((cfg.n_heads, cfg.d_head, cfg.d_model),
                        ("heads", "head_dim", "embed"), dtype),
        # llama-vision gates cross-attn output through tanh(alpha), zero-init
        "gate": ParamSpec((), (), jnp.float32, "zeros"),
    }
    return s


def cross_attention(params, cfg: AttnConfig, x, ctx):
    """x (B,T,D) attends over ctx (B,N,Dc) (precomputed patch embeddings
    from the stub frontend). No positional encoding on ctx (learned in the
    real frontend; stubbed here)."""
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.d_head))
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("bnd,dhk->bnhk", ctx, params["wk"])
    v = jnp.einsum("bnd,dhk->bnhk", ctx, params["wv"])
    mask = jnp.ones((x.shape[0], x.shape[1], ctx.shape[1]), bool)
    out = _sdpa(q, k, v, mask, scale)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return jnp.tanh(params["gate"]).astype(y.dtype) * y
