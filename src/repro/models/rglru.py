"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence (per channel):
  r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
  i_t = sigmoid(W_x x_t + b_x)            (input gate)
  a_t = a^(c * r_t)   with a = sigmoid(Lambda),  c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Implemented as an associative scan over T in log-space for a_t
(TPU-native; the GPU paper uses a custom sequential kernel, the scan is
the published Griffin-JAX formulation). The block wraps the recurrence in
the Griffin "recurrent block": linear in -> conv1d(4) -> RG-LRU -> gated
linear out. Constant-size decode state => runs long_500k.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int                    # recurrence width (gemma: ~ d_model)
    conv_width: int = 4
    c_mult: float = 8.0


def rglru_specs(cfg: RGLRUConfig, dtype=jnp.bfloat16):
    D, R = cfg.d_model, cfg.d_rnn
    return {
        "in_x": ParamSpec((D, R), ("embed", "rnn"), dtype),
        "in_gate": ParamSpec((D, R), ("embed", "rnn"), dtype),
        "conv_w": ParamSpec((cfg.conv_width, R), (None, "rnn"), dtype,
                            init_scale=0.5),
        "conv_b": ParamSpec((R,), ("rnn",), dtype, "zeros"),
        "wa": ParamSpec((R, R), ("rnn", None), dtype, init_scale=0.02),
        "ba": ParamSpec((R,), (None,), jnp.float32, "zeros"),
        "wx": ParamSpec((R, R), ("rnn", None), dtype, init_scale=0.02),
        "bx": ParamSpec((R,), (None,), jnp.float32, "zeros"),
        "lamb": ParamSpec((R,), (None,), jnp.float32, "ones"),
        "out": ParamSpec((R, D), ("rnn", "embed"), dtype),
    }


def _rglru_scan(x, a_log, gated_x, h0=None):
    """h_t = exp(a_log_t) h_{t-1} + gated_x_t, associative over T.
    x unused except shapes; a_log, gated_x (b, T, R) f32."""
    def combine(left, right):
        al, xl = left
        ar, xr = right
        return al + ar, xr + jnp.exp(ar) * xl

    al = a_log.transpose(1, 0, 2)
    xl = gated_x.transpose(1, 0, 2)
    if h0 is not None:
        xl = xl.at[0].add(jnp.exp(al[0]) * h0)
    _, h = jax.lax.associative_scan(combine, (al, xl), axis=0)
    return h.transpose(1, 0, 2)                     # (b, T, R)


def rglru_block(params, cfg: RGLRUConfig, x, cache: Optional[dict] = None):
    """x (b, T, D) -> (y (b, T, D), new_cache {conv, h, index})."""
    b, T, D = x.shape
    R, W = cfg.d_rnn, cfg.conv_width
    gate = jax.nn.gelu(
        jnp.einsum("btd,dr->btr", x, params["in_gate"]).astype(jnp.float32))
    xr = jnp.einsum("btd,dr->btr", x, params["in_x"])

    # causal conv1d
    if cache is None:
        pad = jnp.zeros((b, W - 1, R), xr.dtype)
        xin = jnp.concatenate([pad, xr], axis=1)
    else:
        xin = jnp.concatenate([cache["conv"].astype(xr.dtype), xr], axis=1)
    new_conv = xin[:, -(W - 1):] if W > 1 else None
    idxs = jnp.arange(T)[:, None] + jnp.arange(W)[None, :]
    windows = xin[:, idxs]
    xr = jnp.einsum("btwr,wr->btr", windows, params["conv_w"]) \
        + params["conv_b"].astype(xr.dtype)

    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["wa"].astype(jnp.float32) + params["ba"])
    i = jax.nn.sigmoid(xf @ params["wx"].astype(jnp.float32) + params["bx"])
    log_a_base = jax.nn.log_sigmoid(params["lamb"])          # (R,) < 0
    a_log = cfg.c_mult * r * log_a_base[None, None, :]       # (b, T, R) < 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * a_log), 1e-6))
    gated_x = beta * (i * xf)

    h0 = cache["h"] if cache is not None else None
    h = _rglru_scan(xf, a_log, gated_x, h0)
    y = (h * gate).astype(x.dtype)
    out = jnp.einsum("btr,rd->btd", y, params["out"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(jnp.bfloat16),
                     "h": h[:, -1].astype(jnp.float32),
                     "index": cache["index"] + T}
    return out, new_cache


def init_cache(cfg: RGLRUConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes(cfg: RGLRUConfig) -> dict:
    return {"conv": ("batch", None, "rnn"),
            "h": ("batch", "rnn"), "index": ()}
