"""Counter / gauge / histogram registry (the ``repro.obs`` metrics half).

One :class:`MetricsRegistry` is the single source for every counter the
CI gate tracks: engines accumulate their per-pass work counters through
it (``PassMetrics``), the :class:`~repro.core.runtime.CellCache` keeps
its lifetime hit/prefetch counters in it, and the serving frontend's
lifetime counters and latency quantiles live in it. The per-pass stats
dicts the engines still expose (``engine.stats`` ->
``Collection.last_stats`` -> ``EngineStats``) are *views over registry
increments*, not a parallel bookkeeping path: ``PassMetrics.count``
writes the registry counter and the pass dict in one call, so the two
can never disagree, and :func:`prometheus_text
<repro.obs.export.prometheus_text>` exports the same objects.

Everything is plain host-side Python — no numpy on the increment path,
no locks (the engines are single-threaded per process).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "PassMetrics"]


class Counter:
    """Monotonic accumulator."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1):
        self.value += n
        return self.value


class Gauge:
    """Last-value metric (rates, residency, derived fractions)."""

    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v):
        self.value = v
        return v


class Histogram:
    """Value-list histogram: exact quantiles at export time. Bounded by
    ``maxlen`` (reservoir-free ring: old samples roll off) so long-lived
    serving processes do not grow without bound."""

    __slots__ = ("name", "_values", "count", "total", "maxlen")
    kind = "histogram"

    def __init__(self, name: str, maxlen: int = 65536):
        self.name = name
        self._values: List[float] = []
        self.count = 0
        self.total = 0.0
        self.maxlen = maxlen

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self._values.append(v)
        if len(self._values) > self.maxlen:
            del self._values[: len(self._values) - self.maxlen]

    @property
    def values(self) -> List[float]:
        return list(self._values)

    def percentile(self, p: float) -> float:
        if not self._values:
            return 0.0
        import numpy as np
        return float(np.percentile(np.asarray(self._values, np.float64), p))

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors. Names are flat
    dotted/underscored strings; a name is permanently bound to its first
    kind (asking for a counter named like an existing gauge raises)."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {type(m).__name__}, "
                            f"not a {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # -- reading ------------------------------------------------------------

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def items(self) -> Iterable[Tuple[str, object]]:
        return self._metrics.items()

    def value(self, name: str, default=0):
        m = self._metrics.get(name)
        if m is None:
            return default
        if isinstance(m, Histogram):
            return m.count
        return m.value

    def snapshot(self) -> dict:
        """{name: value} over counters and gauges (histograms report
        their sample count) — pair with :meth:`delta` to scope a pass."""
        out = {}
        for name, m in self._metrics.items():
            out[name] = m.count if isinstance(m, Histogram) else m.value
        return out

    def delta(self, before: dict) -> dict:
        """Counter increments since ``before`` (a :meth:`snapshot`);
        gauges report their current value."""
        out = {}
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                out[name] = m.value - before.get(name, 0)
            elif isinstance(m, Histogram):
                out[name] = m.count - before.get(name, 0)
            else:
                out[name] = m.value
        return out


class PassMetrics:
    """Builds one engine pass's stats dict while folding every numeric
    into the engine's lifetime registry — the single-source contract:
    the dict entry and the registry increment are written by the same
    call, so ``engine.stats`` values are registry values by
    construction.

    ``count`` -> registry counter += v (work counters: waves, bytes,
    active rows); ``set`` -> registry gauge = v (derived values: rates,
    residency); ``put`` -> pass-dict only (strings, nested dicts — not
    meaningfully aggregable).
    """

    __slots__ = ("_reg", "_stats", "_prefix")

    def __init__(self, registry: MetricsRegistry, prefix: str = "",
                 static: Optional[dict] = None):
        self._reg = registry
        self._prefix = prefix
        self._stats = dict(static or {})

    def count(self, name: str, v) -> None:
        self._reg.counter(self._prefix + name).inc(v)
        self._stats[name] = self._stats.get(name, 0) + v

    def set(self, name: str, v) -> None:
        self._reg.gauge(self._prefix + name).set(v)
        self._stats[name] = v

    def put(self, name: str, v) -> None:
        self._stats[name] = v

    def update_counts(self, d: dict) -> None:
        for k, v in d.items():
            self.count(k, v)

    def stats(self) -> dict:
        return self._stats
