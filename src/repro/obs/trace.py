"""Hierarchical span tracing (the ``repro.obs`` tentpole, ISSUE 10).

One :class:`Tracer` records a tree of timed :class:`Span` objects; code
anywhere in the stack marks a region with the module-level helper::

    from repro.obs.trace import span
    with span("hybrid.wave", cells=n_cells, bytes=n_bytes):
        ...

Design points (all load-bearing for the search hot path):

  - **Strict no-op fast path.** When no tracer is active, ``span(...)``
    returns one shared immutable :data:`NOOP_SPAN` — a module-global
    ``is None`` check and a constant return, no object allocation, no
    clock read. The tracing-off QPS budget in the acceptance criteria
    (within 2% of pre-PR) rests on this.
  - **Injectable monotonic clock.** ``Tracer(clock=...)`` accepts any
    zero-arg callable returning float seconds —
    ``time.perf_counter`` by default, or the serving frontend's
    ``VirtualClock`` so open-loop harness traces line up with its
    deterministic timeline.
  - **Optional device sync at span close.** JAX dispatch is async: a
    launch returns before the kernel runs, so a naive span would bill
    device time to whichever later span happens to block. A span can
    ``attach(arrays)`` its launch results; with ``Tracer(sync=True)``
    the span blocks on them (``jax.block_until_ready``) before taking
    its end timestamp, attributing the device work to the right span.
    With ``sync=False`` (default) ``attach`` is free and the natural
    blocking point (``np.asarray`` of the results) still falls inside
    the enclosing span.
  - **Nesting by activation stack.** Spans nest lexically; the parent is
    whatever span is open on the tracer when a child starts. Export
    (``repro.obs.export``) emits Chrome trace events whose ts/dur
    intervals reproduce the tree in Perfetto.

Activation is process-global and explicitly scoped::

    tr = Tracer()
    with tracing(tr):
        ...            # every span(...) in this block records into tr

``Collection.trace(path=...)`` wraps exactly this and writes the
Perfetto JSON on exit. Subsystems that need timings even when the user
traces nothing (the sharded engine's straggler walls, build phase
accounting) use :func:`local_trace`, which reuses the active tracer or
activates a temporary one.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, List, Optional

__all__ = ["Span", "Tracer", "NOOP_SPAN", "span", "tracing",
           "local_trace", "active_tracer", "sum_walls"]


class Span:
    """One finished-or-open timed region. ``attrs`` carries arbitrary
    key/value annotations (cells=, bytes=, shard=, ...)."""

    __slots__ = ("name", "t0", "t1", "parent", "depth", "attrs", "_payload",
                 "_tracer")

    def __init__(self, tracer: "Tracer", name: str, parent: Optional["Span"],
                 attrs: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.parent = parent
        self.depth = 0 if parent is None else parent.depth + 1
        self.attrs = attrs if attrs else {}
        self.t0 = 0.0
        self.t1: Optional[float] = None
        self._payload = None

    # -- annotation ---------------------------------------------------------

    def annotate(self, **kw) -> "Span":
        """Merge key/value attributes into the span."""
        self.attrs.update(kw)
        return self

    def attach(self, payload):
        """Register device arrays (any pytree) this span's work produced;
        a ``sync=True`` tracer blocks on them at close so async device
        time lands in *this* span. Returns the payload unchanged."""
        self._payload = payload
        return payload

    # -- lifecycle (driven by the tracer) -----------------------------------

    def __enter__(self) -> "Span":
        self._tracer._open(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer._close(self)
        return False

    @property
    def duration(self) -> float:
        """Seconds (0.0 while still open)."""
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def interval(self) -> tuple:
        """(t0, t1) in the tracer's clock."""
        return (self.t0, self.t1 if self.t1 is not None else self.t0)

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, dur={self.duration:.6f}, "
                f"depth={self.depth}, attrs={self.attrs})")


class _NoopSpan:
    """The shared do-nothing span returned while tracing is off. One
    immutable instance; every method is a constant-time no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def annotate(self, **kw):
        return self

    def attach(self, payload):
        return payload

    name = "<noop>"
    attrs: dict = {}
    parent = None
    depth = 0
    duration = 0.0

    def interval(self):
        return (0.0, 0.0)


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Records a tree of spans against an injectable monotonic clock.

    ``spans`` lists finished spans in completion order (children before
    their parents); :meth:`roots` / :meth:`children_of` recover the tree.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 sync: bool = False):
        self.clock = clock
        self.sync = bool(sync)
        self.spans: List[Span] = []
        self._stack: List[Span] = []

    # -- span creation ------------------------------------------------------

    def span(self, name: str, **attrs) -> Span:
        """A context manager recording one span under the current one."""
        parent = self._stack[-1] if self._stack else None
        return Span(self, name, parent, attrs)

    def _open(self, sp: Span) -> None:
        # re-parent in case the span object was created early and entered
        # later (or re-entered): nesting is defined at __enter__ time
        sp.parent = self._stack[-1] if self._stack else None
        sp.depth = 0 if sp.parent is None else sp.parent.depth + 1
        self._stack.append(sp)
        sp.t0 = self.clock()

    def _close(self, sp: Span) -> None:
        if self.sync and sp._payload is not None:
            import jax
            jax.block_until_ready(sp._payload)
        sp._payload = None
        sp.t1 = self.clock()
        if self._stack and self._stack[-1] is sp:
            self._stack.pop()
        else:                      # tolerate out-of-order exits
            try:
                self._stack.remove(sp)
            except ValueError:
                pass
        self.spans.append(sp)

    # -- inspection ---------------------------------------------------------

    def mark(self) -> int:
        """Position marker; pair with :meth:`spans_since`."""
        return len(self.spans)

    def spans_since(self, mark: int) -> List[Span]:
        return self.spans[mark:]

    def roots(self) -> List[Span]:
        return [s for s in self.spans if s.parent is None]

    def children_of(self, parent: Span) -> List[Span]:
        return [s for s in self.spans if s.parent is parent]

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def clear(self) -> None:
        self.spans = []
        self._stack = []


def sum_walls(spans, key: str) -> dict:
    """Sum span durations grouped by the ``key`` attribute (spans missing
    it are skipped) — e.g. per-shard walls for the straggler monitor."""
    out: dict = {}
    for s in spans:
        g = s.attrs.get(key)
        if g is None:
            continue
        out[g] = out.get(g, 0.0) + s.duration
    return out


# -- process-global activation ------------------------------------------------

_ACTIVE: Optional[Tracer] = None


def active_tracer() -> Optional[Tracer]:
    """The tracer ``span(...)`` currently records into (None = off)."""
    return _ACTIVE


def span(name: str, **attrs):
    """Module-level span entry point: records into the active tracer, or
    returns the shared :data:`NOOP_SPAN` when tracing is off."""
    t = _ACTIVE
    if t is None:
        return NOOP_SPAN
    return t.span(name, **attrs)


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None, *,
            clock: Callable[[], float] = time.perf_counter,
            sync: bool = False):
    """Activate ``tracer`` (or a fresh one) for the dynamic extent of the
    block; nests — the previous tracer is restored on exit."""
    global _ACTIVE
    tr = tracer if tracer is not None else Tracer(clock=clock, sync=sync)
    prev, _ACTIVE = _ACTIVE, tr
    try:
        yield tr
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def local_trace(clock: Callable[[], float] = time.perf_counter):
    """The active tracer if one is on, else a temporary private one —
    for subsystems whose own accounting (straggler walls, build phase
    timings) is span-derived and must exist even when nobody asked for
    a trace. Spans nest into the user's trace when there is one."""
    tr = _ACTIVE
    if tr is not None:
        yield tr
    else:
        with tracing(Tracer(clock=clock)) as tr:
            yield tr
