"""Exporters for the obs layer: Perfetto-loadable Chrome trace-event
JSON from a :class:`~repro.obs.trace.Tracer`, and Prometheus text
exposition from a :class:`~repro.obs.metrics.MetricsRegistry`.

Chrome trace format: one ``"X"`` (complete) event per finished span,
``ts``/``dur`` in microseconds relative to the earliest span start, span
attributes under ``args``. Load at https://ui.perfetto.dev (or
``chrome://tracing``) — the viewer reconstructs nesting from the
intervals, so parent/child spans stack and concurrent DMA/compute spans
(cache prefetch uploads inside an in-flight traversal span) visibly
overlap. ``docs/observability.md`` walks through reading one.

Prometheus exposition: ``# TYPE`` headers plus one sample line per
counter/gauge; histograms export summary-style quantiles (0.5/0.95/0.99)
with ``_sum`` and ``_count``. Metric names are sanitized to the
Prometheus grammar and prefixed (default ``repro_``).
"""

from __future__ import annotations

import json
import os
import re
from typing import Optional

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Tracer

__all__ = ["chrome_trace_events", "write_chrome_trace", "prometheus_text"]

_US = 1_000_000.0


def _jsonable(v):
    """Span attrs may carry numpy scalars; JSON wants plain types."""
    if hasattr(v, "item"):
        return v.item()
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def chrome_trace_events(tracer: Tracer, *, pid: int = 0,
                        tid: int = 0) -> list:
    """Finished spans as Chrome trace-event dicts (``ph: "X"``)."""
    spans = tracer.spans
    if not spans:
        return []
    t_base = min(s.t0 for s in spans)
    events = []
    for s in spans:
        events.append({
            "name": s.name,
            "ph": "X",
            "ts": (s.t0 - t_base) * _US,
            "dur": s.duration * _US,
            "pid": pid,
            "tid": tid,
            "cat": s.name.split(".", 1)[0],
            "args": {k: _jsonable(v) for k, v in s.attrs.items()},
        })
    # Perfetto reconstructs nesting from intervals; sorting by start time
    # (parents before their children on ties) keeps the file stable
    events.sort(key=lambda e: (e["ts"], -e["dur"]))
    return events


def write_chrome_trace(tracer: Tracer, path: str, *, pid: int = 0,
                       tid: int = 0) -> str:
    """Write ``{"traceEvents": [...]}`` JSON; returns the path."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    doc = {"traceEvents": chrome_trace_events(tracer, pid=pid, tid=tid),
           "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(prefix: str, name: str) -> str:
    out = _NAME_RE.sub("_", prefix + name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _fmt(v) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


def prometheus_text(registry: MetricsRegistry, prefix: str = "repro_",
                    extra: Optional[dict] = None) -> str:
    """Prometheus text exposition (v0.0.4) of every registered metric.
    ``extra`` adds gauge samples computed outside the registry (e.g.
    queue depth read off a live object)."""
    lines = []
    for name, m in sorted(registry.items()):
        pn = _prom_name(prefix, name)
        if isinstance(m, Counter):
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            lines.append(f"# TYPE {pn} summary")
            for q in (0.5, 0.95, 0.99):
                lines.append(f'{pn}{{quantile="{q}"}} '
                             f"{_fmt(m.percentile(100 * q))}")
            lines.append(f"{pn}_sum {_fmt(m.total)}")
            lines.append(f"{pn}_count {_fmt(m.count)}")
    for name, v in sorted((extra or {}).items()):
        pn = _prom_name(prefix, name)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {_fmt(v)}")
    return "\n".join(lines) + "\n"
