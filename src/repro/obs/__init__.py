"""``repro.obs`` — structured tracing + metrics for the whole stack
(ISSUE 10 tentpole).

Three pieces, one reporting path:

``trace``    — hierarchical spans with an injectable monotonic clock, an
               optional ``block_until_ready`` sync at span close (so
               async device work is attributed to the right span), and a
               strict no-op fast path when tracing is off.
``metrics``  — the counter/gauge/histogram registry every layer's
               counters live in; engine per-pass stats dicts are views
               over registry increments (``PassMetrics``), not a
               parallel bookkeeping path.
``export``   — Chrome trace-event JSON (Perfetto-loadable) and
               Prometheus text exposition.

Entry points users actually touch: ``Collection.trace(path=...)``, the
``--trace`` flag on ``benchmarks/run.py``, and
``VectorFrontend.prometheus()``. Span taxonomy and walkthroughs:
``docs/observability.md``.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, PassMetrics)
from repro.obs.trace import (NOOP_SPAN, Span, Tracer,  # noqa: F401
                             active_tracer, local_trace, span, sum_walls,
                             tracing)
from repro.obs.export import (chrome_trace_events,  # noqa: F401
                              prometheus_text, write_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "PassMetrics",
    "NOOP_SPAN", "Span", "Tracer", "active_tracer", "local_trace", "span",
    "sum_walls", "tracing",
    "chrome_trace_events", "prometheus_text", "write_chrome_trace",
]
