"""Fused gather -> predicate-mask -> distance -> k-select scan.

The dense route's kernel (ISSUE 7): ultra-selective filter boxes skip
graph traversal entirely and brute-force their qualifying candidate rows.
Per grid step the scalar-prefetched index array picks the next candidate
row — the vector row AND its attribute row ride the same index_map, so the
range predicate is evaluated in VMEM right next to the diff-square-add and
out-of-range rows never produce a finite distance (one fused pass instead
of gather + separate mask + separate distance). Two variants share the
pattern of gather_distance.py / gather_int8.py:

- f32 table (in-core engine), and
- symmetric-int8 table + per-row scale (hybrid / out-of-core engines,
  whose dense hits then flow through the usual exact fp32 re-rank).

The top-k half of the fusion is ``ops.k_select`` over the masked distance
row — same lower-column-index tie rule the device re-rank relies on, so
candidate ids enumerated in ascending order come out (distance, id)-ordered
exactly like ``mutable.scan_buffer``.

Padding contract (``masked_topk`` / ``masked_topk_q``): d pads to 128 with
zeros (exact), m pads to 128 with zero attrs against [0, 0] bounds (always
in range), idx pads with -1 (+inf). NaN attributes fail every comparison,
so tombstoned rows drop out for free.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config, ops, ref


# -- Pallas kernels ----------------------------------------------------------

def _kernel_f32(idx_ref, q_ref, lo_ref, hi_ref, row_ref, attr_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                     # (1, d)
    row = row_ref[...].astype(jnp.float32)                 # (1, d)
    diff = q - row
    d2 = jnp.sum(diff * diff)
    a = attr_ref[...].astype(jnp.float32)                  # (1, m)
    ok = jnp.all((a >= lo_ref[...]) & (a <= hi_ref[...]))
    invalid = idx_ref[b, j] < 0
    out_ref[0, 0] = jnp.where(invalid | ~ok, jnp.float32(jnp.inf), d2)


def _kernel_int8(idx_ref, q_ref, lo_ref, hi_ref, row_ref, scale_ref,
                 attr_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                     # (1, d)
    row = row_ref[...].astype(jnp.float32)                 # (1, d) int8->f32
    scale = scale_ref[0, 0].astype(jnp.float32)
    diff = q - row * scale
    d2 = jnp.sum(diff * diff)
    a = attr_ref[...].astype(jnp.float32)                  # (1, m)
    ok = jnp.all((a >= lo_ref[...]) & (a <= hi_ref[...]))
    invalid = idx_ref[b, j] < 0
    out_ref[0, 0] = jnp.where(invalid | ~ok, jnp.float32(jnp.inf), d2)


def _grid_spec(B, d, m, nb, with_scale):
    def b_map(b, j, idx_ref):
        return (b, 0)

    def row_map(b, j, idx_ref):
        return (jnp.maximum(idx_ref[b, j], 0), 0)

    def out_map(b, j, idx_ref):
        return (b, j)

    in_specs = [
        pl.BlockSpec((1, d), b_map),       # q
        pl.BlockSpec((1, m), b_map),       # lo
        pl.BlockSpec((1, m), b_map),       # hi
        pl.BlockSpec((1, d), row_map),     # table / vq row
    ]
    if with_scale:
        in_specs.append(pl.BlockSpec((1, 1), row_map))  # vscale
    in_specs.append(pl.BlockSpec((1, m), row_map))      # attrs row
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), out_map),
    )


@jax.jit
def masked_gather_distance(q, table, attrs, lo, hi, idx):
    """q (B,d), table (N,d), attrs (N,m), lo/hi (B,m), idx (B,nb) i32
    -> (B, nb) f32; idx<0 or attrs outside [lo, hi] -> +inf."""
    B, d = q.shape
    m = attrs.shape[1]
    nb = idx.shape[1]
    return pl.pallas_call(
        _kernel_f32,
        grid_spec=_grid_spec(B, d, m, nb, with_scale=False),
        out_shape=jax.ShapeDtypeStruct((B, nb), jnp.float32),
        interpret=config.interpret(),
    )(idx, q, lo, hi, table, attrs)


@jax.jit
def masked_gather_int8_distance(q, vq, vscale, attrs, lo, hi, idx):
    """q (B,d) f32, vq (N,d) i8, vscale (N,1) f32, attrs (N,m),
    lo/hi (B,m), idx (B,nb) i32 -> (B, nb) f32 dequantized distances."""
    B, d = q.shape
    m = attrs.shape[1]
    nb = idx.shape[1]
    return pl.pallas_call(
        _kernel_int8,
        grid_spec=_grid_spec(B, d, m, nb, with_scale=True),
        out_shape=jax.ShapeDtypeStruct((B, nb), jnp.float32),
        interpret=config.interpret(),
    )(idx, q, lo, hi, vq, vscale, attrs)


# -- jnp oracles (also the fast XLA path off-TPU) ----------------------------

def _attr_mask(attrs, lo, hi, idx):
    """(B, nb) bool — gathered attr row fully inside [lo, hi]. NaN attrs
    (tombstones) fail every comparison and mask out."""
    safe = jnp.maximum(idx, 0)
    a = attrs[safe]                                         # (B, nb, m)
    ok = (a >= lo[:, None, :]) & (a <= hi[:, None, :])
    return jnp.all(ok, axis=-1)


def ref_masked_gather_distance(q, table, attrs, lo, hi, idx):
    d2 = ref.gather_distance(q, table, idx)
    ok = _attr_mask(attrs, lo, hi, idx)
    return jnp.where(ok, d2, jnp.float32(jnp.inf))


def ref_masked_gather_int8_distance(q, vq, vscale, attrs, lo, hi, idx):
    d2 = ref.gather_int8_distance(q, vq, vscale.reshape(-1), idx)
    ok = _attr_mask(attrs, lo, hi, idx)
    return jnp.where(ok, d2, jnp.float32(jnp.inf))


# -- padded dispatch wrappers (the public API) -------------------------------

def _pad_inputs(q, attrs, lo, hi, idx):
    qp = ops._pad_to(q.astype(jnp.float32), 1, 128)
    ap = ops._pad_to(attrs.astype(jnp.float32), 1, 128)
    lop = ops._pad_to(lo.astype(jnp.float32), 1, 128)
    hip = ops._pad_to(hi.astype(jnp.float32), 1, 128)
    return qp, ap, lop, hip, idx.astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def _ref_topk_f32(q, table, attrs, lo, hi, idx, k: int):
    d2 = ref_masked_gather_distance(q, table, attrs, lo, hi, idx)
    return ops.k_select(d2, k)


@partial(jax.jit, static_argnames=("k",))
def _ref_topk_int8(q, vq, vscale, attrs, lo, hi, idx, k: int):
    d2 = ref_masked_gather_int8_distance(q, vq, vscale, attrs, lo, hi, idx)
    return ops.k_select(d2, k)


def masked_topk(q, table, attrs, lo, hi, idx, k: int):
    """Fused dense scan over an f32 table.

    q (B,d), table (N,d), attrs (N,m), lo/hi (B,m), idx (B,nb) i32 with
    -1 padding -> (vals (B,k) f32 ascending, pos (B,k) i32 columns into
    ``idx``). Out-of-range / padded slots surface as +inf; ties resolve
    to the lower column index (= lower candidate id when idx ascends).
    """
    if not config.use_pallas():
        return _ref_topk_f32(q, table, attrs, lo, hi, idx, k)
    qp, ap, lop, hip, ip = _pad_inputs(q, attrs, lo, hi, idx)
    tp = ops._pad_to(table.astype(jnp.float32), 1, 128)
    d2 = masked_gather_distance(qp, tp, ap, lop, hip, ip)
    return ops.k_select(d2, k)


def masked_topk_q(q, vq, vscale, attrs, lo, hi, idx, k: int):
    """Fused dense scan over the symmetric-int8 table (hybrid / ooc).

    Same contract as :func:`masked_topk`; distances are the dequantized
    int8 approximation, so callers re-rank the survivors in fp32.
    """
    if not config.use_pallas():
        return _ref_topk_int8(q, vq, vscale, attrs, lo, hi, idx, k)
    qp, ap, lop, hip, ip = _pad_inputs(q, attrs, lo, hi, idx)
    vp = ops._pad_to(vq, 1, 128)
    d2 = masked_gather_int8_distance(
        qp, vp, vscale.reshape(-1, 1).astype(jnp.float32),
        ap, lop, hip, ip)
    return ops.k_select(d2, k)
