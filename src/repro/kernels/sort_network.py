"""Bitonic compare-exchange networks as jnp ops.

These helpers emit a *static* O(log^2 L) sequence of vectorized
compare-exchange stages, usable both inside Pallas kernel bodies (VMEM
arrays) and in plain jnp reference code. This is the TPU adaptation of the
paper's register-level bitonic sort (Alg. 3, line 2): on a TPU there are no
warp shuffles, but an L-lane compare-exchange is a single VPU
permute+select, so the same network maps onto ``jnp.take``/``jnp.where``.

All functions sort *ascending* along the last axis and carry a companion
int32 payload (indices) through the permutation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _compare_exchange(vals, idxs, jsz: int, ksz: int):
    """One bitonic stage: partner = lane ^ jsz, direction from lane & ksz.

    Lane indices are built with iota *inside* the traced code (Pallas kernel
    bodies may not capture array constants), so this helper is usable both
    in kernels and in plain jnp.
    """
    lane = jax.lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    partner = jax.lax.bitwise_xor(lane, jnp.int32(jsz))
    take_min = ((lane & jnp.int32(ksz)) == 0) == (lane < partner)

    pv = jnp.take_along_axis(vals, partner, axis=-1)
    pi = jnp.take_along_axis(idxs, partner, axis=-1)

    # Tie-stable: on equality keep own value (strict < / > comparisons).
    want_partner = jnp.where(take_min, pv < vals, pv > vals)
    new_vals = jnp.where(want_partner, pv, vals)
    new_idxs = jnp.where(want_partner, pi, idxs)
    return new_vals, new_idxs


def bitonic_sort(vals, idxs):
    """Full ascending bitonic sort along the last axis (L must be pow2)."""
    L = vals.shape[-1]
    assert _is_pow2(L), f"bitonic_sort needs pow2 lanes, got {L}"
    ksz = 2
    while ksz <= L:
        jsz = ksz // 2
        while jsz >= 1:
            vals, idxs = _compare_exchange(vals, idxs, jsz, ksz)
            jsz //= 2
        ksz *= 2
    return vals, idxs


def bitonic_merge(vals, idxs):
    """Merge a bitonic sequence (e.g. ascending half ++ descending half)
    of pow2 length into ascending order — the cheap O(log L) tail of the
    sort, used for running top-k merges where both halves are pre-sorted."""
    L = vals.shape[-1]
    assert _is_pow2(L), f"bitonic_merge needs pow2 lanes, got {L}"
    jsz = L // 2
    while jsz >= 1:
        # ksz=L on the final stage of a sort makes every lane ascending.
        vals, idxs = _compare_exchange(vals, idxs, jsz, L)
        jsz //= 2
    return vals, idxs


def _compare_exchange_lex(k1, k2, payloads, jsz: int, ksz: int):
    """One bitonic stage ordering by the lexicographic key (k1, k2).

    Requires the (k1, k2) pairs to be distinct within a row (the callers
    use original lane positions as k2), which makes the network a *stable*
    sort by k1 — the property the traversal's dedup step relies on.
    """
    lane = jax.lax.broadcasted_iota(jnp.int32, k1.shape, k1.ndim - 1)
    partner = jax.lax.bitwise_xor(lane, jnp.int32(jsz))
    take_min = ((lane & jnp.int32(ksz)) == 0) == (lane < partner)

    p1 = jnp.take_along_axis(k1, partner, axis=-1)
    p2 = jnp.take_along_axis(k2, partner, axis=-1)
    p_less = (p1 < k1) | ((p1 == k1) & (p2 < k2))
    want_partner = jnp.where(take_min, p_less, ~p_less)

    out1 = jnp.where(want_partner, p1, k1)
    out2 = jnp.where(want_partner, p2, k2)
    outs = tuple(
        jnp.where(want_partner, jnp.take_along_axis(p, partner, axis=-1), p)
        for p in payloads)
    return out1, out2, outs


def bitonic_sort_lex(k1, k2, payloads=()):
    """Ascending sort by (k1, k2) with distinct pairs; carries payloads.

    k2 = original positions makes this exactly ``jnp.argsort(k1)`` with
    stable tie order, as a static compare-exchange network usable inside
    Pallas kernel bodies.
    """
    L = k1.shape[-1]
    assert _is_pow2(L), f"bitonic_sort_lex needs pow2 lanes, got {L}"
    ksz = 2
    while ksz <= L:
        jsz = ksz // 2
        while jsz >= 1:
            k1, k2, payloads = _compare_exchange_lex(k1, k2, payloads,
                                                     jsz, ksz)
            jsz //= 2
        ksz *= 2
    return k1, k2, payloads


def merge_topk(run_vals, run_idxs, new_vals, new_idxs):
    """Merge sorted-ascending running top-K with sorted-ascending new
    candidates (same width K), returning the ascending best-K of the union.

    Reverses the new half to form a bitonic sequence, then one merge pass.
    """
    K = run_vals.shape[-1]
    assert new_vals.shape[-1] == K
    cat_v = jnp.concatenate([run_vals, new_vals[..., ::-1]], axis=-1)
    cat_i = jnp.concatenate([run_idxs, new_idxs[..., ::-1]], axis=-1)
    merged_v, merged_i = bitonic_merge(cat_v, cat_i)
    return merged_v[..., :K], merged_i[..., :K]
