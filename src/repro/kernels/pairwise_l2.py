"""MXU-tiled pairwise squared-L2 distance kernel.

TPU adaptation of Garfield's warp-per-distance GPU scheme: instead of one
warp computing one ``dis(q, v)``, a 128x128 output tile of the distance
matrix is produced per grid step by one MXU matmul plus VPU rank-1 norm
updates. Arithmetic intensity rises from O(1) (scalar diff-square-add) to
O(d) per output element, which is what moves distance evaluation from the
memory roofline onto the compute roofline on v5e.

Tiling:
  grid = (B/bq, N/bn); q block (bq, d), v block (bn, d), out block (bq, bn).
  d stays whole inside the block (ANN dims are <= a few thousand; a
  (128, 1024) f32 block is 0.5 MB — three such blocks sit comfortably in
  the ~16 MB v5e VMEM budget). ops.py pads B/N/d to tile multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import config


def _kernel(q_ref, v_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)                    # (bq, d)
    v = v_ref[...].astype(jnp.float32)                    # (bn, d)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)           # (bq, 1)
    vn = jnp.sum(v * v, axis=-1, keepdims=True)           # (bn, 1)
    cross = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bq, bn)
    out_ref[...] = qn - 2.0 * cross + vn.T


@functools.partial(jax.jit, static_argnames=("bq", "bn"))
def pairwise_l2(q, v, *, bq: int = 128, bn: int = 128):
    """q: (B, d), v: (N, d) with B % bq == N % bn == 0. Returns (B, N) f32."""
    B, d = q.shape
    N, _ = v.shape
    assert B % bq == 0 and N % bn == 0, (B, N, bq, bn)
    grid = (B // bq, N // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=config.interpret(),
    )(q, v)
