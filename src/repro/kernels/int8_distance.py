"""Symmetric-int8 quantized distance kernel (out-of-core resident path).

Garfield keeps only scalar-quantized vectors resident in accelerator memory
(Section 5.1) and re-ranks survivors on the host with full precision. This
kernel is the resident-side distance: int8 x int8 dot accumulated in int32
(the MXU's 8-bit path — 4x the bf16 FLOP rate on v5e), dequantized with
per-row scales on the VPU.

  dist ~= sq^2 |qq|^2 - 2 sq sv (qq.vq^T) + sv^2 |vq|^2

Tiling matches pairwise_l2: grid (B/bq, N/bn); scales ride along as (bq, 1)
and (bn, 1) f32 blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import config


def _kernel(qq_ref, sq_ref, vq_ref, sv_ref, out_ref):
    qq = qq_ref[...]                                       # (bq, d) int8
    vq = vq_ref[...]                                       # (bn, d) int8
    qi = qq.astype(jnp.int32)
    vi = vq.astype(jnp.int32)
    qn = jnp.sum(qi * qi, axis=-1, keepdims=True).astype(jnp.float32)
    vn = jnp.sum(vi * vi, axis=-1, keepdims=True).astype(jnp.float32)
    cross = jax.lax.dot_general(
        qq, vq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    sq = sq_ref[...].astype(jnp.float32)                   # (bq, 1)
    sv = sv_ref[...].astype(jnp.float32)                   # (bn, 1)
    out_ref[...] = (sq * sq) * qn - 2.0 * (sq * sv.T) * cross + (sv * sv).T * vn.T


@functools.partial(jax.jit, static_argnames=("bq", "bn"))
def int8_distance(qq, q_scale, vq, v_scale, *, bq: int = 128, bn: int = 128):
    """qq: (B, d) i8, q_scale: (B, 1) f32, vq: (N, d) i8, v_scale: (N, 1) f32.
    B % bq == N % bn == 0. Returns (B, N) f32."""
    B, d = qq.shape
    N, _ = vq.shape
    assert B % bq == 0 and N % bn == 0, (B, N, bq, bn)
    grid = (B // bq, N // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=config.interpret(),
    )(qq, q_scale, vq, v_scale)
