"""Scalar-prefetch gather + distance kernel (traversal inner loop).

The hot op of graph traversal: for each query, fetch its current frontier's
neighbor rows from the vector table and compute squared distances. On GPU
the paper leans on coalesced per-warp loads of CAGRA's fixed-degree rows;
the TPU analogue is *scalar-prefetched DMA*: the neighbor index array is
prefetched into SMEM before the grid runs, and each grid step's BlockSpec
index_map reads it to choose which table row the next DMA brings into VMEM.
This is the canonical Pallas TPU "embedding gather" pattern
(PrefetchScalarGridSpec) — the DMA engine chases indices while the VPU
computes the previous row's distance, so the op runs at HBM bandwidth.

Block shape: gather granularity is one table row (1, d) per grid step with
grid = (B, nb). A production variant would batch g rows per DMA
(idx reshaped (B, nb/g, g)); row-granularity keeps the index math exact for
arbitrary nb and is what we validate.

Negative indices are "no neighbor" slots: the index_map clamps them to row
0 and the body overwrites the result with +inf.

Besides the traversal inner loop, this kernel also carries the device-side
exact re-rank (runtime.exact_rerank_device): candidate fp32 rows upload as
a scratch table and one gather->distance->k-select program replaces the
per-query host loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config


def _kernel(idx_ref, q_ref, row_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                     # (1, d)
    row = row_ref[...].astype(jnp.float32)                 # (1, d)
    diff = q - row
    d2 = jnp.sum(diff * diff)
    invalid = idx_ref[b, j] < 0
    out_ref[0, 0] = jnp.where(invalid, jnp.float32(jnp.inf), d2)


@jax.jit
def gather_distance(q, table, idx):
    """q: (B, d), table: (N, d), idx: (B, nb) i32 -> (B, nb) f32."""
    B, d = q.shape
    nb = idx.shape[1]

    def q_map(b, j, idx_ref):
        return (b, 0)

    def row_map(b, j, idx_ref):
        return (jnp.maximum(idx_ref[b, j], 0), 0)

    def out_map(b, j, idx_ref):
        return (b, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, d), q_map),
            pl.BlockSpec((1, d), row_map),
        ],
        out_specs=pl.BlockSpec((1, 1), out_map),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nb), jnp.float32),
        interpret=config.interpret(),
    )(idx, q, table)
