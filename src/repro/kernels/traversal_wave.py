"""One-kernel traversal wave: a whole expansion step fused in Pallas.

The unfused hot path in ``core/traversal.py`` round-trips through >= 3
device programs per hop: a gather-distance kernel, the packed-visited
scatter, and two ``lax.top_k`` merges (plus the dedup argsorts).  This
kernel fuses the entire step — scalar-prefetched neighbor-row gather
(f32 or int8-dequant), squared-L2 distance, range-predicate mask,
packed-visited test+set, candidate dedup, and the dual beam/result
top-k merge — into ONE ``pl.pallas_call``.

Layout (grid = (B, nbp/g), parallel x arbitrary):

- ``cand_ids``/``gids`` ride in SMEM via ``PrefetchScalarGridSpec``; the
  g row/attr/scale BlockSpec index_maps read them to pick the DMA source
  rows for each step — the gather never materializes (B, nb, d) in HBM.
- Each sequential step streams g gathered rows, scores them (distance,
  predicate, visited bitset in VMEM scratch), and parks nav/res scores
  in per-lane scratch.  Mosaic's pipelining double-buffers the next
  step's row DMAs against the current step's compute.
- The last step flushes: a lexicographic (id, pos) bitonic network
  (= stable argsort by id) dedups candidates, then an unrolled run of
  stable (d, pos) insertions merges them into the sorted beam/result
  buffers — bit-identical to the unfused dedup + ``lax.top_k`` path
  (ties break toward the lower concatenated position in both).

``g`` (rows per step) comes from ``launch/roofline.py:
traversal_wave_tiles``; under interpret it collapses to 1 so the
unrolled per-row trace stays compile-tractable on CPU CI.  Blocks keep
their natural (1, d)/(1, m) shapes — on TPU Mosaic relayouts the
non-128 minors; CI runs interpret where layout is moot.

The jnp oracle twins live in ``kernels/ref.py`` (``wave_expand`` /
``wave_seed``); ``core/traversal.py`` dispatches between them via the
static ``fused`` flag resolved from ``kernels/config.py`` at the
``CellRuntime.run`` boundary.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config
from repro.kernels.ref import PAD_ID
from repro.kernels.sort_network import bitonic_sort_lex, next_pow2

_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _insert(bufs, cvals, cd, cp):
    """One stable (d, pos) insertion of a scalar candidate into sorted
    row buffers.  bufs[0] = distances, bufs[1] = positions; extra payload
    columns follow.  Capped: the buffer's worst entry falls off."""
    bd, bp = bufs[0], bufs[1]
    lt = (bd < cd) | ((bd == cd) & (bp < cp))
    at = jnp.sum(lt.astype(jnp.int32))
    lane = jax.lax.broadcasted_iota(jnp.int32, bd.shape, bd.ndim - 1)

    def mix(buf, c):
        shifted = jnp.roll(buf, 1, axis=-1)
        return jnp.where(lane < at, buf, jnp.where(lane == at, c, shifted))

    return tuple(mix(b, c) for b, c in zip(bufs, (cd, cp) + tuple(cvals)))


def _make_kernel(*, g, nbp, W, ef, k, n_real, entry_width, seed_mode, int8,
                 n_steps):
    def kernel(cand_sm, gid_sm, *refs):
        del gid_sm  # consumed by the BlockSpec index maps only
        (q_ref, lo_ref, hi_ref, act_ref, cid_ref, vis_ref,
         bi_ref, bd_ref, be_ref, ri_ref, rd_ref) = refs[:11]
        pos = 11
        row_refs = refs[pos:pos + g]
        pos += g
        if int8:
            sc_refs = refs[pos:pos + g]
            pos += g
        at_refs = refs[pos:pos + g]
        pos += g
        obi, obd, obe, ori, ord_, ovis = refs[pos:pos + 6]
        s_nav, s_res, s_vis = refs[pos + 6:pos + 9]

        b = pl.program_id(0)
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            s_vis[...] = vis_ref[...]
            s_nav[...] = jnp.full((1, nbp), jnp.inf, jnp.float32)
            s_res[...] = jnp.full((1, nbp), jnp.inf, jnp.float32)

        q = q_ref[...].astype(jnp.float32)                  # (1, d)
        lo = lo_ref[...]
        hi = hi_ref[...]
        wlane = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
        clane = jax.lax.broadcasted_iota(jnp.int32, (1, nbp), 1)

        for i in range(g):
            jj = j * g + i
            cid = cand_sm[b, jj]
            valid = (cid >= 0) & (cid < PAD_ID)
            safe = jnp.maximum(cid, 0)
            row = row_refs[i][...].astype(jnp.float32)      # (1, d)
            if int8:
                row = row * sc_refs[i][0, 0]
            diff = row - q
            d2 = jnp.sum(diff * diff)

            widx = jnp.minimum(safe >> 5, W - 1)
            bit = jnp.uint32(1) << (safe & 31).astype(jnp.uint32)
            hitw = wlane == widx
            vis = s_vis[...]
            seen = jnp.any((vis & jnp.where(hitw, bit, jnp.uint32(0))) != 0)
            s_vis[...] = vis | jnp.where(hitw & valid, bit, jnp.uint32(0))

            a = at_refs[i][...]                             # (1, m)
            ok = jnp.all((a >= lo) & (a <= hi))
            nav_c = jnp.where(valid & ~seen, d2, jnp.inf)
            res_c = jnp.where(ok, nav_c, jnp.inf)
            hitc = clane == jj
            s_nav[...] = jnp.where(hitc, nav_c, s_nav[...])
            s_res[...] = jnp.where(hitc, res_c, s_res[...])

        @pl.when(j == n_steps - 1)
        def _flush():
            ids = cid_ref[...]                              # (1, nbp)
            ids_s, pos_s, (nav_s, res_s) = bitonic_sort_lex(
                ids, clane, (s_nav[...], s_res[...]))
            del pos_s
            dup = (ids_s == jnp.roll(ids_s, 1, axis=-1)) & (clane > 0)
            nav_s = jnp.where(dup, jnp.inf, nav_s)
            res_s = jnp.where(dup, jnp.inf, res_s)

            # result pool: sorted state ++ sorted candidates, stable top-k
            klane = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
            rd, rp, ri = rd_ref[...], klane, ri_ref[...]
            for c in range(nbp):
                rd, rp, ri = _insert((rd, rp, ri), (ids_s[0, c],),
                                     res_s[0, c], k + c)
            ori[...] = ri
            ord_[...] = rd

            elane = jax.lax.broadcasted_iota(jnp.int32, (1, ef), 1)
            if seed_mode:
                bd = jnp.full((1, ef), jnp.inf, jnp.float32)
                bi = jnp.full((1, ef), -1, jnp.int32)
                bp = jnp.full((1, ef), PAD_ID, jnp.int32)   # sentinel pos
                for c in range(nbp):
                    bd, bp, bi = _insert((bd, bp, bi), (ids_s[0, c],),
                                         nav_s[0, c], c)
                w = min(entry_width, n_real)
                cut = (elane >= w) | (bi == PAD_ID)
                bi = jnp.where(cut, -1, bi)
                bd = jnp.where(cut, jnp.inf, bd)
                be = (~jnp.isfinite(bd)).astype(jnp.int32)
                act = act_ref[0, 0] != 0
                obi[...] = jnp.where(act, bi, bi_ref[...])
                obd[...] = jnp.where(act, bd, bd_ref[...])
                obe[...] = jnp.where(act, be,
                                     jnp.ones((1, ef), jnp.int32))
            else:
                bd, bp, bi, be = (bd_ref[...], elane, bi_ref[...],
                                  be_ref[...])
                for c in range(nbp):
                    bd, bp, bi, be = _insert(
                        (bd, bp, bi, be),
                        (ids_s[0, c], jnp.int32(0)),
                        nav_s[0, c], ef + c)
                obi[...] = bi
                obd[...] = bd
                obe[...] = be
            ovis[...] = s_vis[...]

    return kernel


@partial(jax.jit, static_argnames=("seed_mode", "entry_width", "n_real",
                                   "g", "interpret"))
def _wave_call(cand_p, gids_p, q, lo, hi, act, visited, beam_ids, beam_d,
               beam_exp, res_ids, res_d, table, scale, attrs, *,
               seed_mode, entry_width, n_real, g, interpret):
    B, nbp = cand_p.shape
    d = q.shape[1]
    m = attrs.shape[1]
    W = visited.shape[1]
    ef = beam_ids.shape[1]
    k = res_ids.shape[1]
    int8 = scale is not None
    n_steps = nbp // g

    def fixed(b, j, cand, gid):
        del j, cand, gid
        return (b, 0)

    def row_map(b, j, cand, gid, i=0):
        del cand
        return (jnp.maximum(gid[b, j * g + i], 0), 0)

    in_specs = [
        pl.BlockSpec((1, d), fixed),                        # q
        pl.BlockSpec((1, m), fixed),                        # lo
        pl.BlockSpec((1, m), fixed),                        # hi
        pl.BlockSpec((1, 1), fixed),                        # act
        pl.BlockSpec((1, nbp), fixed),                      # cand (vector)
        pl.BlockSpec((1, W), fixed),                        # visited
        pl.BlockSpec((1, ef), fixed),                       # beam ids
        pl.BlockSpec((1, ef), fixed),                       # beam d
        pl.BlockSpec((1, ef), fixed),                       # beam expanded
        pl.BlockSpec((1, k), fixed),                        # res ids
        pl.BlockSpec((1, k), fixed),                        # res d
    ]
    args = [q, lo, hi, act, cand_p, visited, beam_ids, beam_d, beam_exp,
            res_ids, res_d]
    for i in range(g):
        in_specs.append(pl.BlockSpec((1, d), partial(row_map, i=i)))
        args.append(table)
    if int8:
        for i in range(g):
            in_specs.append(pl.BlockSpec((1, 1), partial(row_map, i=i)))
            args.append(scale)
    for i in range(g):
        in_specs.append(pl.BlockSpec((1, m), partial(row_map, i=i)))
        args.append(attrs)

    out_specs = [
        pl.BlockSpec((1, ef), fixed), pl.BlockSpec((1, ef), fixed),
        pl.BlockSpec((1, ef), fixed), pl.BlockSpec((1, k), fixed),
        pl.BlockSpec((1, k), fixed), pl.BlockSpec((1, W), fixed),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, ef), jnp.int32),
        jax.ShapeDtypeStruct((B, ef), jnp.float32),
        jax.ShapeDtypeStruct((B, ef), jnp.int32),
        jax.ShapeDtypeStruct((B, k), jnp.int32),
        jax.ShapeDtypeStruct((B, k), jnp.float32),
        jax.ShapeDtypeStruct((B, W), jnp.uint32),
    ]

    kernel = _make_kernel(g=g, nbp=nbp, W=W, ef=ef, k=k, n_real=n_real,
                          entry_width=entry_width, seed_mode=seed_mode,
                          int8=int8, n_steps=n_steps)
    kwargs = {}
    if _CompilerParams is not None and not interpret:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_steps),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((1, nbp), jnp.float32),
                pltpu.VMEM((1, nbp), jnp.float32),
                pltpu.VMEM((1, W), jnp.uint32),
            ]),
        out_shape=out_shape,
        interpret=interpret,
    )(cand_p, gids_p, *args)


def _pad_candidates(cand_ids, gids, g):
    """Pad the candidate axis to a pow2 multiple of g.  Padding ids are
    PAD_ID (sort *after* every real id — see kernels/ref.py) with row 0
    as their harmless gather target."""
    nb = cand_ids.shape[1]
    nbp = max(next_pow2(nb), g)
    if nbp == nb:
        return cand_ids, gids, nb
    pad = nbp - nb
    cand_p = jnp.pad(cand_ids, ((0, 0), (0, pad)), constant_values=PAD_ID)
    gids_p = jnp.pad(gids, ((0, 0), (0, pad)), constant_values=0)
    return cand_p, gids_p, nb


def _tile_g(nbp, d, m, int8, interpret):
    from repro.launch import roofline
    return roofline.traversal_wave_tiles(nbp, d, m, int8=int8,
                                         interpret=interpret)


def wave_expand(q, vectors, vq, vscale, attrs, lo, hi, cand_ids, gids,
                visited, beam_ids, beam_d, beam_exp, res_ids, res_d, *,
                g=None):
    """Fused expansion step (Pallas).  Same contract as ref.wave_expand."""
    int8 = vectors is None
    table = vectors if not int8 else vq
    scale = None if not int8 else vscale.reshape(-1, 1)
    interpret = config.interpret()
    cand_p, gids_p, nb = _pad_candidates(cand_ids, gids,
                                         g or 1)
    if g is None:
        g = _tile_g(cand_p.shape[1], q.shape[1], attrs.shape[1], int8,
                    interpret)
    act = jnp.ones((q.shape[0], 1), jnp.int32)
    bi, bd, be, ri, rd, vis = _wave_call(
        cand_p, gids_p, q.astype(jnp.float32), lo, hi, act, visited,
        beam_ids, beam_d, beam_exp.astype(jnp.int32), res_ids, res_d,
        table, scale, attrs,
        seed_mode=False, entry_width=0, n_real=nb, g=g,
        interpret=interpret)
    return bi, bd, be.astype(bool), ri, rd, vis


def wave_seed(q, vectors, vq, vscale, attrs, lo, hi, cand_ids, gids,
              visited, beam_ids, beam_d, res_ids, res_d, active,
              entry_width: int, *, g=None):
    """Fused seeding step (Pallas).  Same contract as ref.wave_seed."""
    int8 = vectors is None
    table = vectors if not int8 else vq
    scale = None if not int8 else vscale.reshape(-1, 1)
    interpret = config.interpret()
    cand_p, gids_p, nb = _pad_candidates(cand_ids, gids, g or 1)
    if g is None:
        g = _tile_g(cand_p.shape[1], q.shape[1], attrs.shape[1], int8,
                    interpret)
    act = active.astype(jnp.int32).reshape(-1, 1)
    beam_exp = jnp.ones_like(beam_ids)                      # ignored input
    bi, bd, be, ri, rd, vis = _wave_call(
        cand_p, gids_p, q.astype(jnp.float32), lo, hi, act, visited,
        beam_ids, beam_d, beam_exp, res_ids, res_d, table, scale, attrs,
        seed_mode=True, entry_width=entry_width, n_real=nb, g=g,
        interpret=interpret)
    return bi, bd, be.astype(bool), ri, rd, vis
