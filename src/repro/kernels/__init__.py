"""Pallas TPU kernels for Garfield's compute hot spots.

Five kernels, each with an explicit-BlockSpec `pl.pallas_call` implementation
targeting TPU v5e (validated on CPU via ``interpret=True``), a pure-jnp oracle
in :mod:`repro.kernels.ref`, and a jit'd dispatch wrapper in
:mod:`repro.kernels.ops`:

- ``pairwise_l2``    — MXU-tiled squared-L2 distance matrix (paper: warp-per-
                       distance -> systolic matmul ``|q|^2 - 2 q.V^T + |v|^2``).
- ``fused_topk``     — distance + running bitonic top-k merge, never
                       materializing the full (B, N) matrix (paper: bitonic
                       sort in registers -> VMEM compare-exchange network).
- ``int8_distance``  — symmetric-quantized int8 distance on the int8 MXU path
                       (paper: quantized resident vectors in HBM).
- ``gather_distance``— scalar-prefetch row gather + distance (paper: the
                       traversal's neighbor-expansion inner loop).
- ``masked_scan``    — fused gather -> range-predicate mask -> distance ->
                       k-select over candidate rows (the cost model's dense
                       route for ultra-selective filters; f32 + int8
                       variants).
"""

from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.config import get_mode, set_mode  # noqa: F401
