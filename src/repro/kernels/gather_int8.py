"""Scalar-prefetch gather + *quantized* distance kernel.

The out-of-core twin of gather_distance.py: the resident vector table is
symmetric-int8 (paper Section 5.1 keeps only quantized vectors in
accelerator memory), so the gathered row dequantizes in VMEM as
``scale * int8`` before the diff-square-add. The index array is scalar-
prefetched into SMEM; each grid step's BlockSpec index_map picks the table
row (and its scale) for the next DMA while the VPU processes the current
one — gathers run at HBM bandwidth and the int8 rows halve the bytes
fetched versus fp16 (4x vs fp32), which is the whole point of keeping the
quantized copy resident.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config


def _kernel(idx_ref, q_ref, row_ref, scale_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                     # (1, d)
    row = row_ref[...].astype(jnp.float32)                 # (1, d) int8->f32
    scale = scale_ref[0, 0].astype(jnp.float32)
    diff = q - row * scale
    d2 = jnp.sum(diff * diff)
    invalid = idx_ref[b, j] < 0
    out_ref[0, 0] = jnp.where(invalid, jnp.float32(jnp.inf), d2)


@jax.jit
def gather_int8_distance(q, vq, vscale, idx):
    """q: (B, d) f32, vq: (N, d) i8, vscale: (N, 1) f32, idx: (B, nb) i32
    -> (B, nb) f32."""
    B, d = q.shape
    nb = idx.shape[1]

    def q_map(b, j, idx_ref):
        return (b, 0)

    def row_map(b, j, idx_ref):
        return (jnp.maximum(idx_ref[b, j], 0), 0)

    def out_map(b, j, idx_ref):
        return (b, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, d), q_map),
            pl.BlockSpec((1, d), row_map),
            pl.BlockSpec((1, 1), row_map),
        ],
        out_specs=pl.BlockSpec((1, 1), out_map),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nb), jnp.float32),
        interpret=config.interpret(),
    )(idx, q, vq, vscale)
