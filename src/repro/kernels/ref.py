"""Pure-jnp oracles for every kernel. These define correctness.

Each function mirrors its Pallas twin's *math* exactly (same decomposition,
same accumulation dtype) so kernel tests can assert tight allclose, and each
is also the fast XLA path on non-TPU backends (see kernels/config.py).
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def pairwise_l2(q, v):
    """Squared L2 distances. q: (B, d), v: (N, d) -> (B, N) float32.

    Same decomposition as the kernel: |q|^2 - 2 q.V^T + |v|^2, f32 accum.
    """
    q = q.astype(jnp.float32)
    v = v.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)          # (B, 1)
    vn = jnp.sum(v * v, axis=-1)[None, :]                # (1, N)
    cross = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (B, N)
    return qn - 2.0 * cross + vn


def fused_topk(q, v, k: int, bias=None):
    """Top-k smallest distances. Returns (vals (B,k) f32, idxs (B,k) i32).

    ``bias`` is an optional (N,) f32 additive row (0 for valid, +inf to mask
    a point out) — how range predicates reach the kernel.
    """
    d2 = pairwise_l2(q, v)
    if bias is not None:
        d2 = d2 + bias[None, :].astype(jnp.float32)
    neg_vals, idxs = jax.lax.top_k(-d2, k)
    return -neg_vals, idxs.astype(jnp.int32)


def int8_distance(qq, q_scale, vq, v_scale):
    """Quantized squared-L2.

    qq: (B, d) int8, q_scale: (B,) f32 — symmetric per-row quantized query
    vq: (N, d) int8, v_scale: (N,) f32 — symmetric per-row quantized points

    dist ~= sq^2 |qq|^2 - 2 sq sv (qq . vq^T) + sv^2 |vq|^2, with the dot
    accumulated in int32 (the int8 MXU path).
    """
    qi = qq.astype(jnp.int32)
    vi = vq.astype(jnp.int32)
    qn = jnp.sum(qi * qi, axis=-1).astype(jnp.float32)       # (B,)
    vn = jnp.sum(vi * vi, axis=-1).astype(jnp.float32)       # (N,)
    cross = jax.lax.dot_general(
        qq, vq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)  # (B, N)
    sq = q_scale.astype(jnp.float32)[:, None]
    sv = v_scale.astype(jnp.float32)[None, :]
    return (sq * sq) * qn[:, None] - 2.0 * (sq * sv) * cross + (sv * sv) * vn[None, :]


def gather_distance(q, table, idx):
    """Distances from each query row to its own gathered rows.

    q: (B, d), table: (N, d), idx: (B, nb) int32 -> (B, nb) f32.
    Rows with idx < 0 produce +inf (the traversal's "no neighbor" slot).
    """
    q = q.astype(jnp.float32)
    safe = jnp.maximum(idx, 0)
    rows = table.astype(jnp.float32)[safe]                   # (B, nb, d)
    diff = rows - q[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.where(idx < 0, jnp.float32(jnp.inf), d2)


def gather_int8_distance(q, vq, vscale, idx):
    """Quantized gathered-row distances (out-of-core resident path).

    q: (B, d) f32, vq: (N, d) int8, vscale: (N,) f32, idx: (B, nb) i32.
    Rows dequantize as scale * int8; idx < 0 -> +inf.
    """
    q = q.astype(jnp.float32)
    safe = jnp.maximum(idx, 0)
    rows = vq[safe].astype(jnp.float32) * vscale[safe][..., None]
    diff = rows - q[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.where(idx < 0, jnp.float32(jnp.inf), d2)
