"""Pure-jnp oracles for every kernel. These define correctness.

Each function mirrors its Pallas twin's *math* exactly (same decomposition,
same accumulation dtype) so kernel tests can assert tight allclose, and each
is also the fast XLA path on non-TPU backends (see kernels/config.py).
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def pairwise_l2(q, v):
    """Squared L2 distances. q: (B, d), v: (N, d) -> (B, N) float32.

    Same decomposition as the kernel: |q|^2 - 2 q.V^T + |v|^2, f32 accum.
    """
    q = q.astype(jnp.float32)
    v = v.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)          # (B, 1)
    vn = jnp.sum(v * v, axis=-1)[None, :]                # (1, N)
    cross = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (B, N)
    return qn - 2.0 * cross + vn


def fused_topk(q, v, k: int, bias=None):
    """Top-k smallest distances. Returns (vals (B,k) f32, idxs (B,k) i32).

    ``bias`` is an optional (N,) f32 additive row (0 for valid, +inf to mask
    a point out) — how range predicates reach the kernel.
    """
    d2 = pairwise_l2(q, v)
    if bias is not None:
        d2 = d2 + bias[None, :].astype(jnp.float32)
    neg_vals, idxs = jax.lax.top_k(-d2, k)
    return -neg_vals, idxs.astype(jnp.int32)


def int8_distance(qq, q_scale, vq, v_scale):
    """Quantized squared-L2.

    qq: (B, d) int8, q_scale: (B,) f32 — symmetric per-row quantized query
    vq: (N, d) int8, v_scale: (N,) f32 — symmetric per-row quantized points

    dist ~= sq^2 |qq|^2 - 2 sq sv (qq . vq^T) + sv^2 |vq|^2, with the dot
    accumulated in int32 (the int8 MXU path).
    """
    qi = qq.astype(jnp.int32)
    vi = vq.astype(jnp.int32)
    qn = jnp.sum(qi * qi, axis=-1).astype(jnp.float32)       # (B,)
    vn = jnp.sum(vi * vi, axis=-1).astype(jnp.float32)       # (N,)
    cross = jax.lax.dot_general(
        qq, vq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)  # (B, N)
    sq = q_scale.astype(jnp.float32)[:, None]
    sv = v_scale.astype(jnp.float32)[None, :]
    return (sq * sq) * qn[:, None] - 2.0 * (sq * sv) * cross + (sv * sv) * vn[None, :]


def gather_distance(q, table, idx):
    """Distances from each query row to its own gathered rows.

    q: (B, d), table: (N, d), idx: (B, nb) int32 -> (B, nb) f32.
    Rows with idx < 0 produce +inf (the traversal's "no neighbor" slot).
    """
    q = q.astype(jnp.float32)
    safe = jnp.maximum(idx, 0)
    rows = table.astype(jnp.float32)[safe]                   # (B, nb, d)
    diff = rows - q[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.where(idx < 0, jnp.float32(jnp.inf), d2)


def gather_int8_distance(q, vq, vscale, idx):
    """Quantized gathered-row distances (out-of-core resident path).

    q: (B, d) f32, vq: (N, d) int8, vscale: (N,) f32, idx: (B, nb) i32.
    Rows dequantize as scale * int8; idx < 0 -> +inf.
    """
    q = q.astype(jnp.float32)
    safe = jnp.maximum(idx, 0)
    rows = vq[safe].astype(jnp.float32) * vscale[safe][..., None]
    diff = rows - q[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.where(idx < 0, jnp.float32(jnp.inf), d2)


# ---------------------------------------------------------------------------
# traversal wave (one fused expansion step)
# ---------------------------------------------------------------------------
#
# The wave kernel's candidate contract: ``cand_ids`` are view-local ids with
# -1 for invalid lanes and PAD_ID for *padding* lanes.  Padding must sort
# *after* every real id (so the stable id-sort keeps real candidates at the
# same positions they'd have unpadded, preserving +inf tie selection), which
# is why it is INT32_MAX rather than another -1.

PAD_ID = jnp.iinfo(jnp.int32).max


def set_packed_bits(visited, ids, valid):
    """Batch visited-bit test+set on the packed uint32 bitset.

    visited: (B, W) u32, ids: (B, nb) i32, valid: (B, nb) bool.
    Returns (seen, visited'): ``seen`` reads the *pre-update* set (the
    traversal's batch read-then-set semantics), and the update ORs in the
    bit of every valid id — as a single vectorized scatter-add instead of
    the former O(nb) ``fori_loop``.  Bit-identical because each (word, bit)
    pair is added at most once: duplicates are restricted to their first
    occurrence and already-set bits are excluded, so add == OR.
    """
    B, W = visited.shape
    rows_b = jnp.arange(B, dtype=jnp.int32)[:, None]
    safe = jnp.minimum(jnp.maximum(ids, 0), W * 32 - 1)
    widx = safe >> 5
    bit = jnp.uint32(1) << (safe & 31).astype(jnp.uint32)
    seen = (visited[rows_b, widx] & bit) != 0
    vid = jnp.where(valid, ids, -1)
    eq = vid[:, :, None] == vid[:, None, :]                  # (B, nb, nb)
    prior = jnp.tril(jnp.ones((ids.shape[1],) * 2, bool), -1)
    first = ~jnp.any(eq & prior[None, :, :], axis=2)
    add = jnp.where(valid & ~seen & first, bit, jnp.uint32(0))
    return seen, visited.at[rows_b, widx].add(add)


def dedup_inf(ids, d):
    """Stable id-sort per row; duplicates (all but first) masked to +inf."""
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((ids.shape[0], 1), bool), ids_s[:, 1:] == ids_s[:, :-1]],
        axis=1)
    return ids_s, jnp.where(dup, jnp.inf, d_s)


def topk_merge(ids_a, d_a, ids_b, d_b, k, extra_a=None, extra_b=None):
    """Row-wise best-k of two (already internally deduped) sets.  Ties at
    equal distance break toward the lower concatenated position (lax.top_k
    semantics) — the a-side always wins against an equal b-side."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    out_ids = jnp.take_along_axis(ids, pos, axis=1)
    if extra_a is None:
        return out_ids, -neg
    extra = jnp.concatenate([extra_a, extra_b], axis=1)
    return out_ids, -neg, jnp.take_along_axis(extra, pos, axis=1)


def _wave_scores(q, vectors, vq, vscale, attrs, lo, hi, cand_ids, gids,
                 visited):
    """Shared scoring half of one wave step: gather-distance (f32 or
    int8-dequant), packed-visited test+set, range predicate.

    cand_ids: (B, nb) view-local ids (-1 invalid / PAD_ID padding, both
    pre-masked by the caller for inactive lanes); gids: (B, nb) >= 0
    global row ids aligned with cand_ids.  Returns (nav, res, visited').
    """
    valid = (cand_ids >= 0) & (cand_ids < PAD_ID)
    midx = jnp.where(valid, gids, -1)
    if vectors is not None:
        d2 = gather_distance(q, vectors, midx)
    else:
        d2 = gather_int8_distance(q, vq, vscale, midx)
    seen, visited = set_packed_bits(visited, cand_ids, valid)
    nav = jnp.where(valid & ~seen, d2, jnp.inf)
    a_rows = attrs[gids]                                     # (B, nb, m)
    ok = jnp.all((a_rows >= lo[:, None, :]) & (a_rows <= hi[:, None, :]),
                 axis=2)
    res = jnp.where(ok, nav, jnp.inf)
    return nav, res, visited


def wave_expand(q, vectors, vq, vscale, attrs, lo, hi, cand_ids, gids,
                visited, beam_ids, beam_d, beam_exp, res_ids, res_d):
    """One fused expansion step, jnp oracle: score the candidate batch and
    merge it into the (sorted-ascending) beam and result buffers.

    Defines correctness for the Pallas twin in kernels/traversal_wave.py;
    identical math to the unfused _score + dedup + dual topk_merge
    composition in core/traversal.py.
    """
    nav, res, visited = _wave_scores(q, vectors, vq, vscale, attrs, lo, hi,
                                     cand_ids, gids, visited)
    ids_s, nav_s = dedup_inf(cand_ids, nav)
    _, res_s = dedup_inf(cand_ids, res)
    new_ids, new_d, new_exp = topk_merge(
        beam_ids, beam_d, ids_s, nav_s, beam_ids.shape[1],
        beam_exp, jnp.zeros_like(ids_s, dtype=bool))
    r_ids, r_d = topk_merge(res_ids, res_d, ids_s, res_s, res_ids.shape[1])
    return new_ids, new_d, new_exp, r_ids, r_d, visited


def wave_seed(q, vectors, vq, vscale, attrs, lo, hi, cand_ids, gids,
              visited, beam_ids, beam_d, res_ids, res_d, active,
              entry_width: int, n_real: int):
    """One fused seeding step, jnp oracle: score entry candidates, reset
    active lanes' beams to the best ``entry_width`` of them (+inf ties keep
    real ids — they still propose inter-cell hops), merge in-range entries
    into the result pool.  ``n_real`` is the pre-padding candidate count:
    the beam is cut to min(entry_width, n_real) so padding can never widen
    the entry set."""
    B, ef = beam_ids.shape
    nav, res, visited = _wave_scores(q, vectors, vq, vscale, attrs, lo, hi,
                                     cand_ids, gids, visited)
    ids_s, nav_s = dedup_inf(cand_ids, nav)
    _, res_s = dedup_inf(cand_ids, res)

    w = min(entry_width, n_real)
    neg, pos = jax.lax.top_k(-nav_s, min(w, nav_s.shape[1]))
    ent_ids = jnp.take_along_axis(ids_s, pos, axis=1)
    ent_d = -neg
    ent_ids = jnp.where(ent_ids == PAD_ID, -1, ent_ids)
    ent_d = jnp.where(ent_ids < 0, jnp.inf, ent_d)
    pad = ef - ent_ids.shape[1]
    if pad > 0:
        ent_ids = jnp.pad(ent_ids, ((0, 0), (0, pad)), constant_values=-1)
        ent_d = jnp.pad(ent_d, ((0, 0), (0, pad)), constant_values=jnp.inf)

    new_ids = jnp.where(active[:, None], ent_ids, beam_ids)
    new_d = jnp.where(active[:, None], ent_d, beam_d)
    new_exp = jnp.where(active[:, None], ~jnp.isfinite(ent_d),
                        jnp.ones((B, ef), bool))
    r_ids, r_d = topk_merge(res_ids, res_d, ids_s, res_s, res_ids.shape[1])
    return new_ids, new_d, new_exp, r_ids, r_d, visited
