"""Public jit'd kernel API with padding + pallas/ref dispatch.

Everything above this layer (core/, models/, benchmarks/) calls these four
functions; the choice between the Pallas kernel and the jnp oracle is made
by kernels/config.py (Pallas on TPU, oracle-as-XLA elsewhere, both
overridable for tests).

Padding contract: callers pass arbitrary (B, N, d); this layer pads
  d -> multiple of 128 with zeros        (exact: zero dims add 0 distance)
  B -> multiple of bq by repeating row 0 (sliced away)
  N -> multiple of bn with +inf bias     (can never win a top-k slot)
and slices results back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import config, ref
from repro.kernels import pairwise_l2 as _pl2
from repro.kernels import fused_topk as _ftk
from repro.kernels import int8_distance as _i8
from repro.kernels import gather_distance as _gd
from repro.kernels.sort_network import next_pow2


def _pad_to(x, axis: int, mult: int, value=0.0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def _tile_sizes(B: int, N: int):
    """Shrink tiles for small problems so padding overhead stays sane.

    TPU note: sublane tiling wants bq a multiple of 8 and bn a multiple of
    128 for f32; we keep bn=128 always (lane width) and only shrink bq.
    """
    bq = 128 if B >= 128 else max(8, next_pow2(B))
    bn = 128
    return bq, bn


def pairwise_l2(q, v):
    """(B, d) x (N, d) -> (B, N) f32 squared-L2 distance matrix."""
    if not config.use_pallas():
        return ref.pairwise_l2(q, v)
    B, N = q.shape[0], v.shape[0]
    bq, bn = _tile_sizes(B, N)
    qp = _pad_to(_pad_to(q, 1, 128), 0, bq)
    vp = _pad_to(_pad_to(v, 1, 128), 0, bn)
    out = _pl2.pairwise_l2(qp, vp, bq=bq, bn=bn)
    return out[:B, :N]


def topk_l2(q, v, k: int, bias=None):
    """Top-k nearest of v for each q row. Returns (vals (B,k), idx (B,k)).

    bias: optional (N,) f32 additive mask row (+inf filters a point).
    """
    B, N = q.shape[0], v.shape[0]
    k_eff = min(k, N)
    if not config.use_pallas():
        vals, idx = ref.fused_topk(q, v, k_eff, bias)
    else:
        # tile choice lives in the roofline model, not here: interpret
        # mode (CI) gets a compile-tractable bn (the interpreted bitonic
        # network is unrolled per lane), compiled TPU the VMEM-bounded
        # production tile. Both guarantee bn >= next_pow2(k), so the
        # ref fallback below can only fire on an out-of-contract call.
        from repro.launch import roofline
        bq, bn = roofline.fused_topk_tiles(
            B, N, k_eff, q.shape[1], interpret=config.interpret())
        K = next_pow2(max(k_eff, 2))
        if K > bn:  # running buffer wider than a tile: fall back
            vals, idx = ref.fused_topk(q, v, k_eff, bias)
        else:
            qp = _pad_to(_pad_to(q, 1, 128), 0, bq)
            vp = _pad_to(_pad_to(v, 1, 128), 0, bn)
            b = jnp.zeros((N,), jnp.float32) if bias is None else bias.astype(jnp.float32)
            bp = _pad_to(b[None, :], 1, bn, value=jnp.inf)
            vals, idx = _ftk.fused_topk(qp, vp, bp, k_eff, bq=bq, bn=bn)
            vals, idx = vals[:B, :k_eff], idx[:B, :k_eff]
    if k_eff < k:  # N < k: pad result so callers get static (B, k)
        pad_v = jnp.full((B, k - k_eff), jnp.inf, vals.dtype)
        pad_i = jnp.full((B, k - k_eff), -1, idx.dtype)
        vals = jnp.concatenate([vals, pad_v], axis=1)
        idx = jnp.concatenate([idx, pad_i], axis=1)
    return vals, idx


def k_select(scores, k: int):
    """Row-wise ascending k-select over precomputed scores.

    scores (B, n) f32 -> (vals (B, k), pos (B, k)) with vals ascending.
    Ties resolve toward the *lower column index* (lax.top_k's documented
    tie rule) — the contract the device-side exact re-rank relies on to
    stay bit-identical with the host path's stable argsort. +inf rows
    pass through (callers mask invalid slots to +inf and drop them by
    ``isfinite``)."""
    neg, pos = jax.lax.top_k(-scores, k)
    return -neg, pos


def int8_l2(qq, q_scale, vq, v_scale):
    """Quantized distance matrix. qq (B,d) i8, vq (N,d) i8, scales (B,)/(N,)."""
    if not config.use_pallas():
        return ref.int8_distance(qq, q_scale, vq, v_scale)
    B, N = qq.shape[0], vq.shape[0]
    bq, bn = _tile_sizes(B, N)
    qp = _pad_to(_pad_to(qq, 1, 128), 0, bq)
    vp = _pad_to(_pad_to(vq, 1, 128), 0, bn)
    sq = _pad_to(q_scale.reshape(-1, 1).astype(jnp.float32), 0, bq)
    sv = _pad_to(v_scale.reshape(-1, 1).astype(jnp.float32), 0, bn)
    out = _i8.int8_distance(qp, sq, vp, sv, bq=bq, bn=bn)
    return out[:B, :N]


def gather_l2(q, table, idx):
    """Per-query gathered-row distances. idx (B, nb) i32; idx<0 -> +inf."""
    if not config.use_pallas():
        return ref.gather_distance(q, table, idx)
    d = q.shape[1]
    qp = _pad_to(q, 1, 128)
    tp = _pad_to(table, 1, 128)
    return _gd.gather_distance(qp, tp, idx.astype(jnp.int32))


def gather_l2_q(q, vq, vscale, idx):
    """Quantized gathered-row distances (out-of-core resident path).
    q (B, d) f32, vq (N, d) i8, vscale (N,) f32, idx (B, nb) i32."""
    if not config.use_pallas():
        return ref.gather_int8_distance(q, vq, vscale, idx)
    from repro.kernels import gather_int8 as _gi8
    qp = _pad_to(q, 1, 128)
    vp = _pad_to(vq, 1, 128)
    return _gi8.gather_int8_distance(
        qp, vp, vscale.reshape(-1, 1).astype(jnp.float32),
        idx.astype(jnp.int32))
