"""Fused distance + running top-k kernel.

Computes the k nearest points of each query without ever materializing the
(B, N) distance matrix: the grid walks N in bn-wide tiles (sequential minor
axis), each step computing a (bq, bn) distance tile on the MXU, bitonic-
sorting it in VMEM, and merging it into a running (bq, K) best buffer held
in VMEM scratch. The GPU paper does this with a register-resident bitonic
network per warp; on TPU the same network is a static sequence of VPU
permute+select stages (see sort_network.py).

An additive f32 ``bias`` row ((1, N); 0 = valid, +inf = filtered) applies
the range predicate inside the kernel, so out-of-range points can never
enter the candidate buffer — this is the kernel-level form of the paper's
"enforce F during traversal".

Grid/scratch:
  grid = (B/bq, N/bn), semantics ("parallel", "arbitrary").
  scratch: run_vals (bq, K) f32, run_idx (bq, K) i32, persisted across the
  N axis; flushed to the output block on the last N step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import config
from repro.kernels.sort_network import bitonic_sort, merge_topk, next_pow2

# renamed across jax versions (TPUCompilerParams pre-0.5)
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(q_ref, v_ref, bias_ref, vals_out, idx_out, run_vals, run_idx,
            *, K: int, bn: int, n_tiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_vals[...] = jnp.full(run_vals.shape, jnp.inf, jnp.float32)
        run_idx[...] = jnp.full(run_idx.shape, -1, jnp.int32)

    q = q_ref[...].astype(jnp.float32)                    # (bq, d)
    v = v_ref[...].astype(jnp.float32)                    # (bn, d)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    vn = jnp.sum(v * v, axis=-1, keepdims=True)
    cross = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    d2 = qn - 2.0 * cross + vn.T                          # (bq, bn)
    d2 = d2 + bias_ref[...].astype(jnp.float32)           # predicate mask

    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    tile_v, tile_i = bitonic_sort(d2, gidx)               # ascending
    new_v, new_i = merge_topk(run_vals[...], run_idx[...],
                              tile_v[:, :K], tile_i[:, :K])
    run_vals[...] = new_v
    run_idx[...] = new_i

    @pl.when(j == n_tiles - 1)
    def _flush():
        vals_out[...] = run_vals[...]
        idx_out[...] = run_idx[...]


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn"))
def fused_topk(q, v, bias, k: int, *, bq: int = 128, bn: int = 128):
    """q: (B, d), v: (N, d), bias: (1, N) f32. B%bq == N%bn == 0, and the
    padded-k buffer K = next_pow2(k) must satisfy K <= bn.
    Returns (vals (B, K) f32 ascending, idx (B, K) i32); caller slices [:k].
    """
    if _CompilerParams is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; unsupported jax version")
    B, d = q.shape
    N, _ = v.shape
    K = next_pow2(max(k, 2))
    assert B % bq == 0 and N % bn == 0 and K <= bn, (B, N, k, K, bq, bn)
    n_tiles = N // bn
    grid = (B // bq, n_tiles)
    kern = functools.partial(_kernel, K=K, bn=bn, n_tiles=n_tiles)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, K), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, K), jnp.float32),
            pltpu.VMEM((bq, K), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=config.interpret(),
    )(q, v, bias)
    return vals, idx
