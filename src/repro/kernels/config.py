"""Kernel dispatch mode.

``auto``   — Pallas (compiled) on TPU, pure-jnp reference on CPU/GPU. This is
             the production default: the reference path *is* XLA-fused matmul
             code, so CPU test runs stay fast, while TPU runs hit the Pallas
             kernels.
``pallas`` — force Pallas. On non-TPU backends this uses ``interpret=True``,
             executing the kernel body op-by-op in Python — bit-accurate for
             validation, slow for large shapes. Kernel tests use this.
``ref``    — force the jnp oracle everywhere.
"""

from __future__ import annotations

import contextlib

import jax

_MODE = "auto"
_VALID = ("auto", "pallas", "ref")


def set_mode(mode: str) -> None:
    global _MODE
    if mode not in _VALID:
        raise ValueError(f"kernel mode {mode!r} not in {_VALID}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


def use_pallas() -> bool:
    """Resolve the current mode to a concrete pallas-or-ref decision."""
    if _MODE == "pallas":
        return True
    if _MODE == "ref":
        return False
    return jax.default_backend() == "tpu"


def interpret() -> bool:
    """Pallas interpret flag: interpret everywhere except real TPU."""
    return jax.default_backend() != "tpu"


@contextlib.contextmanager
def mode(m: str):
    prev = get_mode()
    set_mode(m)
    try:
        yield
    finally:
        set_mode(prev)
