"""Baseline RFANNS strategies (paper Section 2.2.3 + Section 6.1 methods).

- ``prefilter_search`` (paper's GPU-Pre): exact predicate scan, brute-force
  distances on survivors. Exact by construction; cost O(n·dim) per batch —
  the right tool at very low selectivity, a bandwidth disaster at high.
- ``postfilter_search`` (paper's CAGRA-Post): vanilla graph ANNS over a
  *global* CAGRA-style graph with an expanded candidate pool, predicate
  applied to the results only. Fast at selectivity ~1, recall collapses as
  the filter tightens.
- ``inline_filter_search``: global graph traversal that navigates through
  out-of-range nodes but only admits in-range ones to the result pool —
  the algorithmic core of the iRangeGraph/ACORN query paths (§2.2), here
  as the third comparison point.

All run on the same kernels as Garfield so the comparison isolates the
*index + traversal strategy*, matching the paper's experimental framing.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import graph as graph_mod
from repro.core.traversal import global_search
from repro.kernels import ops


@dataclasses.dataclass
class FlatBaseline:
    """Shared state for the baselines: raw data + one global graph."""

    vectors: np.ndarray            # (n, dim) f32
    attrs: np.ndarray              # (n, m) f32
    adj: np.ndarray | None = None  # (n, deg) i32 global CAGRA-style graph

    @classmethod
    def build(cls, vectors: np.ndarray, attrs: np.ndarray,
              degree: int = 16, with_graph: bool = True,
              exact_threshold: int = 16384, seed: int = 0):
        adj = None
        if with_graph:
            adj = graph_mod.build_cell_graph(
                vectors, degree, exact_threshold=exact_threshold, seed=seed)
        return cls(vectors=np.asarray(vectors, np.float32),
                   attrs=np.asarray(attrs, np.float32), adj=adj)

    def nbytes(self) -> dict:
        g = self.adj.nbytes if self.adj is not None else 0
        return {"graph_bytes": int(g), "vector_bytes": int(self.vectors.nbytes)}


# ---------------------------------------------------------------------------
# GPU-Pre: exact pre-filter + brute-force scan
# ---------------------------------------------------------------------------

def _predicate_bias(attrs, lo, hi):
    """(B, n) f32 additive bias: 0 where in-range, +inf where filtered."""
    ok = (attrs[None] >= lo[:, None, :]) & (attrs[None] <= hi[:, None, :])
    return jnp.where(ok.all(axis=2), 0.0, jnp.inf).astype(jnp.float32)


def prefilter_search(base: FlatBaseline, q: np.ndarray, lo: np.ndarray,
                     hi: np.ndarray, k: int, chunk: int = 65536):
    """Exact RFNNS. Streams the dataset in chunks through the fused
    distance+topk kernel with the predicate folded in as a bias row, then
    merges chunk winners — the brute-force strategy never builds an index.
    Returns (ids (B, k) i64, dists (B, k) f32), -1/inf padded."""
    n = base.vectors.shape[0]
    B = q.shape[0]
    qd = jnp.asarray(q)
    lod, hid = jnp.asarray(lo), jnp.asarray(hi)
    best_d = jnp.full((B, k), jnp.inf, jnp.float32)
    best_i = jnp.full((B, k), -1, jnp.int32)

    @jax.jit
    def fold(best_d, best_i, v, a, offset):
        bias = _predicate_bias(a, lod, hid)
        # bias applies per (query, point): fused kernel takes a shared (N,)
        # row, so compute the matrix path here (chunked => bounded memory).
        d2 = ops.pairwise_l2(qd, v) + bias
        vals, idx = jax.lax.top_k(-d2, min(k, v.shape[0]))
        vals, idx = -vals, idx + offset
        cd = jnp.concatenate([best_d, vals], axis=1)
        ci = jnp.concatenate([best_i, idx.astype(jnp.int32)], axis=1)
        neg, pos = jax.lax.top_k(-cd, k)
        return -neg, jnp.take_along_axis(ci, pos, axis=1)

    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        best_d, best_i = fold(best_d, best_i,
                              jnp.asarray(base.vectors[s:e]),
                              jnp.asarray(base.attrs[s:e]), s)
    ids = np.asarray(best_i, np.int64)
    d = np.asarray(best_d)
    ids[~np.isfinite(d)] = -1
    return ids, d


# ---------------------------------------------------------------------------
# CAGRA-Post: vanilla ANNS + post-filter
# ---------------------------------------------------------------------------

def postfilter_search(base: FlatBaseline, q: np.ndarray, lo: np.ndarray,
                      hi: np.ndarray, k: int, expand: int = 4,
                      ef: int = 64, max_iters: int = 256, seed: int = 0):
    """Vanilla graph ANNS for k' = expand*k candidates, then filter.

    The expansion factor is the paper's "retrieve substantial candidates"
    knob — the cost post-filtering pays to survive selective predicates."""
    assert base.adj is not None, "postfilter baseline needs the global graph"
    B, m = q.shape[0], base.attrs.shape[1]
    kk = expand * k
    no_lo = jnp.full((B, m), -jnp.inf, jnp.float32)
    no_hi = jnp.full((B, m), jnp.inf, jnp.float32)
    ids, d = global_search(
        jnp.asarray(base.vectors), jnp.asarray(base.attrs),
        jnp.asarray(base.adj), jnp.asarray(q), no_lo, no_hi,
        jax.random.PRNGKey(seed), k=kk, ef=max(ef, kk),
        entry_width=min(ef, 16), max_iters=max_iters)
    ids = np.asarray(ids, np.int64)
    d = np.asarray(d)
    # post-filter on the host (attrs lookup + range check)
    out_i = -np.ones((B, k), np.int64)
    out_d = np.full((B, k), np.inf, np.float32)
    for b in range(B):
        sel = ids[b][ids[b] >= 0]
        if len(sel) == 0:
            continue
        ok = ((base.attrs[sel] >= lo[b]) & (base.attrs[sel] <= hi[b])).all(1)
        keep = sel[ok][:k]
        out_i[b, :len(keep)] = keep
        out_d[b, :len(keep)] = d[b][ids[b] >= 0][ok][:k]
    return out_i, out_d


# ---------------------------------------------------------------------------
# inline filtering on a global graph (iRangeGraph/ACORN-style query path)
# ---------------------------------------------------------------------------

def inline_filter_search(base: FlatBaseline, q: np.ndarray, lo: np.ndarray,
                         hi: np.ndarray, k: int, ef: int = 64,
                         max_iters: int = 256, seed: int = 0):
    """Greedy traversal that navigates freely but admits only in-range
    nodes to the result pool (global_search already implements exactly
    this split between navigation beam and filtered results)."""
    assert base.adj is not None
    ids, d = global_search(
        jnp.asarray(base.vectors), jnp.asarray(base.attrs),
        jnp.asarray(base.adj), jnp.asarray(q), jnp.asarray(lo),
        jnp.asarray(hi), jax.random.PRNGKey(seed), k=k, ef=ef,
        entry_width=min(ef, 16), max_iters=max_iters)
    return np.asarray(ids, np.int64), np.asarray(d)
