"""End-to-end in-core query processing (paper Section 4, Alg. 2).

Internal layer: the public entry point is ``repro.api.Collection``, which
owns the index lifecycle (build/search/save/load), compiles named-attribute
filter expressions down to the dense ``(lo, hi)`` arrays consumed here,
and dispatches between the engine modes (in-core / hybrid-cached /
out-of-core) from a declared device-memory budget. Use ``Searcher``
directly only for engine-level ablations.

Engine-mode matrix (storage x graph residency x seeding) — this module
is the **incore** row; all three run on the same traversal core via
``repro.core.runtime.CellRuntime``:

  mode    | vector storage        | graph residency        | seeding
  --------+-----------------------+------------------------+--------------
  incore  | fp32 resident         | fully resident         | fresh beam
  hybrid  | int8 resident +rerank | LRU slot cache         | carried pool
  ooc     | int8 resident +rerank | streamed batch window  | carried pool

``Searcher`` is a thin orchestrator over the runtime: it owns the
adaptive three-way split per query batch —

  1. cell selection   — vectorized box intersection (select.py)
  2. cell ordering    — cluster-histogram cardinality vote (ordering.py)
  3. cell traversal   — sequential search-jump-search (traversal core)

plus the adaptive global path (Alg. 2 lines 5-8) for lanes whose selected
cell count exceeds S_thre and the exact dense-scan path for tiny
candidate sets. The split is decided host-side and the sub-batches run
as separate fixed-shape programs (pow2-padded by the runtime so jit
caches stay warm) — the TPU analogue of the paper's divergence-free
dispatch. Cross-cell candidate reuse (``SearchParams.pool_reuse``) lets
the in-range result pool propose inter-cell entries on every itinerary
hop, the same candidate recycling the streaming modes get from their
carried pool.

Batch-composition independence (serving contract, ISSUE 6): a query's
result depends only on (vector, box, knobs, ``params.seed``) — never on
which other queries share the batch or where it sits in it. The split is
per-row, each path's PRNG key is *folded by path id* (not drawn from an
order-dependent split sequence), the traversal core's entry randoms are
lane-position-independent, and the itinerary path always runs its result
pool at width ``max(k, entry_beam_l)`` so differing ``k``'s cannot change
which nodes ``pool_reuse`` hops from (results are then k-prefixes of one
deterministic (distance, id) order). The serving front-end's coalesced
widened pass is bit-identical to solo calls because of this contract
(ties between *distinct* points at exactly equal f32 distance remain the
documented exact-float caveat, as in ``runtime``'s rerank parity).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import runtime as rt_mod
from repro.core import select as select_mod
from repro.core.ordering import order_cells
from repro.core.runtime import merge_segment_topk  # noqa: F401  (re-export)
from repro.core.runtime import CellRuntime, pad_pow2
from repro.core.types import GMGIndex, SearchParams

# back-compat alias: callers historically imported the padding helper here
_pad_pow2 = pad_pow2


@dataclasses.dataclass
class Searcher:
    """Device-resident in-core search context for one built index."""

    index: GMGIndex

    def __post_init__(self):
        idx = self.index
        self.rt = CellRuntime(idx, storage="f32")
        # engine-level views (ablation benches poke these directly)
        self.vectors = self.rt.store.vectors
        self.attrs = self.rt.store.attrs
        self.cell_start = self.rt.cell_start_dev
        self.cell_lo = jnp.asarray(idx.cell_lo)
        self.cell_hi = jnp.asarray(idx.cell_hi)
        self.centroids = jnp.asarray(idx.centroids)
        self.hist = jnp.asarray(idx.hist)
        # per-call engine counters, snapshotted by Collection.search onto
        # QueryResult.stats (observability satellite, ISSUE 6)
        self.stats: dict = {}

    def refresh_index(self, index: GMGIndex) -> None:
        """Delete path (core.mutable): adopt a same-layout index whose
        attrs carry tombstone NaN masks — one attr re-upload, resident
        vectors/graph untouched."""
        self.index = index
        self.rt.refresh_index(index)
        self.attrs = self.rt.store.attrs

    # -- device half: one fixed-shape program per (B, knobs) ---------------

    def _traverse(self, q, lo, hi, params: SearchParams, key):
        """Itinerary path over the fully-resident graph. Takes numpy
        sub-batch arrays; pow2-pads once so selection, ordering and the
        traversal core all see the same stable shape."""
        cfg = self.index.config
        ef = params.ef or cfg.search_ef
        qp, real = pad_pow2(np.asarray(q, np.float32))
        lop, _ = pad_pow2(np.asarray(lo, np.float32))
        hip, _ = pad_pow2(np.asarray(hi, np.float32))
        qd, lod, hid = jnp.asarray(qp), jnp.asarray(lop), jnp.asarray(hip)
        mask = select_mod.select_cells(lod, hid, self.cell_lo, self.cell_hi)
        T = self.index.n_cells if params.max_cells is None \
            else min(params.max_cells, self.index.n_cells)
        if params.use_ordering:
            order, _ = order_cells(qd, self.centroids, self.hist, mask,
                                   top_m=cfg.top_m_clusters, T=T)
        else:  # ablation Fig 13(b): grid order
            S = mask.shape[1]
            ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   mask.shape)
            srt = jnp.where(mask, ids, S + 1)
            order = jnp.sort(srt, axis=1)[:, :T].astype(jnp.int32)
            order = jnp.where(order <= S - 1, order, -1)
        # k-prefix contract (serving, ISSUE 6): the result pool doubles as
        # the pool_reuse hop source (top entry_beam_l rows), so its width
        # must not depend on the caller's k or coalescing requests with
        # heterogeneous k's would perturb each other's walks. Run at
        # max(k, entry_beam_l) and slice: the first k columns of the wider
        # pool are exactly the k the narrower run would return.
        k_run = max(params.k, cfg.entry_beam_l)
        ids, d = self.rt.run(
            self.rt.resident_graph(), qp, lop, hip, key,
            k=k_run, ef=ef, cell_order=order,
            use_inter=params.use_inter_edges,
            pool_reuse=params.pool_reuse)
        return ids[:real, :params.k], d[:real, :params.k]

    def _global(self, q, lo, hi, params: SearchParams, key):
        """Adaptive high-selectivity path: one greedy traversal over the
        whole graph, predicate enforced on the result pool only."""
        cfg = self.index.config
        ef = params.ef or cfg.search_ef
        return self.rt.run(
            self.rt.global_graph(), q, lo, hi, key,
            k=params.k, ef=ef, cell_order=None, seeds=None,
            entry_random=0, entry_beam_l=0,
            max_iters=cfg.max_iters_per_cell * 4)

    def _dense_scan(self, q, lo, hi, inc, k: int):
        """Exact MXU scan over the selected cells (adaptive low-candidate
        path). For each cell, the sub-batch of queries selecting it scans
        the cell's contiguous rows with the predicate folded in as +inf
        bias; winners merge on the host. Exact within the selected cells.
        Returns (ids (B, k) internal, d (B, k))."""
        from repro.kernels import ops
        B = q.shape[0]
        out_i = np.full((B, k), -1, np.int32)
        out_d = np.full((B, k), np.inf, np.float32)
        starts = self.index.cell_start

        @functools.partial(jax.jit, static_argnames=("s", "e", "kk"))
        def scan_cell(qs, los, his, s: int, e: int, kk: int):
            vcell = jax.lax.slice_in_dim(self.vectors, s, e)
            acell = jax.lax.slice_in_dim(self.attrs, s, e)
            d2 = ops.pairwise_l2(qs, vcell)
            ok = (acell[None] >= los[:, None, :]) & \
                 (acell[None] <= his[:, None, :])
            d2 = jnp.where(ok.all(axis=2), d2, jnp.inf)
            neg, pos = jax.lax.top_k(-d2, kk)
            return -neg, pos + s

        for c in range(self.index.n_cells):
            rows = np.nonzero(inc[:, c])[0]
            if len(rows) == 0:
                continue
            s, e = int(starts[c]), int(starts[c + 1])
            if e <= s:
                continue
            qs, real = pad_pow2(q[rows])
            los, _ = pad_pow2(lo[rows])
            his, _ = pad_pow2(hi[rows])
            kk = min(k, e - s)
            d_c, i_c = scan_cell(jnp.asarray(qs), jnp.asarray(los),
                                 jnp.asarray(his), s, e, kk)
            d_c = np.asarray(d_c[:real])
            i_c = np.asarray(i_c[:real], np.int32)
            md = np.concatenate([out_d[rows], d_c], axis=1)
            mi = np.concatenate([out_i[rows], i_c], axis=1)
            ordr = np.argsort(md, axis=1, kind="stable")[:, :k]
            out_d[rows] = np.take_along_axis(md, ordr, axis=1)
            out_i[rows] = np.take_along_axis(mi, ordr, axis=1)
        out_i[~np.isfinite(out_d)] = -1
        return out_i, out_d

    def _estimate_selectivity(self, lo, hi):
        """(B,) product of per-attribute selectivities from the stored
        empirical CDF grids (the conjunction-independence estimate)."""
        qgrid = self.index.attr_quantiles        # (m, n_grid)
        ng = qgrid.shape[1] - 1
        est = np.ones(lo.shape[0], np.float64)
        for j in range(qgrid.shape[0]):
            cdf_lo = np.searchsorted(qgrid[j], lo[:, j], side="left") / ng
            cdf_hi = np.searchsorted(qgrid[j], hi[:, j], side="right") / ng
            est *= np.clip(cdf_hi - cdf_lo, 0.0, 1.0)
        return est

    # -- host half: adaptive split + id mapping ----------------------------

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None,
               route_k: Optional[np.ndarray] = None):
        """Returns (ids (B, k) i64 original ids [-1 pad], dists (B, k)).

        With ``qmap`` (a (B,) row -> original-query segment map from a
        disjunctive plan), rows are per-box sub-queries: the widened
        batch still runs as one adaptive pass, and per-box candidates
        fold back to (n_queries, k) via :func:`merge_segment_topk`.

        ``route_k`` ((B,) int, default ``params.k`` everywhere) is the
        per-row k the adaptive *path split* should assume. The serving
        front-end coalesces requests with heterogeneous k's into one
        pass at k = max over requests; handing each row its own
        request's k here keeps the dense/itinerary routing decision —
        the one k-sensitive branch — identical to what the request's
        solo call would have picked, preserving exact-id parity.
        """
        params = params or SearchParams()
        q = np.asarray(q, np.float32)
        lo = np.asarray(lo, np.float32)
        hi = np.asarray(hi, np.float32)
        B = q.shape[0]
        if qmap is not None:
            qmap = rt_mod.check_qmap(qmap, B)
            if n_queries is None:
                # inferring from qmap.max() would silently drop trailing
                # queries whose boxes were all pruned by the planner
                raise ValueError("n_queries is required with qmap")
        t0 = time.perf_counter()
        self.stats = {"engine": "incore", "n_rows": int(B),
                      "n_dense": 0, "n_global": 0, "n_itinerary": 0}
        if B == 0:
            nq = n_queries if qmap is not None else 0
            self.stats["wall_seconds"] = time.perf_counter() - t0
            return rt_mod.empty_topk(nq, params.k)
        base_key = jax.random.PRNGKey(params.seed)

        cfg = self.index.config
        inc = select_mod.incidence_numpy(lo, hi, self.index.cell_lo,
                                         self.index.cell_hi)
        sizes = np.diff(self.index.cell_start)
        cand_rows = inc @ sizes                 # rows inside selected cells
        if params.adaptive_global:
            use_global = inc.sum(axis=1) > cfg.s_thre
        else:
            use_global = np.zeros(B, bool)
        # adaptive dense path (Alg. 2 extended; DESIGN.md §2): tiny
        # candidate sets are cheaper as one exact MXU pass than any walk.
        use_dense = (cand_rows <= cfg.dense_threshold) \
            if cfg.dense_threshold else np.zeros(B, bool)
        # selectivity-aware extension (beyond paper, §Perf G2): a query
        # whose *conjunction* over all m attributes is estimated to leave
        # very few in-range rows starves graph traversal — scan instead,
        # regardless of how many grid cells its partitioned dims span.
        if cfg.dense_threshold and self.index.attr_quantiles is not None:
            est = self._estimate_selectivity(lo, hi)
            est_rows = est * self.index.n
            rk = (np.full(B, params.k, np.int64) if route_k is None
                  else np.asarray(route_k, np.int64))
            if rk.shape != (B,):
                raise ValueError(f"route_k shape {rk.shape} != ({B},)")
            use_dense |= ((est_rows <= np.maximum(8 * rk, 64))
                          & (cand_rows <= 16 * cfg.dense_threshold))
        use_dense &= cand_rows > 0
        use_global &= ~use_dense

        out_i = np.full((B, params.k), -1, np.int64)
        out_d = np.full((B, params.k), np.inf, np.float32)

        dense_rows = np.nonzero(use_dense)[0]
        if len(dense_rows) > 0:
            ids, d = self._dense_scan(q[dense_rows], lo[dense_rows],
                                      hi[dense_rows], inc[dense_rows],
                                      params.k)
            orig = np.where(ids >= 0, self.index.perm[np.maximum(ids, 0)], -1)
            out_i[dense_rows] = orig
            out_d[dense_rows] = d
        self.stats["n_dense"] = int(len(dense_rows))

        for path_idx, (flag, fn, stat) in enumerate(
                ((False, self._traverse, "n_itinerary"),
                 (True, self._global, "n_global"))):
            sel = np.nonzero((use_global == flag) & ~use_dense)[0]
            self.stats[stat] = int(len(sel))
            if len(sel) == 0:
                continue
            # independent entry randomization per path, keyed by *path
            # identity* (fold_in) rather than an order-dependent split
            # chain: a query's key must not change when the other path's
            # sub-batch happens to be empty (batch-composition contract)
            sub = jax.random.fold_in(base_key, path_idx)
            ids, d = fn(q[sel], lo[sel], hi[sel], params, sub)
            orig = np.where(ids >= 0, self.index.perm[np.maximum(ids, 0)], -1)
            out_i[sel] = orig
            out_d[sel] = d
        self.stats["wall_seconds"] = time.perf_counter() - t0
        if qmap is not None:
            return merge_segment_topk(out_i, out_d, qmap, n_queries,
                                      params.k)
        return out_i, out_d


def ground_truth(vectors: np.ndarray, attrs: np.ndarray, q: np.ndarray,
                 lo: np.ndarray, hi: np.ndarray, k: int,
                 chunk: int = 65536):
    """Exact RFNNS answer set for recall measurement (original ids)."""
    from repro.core.baselines import FlatBaseline, prefilter_search
    base = FlatBaseline(vectors=np.asarray(vectors, np.float32),
                        attrs=np.asarray(attrs, np.float32))
    return prefilter_search(base, q, lo, hi, k, chunk=chunk)


def recall_at_k(result_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |result ∩ truth| / |truth| over queries (paper's Recall)."""
    total, hit = 0, 0
    for r, t in zip(result_ids, true_ids):
        t = set(int(x) for x in t if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in r if x >= 0)
        hit += len(r & t)
        total += len(t)
    return hit / max(total, 1)
