"""End-to-end in-core query processing (paper Section 4, Alg. 2).

Internal layer: the public entry point is ``repro.api.Collection``, which
owns the index lifecycle (build/search/save/load), compiles named-attribute
filter expressions down to the dense ``(lo, hi)`` arrays consumed here,
and dispatches between this in-core engine and the out-of-core pipeline
from a declared device-memory budget. Use ``Searcher`` directly only for
engine-level ablations.

``Searcher`` owns the device-resident copies of a built GMG index and runs
the three-stage pipeline per query batch:

  1. cell selection   — vectorized box intersection (select.py)
  2. cell ordering    — cluster-histogram cardinality vote (ordering.py)
  3. cell traversal   — sequential search-jump-search (traversal.py)

plus the adaptive global path (Alg. 2 lines 5-8) for lanes whose selected
cell count exceeds S_thre: those queries skip the itinerary and run one
greedy traversal over the global graph (intra ++ inter edges), with the
predicate enforced on the result pool. The split is decided host-side and
the two sub-batches run as separate fixed-shape programs (pow2-padded so
jit caches stay warm) — the TPU analogue of the paper's divergence-free
dispatch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gmg as gmg_mod
from repro.core import select as select_mod
from repro.core.ordering import order_cells
from repro.core.traversal import global_search, multi_cell_search
from repro.core.types import GMGIndex, SearchParams


def _pad_pow2(x: np.ndarray, axis: int = 0):
    """Pad axis 0 to the next power of two by repeating row 0."""
    n = x.shape[axis]
    if n == 0:
        raise ValueError(
            "cannot pad an empty batch (callers must early-return on B=0)")
    p = 1
    while p < n:
        p *= 2
    if p == n:
        return x, n
    reps = np.repeat(x[:1], p - n, axis=0)
    return np.concatenate([x, reps], axis=0), n


@dataclasses.dataclass
class Searcher:
    """Device-resident search context for one built index."""

    index: GMGIndex

    def __post_init__(self):
        idx = self.index
        self.vectors = jnp.asarray(idx.vectors)
        self.attrs = jnp.asarray(idx.attrs)
        self.intra = jnp.asarray(idx.intra_adj)
        self.inter = jnp.asarray(idx.inter_adj)
        self.cell_start = jnp.asarray(idx.cell_start)
        self.cell_lo = jnp.asarray(idx.cell_lo)
        self.cell_hi = jnp.asarray(idx.cell_hi)
        self.centroids = jnp.asarray(idx.centroids)
        self.hist = jnp.asarray(idx.hist)
        self.global_adj = jnp.asarray(gmg_mod.global_adjacency(idx))

    # -- device half: one fixed-shape program per (B, knobs) ---------------

    def _traverse(self, q, lo, hi, params: SearchParams, key):
        cfg = self.index.config
        ef = params.ef or cfg.search_ef
        mask = select_mod.select_cells(lo, hi, self.cell_lo, self.cell_hi)
        T = self.index.n_cells if params.max_cells is None \
            else min(params.max_cells, self.index.n_cells)
        if params.use_ordering:
            order, _ = order_cells(q, self.centroids, self.hist, mask,
                                   top_m=cfg.top_m_clusters, T=T)
        else:  # ablation Fig 13(b): grid order
            S = mask.shape[1]
            ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   mask.shape)
            srt = jnp.where(mask, ids, S + 1)
            order = jnp.sort(srt, axis=1)[:, :T].astype(jnp.int32)
            order = jnp.where(order <= S - 1, order, -1)
        return multi_cell_search(
            self.vectors, self.attrs, self.intra, self.inter,
            self.cell_start, q, lo, hi, order, key,
            k=params.k, ef=ef, entry_width=cfg.entry_width,
            entry_random=cfg.entry_random, entry_beam_l=cfg.entry_beam_l,
            max_iters=cfg.max_iters_per_cell,
            use_inter=params.use_inter_edges)

    def _global(self, q, lo, hi, params: SearchParams, key):
        cfg = self.index.config
        ef = params.ef or cfg.search_ef
        return global_search(
            self.vectors, self.attrs, self.global_adj, q, lo, hi, key,
            k=params.k, ef=ef, entry_width=cfg.entry_width,
            max_iters=cfg.max_iters_per_cell * 4)

    def _dense_scan(self, q, lo, hi, inc, k: int):
        """Exact MXU scan over the selected cells (adaptive low-candidate
        path). For each cell, the sub-batch of queries selecting it scans
        the cell's contiguous rows with the predicate folded in as +inf
        bias; winners merge on the host. Exact within the selected cells.
        Returns (ids (B, k) internal, d (B, k))."""
        import jax.numpy as jnp
        from repro.kernels import ops
        B = q.shape[0]
        out_i = np.full((B, k), -1, np.int32)
        out_d = np.full((B, k), np.inf, np.float32)
        starts = self.index.cell_start

        @functools.partial(jax.jit, static_argnames=("s", "e", "kk"))
        def scan_cell(qs, los, his, s: int, e: int, kk: int):
            vcell = jax.lax.slice_in_dim(self.vectors, s, e)
            acell = jax.lax.slice_in_dim(self.attrs, s, e)
            d2 = ops.pairwise_l2(qs, vcell)
            ok = (acell[None] >= los[:, None, :]) & \
                 (acell[None] <= his[:, None, :])
            d2 = jnp.where(ok.all(axis=2), d2, jnp.inf)
            neg, pos = jax.lax.top_k(-d2, kk)
            return -neg, pos + s

        for c in range(self.index.n_cells):
            rows = np.nonzero(inc[:, c])[0]
            if len(rows) == 0:
                continue
            s, e = int(starts[c]), int(starts[c + 1])
            if e <= s:
                continue
            qs, real = _pad_pow2(q[rows])
            los, _ = _pad_pow2(lo[rows])
            his, _ = _pad_pow2(hi[rows])
            kk = min(k, e - s)
            d_c, i_c = scan_cell(jnp.asarray(qs), jnp.asarray(los),
                                 jnp.asarray(his), s, e, kk)
            d_c = np.asarray(d_c[:real])
            i_c = np.asarray(i_c[:real], np.int32)
            md = np.concatenate([out_d[rows], d_c], axis=1)
            mi = np.concatenate([out_i[rows], i_c], axis=1)
            ordr = np.argsort(md, axis=1)[:, :k]
            out_d[rows] = np.take_along_axis(md, ordr, axis=1)
            out_i[rows] = np.take_along_axis(mi, ordr, axis=1)
        out_i[~np.isfinite(out_d)] = -1
        return out_i, out_d

    def _estimate_selectivity(self, lo, hi):
        """(B,) product of per-attribute selectivities from the stored
        empirical CDF grids (the conjunction-independence estimate)."""
        qgrid = self.index.attr_quantiles        # (m, n_grid)
        ng = qgrid.shape[1] - 1
        est = np.ones(lo.shape[0], np.float64)
        for j in range(qgrid.shape[0]):
            cdf_lo = np.searchsorted(qgrid[j], lo[:, j], side="left") / ng
            cdf_hi = np.searchsorted(qgrid[j], hi[:, j], side="right") / ng
            est *= np.clip(cdf_hi - cdf_lo, 0.0, 1.0)
        return est

    # -- host half: adaptive split + id mapping ----------------------------

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None):
        """Returns (ids (B, k) i64 original ids [-1 pad], dists (B, k)).

        With ``qmap`` (a (B,) row -> original-query segment map from a
        disjunctive plan), rows are per-box sub-queries: the widened
        batch still runs as one adaptive pass, and per-box candidates
        fold back to (n_queries, k) via :func:`merge_segment_topk`.
        """
        params = params or SearchParams()
        q = np.asarray(q, np.float32)
        lo = np.asarray(lo, np.float32)
        hi = np.asarray(hi, np.float32)
        B = q.shape[0]
        if qmap is not None:
            qmap = np.asarray(qmap, np.int64)
            if qmap.shape != (B,):
                raise ValueError(
                    f"qmap shape {qmap.shape} != batch ({B},)")
            if n_queries is None:
                # inferring from qmap.max() would silently drop trailing
                # queries whose boxes were all pruned by the planner
                raise ValueError("n_queries is required with qmap")
        if B == 0:
            nq = n_queries if qmap is not None else 0
            return (np.full((nq, params.k), -1, np.int64),
                    np.full((nq, params.k), np.inf, np.float32))
        key = jax.random.PRNGKey(params.seed)

        cfg = self.index.config
        inc = select_mod.incidence_numpy(lo, hi, self.index.cell_lo,
                                         self.index.cell_hi)
        sizes = np.diff(self.index.cell_start)
        cand_rows = inc @ sizes                 # rows inside selected cells
        if params.adaptive_global:
            use_global = inc.sum(axis=1) > cfg.s_thre
        else:
            use_global = np.zeros(B, bool)
        # adaptive dense path (Alg. 2 extended; DESIGN.md §2): tiny
        # candidate sets are cheaper as one exact MXU pass than any walk.
        use_dense = (cand_rows <= cfg.dense_threshold) \
            if cfg.dense_threshold else np.zeros(B, bool)
        # selectivity-aware extension (beyond paper, §Perf G2): a query
        # whose *conjunction* over all m attributes is estimated to leave
        # very few in-range rows starves graph traversal — scan instead,
        # regardless of how many grid cells its partitioned dims span.
        if cfg.dense_threshold and self.index.attr_quantiles is not None:
            est = self._estimate_selectivity(lo, hi)
            est_rows = est * self.index.n
            use_dense |= ((est_rows <= max(8 * params.k, 64))
                          & (cand_rows <= 16 * cfg.dense_threshold))
        use_dense &= cand_rows > 0
        use_global &= ~use_dense

        out_i = np.full((B, params.k), -1, np.int64)
        out_d = np.full((B, params.k), np.inf, np.float32)

        dense_rows = np.nonzero(use_dense)[0]
        if len(dense_rows) > 0:
            ids, d = self._dense_scan(q[dense_rows], lo[dense_rows],
                                      hi[dense_rows], inc[dense_rows],
                                      params.k)
            orig = np.where(ids >= 0, self.index.perm[np.maximum(ids, 0)], -1)
            out_i[dense_rows] = orig
            out_d[dense_rows] = d

        for flag, fn in ((False, self._traverse), (True, self._global)):
            sel = np.nonzero((use_global == flag) & ~use_dense)[0]
            if len(sel) == 0:
                continue
            qs, real = _pad_pow2(q[sel])
            los, _ = _pad_pow2(lo[sel])
            his, _ = _pad_pow2(hi[sel])
            # independent entry randomization per sub-batch: sharing one
            # key would correlate the itinerary and global walks
            key, sub = jax.random.split(key)
            ids, d = fn(jnp.asarray(qs), jnp.asarray(los), jnp.asarray(his),
                        params, sub)
            ids = np.asarray(ids[:real])
            d = np.asarray(d[:real])
            orig = np.where(ids >= 0, self.index.perm[np.maximum(ids, 0)], -1)
            out_i[sel] = orig
            out_d[sel] = d
        if qmap is not None:
            return merge_segment_topk(out_i, out_d, qmap, n_queries,
                                      params.k)
        return out_i, out_d


def merge_segment_topk(ids: np.ndarray, dists: np.ndarray,
                       qmap: np.ndarray, n_queries: int, k: int):
    """Fold per-box candidate rows back into per-query top-k.

    ``ids`` (T, kk) with -1 pads and ``dists`` (T, kk) with +inf pads are
    per-box results; ``qmap`` (T,) maps each row to its original query.
    Returns ((n_queries, k) i64 ids, (n_queries, k) f32 dists).

    Deterministic by construction: duplicate ids within a query (a point
    matching several boxes) collapse to their best distance, candidates
    order by (distance, id) so distance ties break toward the smaller
    id, and queries with no boxes/candidates come back fully padded.
    """
    ids = np.asarray(ids)
    dists = np.asarray(dists)
    out_i = np.full((n_queries, k), -1, np.int64)
    out_d = np.full((n_queries, k), np.inf, np.float32)
    if ids.size == 0:
        return out_i, out_d
    T, kk = ids.shape
    fq = np.repeat(np.asarray(qmap, np.int64), kk)
    fi = ids.ravel().astype(np.int64)
    fd = dists.ravel().astype(np.float32)
    valid = fi >= 0
    fi, fd, fq = fi[valid], fd[valid], fq[valid]
    if fi.size == 0:
        return out_i, out_d
    # dedup: sort by (query, id, dist), keep each (query, id)'s best dist
    o = np.lexsort((fd, fi, fq))
    fi, fd, fq = fi[o], fd[o], fq[o]
    first = np.ones(fi.shape[0], bool)
    first[1:] = (fq[1:] != fq[:-1]) | (fi[1:] != fi[:-1])
    fi, fd, fq = fi[first], fd[first], fq[first]
    # rank survivors by (query, dist, id) and take each query's first k
    o = np.lexsort((fi, fd, fq))
    fi, fd, fq = fi[o], fd[o], fq[o]
    starts = np.searchsorted(fq, np.arange(n_queries))
    rank = np.arange(fq.shape[0]) - starts[fq]
    keep = rank < k
    out_i[fq[keep], rank[keep]] = fi[keep]
    out_d[fq[keep], rank[keep]] = fd[keep]
    return out_i, out_d


def ground_truth(vectors: np.ndarray, attrs: np.ndarray, q: np.ndarray,
                 lo: np.ndarray, hi: np.ndarray, k: int,
                 chunk: int = 65536):
    """Exact RFNNS answer set for recall measurement (original ids)."""
    from repro.core.baselines import FlatBaseline, prefilter_search
    base = FlatBaseline(vectors=np.asarray(vectors, np.float32),
                        attrs=np.asarray(attrs, np.float32))
    return prefilter_search(base, q, lo, hi, k, chunk=chunk)


def recall_at_k(result_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |result ∩ truth| / |truth| over queries (paper's Recall)."""
    total, hit = 0, 0
    for r, t in zip(result_ids, true_ids):
        t = set(int(x) for x in t if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in r if x >= 0)
        hit += len(r & t)
        total += len(t)
    return hit / max(total, 1)
