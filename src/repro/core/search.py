"""End-to-end in-core query processing (paper Section 4, Alg. 2).

Internal layer: the public entry point is ``repro.api.Collection``, which
owns the index lifecycle (build/search/save/load), compiles named-attribute
filter expressions down to the dense ``(lo, hi)`` arrays consumed here,
and dispatches between the engine modes (in-core / hybrid-cached /
out-of-core) from a declared device-memory budget. Use ``Searcher``
directly only for engine-level ablations.

Engine-mode matrix (storage x graph residency x seeding) — this module
is the **incore** row; all three run on the same traversal core via
``repro.core.runtime.CellRuntime``:

  mode    | vector storage        | graph residency        | seeding
  --------+-----------------------+------------------------+--------------
  incore  | fp32 resident         | fully resident         | fresh beam
  hybrid  | int8 resident +rerank | LRU slot cache         | carried pool
  ooc     | int8 resident +rerank | streamed batch window  | carried pool

``Searcher`` is a thin orchestrator over the runtime: it owns the
adaptive three-way split per query batch —

  1. cell selection   — vectorized box intersection (select.py)
  2. cell ordering    — cluster-histogram cardinality vote (ordering.py)
  3. cell traversal   — sequential search-jump-search (traversal core)

plus the adaptive global path (Alg. 2 lines 5-8) for lanes whose selected
cell count exceeds S_thre and the exact dense-scan path for tiny
candidate sets. The split is decided host-side and the sub-batches run
as separate fixed-shape programs (pow2-padded by the runtime so jit
caches stay warm) — the TPU analogue of the paper's divergence-free
dispatch. Cross-cell candidate reuse (``SearchParams.pool_reuse``) lets
the in-range result pool propose inter-cell entries on every itinerary
hop, the same candidate recycling the streaming modes get from their
carried pool.

Batch-composition independence (serving contract, ISSUE 6): a query's
result depends only on (vector, box, knobs, ``params.seed``) — never on
which other queries share the batch or where it sits in it. The split is
per-row, each path's PRNG key is *folded by path id* (not drawn from an
order-dependent split sequence), the traversal core's entry randoms are
lane-position-independent, and the itinerary path always runs its result
pool at width ``max(k, entry_beam_l)`` so differing ``k``'s cannot change
which nodes ``pool_reuse`` hops from (results are then k-prefixes of one
deterministic (distance, id) order). The serving front-end's coalesced
widened pass is bit-identical to solo calls because of this contract
(ties between *distinct* points at exactly equal f32 distance remain the
documented exact-float caveat, as in ``runtime``'s rerank parity).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import runtime as rt_mod
from repro.core import select as select_mod
from repro.core import selectivity as sel_mod
from repro.core.ordering import order_cells
from repro.core.runtime import merge_segment_topk  # noqa: F401  (re-export)
from repro.core.runtime import CellRuntime, pad_pow2
from repro.core.types import GMGIndex, SearchParams
from repro.obs.metrics import MetricsRegistry, PassMetrics
from repro.obs.trace import span

# back-compat alias: callers historically imported the padding helper here
_pad_pow2 = pad_pow2


@dataclasses.dataclass
class Searcher:
    """Device-resident in-core search context for one built index."""

    index: GMGIndex

    def __post_init__(self):
        idx = self.index
        self.rt = CellRuntime(idx, storage="f32")
        # engine-level views (ablation benches poke these directly)
        self.vectors = self.rt.store.vectors
        self.attrs = self.rt.store.attrs
        self.cell_start = self.rt.cell_start_dev
        self.cell_lo = jnp.asarray(idx.cell_lo)
        self.cell_hi = jnp.asarray(idx.cell_hi)
        self.centroids = jnp.asarray(idx.centroids)
        self.hist = jnp.asarray(idx.hist)
        # per-call engine counters, snapshotted by Collection.search onto
        # QueryResult.stats (observability satellite, ISSUE 6)
        self.stats: dict = {}
        # per-engine obs registry: per-pass stats dicts are views over
        # increments into it (PassMetrics, ISSUE 10)
        self.metrics = MetricsRegistry()

    def refresh_index(self, index: GMGIndex) -> None:
        """Delete path (core.mutable): adopt a same-layout index whose
        attrs carry tombstone NaN masks — one attr re-upload, resident
        vectors/graph untouched."""
        self.index = index
        self.rt.refresh_index(index)
        self.attrs = self.rt.store.attrs

    # -- device half: one fixed-shape program per (B, knobs) ---------------

    def _traverse(self, q, lo, hi, params: SearchParams, key,
                  ef_mult: int = 1):
        """Itinerary path over the fully-resident graph. Takes numpy
        sub-batch arrays; pow2-pads once so selection, ordering and the
        traversal core all see the same stable shape. ``ef_mult`` is the
        cost model's mid-range effort factor: it widens the candidate
        pool and the entry beam together (range-aware effort instead of
        a fixed ef; see docs/tuning.md)."""
        cfg = self.index.config
        ef = (params.ef or cfg.search_ef) * ef_mult
        beam = cfg.entry_beam_l if ef_mult == 1 \
            else min(cfg.entry_beam_l * ef_mult, ef)
        qp, real = pad_pow2(np.asarray(q, np.float32))
        lop, _ = pad_pow2(np.asarray(lo, np.float32))
        hip, _ = pad_pow2(np.asarray(hi, np.float32))
        qd, lod, hid = jnp.asarray(qp), jnp.asarray(lop), jnp.asarray(hip)
        mask = select_mod.select_cells(lod, hid, self.cell_lo, self.cell_hi)
        T = self.index.n_cells if params.max_cells is None \
            else min(params.max_cells, self.index.n_cells)
        if params.use_ordering:
            order, _ = order_cells(qd, self.centroids, self.hist, mask,
                                   top_m=cfg.top_m_clusters, T=T)
        else:  # ablation Fig 13(b): grid order
            S = mask.shape[1]
            ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   mask.shape)
            srt = jnp.where(mask, ids, S + 1)
            order = jnp.sort(srt, axis=1)[:, :T].astype(jnp.int32)
            order = jnp.where(order <= S - 1, order, -1)
        # k-prefix contract (serving, ISSUE 6): the result pool doubles as
        # the pool_reuse hop source (top entry_beam_l rows), so its width
        # must not depend on the caller's k or coalescing requests with
        # heterogeneous k's would perturb each other's walks. Run at
        # max(k, beam) and slice: the first k columns of the wider
        # pool are exactly the k the narrower run would return. (ef_mult
        # is route-derived per row, so the width stays batch-independent.)
        k_run = max(params.k, beam)
        ids, d = self.rt.run(
            self.rt.resident_graph(), qp, lop, hip, key,
            k=k_run, ef=ef, cell_order=order,
            entry_beam_l=beam,
            use_inter=params.use_inter_edges,
            pool_reuse=params.pool_reuse)
        return ids[:real, :params.k], d[:real, :params.k]

    def _global(self, q, lo, hi, params: SearchParams, key,
                ef_mult: int = 1):
        """Adaptive high-selectivity path: one greedy traversal over the
        whole graph, predicate enforced on the result pool only."""
        cfg = self.index.config
        ef = (params.ef or cfg.search_ef) * ef_mult
        return self.rt.run(
            self.rt.global_graph(), q, lo, hi, key,
            k=params.k, ef=ef, cell_order=None, seeds=None,
            entry_random=0, entry_beam_l=0,
            max_iters=cfg.max_iters_per_cell * 4)

    def _dense_scan(self, q, lo, hi, inc, k: int):
        """Dense route: fused gather->predicate->distance->k-select scan
        over the selected cells' rows (``runtime.masked_dense_scan`` on
        the resident f32 table — exact within the selected cells).
        Returns (ids (B, k) internal, d (B, k)); also stashes the exact
        qualifying counts for the estimator-error stat."""
        ids, d, n_qual = rt_mod.masked_dense_scan(
            self.rt, q, lo, hi, inc, k)
        self._last_dense_qual = n_qual
        return ids, d

    def _estimate_selectivity(self, lo, hi):
        """(B,) clamped product of per-attribute selectivities from the
        stored empirical CDF grids (the conjunction-independence
        estimate). Thin wrapper over the public
        :func:`repro.core.selectivity.estimate_selectivity`."""
        return sel_mod.estimate_selectivity(self.index, lo, hi)

    # -- host half: adaptive split + id mapping ----------------------------

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None,
               route_k: Optional[np.ndarray] = None,
               routes: Optional[sel_mod.RouteDecision] = None):
        """Returns (ids (B, k) i64 original ids [-1 pad], dists (B, k)).

        With ``qmap`` (a (B,) row -> original-query segment map from a
        disjunctive plan), rows are per-box sub-queries: the widened
        batch still runs as one adaptive pass, and per-box candidates
        fold back to (n_queries, k) via :func:`merge_segment_topk`.

        ``route_k`` ((B,) int, default ``params.k`` everywhere) is the
        per-row k the cost model's *route split* should assume. The
        serving front-end coalesces requests with heterogeneous k's
        into one pass at k = max over requests; handing each row its
        own request's k here keeps the dense/itinerary routing decision
        — the one k-sensitive branch — identical to what the request's
        solo call would have picked, preserving exact-id parity.

        ``routes`` is a precomputed per-box
        :class:`~repro.core.selectivity.RouteDecision` (the Collection
        passes the planner's histogram-refined one); None computes it
        here from the global CDF product and ``params.cost``. Routing
        is per-row and estimate-driven, so it never breaks the
        batch-composition contract.
        """
        params = params or SearchParams()
        q = np.asarray(q, np.float32)
        lo = np.asarray(lo, np.float32)
        hi = np.asarray(hi, np.float32)
        B = q.shape[0]
        if qmap is not None:
            qmap = rt_mod.check_qmap(qmap, B)
            if n_queries is None:
                # inferring from qmap.max() would silently drop trailing
                # queries whose boxes were all pruned by the planner
                raise ValueError("n_queries is required with qmap")
        t0 = time.perf_counter()
        # pass stats are a view over obs-registry increments (ISSUE 10):
        # every numeric lands in self.metrics through the same call that
        # writes the dict entry
        pm = PassMetrics(self.metrics, static={"engine": "incore"})
        pm.count("n_rows", int(B))
        for name in ("n_dense", "n_mid", "n_broad", "n_global",
                     "n_itinerary"):
            pm.count(name, 0)
        self.stats = pm.stats()
        if B == 0:
            nq = n_queries if qmap is not None else 0
            pm.set("wall_seconds", time.perf_counter() - t0)
            return rt_mod.empty_topk(nq, params.k)
        base_key = jax.random.PRNGKey(params.seed)

        cfg = self.index.config
        inc = select_mod.incidence_numpy(lo, hi, self.index.cell_lo,
                                         self.index.cell_hi)
        if routes is None:
            rk = (np.full(B, params.k, np.int64) if route_k is None
                  else np.asarray(route_k, np.int64))
            routes = sel_mod.route_boxes(self.index, lo, hi, rk,
                                         cost=params.cost, inc=inc)
        use_dense = routes.route == sel_mod.ROUTE_DENSE
        if params.adaptive_global:
            use_global = inc.sum(axis=1) > cfg.s_thre
        else:
            use_global = np.zeros(B, bool)
        use_global &= ~use_dense
        pm.update_counts(routes.counts())

        out_i = np.full((B, params.k), -1, np.int64)
        out_d = np.full((B, params.k), np.inf, np.float32)

        dense_rows = np.nonzero(use_dense)[0]
        if len(dense_rows) > 0:
            with span("incore.dense", rows=len(dense_rows)) as dsp:
                ids, d = self._dense_scan(q[dense_rows], lo[dense_rows],
                                          hi[dense_rows], inc[dense_rows],
                                          params.k)
                dsp.attach((ids, d))
            orig = np.where(ids >= 0, self.index.perm[np.maximum(ids, 0)], -1)
            out_i[dense_rows] = orig
            out_d[dense_rows] = d
            # estimator error against the scan's exact qualifying counts
            exact = self._last_dense_qual.astype(np.float64)
            est_r = routes.est_rows[dense_rows]
            pm.set("est_rel_err_dense", float(
                np.mean(np.abs(est_r - exact) / np.maximum(exact, 1.0))))

        for path_idx, (flag, fn, stat, sname) in enumerate(
                ((False, self._traverse, "n_itinerary", "incore.traverse"),
                 (True, self._global, "n_global", "incore.global"))):
            path_rows = (use_global == flag) & ~use_dense
            pm.count(stat, int(path_rows.sum()))
            for mult in np.unique(routes.ef_mult[path_rows]):
                sel = np.nonzero(path_rows
                                 & (routes.ef_mult == mult))[0]
                if len(sel) == 0:
                    continue
                # independent entry randomization per (path, effort)
                # bucket, keyed by *identity* (fold_in) rather than an
                # order-dependent split chain: a query's key must not
                # change when another bucket happens to be empty
                # (batch-composition contract). mult=1 reproduces the
                # historical codes 0/1 exactly.
                code = path_idx + 2 * int(mult).bit_length() - 2
                sub = jax.random.fold_in(base_key, code)
                with span(sname, rows=len(sel), ef_mult=int(mult)) as tsp:
                    ids, d = fn(q[sel], lo[sel], hi[sel], params, sub,
                                ef_mult=int(mult))
                    tsp.attach((ids, d))
                orig = np.where(ids >= 0,
                                self.index.perm[np.maximum(ids, 0)], -1)
                out_i[sel] = orig
                out_d[sel] = d
        pm.set("wall_seconds", time.perf_counter() - t0)
        if qmap is not None:
            return merge_segment_topk(out_i, out_d, qmap, n_queries,
                                      params.k)
        return out_i, out_d


def ground_truth(vectors: np.ndarray, attrs: np.ndarray, q: np.ndarray,
                 lo: np.ndarray, hi: np.ndarray, k: int,
                 chunk: int = 65536):
    """Exact RFNNS answer set for recall measurement (original ids)."""
    from repro.core.baselines import FlatBaseline, prefilter_search
    base = FlatBaseline(vectors=np.asarray(vectors, np.float32),
                        attrs=np.asarray(attrs, np.float32))
    return prefilter_search(base, q, lo, hi, k, chunk=chunk)


def recall_at_k(result_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |result ∩ truth| / |truth| over queries (paper's Recall)."""
    total, hit = 0, 0
    for r, t in zip(result_ids, true_ids):
        t = set(int(x) for x in t if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in r if x >= 0)
        hit += len(r & t)
        total += len(t)
    return hit / max(total, 1)
