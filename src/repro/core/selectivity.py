"""Per-box selectivity estimation + cost-based route selection.

The planner-level cost model (ISSUE 7 tentpole, ROADMAP item 3 —
VecFlow-style selectivity-adaptive execution). Every canonical filter
box gets an estimated qualifying-row count and one of three execution
routes, shared by all three engine modes:

  ``ROUTE_DENSE``  — ultra-selective: skip traversal entirely and run
                     the fused gather->predicate-mask->distance->k-select
                     scan over the qualifying candidate rows
                     (``kernels/masked_scan.py`` via
                     ``runtime.masked_dense_scan``).
  ``ROUTE_MID``    — mid-range: keep cell traversal but scale the
                     candidate-pool width ``ef`` (and with it the entry
                     beam) by a power-of-two factor derived from the
                     estimate — range-aware effort instead of a fixed
                     constant (RNSG's observation in PAPERS.md).
  ``ROUTE_BROAD``  — broad: the unchanged traversal path.

Estimation is two-tier:

  1. :func:`estimate_selectivity` — the global per-attribute empirical
     CDF product (``GMGIndex.attr_quantiles``), i.e. the
     conjunction-independence estimate. Cheap, but correlated
     attributes multiply their marginals and blow the estimate low.
  2. :class:`SelectivityEstimator` — per-cell per-attribute histograms.
     The estimate becomes ``sum_c inc(c) * n_c * prod_j frac_j(c)``:
     cells already separate correlated partitioned attributes (a cell
     only holds rows whose partitioned attrs are jointly in its box),
     so the per-cell marginal product is conditioned on the cell and
     the cross-cell correlation error disappears.

Knobs live in :class:`CostModel` (attach via ``SearchParams.cost``);
see ``docs/tuning.md`` for guidance tied to the ``bench_selectivity``
regimes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.types import GMGIndex

# route codes carried in RouteDecision.route ((T,) int8)
ROUTE_DENSE = 0
ROUTE_MID = 1
ROUTE_BROAD = 2

ROUTE_NAMES = {ROUTE_DENSE: "dense", ROUTE_MID: "mid", ROUTE_BROAD: "broad"}


# -- CDF evaluation ----------------------------------------------------------
#
# Both tiers evaluate empirical CDFs stored as (edges, cumulative-fraction)
# pairs. np.interp would be the obvious tool but breaks on duplicate edges
# (discrete or constant attributes produce zero-width bins: a constant
# column's quantile grid is one repeated value), so evaluation is
# searchsorted + guarded linear interpolation. ``side`` picks the bound
# semantics: "left" for a range's lower bound (mass strictly below lo is
# excluded... approximately; the grid cannot distinguish < from <=) and
# "right" for the upper bound (mass at hi counts).

def _cdf_eval(edges: np.ndarray, cdf: np.ndarray, x: np.ndarray,
              side: str) -> np.ndarray:
    """Evaluate empirical CDF(s) at points ``x``.

    edges (ng+1,) ascending (duplicates allowed); cdf (..., ng+1)
    cumulative fraction at each edge (cdf[..., 0] == 0); x (T,).
    Returns (..., T) — F(x) per cdf row per point, in [0, cdf[..., -1]].
    """
    x = np.asarray(x, np.float64)
    ng1 = edges.shape[0]
    i = np.searchsorted(edges, x, side=side)              # (T,) in [0, ng1]
    li = np.clip(i - 1, 0, ng1 - 1)
    ri = np.clip(i, 0, ng1 - 1)
    le, re_ = edges[li], edges[ri]
    width = re_ - le
    # zero-width bin (duplicate edges): all mass sits at the edge value —
    # include it for an upper bound ("right"), exclude for a lower ("left")
    t = np.where(width > 0,
                 (x - le) / np.where(width > 0, width, 1.0),
                 1.0 if side == "right" else 0.0)
    t = np.clip(t, 0.0, 1.0)
    c_lo = cdf[..., li]
    c_hi = cdf[..., ri]
    F = c_lo + t * (c_hi - c_lo)
    F = np.where(i <= 0, 0.0, F)
    F = np.where(i >= ng1, cdf[..., -1][..., None], F)
    return F


def estimate_selectivity(index: GMGIndex, lo: np.ndarray,
                         hi: np.ndarray) -> np.ndarray:
    """(B,) estimated in-range fraction per box — the clamped
    conjunction-independence product over the per-attribute empirical
    CDF grids (``index.attr_quantiles``).

    The public helper the planner (and ``Searcher``) call: each factor
    and the final product are clamped to [0, 1], and degenerate grids
    (constant attributes collapse every quantile to one value) evaluate
    to 1 for ranges containing the value and 0 otherwise instead of
    over/undershooting. With no quantile grid on the index the estimate
    degrades to the uninformative 1.0 (route everything broad).
    """
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    B = lo.shape[0]
    qgrid = index.attr_quantiles
    if qgrid is None:
        return np.ones(B, np.float64)
    ng = qgrid.shape[1] - 1
    uniform_cdf = np.linspace(0.0, 1.0, ng + 1)
    est = np.ones(B, np.float64)
    for j in range(qgrid.shape[0]):
        f_hi = _cdf_eval(qgrid[j].astype(np.float64), uniform_cdf,
                         hi[:, j], side="right")
        f_lo = _cdf_eval(qgrid[j].astype(np.float64), uniform_cdf,
                         lo[:, j], side="left")
        est *= np.clip(f_hi - f_lo, 0.0, 1.0)
    return np.clip(est, 0.0, 1.0)


# -- tier 2: per-cell attribute histograms -----------------------------------

class SelectivityEstimator:
    """Per-cell per-attribute histogram refinement of the CDF product.

    Bin edges are quantile-spaced globally (subsampled from the index's
    ``attr_quantiles`` grid so no second data pass is needed); counts
    are per (cell, attribute, bin). Tombstoned rows (NaN attrs on the
    engine replica) drop out of the counts, so estimates track deletes.

    ``estimate_rows(lo, hi, inc)`` returns the refined qualifying-row
    estimate ``sum_c inc[:, c] * n_c * prod_j frac_j(c, [lo_j, hi_j])``
    — the within-cell independence product, summed over selected cells.
    Cross-cell attribute correlation (the failure mode of the global
    product) is captured because each cell's marginals are conditioned
    on membership in that cell.
    """

    def __init__(self, index: GMGIndex, n_bins: int = 32):
        attrs = np.asarray(index.attrs, np.float64)
        n, m = attrs.shape
        S = index.n_cells
        self.n_bins = int(n_bins)
        qgrid = index.attr_quantiles
        if qgrid is None:
            # degrade to one bin per attribute spanning the data range
            lo_v = np.nanmin(attrs, axis=0) if n else np.zeros(m)
            hi_v = np.nanmax(attrs, axis=0) if n else np.ones(m)
            self.edges = np.stack([np.linspace(lo_v[j], hi_v[j], 2)
                                   for j in range(m)])
            self.n_bins = 1
        else:
            ng = qgrid.shape[1] - 1
            step = max(1, ng // self.n_bins)
            cols = list(range(0, ng + 1, step))
            if cols[-1] != ng:
                cols.append(ng)
            self.edges = qgrid[:, cols].astype(np.float64)   # (m, nb+1)
            self.n_bins = self.edges.shape[1] - 1
        nb = self.n_bins
        counts = np.zeros((S, m, nb), np.float64)
        cell_of = np.asarray(index.cell_of, np.int64)
        for j in range(m):
            col = attrs[:, j]
            live = ~np.isnan(col)
            b = np.searchsorted(self.edges[j], col[live], side="right") - 1
            b = np.clip(b, 0, nb - 1)
            np.add.at(counts, (cell_of[live], j, b), 1.0)
        self.counts = counts                                  # (S, m, nb)
        # per-(cell, attr) live-row totals; attrs NaN independently only
        # for tombstones (whole row), so totals agree across j in practice
        self.cell_live = counts.sum(axis=2)                   # (S, m)
        # per-cell cumulative fraction at each edge: (S, m, nb+1)
        csum = np.concatenate(
            [np.zeros((S, m, 1)), np.cumsum(counts, axis=2)], axis=2)
        denom = np.maximum(self.cell_live[..., None], 1.0)
        self.cdf = csum / denom
        self.n_live = float(self.cell_live.max(axis=1).sum())

    def cell_fracs(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """(T, S) estimated in-range fraction of each cell's live rows
        for each box (within-cell independence product over attrs)."""
        lo = np.asarray(lo, np.float64)
        hi = np.asarray(hi, np.float64)
        T = lo.shape[0]
        S, m, _ = self.counts.shape
        frac = np.ones((T, S), np.float64)
        for j in range(m):
            f_hi = _cdf_eval(self.edges[j], self.cdf[:, j, :], hi[:, j],
                             side="right")                    # (S, T)
            f_lo = _cdf_eval(self.edges[j], self.cdf[:, j, :], lo[:, j],
                             side="left")
            frac *= np.clip(f_hi - f_lo, 0.0, 1.0).T          # (T, S)
        return frac

    def estimate_rows(self, lo: np.ndarray, hi: np.ndarray,
                      inc: Optional[np.ndarray] = None) -> np.ndarray:
        """(T,) refined qualifying-row estimate per box. ``inc`` is the
        (T, S) cell-incidence matrix (cells whose grid box intersects
        the query box); without it every cell contributes."""
        frac = self.cell_fracs(lo, hi)
        cell_n = self.cell_live.max(axis=1)                   # (S,)
        if inc is not None:
            frac = np.where(np.asarray(inc, bool), frac, 0.0)
        return frac @ cell_n


# -- the cost model ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-box route thresholds (attach via ``SearchParams.cost``).

    Dense when ANY of:
      - the selected cells hold <= ``config.dense_threshold`` rows
        (the legacy candidate-count rule — scanning them is one pass);
      - the estimate leaves <= ``max(dense_rows_per_k * k,
        dense_rows_min)`` qualifying rows (a starved graph walk) and the
        candidate set is <= ``dense_cand_mult * dense_threshold``;
      - the estimated in-range *fraction* is <= ``dense_frac`` and the
        candidate cap above holds (ultra-selective regardless of k).
    Never dense with zero candidate rows.

    Mid (not dense, estimated fraction <= ``mid_frac``): traversal with
    ``ef`` scaled by a power-of-two factor <= ``ef_boost_max`` — 2x in
    the upper half of the mid band, 4x in the lower (geometric) half.

    Broad (everything else): the unchanged traversal path.

    ``CostModel.off()`` disables routing entirely (every box broad,
    factor 1) — the forced-traversal ablation arm ``bench_selectivity``
    measures the dense/mid wins against.
    """

    dense_frac: float = 1e-3
    dense_rows_per_k: int = 8
    dense_rows_min: int = 64
    dense_cand_mult: int = 16
    mid_frac: float = 0.05
    ef_boost_max: int = 4
    enabled: bool = True

    @classmethod
    def off(cls) -> "CostModel":
        """Forced-traversal ablation: no dense route, no ef scaling."""
        return cls(enabled=False)


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Per-box routing output (one row per plan box)."""

    route: np.ndarray      # (T,) int8 — ROUTE_DENSE | ROUTE_MID | ROUTE_BROAD
    est: np.ndarray        # (T,) f64 estimated in-range fraction
    est_rows: np.ndarray   # (T,) f64 estimated qualifying rows
    cand_rows: np.ndarray  # (T,) i64 rows inside the selected cells
    ef_mult: np.ndarray    # (T,) i64 pow2 ef/entry-beam factor (1 = none)

    def counts(self) -> dict:
        """Per-route row counts for stats reporting."""
        r = self.route
        return {"n_dense": int((r == ROUTE_DENSE).sum()),
                "n_mid": int((r == ROUTE_MID).sum()),
                "n_broad": int((r == ROUTE_BROAD).sum())}


def route_boxes(index: GMGIndex, lo: np.ndarray, hi: np.ndarray,
                route_k: np.ndarray, cost: Optional[CostModel] = None,
                estimator: Optional[SelectivityEstimator] = None,
                est_rows: Optional[np.ndarray] = None,
                inc: Optional[np.ndarray] = None) -> RouteDecision:
    """Decide each box's execution route (shared by all three engines).

    ``route_k`` is the per-row k the decision should assume (the serving
    front-end hands each coalesced row its own request's k).
    ``estimator`` refines the row estimate with per-cell histograms;
    ``est_rows`` short-circuits estimation entirely (e.g. a plan already
    annotated by ``api.planner.annotate_plan``). ``inc`` is the (T, S)
    incidence matrix if the caller already computed it.
    """
    from repro.core import select as select_mod
    cost = cost if cost is not None else CostModel()
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    T = lo.shape[0]
    rk = np.asarray(route_k, np.int64)
    if rk.shape != (T,):
        raise ValueError(f"route_k shape {rk.shape} != ({T},)")
    if inc is None:
        inc = select_mod.incidence_numpy(lo, hi, index.cell_lo,
                                         index.cell_hi)
    sizes = np.diff(index.cell_start)
    cand_rows = (inc @ sizes).astype(np.int64)

    n_ref = float(max(index.n, 1))
    if est_rows is not None:
        est_rows = np.asarray(est_rows, np.float64)
        if estimator is not None:
            n_ref = max(estimator.n_live, 1.0)
        est = est_rows / n_ref
    elif estimator is not None:
        est_rows = estimator.estimate_rows(lo, hi, inc)
        n_ref = max(estimator.n_live, 1.0)
        est = est_rows / n_ref
    else:
        est = estimate_selectivity(index, lo, hi)
        est_rows = est * index.n

    route = np.full(T, ROUTE_BROAD, np.int8)
    ef_mult = np.ones(T, np.int64)
    thr = index.config.dense_threshold
    if cost.enabled and thr:
        cand_cap = cost.dense_cand_mult * thr
        use_dense = cand_rows <= thr
        use_dense |= ((est_rows <= np.maximum(
            cost.dense_rows_per_k * rk, cost.dense_rows_min))
            & (cand_rows <= cand_cap))
        use_dense |= (est <= cost.dense_frac) & (cand_rows <= cand_cap)
        use_dense &= cand_rows > 0
        route[use_dense] = ROUTE_DENSE
        # empty candidate sets (inverted/impossible boxes) stay broad at
        # 1x: they return nothing regardless, so never buy them effort
        mid = ~use_dense & (est <= cost.mid_frac) & (cand_rows > 0)
        route[mid] = ROUTE_MID
        # pow2 effort buckets: 2x over the mid band, 4x in its lower
        # (geometric) half — few distinct widths keep jit caches warm
        lower = np.sqrt(max(cost.mid_frac, 1e-30)
                        * max(cost.dense_frac, 1e-30))
        ef_mult[mid] = np.where(est[mid] <= lower,
                                min(4, cost.ef_boost_max),
                                min(2, cost.ef_boost_max))
    return RouteDecision(route=route, est=est, est_rows=est_rows,
                         cand_rows=cand_rows, ef_mult=ef_mult)
