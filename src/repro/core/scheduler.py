"""Cell-oriented batch scheduling (paper Section 5.2, Alg. 5).

Host-side greedy: place each cell into the batch (capacity b) whose active
query count grows least — minimizing sum_k Active(B_k), the number of live
per-query traversal states the accelerator must keep resident per batch.

Placement key (lexicographic, smaller wins)::

    (added_active, cache_affinity, current_active, batch_index)

``added_active`` is Alg. 5's objective and always dominates.
``cache_affinity`` is the locality extension (0 unless the caller hands a
``resident`` cell set, so the base algorithm is byte-identical to Alg. 5):

  - a cell already resident in the caller's device cell cache scores its
    *batch index*, steering it into the earliest wave under equal gain —
    it executes before LRU eviction can claim its slot, turning the
    upload it would otherwise cost into a cache hit;
  - a non-resident cell scores ``-overlap``: the number of its queries
    shared with resident cells already placed in that batch. Co-accessed
    cells travel together, so a miss lands in the wave whose resident
    members its queries already need (RNSG-style range locality).

The final ``(current_active, batch_index)`` pair preserves the existing
deterministic tie-break — equal-gain equal-affinity ties resolve toward
the currently-least-active batch (exactly as Alg. 5) and then the lowest
batch index, so identical inputs always yield an identical batch plan
(reproducible streamed/hybrid executions).

Size-aware capacity: with ``weights`` (rows each cell occupies in the
device arena) and ``capacity`` (total arena rows), a batch only admits a
cell whose weight still fits — every scheduled wave is simultaneously
residentable in a byte-granular cell cache. New batches are appended
deterministically when no existing batch can admit a cell.

Because Eq. 3's objective sums over waves it is invariant under wave
*order*; :func:`order_waves` exploits that freedom to run the waves
holding the most already-resident rows first — the transfer half of the
cache-aware schedule, at zero total_active cost.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np


def active_queries(incidence: np.ndarray, batch: Sequence[int]) -> int:
    """Active(B_k) = #queries touching >= 1 cell of the batch."""
    if len(batch) == 0:
        return 0
    return int((incidence[:, list(batch)].any(axis=1)).sum())


def schedule_cells(incidence: np.ndarray, batch_size: int,
                   cells: Sequence[int] | None = None, *,
                   resident: Optional[Iterable[int]] = None,
                   weights: Optional[np.ndarray] = None,
                   capacity: Optional[int] = None) -> list[list[int]]:
    """Alg. 5 with optional cache affinity and size-aware capacity.

    incidence: (m_queries, n_cells) bool; returns batches of cell ids,
    each |batch| <= batch_size, covering `cells` (default: every cell
    touched by at least one query).

    resident: cells currently held by the caller's device cell cache
    (e.g. ``CellCache.resident_cells()``); biases equal-gain placements
    toward cache hits (see module docstring). None = pure Alg. 5.
    weights/capacity: per-cell arena rows and the arena row total; a
    batch additionally admits a cell only while its summed weight fits.
    """
    m, n = incidence.shape
    if cells is None:
        cells = [c for c in range(n) if incidence[:, c].any()]
    cells = sorted(int(c) for c in cells)      # deterministic visit order
    res = frozenset(int(c) for c in resident) if resident is not None \
        else frozenset()
    if weights is not None:
        weights = np.asarray(weights)
        if capacity is None:
            raise ValueError("weights requires capacity")
        too_big = [c for c in cells if int(weights[c]) > capacity]
        if too_big:
            raise ValueError(
                f"cells {too_big} exceed the batch capacity {capacity} "
                "on their own")
    n_batches = max(1, -(-len(cells) // batch_size))
    batches: list[list[int]] = [[] for _ in range(n_batches)]
    # incremental active masks per batch: queries already active
    active_mask = [np.zeros(m, dtype=bool) for _ in range(n_batches)]
    active_cnt = [0] * n_batches
    # queries covered by *resident* members of each batch (affinity term)
    res_mask = [np.zeros(m, dtype=bool) for _ in range(n_batches)]
    weight_used = [0] * n_batches

    def admits(k: int, c: int) -> bool:
        if len(batches[k]) >= batch_size:
            return False
        if weights is not None and \
                weight_used[k] + int(weights[c]) > capacity:
            return False
        return True

    for c in cells:
        col = incidence[:, c]
        # stable placement: lexicographic (added_active, cache_affinity,
        # current_active, batch_index) — ties under equal gain and equal
        # affinity always resolve the same way
        best_k, best_key, best_inc = -1, None, 0
        for k in range(n_batches):
            if not admits(k, c):
                continue
            inc = int((col & ~active_mask[k]).sum())
            if res:
                aff = k if c in res else -int((col & res_mask[k]).sum())
            else:
                aff = 0
            cand = (inc, aff, active_cnt[k], k)
            if best_key is None or cand < best_key:
                best_k, best_key, best_inc = k, cand, inc
        if best_k < 0:
            # capacity-constrained: no existing batch admits this cell;
            # open a new one (deterministic: always appended at the end)
            best_k = n_batches
            best_inc = int(col.sum())
            n_batches += 1
            batches.append([])
            active_mask.append(np.zeros(m, dtype=bool))
            active_cnt.append(0)
            res_mask.append(np.zeros(m, dtype=bool))
            weight_used.append(0)
        batches[best_k].append(c)
        active_mask[best_k] |= col
        # incremental: the placement's own gain IS the count delta —
        # recomputing the O(m) mask sum per placement was pure waste
        active_cnt[best_k] += best_inc
        if c in res:
            res_mask[best_k] |= col
        if weights is not None:
            weight_used[best_k] += int(weights[c])
    return [b for b in batches if b]


def order_waves(batches: list[list[int]],
                resident: Optional[Iterable[int]] = None,
                weights: Optional[np.ndarray] = None) -> list[list[int]]:
    """Cache-aware execution order for a batch plan.

    ``total_active`` (Eq. 3) sums over waves, so it is *invariant under
    wave order* — but an LRU cell cache is not: cells resident from the
    previous execution only hit if their wave runs before later waves
    evict them. Run the waves with the most resident rows first (ties:
    original greedy order), turning the previous execution's tail into
    this execution's warm head. ``weights`` scores residency in arena
    rows (bytes saved); without it each resident cell counts 1.
    """
    if resident is None:
        return batches
    res = frozenset(int(c) for c in resident)
    if not res:
        return batches

    def saved(batch):
        if weights is None:
            return sum(1 for c in batch if c in res)
        return sum(int(weights[c]) for c in batch if c in res)

    order = sorted(range(len(batches)),
                   key=lambda i: (-saved(batches[i]), i))
    return [batches[i] for i in order]


def shard_schedules(incidence: np.ndarray, cell_shard: np.ndarray,
                    n_shards: int, batch_size: int, *,
                    resident: Optional[Sequence[Iterable[int]]] = None,
                    weights: Optional[np.ndarray] = None,
                    capacity: Optional[int] = None):
    """Per-shard Alg. 5: wave packing under a cell -> shard assignment.

    ``cell_shard`` maps each cell to its serving shard (e.g. the
    per-pass assignment from ``repro.core.shard.assign_cells``, or a
    static ``Placement.owner``). Each shard schedules only its own
    selected cells — Eq. 3's objective sums over waves, so a partition
    of the cells partitions the objective and per-shard greedy packing
    composes without changing any shard's result (the order-invariance
    the paper's Eq. 3 gives us, now applied across devices).

    ``resident`` optionally supplies each shard's cache-resident cell
    set (indexable by shard id) for the affinity bias. Returns
    ``(per-shard batch lists, per-shard total_active)``.
    """
    cell_shard = np.asarray(cell_shard)
    plans, totals = [], []
    for s in range(n_shards):
        cells = [c for c in range(incidence.shape[1])
                 if cell_shard[c] == s and incidence[:, c].any()]
        batches = schedule_cells(
            incidence, batch_size, cells,
            resident=None if resident is None else resident[s],
            weights=weights, capacity=capacity)
        plans.append(batches)
        totals.append(total_active(incidence, batches))
    return plans, totals


def naive_schedule(incidence: np.ndarray, batch_size: int) -> list[list[int]]:
    """Original-order dispatch (the paper's Fig. 6(a) strawman)."""
    cells = [c for c in range(incidence.shape[1]) if incidence[:, c].any()]
    return [cells[i:i + batch_size] for i in range(0, len(cells), batch_size)]


def total_active(incidence: np.ndarray, batches: list[list[int]]) -> int:
    """The objective of Eq. 3."""
    return sum(active_queries(incidence, b) for b in batches)
