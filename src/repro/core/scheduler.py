"""Cell-oriented batch scheduling (paper Section 5.2, Alg. 5).

Host-side greedy: place each cell into the batch (capacity b) whose active
query count grows least — minimizing sum_k Active(B_k), the number of live
per-query traversal states the accelerator must keep resident per batch.

Deterministic by construction: cells are visited in ascending id order
and each placement minimizes the explicit lexicographic key
``(added_active, current_active, batch_index)`` — equal-gain ties break
toward the currently-least-active batch (exactly as Alg. 5) and then
toward the lowest batch index, so identical incidence always yields an
identical batch plan (reproducible streamed/hybrid executions).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def active_queries(incidence: np.ndarray, batch: Sequence[int]) -> int:
    """Active(B_k) = #queries touching >= 1 cell of the batch."""
    if len(batch) == 0:
        return 0
    return int((incidence[:, list(batch)].any(axis=1)).sum())


def schedule_cells(incidence: np.ndarray, batch_size: int,
                   cells: Sequence[int] | None = None) -> list[list[int]]:
    """Alg. 5. incidence: (m_queries, n_cells) bool; returns batches of
    cell ids, each |batch| <= batch_size, covering `cells` (default: every
    cell touched by at least one query)."""
    m, n = incidence.shape
    if cells is None:
        cells = [c for c in range(n) if incidence[:, c].any()]
    cells = sorted(int(c) for c in cells)      # deterministic visit order
    n_batches = max(1, -(-len(cells) // batch_size))
    batches: list[list[int]] = [[] for _ in range(n_batches)]
    # incremental active masks per batch: queries already active
    active_mask = [np.zeros(m, dtype=bool) for _ in range(n_batches)]
    active_cnt = [0] * n_batches

    for c in cells:
        col = incidence[:, c]
        # stable placement: lexicographic (added_active, current_active,
        # batch_index) — ties under equal gain always resolve the same way
        best_k, best_key = -1, None
        for k in range(n_batches):
            if len(batches[k]) >= batch_size:
                continue
            inc = int((col & ~active_mask[k]).sum())
            cand = (inc, active_cnt[k], k)
            if best_key is None or cand < best_key:
                best_k, best_key = k, cand
        batches[best_k].append(c)
        active_mask[best_k] |= col
        active_cnt[best_k] = int(active_mask[best_k].sum())
    return [b for b in batches if b]


def naive_schedule(incidence: np.ndarray, batch_size: int) -> list[list[int]]:
    """Original-order dispatch (the paper's Fig. 6(a) strawman)."""
    cells = [c for c in range(incidence.shape[1]) if incidence[:, c].any()]
    return [cells[i:i + batch_size] for i in range(0, len(cells), batch_size)]


def total_active(incidence: np.ndarray, batches: list[list[int]]) -> int:
    """The objective of Eq. 3."""
    return sum(active_queries(incidence, b) for b in batches)
