"""Cardinality-balanced quantile grid (paper Section 3.1, Alg. 1 lines 1-4).

Host-side (numpy): partitioning is a sort over n scalars per attribute —
the paper also runs this on CPU. The p partitioned attributes each get
S_i quantile segments; an object's cell is the mixed-radix code of its
per-attribute segment ids.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def quantile_edges(values: np.ndarray, n_segments: int) -> np.ndarray:
    """(S_i + 1,) edges with ~equal-cardinality buckets.

    Edges are half-open on the right except the last bucket, which is
    closed: segment(x) = searchsorted(edges[1:-1], x, side='right').
    """
    qs = np.linspace(0.0, 1.0, n_segments + 1)
    edges = np.quantile(values.astype(np.float64), qs)
    edges[0], edges[-1] = -np.inf, np.inf   # grid covers the whole line
    # Duplicate quantiles (heavily skewed attrs) would create empty
    # segments; nudge them apart so searchsorted stays monotone. Balance
    # degrades gracefully, correctness does not depend on it.
    for i in range(1, len(edges) - 1):
        if edges[i] <= edges[i - 1]:
            edges[i] = np.nextafter(edges[i - 1], np.inf)
    return edges.astype(np.float64)


def segment_of(values: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Segment id per value given quantile edges."""
    return np.searchsorted(edges[1:-1], values, side="right").astype(np.int32)


def assign_cells(attrs: np.ndarray, seg_bounds: list,
                 seg_per_attr: Sequence[int]) -> np.ndarray:
    """Mixed-radix cell id over the p partitioned attributes (attrs[:, :p])."""
    p = len(seg_per_attr)
    cell = np.zeros(attrs.shape[0], dtype=np.int64)
    for i in range(p):
        seg = segment_of(attrs[:, i], seg_bounds[i])
        cell = cell * seg_per_attr[i] + seg
    return cell.astype(np.int32)


def build_grid(attrs: np.ndarray, seg_per_attr: Sequence[int]):
    """Returns (seg_bounds, cell_of, order, cell_start, cell_lo, cell_hi).

    ``order`` sorts objects into cell-contiguous internal layout.
    cell_lo/cell_hi are the (S, p) grid-box edges used for query-box
    intersection (Section 4.1 cell selection).
    """
    p = len(seg_per_attr)
    S = int(np.prod(seg_per_attr))
    seg_bounds = [quantile_edges(attrs[:, i], seg_per_attr[i]) for i in range(p)]
    cell_of = assign_cells(attrs, seg_bounds, seg_per_attr)

    order = np.argsort(cell_of, kind="stable")
    counts = np.bincount(cell_of, minlength=S)
    cell_start = np.zeros(S + 1, dtype=np.int32)
    np.cumsum(counts, out=cell_start[1:])

    # per-cell boxes from the mixed-radix decomposition
    cell_lo = np.zeros((S, p), dtype=np.float64)
    cell_hi = np.zeros((S, p), dtype=np.float64)
    for c in range(S):
        rem, code = c, []
        for i in reversed(range(p)):
            code.append(rem % seg_per_attr[i])
            rem //= seg_per_attr[i]
        code.reverse()
        for i in range(p):
            cell_lo[c, i] = seg_bounds[i][code[i]]
            cell_hi[c, i] = seg_bounds[i][code[i] + 1]
    return seg_bounds, cell_of, order, cell_start, cell_lo, cell_hi


def cells_for_box(cell_lo: np.ndarray, cell_hi: np.ndarray,
                  lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Query-box -> cell mask (paper Alg. 2 lines 2-4; vectorized).

    lo/hi: (B, m) query ranges (use -inf/+inf for unconstrained attrs);
    only the first p columns participate in grid intersection. A cell
    [clo, chi) intersects [l, r] iff l < chi and r >= clo.
    Returns bool (B, S).
    """
    p = cell_lo.shape[1]
    l = lo[:, None, :p]
    r = hi[:, None, :p]
    inter = (l < cell_hi[None]) & (r >= cell_lo[None])
    return inter.all(axis=2)
