"""Inter-cell edge construction (paper Section 3.2, step 3).

Every node queries every *other* cell's local graph for its top-l ANN
(Alg. 1 lines 10-12), batched. We reuse the batched traversal engine with
a single-cell itinerary and no predicate; tiny cells fall back to exact
top-l (cheaper than a graph walk).

Two entry points share one per-cell core (:func:`_cell_topl`):
``build_inter_edges`` (the full offline build) and
``inter_edges_for_queries`` (edges into a *subset* of cells for an
arbitrary query set — the streaming-mutability repair path: recompute
the touched cells' columns after a flush, and give freshly inserted
rows their edges into the untouched cells).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.traversal import multi_cell_search
from repro.kernels import ops


def _cell_topl(v_dev, a_dev, adj_dev, no_inter, cs_dev, cell_start,
               c: int, q_dev, l: int, *, ef: int, exact_threshold: int,
               max_iters: int, key):
    """Top-l ANN of each query among cell ``c``'s rows (global ids).

    Returns ((B, l) int32 numpy, next_key); -1-padded when the cell
    holds fewer than l rows. Small cells take the exact MXU path, large
    ones a predicate-free single-cell traversal. ``no_inter`` is the
    caller-hoisted (n, S, 1) all--1 dummy inter adjacency (allocated
    once per entry point, not per cell/chunk).
    """
    s, e = int(cell_start[c]), int(cell_start[c + 1])
    n_c = e - s
    B = q_dev.shape[0]
    if n_c == 0:
        return -np.ones((B, l), np.int32), key
    if n_c <= exact_threshold:
        _, idx = ops.topk_l2(q_dev, v_dev[s:e], min(l, n_c))
        ids = np.asarray(idx)
        ids = np.where(ids >= 0, ids + s, -1).astype(np.int32)
        if ids.shape[1] < l:
            ids = np.concatenate(
                [ids, -np.ones((B, l - ids.shape[1]), np.int32)], 1)
    else:
        m = a_dev.shape[1]
        lo = jnp.full((B, m), -jnp.inf, jnp.float32)
        hi = jnp.full((B, m), jnp.inf, jnp.float32)
        itinerary = jnp.full((B, 1), c, jnp.int32)
        key, sub = jax.random.split(key)
        ids_j, _ = multi_cell_search(
            v_dev, a_dev, adj_dev, no_inter, cs_dev,
            q_dev, lo, hi, itinerary, sub,
            k=l, ef=ef, entry_width=min(ef, 16),
            entry_random=min(ef, 16), entry_beam_l=1,
            max_iters=max_iters, use_inter=False)
        ids = np.asarray(ids_j, np.int32)
    return ids[:, :l], key


def build_inter_edges(vectors: np.ndarray, attrs: np.ndarray,
                      intra_adj: np.ndarray, cell_start: np.ndarray,
                      l: int, ef: int = 32, chunk: int = 4096,
                      exact_threshold: int = 512, seed: int = 0,
                      max_iters: int = 64) -> np.ndarray:
    """Returns inter_adj (n, S, l) int32 (own-cell column = -1)."""
    n, dim = vectors.shape
    S = len(cell_start) - 1
    inter = -np.ones((n, S, l), dtype=np.int32)

    v_dev = jnp.asarray(vectors)
    a_dev = jnp.asarray(attrs)
    adj_dev = jnp.asarray(intra_adj)
    cs_dev = jnp.asarray(cell_start)
    # no predicate during construction searches
    no_inter = jnp.zeros((n, S, 1), jnp.int32) - 1

    key = jax.random.PRNGKey(seed)
    for c in range(S):
        s, e = int(cell_start[c]), int(cell_start[c + 1])
        if e <= s:
            continue
        for qs in range(0, n, chunk):
            qe = min(qs + chunk, n)
            ids, key = _cell_topl(
                v_dev, a_dev, adj_dev, no_inter, cs_dev, cell_start, c,
                v_dev[qs:qe], l, ef=ef, exact_threshold=exact_threshold,
                max_iters=max_iters, key=key)
            inter[qs:qe, c, :] = ids

        # own-cell column: a node must not point at itself; simplest is to
        # blank the whole own-cell column (paper: edges to *other* cells).
        inter[s:e, c, :] = -1
    return inter


def inter_edges_for_queries(vectors: np.ndarray, attrs: np.ndarray,
                            intra_adj: np.ndarray, cell_start: np.ndarray,
                            q: np.ndarray, l: int, *, cells=None,
                            ef: int = 32, chunk: int = 4096,
                            exact_threshold: int = 512, seed: int = 0,
                            max_iters: int = 64) -> np.ndarray:
    """Top-l edges from each query row into each cell of ``cells``.

    The single-cell repair entry point beneath streaming mutability:
    after a flush splices rows into a cell, every row's column for that
    cell is re-resolved here (and new rows get their columns into the
    untouched cells). Returns (nq, len(cells), l) int32 *global* ids;
    own-cell blanking is the caller's business (it knows which query
    rows live in which cell).
    """
    S = len(cell_start) - 1
    if cells is None:
        cells = list(range(S))
    nq = q.shape[0]
    out = -np.ones((nq, len(cells), l), np.int32)
    if nq == 0 or not cells:
        return out

    v_dev = jnp.asarray(vectors)
    a_dev = jnp.asarray(attrs)
    adj_dev = jnp.asarray(intra_adj)
    cs_dev = jnp.asarray(np.asarray(cell_start, np.int32))
    no_inter = jnp.zeros((vectors.shape[0], S, 1), jnp.int32) - 1
    q_dev = jnp.asarray(np.asarray(q, np.float32))   # one upload, sliced

    key = jax.random.PRNGKey(seed)
    for j, c in enumerate(cells):
        for qs in range(0, nq, chunk):
            qe = min(qs + chunk, nq)
            ids, key = _cell_topl(
                v_dev, a_dev, adj_dev, no_inter, cs_dev, cell_start,
                int(c), q_dev[qs:qe], l,
                ef=ef, exact_threshold=exact_threshold,
                max_iters=max_iters, key=key)
            out[qs:qe, j, :] = ids
    return out
