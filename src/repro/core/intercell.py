"""Inter-cell edge construction (paper Section 3.2, step 3).

Every node queries every *other* cell's local graph for its top-l ANN
(Alg. 1 lines 10-12), batched. We reuse the batched traversal engine with
a single-cell itinerary and no predicate; tiny cells fall back to exact
top-l (cheaper than a graph walk).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.traversal import multi_cell_search
from repro.kernels import ops


def build_inter_edges(vectors: np.ndarray, attrs: np.ndarray,
                      intra_adj: np.ndarray, cell_start: np.ndarray,
                      l: int, ef: int = 32, chunk: int = 4096,
                      exact_threshold: int = 512, seed: int = 0,
                      max_iters: int = 64) -> np.ndarray:
    """Returns inter_adj (n, S, l) int32 (own-cell column = -1)."""
    n, dim = vectors.shape
    S = len(cell_start) - 1
    m = attrs.shape[1]
    inter = -np.ones((n, S, l), dtype=np.int32)

    v_dev = jnp.asarray(vectors)
    a_dev = jnp.asarray(attrs)
    adj_dev = jnp.asarray(intra_adj)
    cs_dev = jnp.asarray(cell_start)
    # no predicate during construction searches
    no_inter = jnp.zeros((n, S, 1), jnp.int32) - 1

    key = jax.random.PRNGKey(seed)
    for c in range(S):
        s, e = int(cell_start[c]), int(cell_start[c + 1])
        n_c = e - s
        if n_c == 0:
            continue
        for qs in range(0, n, chunk):
            qe = min(qs + chunk, n)
            B = qe - qs
            q = v_dev[qs:qe]
            if n_c <= exact_threshold:
                _, idx = ops.topk_l2(q, v_dev[s:e], min(l, n_c))
                ids = np.asarray(idx)
                ids = np.where(ids >= 0, ids + s, -1)
                if ids.shape[1] < l:
                    ids = np.concatenate(
                        [ids, -np.ones((B, l - ids.shape[1]), np.int32)], 1)
            else:
                lo = jnp.full((B, m), -jnp.inf, jnp.float32)
                hi = jnp.full((B, m), jnp.inf, jnp.float32)
                itinerary = jnp.full((B, 1), c, jnp.int32)
                key, sub = jax.random.split(key)
                ids_j, _ = multi_cell_search(
                    v_dev, a_dev, adj_dev, no_inter, cs_dev,
                    q, lo, hi, itinerary, sub,
                    k=l, ef=ef, entry_width=min(ef, 16),
                    entry_random=min(ef, 16), entry_beam_l=1,
                    max_iters=max_iters, use_inter=False)
                ids = np.asarray(ids_j)
            inter[qs:qe, c, :] = ids[:, :l]

        # own-cell column: a node must not point at itself; simplest is to
        # blank the whole own-cell column (paper: edges to *other* cells).
        inter[s:e, c, :] = -1
    return inter
