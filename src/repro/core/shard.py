"""Cell-sharded multi-device execution over a JAX device mesh.

The mesh tier of the engine-mode matrix (``incore | hybrid | ooc`` x
``1 device | mesh``): cells — already the unit of residency, scheduling
and mutation everywhere else in the engine — become the unit of
*placement*. A :class:`ShardSpec` drives a deterministic placement plan
(:func:`plan_placement`): cells are assigned to shards balanced by
resident bytes (greedy descending weight onto the least-loaded shard),
and the top-N hottest cells can be *replicated* on every shard so broad
queries spread their heaviest cells across the mesh per pass.

Each shard holds a self-contained sub-index (:func:`shard_index`) over
its resident cells — the same global->local remap idiom the out-of-core
engine uses per streamed batch, applied once at placement time — and
runs the *existing* engines over it: an in-core :class:`CellRuntime`,
or a per-shard :class:`HybridEngine` / :class:`OutOfCoreEngine` whose
wave schedules are automatically per-shard because they see only local
incidence. Per-query routing assigns each selected cell to exactly one
shard per pass (:func:`assign_cells` — owners for placed cells,
least-loaded holder for replicated ones), and per-shard top-k results
fold back through the one deterministic ``merge_segment_topk``.

Single-host simulated meshes (``XLA_FLAGS=
--xla_force_host_platform_device_count=8``) exercise the same code: the
placement layer is device-count transparent — shard s lives on device
``s % len(jax.devices())`` — so everything here also runs, bit-for-bit,
on one device.

Parity contract (tested by tests/test_sharding.py):

  incore — **exact id parity** with single-device execution. Sharded
    in-core traversal pins the *partition-independent profile*
    (``use_inter_edges=False``, ``adaptive_global=False``). Under it a
    cell's search is fully self-contained: the beam is reset from
    within-cell entries at every itinerary step, intra edges never leave
    the cell, expansion is gated on the beam only (the result pool is a
    write-only accumulator), and visited sets are disjoint across cells.
    Entry randomness aligns across shards because the per-step draw is
    ``fold_in(key, t)`` at the *global* itinerary position t — the
    engine computes ONE global cell itinerary (identical to the
    single-device order) and masks it per shard, preserving positions —
    and the draw is an offset *within* the cell, which the local layout
    preserves. Per-shard top-k therefore covers the global top-k, and
    the (distance, id) merge reproduces single-device ids exactly (the
    repo-wide exact-float caveat on ties between distinct equidistant
    points applies, as everywhere).

  hybrid / ooc — **recall parity** (the PR-6 contract for streamed
    modes): per-shard carried pools and within-shard inter edges change
    which candidates surface, not their quality; duplicates across
    shards (replicated cells) collapse in the merge.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import runtime as rt_mod
from repro.core import select as select_mod
from repro.core import selectivity as sel_mod
from repro.core.ordering import order_cells
from repro.core.runtime import CellRuntime, merge_segment_topk, pad_pow2
from repro.core.types import GMGIndex, SearchParams
from repro.dist.straggler import StragglerMonitor
from repro.obs.metrics import MetricsRegistry, PassMetrics
from repro.obs.trace import local_trace, span

BALANCE_BY = ("bytes", "rows")
SHARD_MODES = ("incore", "hybrid", "ooc")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Validated cell-placement knob set (``Collection(shards=...)``).

    n_shards       — shards in the mesh tier; each lives on device
                     ``s % len(jax.devices())``. 1 is valid (and useful:
                     it exercises the identical partitioned code path).
    replicate_hot  — top-N heaviest cells resident on EVERY shard; per
                     pass each replicated cell is served by the
                     least-loaded holder (see :func:`assign_cells`).
    balance_by     — placement weight: "bytes" (resident bytes per cell,
                     the default) or "rows".
    hot_cells      — explicit replicated cell ids, overriding the
                     weight-derived top-N pick.
    """

    n_shards: int = 1
    replicate_hot: int = 0
    balance_by: str = "bytes"
    hot_cells: Optional[tuple] = None

    def __post_init__(self):
        if int(self.n_shards) < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if int(self.replicate_hot) < 0:
            raise ValueError("replicate_hot must be >= 0")
        if self.balance_by not in BALANCE_BY:
            raise ValueError(f"unknown balance_by {self.balance_by!r}; "
                             f"expected one of {BALANCE_BY}")
        if self.hot_cells is not None:
            object.__setattr__(self, "hot_cells",
                               tuple(int(c) for c in self.hot_cells))

    @classmethod
    def canon(cls, spec: Union[None, int, "ShardSpec"]
              ) -> Optional["ShardSpec"]:
        """Normalize the ``Collection.shards`` knob: None stays None
        (single-device engines untouched), an int becomes
        ``ShardSpec(n_shards=int)``, a ShardSpec passes through."""
        if spec is None:
            return None
        if isinstance(spec, ShardSpec):
            return spec
        if isinstance(spec, (int, np.integer)) and not isinstance(spec, bool):
            return cls(n_shards=int(spec))
        raise TypeError(
            f"shards must be None, an int, or a ShardSpec, got {spec!r}")


def cell_weights(index: GMGIndex, balance_by: str = "bytes") -> np.ndarray:
    """(S,) int64 placement weight per cell: rows, or the bytes a cell
    keeps resident on its shard (vectors + attrs + graph rows [+ int8
    copy]) — the balance target of :func:`plan_placement`."""
    rows = np.diff(index.cell_start).astype(np.int64)
    if balance_by == "rows":
        return rows
    per_row = (index.vectors.itemsize * index.dim
               + index.attrs.itemsize * index.attrs.shape[1]
               + index.intra_adj.itemsize * index.intra_adj.shape[1]
               + index.inter_adj.itemsize
               * index.inter_adj.shape[1] * index.inter_adj.shape[2])
    if index.vq is not None:
        per_row += index.vq.itemsize * index.dim + index.vscale.itemsize
    return rows * per_row


@dataclasses.dataclass(frozen=True)
class Placement:
    """Deterministic cell -> shard plan (pure function of (index, spec))."""

    n_shards: int
    owner: np.ndarray        # (S,) i32 home shard per cell
    replicated: np.ndarray   # (S,) bool: resident on every shard
    weights: np.ndarray      # (S,) i64 placement weights
    shard_cells: tuple       # per shard: sorted global cell ids resident
    loads: np.ndarray        # (n_shards,) i64 owned weight per shard

    def balance(self) -> float:
        """max/mean owned-weight ratio over shards (1.0 = perfect)."""
        mean = float(self.loads.mean()) if self.n_shards else 0.0
        return float(self.loads.max()) / max(mean, 1e-12)


def plan_placement(index: GMGIndex, spec: ShardSpec) -> Placement:
    """Greedy balanced placement: cells descend by weight (ties break to
    the lower cell id) onto the least-loaded shard (ties to the lower
    shard id). ``replicate_hot``/``hot_cells`` marks cells additionally
    resident on every shard; their *home* shard still carries their
    weight (it serves them when no rebalancing is needed)."""
    S = index.n_cells
    if spec.n_shards > S:
        raise ValueError(
            f"n_shards={spec.n_shards} exceeds the index's {S} cells")
    w = cell_weights(index, spec.balance_by)
    if spec.hot_cells is not None:
        hot = np.asarray(spec.hot_cells, np.int64)
        if hot.size and (hot.min() < 0 or hot.max() >= S):
            raise ValueError(f"hot_cells out of range [0, {S})")
    else:
        n_hot = min(int(spec.replicate_hot), S)
        # heaviest first, ascending id on ties — deterministic
        order = np.lexsort((np.arange(S), -w))
        hot = order[:n_hot]
    replicated = np.zeros(S, bool)
    replicated[hot] = True

    owner = np.full(S, -1, np.int32)
    loads = np.zeros(spec.n_shards, np.int64)
    for c in np.lexsort((np.arange(S), -w)):
        s = int(np.argmin(loads))          # ties -> lowest shard id
        owner[c] = s
        loads[s] += int(w[c])
    shard_cells = tuple(
        np.nonzero((owner == s) | replicated)[0].astype(np.int64)
        for s in range(spec.n_shards))
    return Placement(n_shards=spec.n_shards, owner=owner,
                     replicated=replicated, weights=w,
                     shard_cells=shard_cells, loads=loads)


def shard_index(index: GMGIndex, cells: np.ndarray):
    """Build one shard's self-contained sub-index over ``cells``
    (ascending global cell ids). Returns ``(sub, rows, g2l_cell)``:
    ``rows`` maps local internal ids -> global internal ids, and
    ``g2l_cell`` is the (S,) global -> local cell map (-1 elsewhere).

    The same gather+remap the streaming engine applies per batch
    (``pipeline._remap_plan``), applied once: intra edges are within-cell
    and remap losslessly; inter edges keep only the columns between
    resident cells; ``perm`` carries *original* ids so cross-shard
    merges need no translation; ordering/selectivity metadata row-slices
    by cell (hist, cell boxes) or stays global (centroids, quantiles)."""
    cells = np.asarray(sorted(int(c) for c in cells), np.int64)
    S = index.n_cells
    starts = index.cell_start
    sizes = np.diff(starts).astype(np.int64)
    local_start = np.zeros(len(cells) + 1, np.int64)
    np.cumsum(sizes[cells], out=local_start[1:])
    rows = np.concatenate(
        [np.arange(starts[c], starts[c + 1], dtype=np.int64)
         for c in cells]) if len(cells) else np.empty(0, np.int64)

    offset = np.zeros(S, np.int64)
    in_sub = np.zeros(S, bool)
    for li, c in enumerate(cells):
        offset[c] = int(local_start[li]) - int(starts[c])
        in_sub[c] = True

    def remap(ids: np.ndarray) -> np.ndarray:
        safe = np.maximum(ids, 0)
        cell = index.cell_of[safe]
        return np.where((ids >= 0) & in_sub[cell],
                        safe + offset[cell], -1).astype(np.int32)

    g2l_cell = np.full(S, -1, np.int32)
    g2l_cell[cells] = np.arange(len(cells), dtype=np.int32)
    sub = GMGIndex(
        config=index.config,
        vectors=index.vectors[rows],
        attrs=index.attrs[rows],
        perm=index.perm[rows],
        seg_bounds=index.seg_bounds,
        cell_of=np.repeat(np.arange(len(cells), dtype=np.int32),
                          sizes[cells]),
        cell_start=local_start.astype(np.int32),
        cell_lo=index.cell_lo[cells],
        cell_hi=index.cell_hi[cells],
        intra_adj=remap(index.intra_adj[rows]),
        inter_adj=remap(index.inter_adj[rows][:, cells, :]),
        centroids=index.centroids,
        hist=index.hist[cells],
        attr_quantiles=index.attr_quantiles,
        vq=None if index.vq is None else index.vq[rows],
        vscale=None if index.vscale is None else index.vscale[rows],
    )
    return sub, rows, g2l_cell


def assign_cells(inc: np.ndarray, placement: Placement):
    """Per-pass cell -> serving shard assignment.

    Placed cells go to their owner. Each *replicated* cell selected by
    at least one row goes to the currently least-loaded holder (load =
    selected (row, cell) incidences assigned so far; replicated cells
    assign heaviest-demand first, ties ascending cell id, shard ties to
    the lowest id) — deterministic, and result-invariant because a
    cell's per-query work is identical on any holder. Returns
    ``(assign (S,) i32, replica_hits)`` where ``replica_hits`` counts
    (row, cell) incidences served by a non-home shard."""
    assign = placement.owner.copy()
    demand = inc.sum(axis=0).astype(np.int64)
    loads = np.zeros(placement.n_shards, np.int64)
    sel = np.nonzero(demand > 0)[0]
    for c in sel:
        if not placement.replicated[c]:
            loads[assign[c]] += demand[c]
    hits = 0
    rep_sel = sorted((c for c in sel if placement.replicated[c]),
                     key=lambda c: (-int(demand[c]), int(c)))
    for c in rep_sel:
        s = int(np.argmin(loads))
        assign[c] = s
        loads[s] += demand[c]
        if s != placement.owner[c]:
            hits += int(demand[c])
    return assign, hits


def _slice_routes(routes: sel_mod.RouteDecision,
                  rows: np.ndarray) -> sel_mod.RouteDecision:
    """Row-subset view of a RouteDecision (routing stays planner-level:
    shards execute the global decision, never re-derive it)."""
    return dataclasses.replace(
        routes, route=routes.route[rows], est=routes.est[rows],
        est_rows=routes.est_rows[rows], cand_rows=routes.cand_rows[rows],
        ef_mult=routes.ef_mult[rows])


@dataclasses.dataclass
class _Shard:
    """One shard's residency: sub-index + engine on its device."""
    sid: int
    device: object
    cells: np.ndarray        # (n_local_cells,) global cell ids, ascending
    rows: np.ndarray         # (n_local,) local -> global internal ids
    g2l: np.ndarray          # (S,) global -> local cell id, -1 elsewhere
    sub: GMGIndex
    rt: Optional[CellRuntime] = None       # incore
    engine: object = None                  # hybrid / ooc sub-engine


@dataclasses.dataclass
class ShardedEngine:
    """Engine-compatible wrapper running one mode across a cell-sharded
    mesh. ``Collection._engine_for`` returns this when ``shards`` is
    set; its ``search``/``stats``/``refresh_index`` surface matches the
    single-device engines."""

    index: GMGIndex
    spec: ShardSpec
    mode: str = "incore"
    device_budget_bytes: Optional[int] = None
    cache_policy: str = "size_aware"
    rerank: str = "device"

    def __post_init__(self):
        if self.mode not in SHARD_MODES:
            raise ValueError(f"unknown sharded mode {self.mode!r}; "
                             f"expected one of {SHARD_MODES}")
        self.placement = plan_placement(self.index, self.spec)
        devices = jax.devices()
        self.shards: list[_Shard] = []
        for s in range(self.spec.n_shards):
            dev = devices[s % len(devices)]
            sub, rows, g2l = shard_index(self.index,
                                         self.placement.shard_cells[s])
            sh = _Shard(sid=s, device=dev,
                        cells=self.placement.shard_cells[s],
                        rows=rows, g2l=g2l, sub=sub)
            with jax.default_device(dev):
                if self.mode == "incore":
                    sh.rt = CellRuntime(sub, storage="f32")
                    sh.rt.resident_graph()        # build under the device
                elif self.mode == "hybrid":
                    from repro.core.hybrid import HybridEngine
                    sh.engine = HybridEngine(
                        sub, cache_budget_bytes=self._sub_window(sub),
                        cache_policy=self.cache_policy, rerank=self.rerank)
                else:
                    from repro.core.pipeline import OutOfCoreEngine
                    sh.engine = OutOfCoreEngine(
                        sub, hbm_budget_bytes=self._sub_window(sub),
                        rerank=self.rerank)
            self.shards.append(sh)
        # global ordering geometry for the one shared itinerary (incore)
        self._cell_lo_dev = jnp.asarray(self.index.cell_lo)
        self._cell_hi_dev = jnp.asarray(self.index.cell_hi)
        self._centroids_dev = jnp.asarray(self.index.centroids)
        self._hist_dev = jnp.asarray(self.index.hist)
        # per-shard walls are span-derived (obs, ISSUE 10): every shard
        # launch runs under a "shard.*" span tagged shard=sid, and the
        # fleet monitor (repro.dist.straggler) ingests those spans —
        # one timing path for traces, stats, and straggler detection
        self.straggler = StragglerMonitor(self.spec.n_shards)
        self.stats: dict = {}
        # per-engine obs registry: per-pass stats dicts are views over
        # increments into it (PassMetrics, ISSUE 10)
        self.metrics = MetricsRegistry()

    def _sub_window(self, sub: GMGIndex) -> Optional[int]:
        """Per-shard cache/window budget: the declared *per-device*
        budget minus the shard's own int8 residents (the same rule
        ``Collection`` applies globally)."""
        if self.device_budget_bytes is None:
            return None
        resident = 0
        if sub.vq is not None:
            resident = sub.vq.nbytes + sub.vscale.nbytes + sub.attrs.nbytes
        return max(self.device_budget_bytes - resident, 1)

    def refresh_index(self, index: GMGIndex) -> None:
        """Delete path: push tombstone-NaN attrs into every shard's
        engine in place (one per-shard attr slice + re-upload; graphs
        and caches stay resident, same as single-device engines)."""
        self.index = index
        for sh in self.shards:
            sh.sub = dataclasses.replace(sh.sub, attrs=index.attrs[sh.rows])
            with jax.default_device(sh.device):
                if sh.rt is not None:
                    sh.rt.refresh_index(sh.sub)
                else:
                    sh.engine.refresh_index(sh.sub)

    def stragglers(self) -> list:
        """Shards currently flagged by the fleet monitor."""
        return [s for s in range(self.spec.n_shards)
                if self.straggler.is_straggler(s)]

    # -- search --------------------------------------------------------------

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None,
               route_k: Optional[np.ndarray] = None,
               routes: Optional[sel_mod.RouteDecision] = None):
        """Engine-compatible sharded search; see the module docstring
        for the parity contract per mode."""
        params = params or SearchParams()
        q = np.asarray(q, np.float32)
        lo = np.asarray(lo, np.float32)
        hi = np.asarray(hi, np.float32)
        B = q.shape[0]
        k = params.k
        if qmap is not None:
            qmap = rt_mod.check_qmap(qmap, B)
            if n_queries is None:
                raise ValueError("n_queries is required with qmap")
        t0 = time.perf_counter()
        # pass stats as views over the engine registry (ISSUE 10)
        pm = PassMetrics(self.metrics,
                         static={"engine": self.mode, "sharded": True})
        pm.count("n_rows", int(B))
        pm.put("n_shards", self.spec.n_shards)
        pm.put("replicated_cells", int(self.placement.replicated.sum()))
        pm.count("replica_hits", 0)
        pm.count("total_active", 0)
        pm.put("shards", [])
        self.stats = pm.stats()
        if B == 0:
            pm.set("wall_seconds", time.perf_counter() - t0)
            nq = n_queries if qmap is not None else 0
            return rt_mod.empty_topk(nq, k)

        idx = self.index
        inc = select_mod.incidence_numpy(lo, hi, idx.cell_lo, idx.cell_hi)
        if routes is None:
            rk = (np.full(B, k, np.int64) if route_k is None
                  else np.asarray(route_k, np.int64))
            routes = sel_mod.route_boxes(idx, lo, hi, rk,
                                         cost=params.cost, inc=inc)
        pm.update_counts(routes.counts())
        assign, replica_hits = assign_cells(inc, self.placement)
        pm.count("replica_hits", replica_hits)
        demand = inc.sum(axis=0).astype(np.int64)
        shard_stats = []
        for sh in self.shards:
            mine = assign[sh.cells] == sh.sid
            away = mine & (self.placement.owner[sh.cells] != sh.sid)
            shard_stats.append({
                "shard": sh.sid, "device": str(sh.device),
                "n_cells": int(len(sh.cells)),
                "n_rows": int(len(sh.rows)),
                "active_rows": 0,
                "total_active": int(demand[sh.cells][mine].sum()),
                "replica_hits": int(demand[sh.cells][away].sum()),
                "transfer_bytes": 0, "wall_seconds": 0.0,
            })
        pm.count("total_active",
                 int(sum(st["total_active"] for st in shard_stats)))

        # per-shard walls come from the "shard.*" spans the launches
        # emit below; local_trace records them even when nobody asked
        # for a trace (and nests them into the user's trace when one is
        # active), so the straggler monitor and per-shard stats read the
        # exact numbers a Perfetto export would show
        with local_trace() as tr:
            mark = tr.mark()
            if self.mode == "incore":
                out_i, out_d = self._search_incore(
                    q, lo, hi, inc, assign, routes, params, shard_stats,
                    pm)
            else:
                out_i, out_d = self._search_streamed(
                    q, lo, hi, inc, assign, routes, params, shard_stats)
            walls = self.straggler.ingest(tr.spans_since(mark),
                                          key="shard")
        for st in shard_stats:
            st["wall_seconds"] = float(walls.get(st["shard"], 0.0))
        pm.put("shards", shard_stats)
        pm.count("transfer_bytes",
                 int(sum(st["transfer_bytes"] for st in shard_stats)))
        if qmap is not None:
            pm.count("n_boxes", B)
            out_i, out_d = merge_segment_topk(out_i, out_d, qmap,
                                              n_queries, k)
        pm.set("wall_seconds", time.perf_counter() - t0)
        return out_i, out_d

    # -- incore: the partition-independent traversal profile -----------------

    def _search_incore(self, q, lo, hi, inc, assign, routes,
                       params: SearchParams, shard_stats, pm: PassMetrics):
        idx = self.index
        cfg = idx.config
        B, k = q.shape[0], params.k
        base_key = jax.random.PRNGKey(params.seed)
        use_dense = routes.route == sel_mod.ROUTE_DENSE
        pm.put("profile", "partitioned")
        pm.count("n_itinerary", int((~use_dense).sum()))
        pm.count("n_global", 0)
        # (S,) assigned-cell -> local id per shard, this pass
        assigned_local = []
        for sh in self.shards:
            al = np.full(idx.n_cells, -1, np.int32)
            m = assign == sh.sid
            al[m] = sh.g2l[m]
            assigned_local.append(al)
        cand_i, cand_d, cand_q = [], [], []

        # dense route: each shard exact-scans its assigned selected cells;
        # assignment partitions the cells, so per-shard qualifying counts
        # sum to the global count and candidates never duplicate
        dense_rows = np.nonzero(use_dense)[0]
        if len(dense_rows) > 0:
            n_qual_total = np.zeros(len(dense_rows), np.int64)
            for sh in self.shards:
                inc_loc = (inc[np.ix_(dense_rows, sh.cells)]
                           & (assign[sh.cells] == sh.sid)[None, :])
                act = np.nonzero(inc_loc.any(axis=1))[0]
                if len(act) == 0:
                    continue
                rows = dense_rows[act]
                with span("shard.dense", shard=sh.sid, rows=len(act)):
                    with jax.default_device(sh.device):
                        ids_l, d_l, n_qual = rt_mod.masked_dense_scan(
                            sh.rt, q[rows], lo[rows], hi[rows],
                            inc_loc[act], k)
                shard_stats[sh.sid]["active_rows"] += int(len(act))
                cand_i.append(np.where(
                    ids_l >= 0, sh.sub.perm[np.maximum(ids_l, 0)], -1))
                cand_d.append(d_l)
                cand_q.append(rows)
                n_qual_total[act] += n_qual
            exact = n_qual_total.astype(np.float64)
            est_r = routes.est_rows[dense_rows]
            pm.set("est_rel_err_dense", float(
                np.mean(np.abs(est_r - exact) / np.maximum(exact, 1.0))))

        # itinerary path: ONE global cell order (identical to the
        # single-device Searcher's), masked per shard at the same
        # positions so the per-step fold_in(key, t) draws align
        path_rows = ~use_dense
        ef_base = params.ef or cfg.search_ef
        for mult in np.unique(routes.ef_mult[path_rows]):
            sel = np.nonzero(path_rows & (routes.ef_mult == mult))[0]
            if len(sel) == 0:
                continue
            # identity-keyed per (path, effort) bucket exactly as the
            # single-device engine (path_idx = 0: itinerary)
            code = 2 * int(mult).bit_length() - 2
            sub_key = jax.random.fold_in(base_key, code)
            ef = ef_base * int(mult)
            beam = cfg.entry_beam_l if mult == 1 \
                else min(cfg.entry_beam_l * int(mult), ef)
            k_run = max(k, beam)
            qp, real = pad_pow2(q[sel])
            lop, _ = pad_pow2(lo[sel])
            hip, _ = pad_pow2(hi[sel])
            qd = jnp.asarray(qp)
            lod, hid = jnp.asarray(lop), jnp.asarray(hip)
            mask = select_mod.select_cells(lod, hid, self._cell_lo_dev,
                                           self._cell_hi_dev)
            T = idx.n_cells if params.max_cells is None \
                else min(params.max_cells, idx.n_cells)
            if params.use_ordering:
                order, _ = order_cells(qd, self._centroids_dev,
                                       self._hist_dev, mask,
                                       top_m=cfg.top_m_clusters, T=T)
            else:  # grid-order ablation, mirrored from the Searcher
                S = mask.shape[1]
                ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                       mask.shape)
                srt = jnp.where(mask, ids, S + 1)
                order = jnp.sort(srt, axis=1)[:, :T].astype(jnp.int32)
                order = jnp.where(order <= S - 1, order, -1)
            order_np = np.asarray(order)[:real]          # (n_sel, T) global

            launches = []
            for sh in self.shards:
                order_s = np.where(
                    order_np >= 0,
                    assigned_local[sh.sid][np.maximum(order_np, 0)],
                    -1).astype(np.int32)
                act = np.nonzero((order_s >= 0).any(axis=1))[0]
                if len(act) == 0:
                    continue
                q_s, real_s = pad_pow2(q[sel][act])
                lo_s, _ = pad_pow2(lo[sel][act])
                hi_s, _ = pad_pow2(hi[sel][act])
                ord_p = np.full((q_s.shape[0], order_s.shape[1]), -1,
                                np.int32)
                ord_p[:real_s] = order_s[act]
                # dispatch-only span: async launch returns immediately;
                # the blocking materialization is the shard.block span —
                # summed per shard=sid they reproduce the old
                # launch+block wall the straggler monitor judged
                with span("shard.launch", shard=sh.sid, rows=len(act),
                          ef=ef):
                    with jax.default_device(sh.device):
                        ids_dev, d_dev, _ = sh.rt.run_launch(
                            sh.rt.resident_graph(), q_s, lo_s, hi_s,
                            sub_key, k=k_run, ef=ef, cell_order=ord_p,
                            entry_beam_l=beam, use_inter=False,
                            pool_reuse=params.pool_reuse)
                launches.append((sh, ids_dev, d_dev, real_s, act))
            # all shards launched (async dispatch overlaps across
            # devices); now block each and fold candidates
            for sh, ids_dev, d_dev, real_s, act in launches:
                with span("shard.block", shard=sh.sid, rows=len(act)):
                    ids_l = np.asarray(ids_dev[:real_s, :k])
                    d_l = np.asarray(d_dev[:real_s, :k])
                shard_stats[sh.sid]["active_rows"] += int(len(act))
                cand_i.append(np.where(
                    ids_l >= 0, sh.sub.perm[np.maximum(ids_l, 0)], -1))
                cand_d.append(d_l)
                cand_q.append(sel[act])

        if not cand_q:
            return rt_mod.empty_topk(B, k)
        # per-row (distance, id) fold across shards — ALWAYS through the
        # one merge, so 1-shard and N-shard orderings are identical
        return merge_segment_topk(
            np.concatenate(cand_i, axis=0).astype(np.int64),
            np.concatenate(cand_d, axis=0),
            np.concatenate(cand_q), B, k)

    # -- hybrid / ooc: per-shard sub-engines ---------------------------------

    def _search_streamed(self, q, lo, hi, inc, assign, routes,
                         params: SearchParams, shard_stats):
        """Each shard with assigned selected cells runs its own
        sub-engine over the row subset that needs it; per-shard wave /
        batch schedules come from local incidence (wave packing is
        per-shard by construction). Duplicates across shards (replicated
        cells reachable via within-shard inter edges) collapse in the
        merge; recall parity, not id parity, is the contract here."""
        B, k = q.shape[0], params.k
        cand_i, cand_d, cand_q = [], [], []
        for sh in self.shards:
            inc_loc = (inc[:, sh.cells]
                       & (assign[sh.cells] == sh.sid)[None, :])
            act = np.nonzero(inc_loc.any(axis=1))[0]
            if len(act) == 0:
                continue
            # the sub-engine's own spans (hybrid.wave / ooc.batch / ...)
            # nest inside this one; only shard.search carries the shard=
            # attr, so per-shard wall sums never double-count children
            with span("shard.search", shard=sh.sid, mode=self.mode,
                      rows=len(act)) as ssp:
                with jax.default_device(sh.device):
                    ids_s, d_s = sh.engine.search(
                        q[act], lo[act], hi[act], params,
                        routes=_slice_routes(routes, act))
                ssp.attach((ids_s, d_s))
            st = shard_stats[sh.sid]
            st["active_rows"] += int(len(act))
            est = sh.engine.stats
            st["transfer_bytes"] += int(est.get("transfer_bytes", 0))
            for key in ("n_waves", "n_batches", "total_active"):
                if key in est:
                    st[f"engine_{key}"] = (st.get(f"engine_{key}", 0)
                                           + int(est[key]))
            cand_i.append(np.asarray(ids_s, np.int64))
            cand_d.append(np.asarray(d_s, np.float32))
            cand_q.append(act)
        if not cand_q:
            return rt_mod.empty_topk(B, k)
        return merge_segment_topk(
            np.concatenate(cand_i, axis=0),
            np.concatenate(cand_d, axis=0),
            np.concatenate(cand_q), B, k)
