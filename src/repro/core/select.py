"""Cell selection (paper Section 4.1, Alg. 2 lines 2-4).

A query's range box intersects a grid cell iff, per partitioned attribute,
``lo < cell_hi`` and ``hi >= cell_lo``. The paper evaluates this with one
GPU thread per cell; on TPU it is a single vectorized (B, S, p) predicate
over the cell-box tensors — no per-cell control flow at all.

Also provides the query->cell incidence matrix used by the out-of-core
scheduler (Section 5.2) and the adaptive-path split (|C_Q| vs S_thre).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def select_cells(lo, hi, cell_lo, cell_hi):
    """lo/hi: (B, m) query ranges; cell_lo/cell_hi: (S, p) grid boxes.

    Only the first p attribute columns participate (the partitioned
    attributes); the remaining m-p predicates are enforced during
    traversal. Returns bool (B, S) incidence.
    """
    p = cell_lo.shape[1]
    l = lo[:, None, :p]
    r = hi[:, None, :p]
    inter = (l < cell_hi[None]) & (r >= cell_lo[None])
    return inter.all(axis=2)


@jax.jit
def count_selected(mask):
    """|C_Q| per query (B,)."""
    return mask.sum(axis=1).astype(jnp.int32)


def incidence_numpy(lo: np.ndarray, hi: np.ndarray, cell_lo: np.ndarray,
                    cell_hi: np.ndarray) -> np.ndarray:
    """Host-side incidence for the out-of-core scheduler (bool (B, S))."""
    p = cell_lo.shape[1]
    l = lo[:, None, :p]
    r = hi[:, None, :p]
    inter = (l < cell_hi[None]) & (r >= cell_lo[None])
    return inter.all(axis=2)


@functools.partial(jax.jit, static_argnames=("s_thre",))
def adaptive_split(mask, *, s_thre: int):
    """Alg. 2 lines 5-8 split: lanes whose |C_Q| exceeds S_thre take the
    global-graph path. Returns bool (B,) ``use_global``."""
    return count_selected(mask) > s_thre
