"""Streaming mutability: append buffers, tombstones, cell maintenance.

The batch-built GMG index (ISSUE 5 tentpole) becomes incrementally
updatable without giving up any engine mode:

  insert  — new rows are *routed* through the existing quantile grid
            (``grid.assign_cells`` on the frozen ``seg_bounds``) into a
            bounded per-cell **append buffer** held host-side. Buffered
            rows are immediately searchable: every query brute-force
            scans the (few, by construction) buffered rows and folds
            them into the engine's top-k through the same deterministic
            ``merge_segment_topk`` path the disjunctive planner uses —
            incremental state never changes recall semantics.
  delete  — a **tombstone bitmap** over internal rows. At query time the
            tombstone is folded into the predicate mask (deleted rows'
            attributes read as NaN on the engine's resident attribute
            table, so no range can admit them): zero traversal change,
            graph connectivity intact. Space is reclaimed at compaction.
  flush   — buffered rows are spliced into the cell-contiguous layout
            (each cell's new rows append to its own dense range; every
            stored global id is remapped by a cumulative shift),
            quantized to int8, and linked into the cell's local graph —
            either a **device-side batched greedy-insert** pass (the
            same exact-kNN / traversal kernels the builder uses propose
            neighbors; an occlusion prune + reverse link attaches them)
            or a full local cell rebuild when the batch is a large
            fraction of the cell. Cross-cell edges are repaired via
            ``intercell`` for just the touched cells.
  compact — drop tombstoned rows and rebuild from the surviving rows
            (original-id order, same config/seed), so the compacted
            collection behaves identically to a fresh build on the
            survivors; external ids are preserved through ``perm``.

A cell whose buffer exceeds its bound triggers maintenance (flush of
that cell) automatically; cells that outgrow the cache arena's slot
quantum are reported (``oversized_cells``) and rebalanced at the next
``compact()`` — the split policy itself is deferred (see ROADMAP).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import gmg as gmg_mod
from repro.core import grid as grid_mod
from repro.core import graph as graph_mod
from repro.core import intercell, ordering, quantize
from repro.core.types import GMGIndex


@dataclasses.dataclass
class MutationState:
    """Host-side mutable companion of one (immutable-layout) GMGIndex."""

    next_id: int                      # next original id to hand out
    epoch: int = 0                    # bumps on every engine-visible change
    buf_vectors: np.ndarray = None    # (nb, dim) f32 pending rows
    buf_attrs: np.ndarray = None      # (nb, m) f32
    buf_ids: np.ndarray = None        # (nb,) i64 assigned original ids
    buf_cells: np.ndarray = None      # (nb,) i32 routed grid cell
    tombstone: np.ndarray = None      # (n,) bool over internal rows, lazy

    @classmethod
    def fresh(cls, index: GMGIndex) -> "MutationState":
        nid = int(index.perm.max()) + 1 if index.n else 0
        st = cls(next_id=nid)
        st.buf_vectors = np.empty((0, index.dim), np.float32)
        st.buf_attrs = np.empty((0, index.attrs.shape[1]), np.float32)
        st.buf_ids = np.empty(0, np.int64)
        st.buf_cells = np.empty(0, np.int32)
        return st

    @property
    def pending_rows(self) -> int:
        return int(self.buf_ids.shape[0])

    @property
    def deleted_rows(self) -> int:
        return 0 if self.tombstone is None else int(self.tombstone.sum())

    def pending_per_cell(self, n_cells: int) -> np.ndarray:
        return np.bincount(self.buf_cells, minlength=n_cells)

    def ensure_tombstone(self, n: int) -> np.ndarray:
        if self.tombstone is None:
            self.tombstone = np.zeros(n, bool)
        return self.tombstone

    def append(self, vectors: np.ndarray, attrs: np.ndarray,
               cells: np.ndarray) -> np.ndarray:
        """Buffer routed rows; returns their newly-assigned original ids."""
        nb = vectors.shape[0]
        ids = np.arange(self.next_id, self.next_id + nb, dtype=np.int64)
        self.next_id += nb
        self.buf_vectors = np.concatenate([self.buf_vectors, vectors])
        self.buf_attrs = np.concatenate([self.buf_attrs, attrs])
        self.buf_ids = np.concatenate([self.buf_ids, ids])
        self.buf_cells = np.concatenate(
            [self.buf_cells, cells.astype(np.int32)])
        return ids

    def drop_buffered(self, keep: np.ndarray) -> None:
        self.buf_vectors = self.buf_vectors[keep]
        self.buf_attrs = self.buf_attrs[keep]
        self.buf_ids = self.buf_ids[keep]
        self.buf_cells = self.buf_cells[keep]


def route_rows(index: GMGIndex, attrs: np.ndarray) -> np.ndarray:
    """Grid cell per new row via the frozen quantile segment bounds."""
    return grid_mod.assign_cells(np.asarray(attrs, np.float64),
                                 index.seg_bounds,
                                 index.config.seg_per_attr)


def masked_attrs(index: GMGIndex, tombstone: np.ndarray) -> np.ndarray:
    """Attribute table with tombstoned rows masked to NaN — NaN fails
    every range comparison, so deleted rows can never enter a result
    pool (traversal, dense scan, re-rank) while the graph still walks
    *through* them. This is the query-time AND of the tombstone bitmap
    into the predicate mask."""
    return np.where(tombstone[:, None], np.nan,
                    index.attrs).astype(np.float32)


# -- query-side fold of the append buffer -------------------------------------

def scan_buffer(state: MutationState, q: np.ndarray, lo: np.ndarray,
                hi: np.ndarray, k: int):
    """Brute-force top-k over the pending rows, one row per plan box.

    q/lo/hi are (T, ...) *plan* rows (already replicated per box for
    disjunctive plans). Returns ((T, k) i64 ids, (T, k) f32 exact d2),
    padded with -1/+inf, candidates ordered (distance, id) to match the
    deterministic segment merge downstream.
    """
    T = q.shape[0]
    out_i = np.full((T, k), -1, np.int64)
    out_d = np.full((T, k), np.inf, np.float32)
    nb = state.pending_rows
    if nb == 0 or T == 0:
        return out_i, out_d
    bv, ba, bids = state.buf_vectors, state.buf_attrs, state.buf_ids
    diff = q[:, None, :].astype(np.float32) - bv[None]
    d2 = (diff * diff).sum(axis=2).astype(np.float32)        # (T, nb)
    ok = ((ba[None] >= lo[:, None, :]) &
          (ba[None] <= hi[:, None, :])).all(axis=2)
    d2 = np.where(ok, d2, np.inf)
    # (distance, id) order so boundary ties resolve like the merge does
    order = np.lexsort((np.broadcast_to(bids, (T, nb)), d2), axis=1)
    kk = min(k, nb)
    top = order[:, :kk]
    td = np.take_along_axis(d2, top, axis=1)
    ti = np.where(np.isfinite(td), bids[top], -1)
    out_i[:, :kk] = ti
    out_d[:, :kk] = np.where(np.isfinite(td), td, np.inf)
    return out_i, out_d


# -- flush: splice buffered rows into the cell-contiguous layout --------------

def _greedy_link_cell(vectors_cell: np.ndarray, adj_local: np.ndarray,
                      n_old: int, config, seed: int) -> np.ndarray:
    """Link the cell's trailing new rows into its existing local graph.

    Neighbor candidates come from the same device kernels the builder
    uses — exact MXU top-k for cells under the exact-build threshold, a
    single-cell traversal (the batched greedy-insert pass) above it —
    then ``graph.insert_nodes`` occlusion-prunes and reverse-links.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.traversal import multi_cell_search
    from repro.kernels import ops

    n_c = vectors_cell.shape[0]
    n_new = n_c - n_old
    new_local = np.arange(n_old, n_c, dtype=np.int32)
    degree = adj_local.shape[1]
    k_cand = min(2 * degree, n_old)
    q_new = jnp.asarray(vectors_cell[n_old:])
    if n_old <= config.exact_build_threshold:
        _, idx = ops.topk_l2(q_new, jnp.asarray(vectors_cell[:n_old]),
                             k_cand)
        cand = np.asarray(idx, np.int32)
    else:
        m = 1   # predicate-free search: one dummy attribute column
        v_old = jnp.asarray(vectors_cell[:n_old])
        a_old = jnp.zeros((n_old, m), jnp.float32)
        adj_old = jnp.asarray(np.where(adj_local[:n_old] >= 0,
                                       adj_local[:n_old], -1))
        no_inter = jnp.full((n_old, 1, 1), -1, jnp.int32)
        cs = jnp.asarray(np.array([0, n_old], np.int32))
        lo = jnp.full((n_new, m), -jnp.inf, jnp.float32)
        hi = jnp.full((n_new, m), jnp.inf, jnp.float32)
        itin = jnp.zeros((n_new, 1), jnp.int32)
        ids_j, _ = multi_cell_search(
            v_old, a_old, adj_old, no_inter, cs, q_new, lo, hi, itin,
            jax.random.PRNGKey(seed), k=k_cand, ef=config.build_ef,
            entry_width=min(config.build_ef, 16),
            entry_random=min(config.build_ef, 16), entry_beam_l=1,
            max_iters=config.max_iters_per_cell, use_inter=False)
        cand = np.asarray(ids_j, np.int32)
    return graph_mod.insert_nodes(vectors_cell, adj_local, new_local,
                                  cand, alpha=config.prune_alpha)


def flush_index(index: GMGIndex, vec_new: np.ndarray, attrs_new: np.ndarray,
                ids_new: np.ndarray, cells_new: np.ndarray, *,
                seed: int = 0, graph_mode: str = "auto",
                greedy_frac: float = 0.05, repair_inter: bool = True):
    """Splice buffered rows into the index. Returns (new_index,
    old_to_new) where ``old_to_new`` maps old internal rows to their new
    positions (tombstones ride along on it).

    ``graph_mode``: "greedy" links new rows into the existing cell
    graphs (cheap, local), "rebuild" rebuilds each touched cell's graph
    from scratch (builder-quality), "auto" picks greedy only when the
    batch is a small fraction (< ``greedy_frac``) of the cell. A cell
    with no pre-existing rows always rebuilds — greedy candidates come
    from the old rows, so there is nothing to link into — which keeps
    the explicit "greedy" override from silently leaving rows
    disconnected.
    """
    if graph_mode not in ("auto", "greedy", "rebuild"):
        raise ValueError(f"unknown graph_mode {graph_mode!r}")
    cfg = index.config
    n, dim = index.vectors.shape
    S = index.n_cells
    n_new = int(vec_new.shape[0])
    if n_new == 0:
        return index, np.arange(n, dtype=np.int64)

    add = np.bincount(cells_new, minlength=S).astype(np.int64)
    shift_before = np.zeros(S, np.int64)
    np.cumsum(add[:-1], out=shift_before[1:])
    old_to_new = np.arange(n, dtype=np.int64) + shift_before[index.cell_of]
    cell_start2 = index.cell_start.astype(np.int64).copy()
    cell_start2[1:] += np.cumsum(add)

    # new rows land at the tail of their cell's (shifted) range,
    # insertion order preserved within a cell
    order_new = np.argsort(cells_new, kind="stable")
    pos_new = np.empty(n_new, np.int64)
    cursor = 0
    touched = np.nonzero(add)[0]
    for c in touched:
        k_c = int(add[c])
        end = cell_start2[c + 1]
        pos_new[order_new[cursor:cursor + k_c]] = np.arange(end - k_c, end)
        cursor += k_c

    n2 = n + n_new
    vectors2 = np.empty((n2, dim), np.float32)
    vectors2[old_to_new] = index.vectors
    vectors2[pos_new] = np.asarray(vec_new, np.float32)
    attrs2 = np.empty((n2, index.attrs.shape[1]), np.float32)
    attrs2[old_to_new] = index.attrs
    attrs2[pos_new] = np.asarray(attrs_new, np.float32)
    perm2 = np.empty(n2, np.int64)
    perm2[old_to_new] = index.perm
    perm2[pos_new] = np.asarray(ids_new, np.int64)
    cell_of2 = np.empty(n2, np.int32)
    cell_of2[old_to_new] = index.cell_of
    cell_of2[pos_new] = cells_new.astype(np.int32)

    def remap(a: np.ndarray) -> np.ndarray:
        safe = np.maximum(a, 0)
        shifted = safe + shift_before[index.cell_of[safe]]
        return np.where(a >= 0, shifted, -1).astype(np.int32)

    deg = index.intra_adj.shape[1]
    l = index.inter_adj.shape[2]
    intra2 = np.full((n2, deg), -1, np.int32)
    intra2[old_to_new] = remap(index.intra_adj)
    inter2 = np.full((n2, S, l), -1, np.int32)
    inter2[old_to_new] = remap(index.inter_adj.reshape(n, -1)).reshape(
        n, S, l)

    # per touched cell: greedy-link or rebuild the local graph
    for c in touched:
        s2, e2 = int(cell_start2[c]), int(cell_start2[c + 1])
        n_old_c = e2 - s2 - int(add[c])
        cellv = vectors2[s2:e2]
        adj_local = np.where(intra2[s2:e2] >= 0, intra2[s2:e2] - s2, -1)
        rebuild = (graph_mode == "rebuild"
                   or n_old_c == 0
                   or (graph_mode == "auto"
                       and add[c] > greedy_frac * n_old_c))
        if rebuild:
            adj_local = gmg_mod.cell_graph(cellv, cfg, seed=seed + int(c))
        else:
            adj_local = _greedy_link_cell(cellv, adj_local, n_old_c, cfg,
                                          seed=seed + int(c))
        intra2[s2:e2] = np.where(adj_local >= 0, adj_local + s2, -1)

    # cross-cell edges: repaired columns for the touched cells (every
    # row re-resolves its top-l into the changed cells), fresh columns
    # into the untouched cells for the new rows only
    if repair_inter:
        cols = intercell.inter_edges_for_queries(
            vectors2, attrs2, intra2, cell_start2, vectors2,
            l, cells=list(touched), ef=cfg.search_ef, seed=seed)
        for j, c in enumerate(touched):
            inter2[:, c, :] = cols[:, j, :]
            s2, e2 = int(cell_start2[c]), int(cell_start2[c + 1])
            inter2[s2:e2, c, :] = -1
    untouched = [int(c) for c in range(S) if add[c] == 0]
    if untouched:
        cols = intercell.inter_edges_for_queries(
            vectors2, attrs2, intra2, cell_start2, vectors2[pos_new],
            l, cells=untouched, ef=cfg.search_ef, seed=seed + 1)
        for j, c in enumerate(untouched):
            inter2[pos_new, c, :] = cols[:, j, :]

    # ordering sketch: count new rows into their cell's histogram
    hist2 = index.hist.copy()
    assign = ordering.assign_clusters(np.asarray(vec_new, np.float32),
                                      index.centroids)
    np.add.at(hist2, (cells_new.astype(np.int64), assign), 1.0)

    vq2 = vscale2 = None
    if index.vq is not None:
        qn, sn = quantize.quantize(np.asarray(vec_new, np.float32))
        vq2 = np.empty((n2, dim), np.int8)
        vq2[old_to_new] = index.vq
        vq2[pos_new] = qn
        vscale2 = np.empty(n2, np.float32)
        vscale2[old_to_new] = index.vscale
        vscale2[pos_new] = sn

    new_index = GMGIndex(
        config=cfg, vectors=vectors2, attrs=attrs2, perm=perm2,
        seg_bounds=index.seg_bounds, cell_of=cell_of2,
        cell_start=cell_start2.astype(np.int32),
        cell_lo=index.cell_lo, cell_hi=index.cell_hi,
        intra_adj=intra2, inter_adj=inter2,
        centroids=index.centroids, hist=hist2,
        attr_quantiles=gmg_mod.attr_quantile_grid(attrs2),
        vq=vq2, vscale=vscale2)
    return new_index, old_to_new


# -- compaction: rebuild on the surviving rows --------------------------------

def live_rows(index: GMGIndex, state: MutationState | None):
    """(vectors, attrs, original ids) of every live row — surviving base
    rows plus pending buffered rows — sorted by original id, i.e. the
    exact input a fresh build on the survivors would see."""
    if state is not None and state.tombstone is not None:
        keep = np.nonzero(~state.tombstone)[0]
    else:
        keep = np.arange(index.n)
    v = index.vectors[keep]
    a = index.attrs[keep]
    ids = index.perm[keep]
    if state is not None and state.pending_rows:
        v = np.concatenate([v, state.buf_vectors])
        a = np.concatenate([a, state.buf_attrs])
        ids = np.concatenate([ids, state.buf_ids])
    order = np.argsort(ids, kind="stable")
    return v[order], a[order], ids[order]


def compact_index(index: GMGIndex, state: MutationState | None,
                  seed: int = 0) -> GMGIndex:
    """Drop tombstoned rows, fold in pending buffers, rebuild. The
    result behaves identically to a fresh ``build_gmg`` on the surviving
    rows (same row order, config and seed); original ids survive through
    ``perm`` composition."""
    v, a, ids = live_rows(index, state)
    if v.shape[0] == 0:
        raise ValueError("cannot compact an empty collection")
    new_index = gmg_mod.build_gmg(v, a, index.config, seed=seed)
    new_index.perm = ids[new_index.perm]
    return new_index


def oversized_cells(index: GMGIndex,
                    state: MutationState | None = None) -> list:
    """Cells whose row count (incl. pending) exceeds the slot quantum
    the cache arena packs by (the build-time largest cell, rounded up) —
    rebalanced by the next ``compact()``; an in-place split policy is
    deferred (ROADMAP)."""
    from repro.core.runtime import cache_slot_rows
    sizes = np.diff(index.cell_start).astype(np.int64)
    if state is not None and state.pending_rows:
        sizes = sizes + state.pending_per_cell(index.n_cells)
    quantum = cache_slot_rows(index)
    return [int(c) for c in np.nonzero(sizes > quantum)[0]]
