"""Cell-oriented out-of-core execution (paper Section 5).

Internal layer: the public entry point is ``repro.api.Collection``, which
selects this streaming engine automatically when the declared
``device_budget_bytes`` cannot hold the fully-resident in-core searcher
(the remaining budget becomes the streamed graph window). Instantiate
``OutOfCoreEngine`` directly only for engine-level ablations.

Memory model (paper Fig. 5, adapted to TPU — DESIGN.md §2):

  host DRAM   : full fp32 vectors, full GMG index, cell metadata
  device HBM  : int8 quantized vectors + per-row scales (always resident)
                + a bounded *cell-batch window* of the graph (streamed)

Per query batch:
  (1) CPU: cell selection -> incidence matrix          (select.py)
  (2) CPU: greedy batch scheduling, Alg. 5             (scheduler.py)
  (3) CPU: gather each batch's partial index (intra edges + inter edges
      *between batch cells*), remapped to batch-local ids
  (4) async device_put of the partial index (JAX dispatch overlaps the
      copy of batch t+1 with the compute of batch t — the paper's
      PCIe/compute double buffering, on the TPU DMA path)
  (5) device: masked multi-cell traversal over the batch-local graph,
      distances on the int8 resident vectors
  (6) candidates flow back; (7) CPU re-ranks survivors with exact fp32
      and merges into the global per-query pool.

Entry-point propagation across batches follows the paper: each query
carries its current global candidate pool; when its next cell appears in
a later batch, the pool's inter-cell edges provide the entries.  Here the
carried state is the per-query top-ef candidate ids (host-side), re-seeded
into the device search of the next batch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import select as select_mod
from repro.core import scheduler as sched_mod
from repro.core.traversal import multi_cell_search_seeded
from repro.core.types import GMGIndex, SearchParams


@dataclasses.dataclass
class BatchPlan:
    """One streamed cell batch, host-side."""
    cells: list                     # global cell ids in this batch
    rows: np.ndarray                # global internal ids of batch rows
    local_start: np.ndarray         # (n_batch_cells + 1,) local CSR
    intra: np.ndarray               # (n_rows, d) batch-local adjacency
    inter: np.ndarray               # (n_rows, n_batch_cells, l) batch-local
    active_queries: np.ndarray      # query ids active in this batch
    itinerary: np.ndarray           # (n_active, n_batch_cells) local cell
                                    # order (-1 padded), most-promising first


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _remap_plan(index: GMGIndex, cells: list, incidence: np.ndarray,
                order_rank: np.ndarray, pad_cells: int,
                row_quantum: int = 4096) -> BatchPlan:
    """Gather + remap one batch's partial index (paper step 3).

    Shapes are padded (rows to a quantum, cells to the batch capacity) so
    every batch lowers to the *same* jitted program — the fixed-shape
    analogue of the paper's 'bounded and stable' HBM window."""
    S = index.n_cells
    starts = index.cell_start
    sizes = np.diff(starts)
    n_rows = int(sizes[cells].sum())
    n_pad = _round_up(max(n_rows, 1), row_quantum)

    # global->local row remap over the batch cells
    local_start = np.zeros(pad_cells + 1, np.int64)
    np.cumsum(sizes[cells], out=local_start[1:len(cells) + 1])
    local_start[len(cells) + 1:] = local_start[len(cells)]  # empty pad cells
    offset = np.zeros(S, np.int64)             # per-cell local offset delta
    in_batch = np.zeros(S, bool)
    rows = np.zeros(n_pad, np.int64)
    for li, c in enumerate(cells):
        s, e = int(starts[c]), int(starts[c + 1])
        rows[local_start[li]:local_start[li + 1]] = np.arange(s, e)
        offset[c] = local_start[li] - s         # deltas may be negative!
        in_batch[c] = True

    def remap(ids: np.ndarray) -> np.ndarray:
        """global internal ids -> batch-local ids (-1 if outside batch)."""
        safe = np.maximum(ids, 0)
        cell = index.cell_of[safe]
        out = np.where((ids >= 0) & in_batch[cell], safe + offset[cell], -1)
        return out.astype(np.int32)

    l = index.inter_adj.shape[2]
    intra = -np.ones((n_pad, index.intra_adj.shape[1]), np.int32)
    inter = -np.ones((n_pad, pad_cells, l), np.int32)
    real = rows[:n_rows]
    intra[:n_rows] = remap(index.intra_adj[real])
    inter[:n_rows, :len(cells)] = remap(index.inter_adj[real][:, cells, :])

    active = np.nonzero(incidence[:, cells].any(axis=1))[0]
    # per-active-query itinerary over batch-local cells, best rank first
    itin = np.full((len(active), pad_cells), -1, np.int32)
    for i, qid in enumerate(active):
        sel = [li for li, c in enumerate(cells) if incidence[qid, c]]
        sel.sort(key=lambda li: order_rank[qid, cells[li]])
        itin[i, :len(sel)] = sel
    return BatchPlan(cells=list(cells), rows=rows,
                     local_start=local_start.astype(np.int32),
                     intra=intra, inter=inter, active_queries=active,
                     itinerary=itin)


@dataclasses.dataclass
class OutOfCoreEngine:
    """Streaming searcher. Keeps int8 vectors resident; graph streamed."""

    index: GMGIndex
    hbm_budget_bytes: Optional[int] = None   # overrides config.batch_cells

    def __post_init__(self):
        idx = self.index
        assert idx.vq is not None, "out-of-core mode needs quantize=True"
        self.vq = jnp.asarray(idx.vq)               # resident (paper §5.1)
        self.vscale = jnp.asarray(idx.vscale)
        self.attrs_dev = jnp.asarray(idx.attrs)     # attrs ride along (f32)
        self.stats: dict = {}

    # -- batch size under an explicit HBM constraint ------------------------

    def cells_per_batch(self) -> int:
        cfg = self.index.config
        if self.hbm_budget_bytes is None:
            return cfg.batch_cells
        sizes = np.diff(self.index.cell_start)
        mean_cell = max(int(sizes.mean()), 1)
        per_cell = mean_cell * (
            self.index.intra_adj.shape[1] * 4          # intra row
            + self.index.inter_adj.shape[1] * self.index.inter_adj.shape[2] * 4)
        return max(1, int(self.hbm_budget_bytes // max(per_cell, 1)))

    # -- the pipeline --------------------------------------------------------

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               use_schedule: bool = True,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None):
        """Returns (ids (B, k) original ids, dists (B, k) exact fp32).

        With ``qmap`` (row -> original-query segment map from a
        disjunctive plan), rows are per-box sub-queries that stream
        through the cell batches as one widened batch; per-box survivors
        fold back to (n_queries, k) after the exact re-rank.
        """
        params = params or SearchParams()
        idx = self.index
        cfg = idx.config
        k, ef = params.k, params.ef or cfg.search_ef
        B = q.shape[0]
        if qmap is not None:
            qmap = np.asarray(qmap, np.int64)
            if qmap.shape != (B,):
                raise ValueError(
                    f"qmap shape {qmap.shape} != batch ({B},)")
            if n_queries is None:
                # inferring from qmap.max() would silently drop trailing
                # queries whose boxes were all pruned by the planner
                raise ValueError("n_queries is required with qmap")
        if B == 0:
            self.stats = {"n_batches": 0, "total_active": 0,
                          "cells_per_batch": self.cells_per_batch(),
                          "transfer_bytes": 0, "wall_seconds": 0.0}
            nq = n_queries if qmap is not None else 0
            return (np.full((nq, k), -1, np.int64),
                    np.full((nq, k), np.inf, np.float32))
        t_start = time.perf_counter()

        # (1) selection + ordering ranks (host)
        inc = select_mod.incidence_numpy(lo, hi, idx.cell_lo, idx.cell_hi)
        rank = self._order_ranks(q, inc)

        # (2) scheduling (Alg. 5) vs naive (ablation Table 3)
        b = self.cells_per_batch()
        if use_schedule:
            batches = sched_mod.schedule_cells(inc, b)
        else:
            batches = sched_mod.naive_schedule(inc, b)
        self.stats = {
            "n_batches": len(batches),
            "total_active": sched_mod.total_active(inc, batches),
            "cells_per_batch": b,
        }

        # carried per-query candidate pool (global internal ids + dists)
        pool_ids = np.full((B, ef), -1, np.int32)
        pool_d = np.full((B, ef), np.inf, np.float32)

        qd = jnp.asarray(q)
        lod, hid = jnp.asarray(lo), jnp.asarray(hi)
        key = jax.random.PRNGKey(params.seed)

        # (3)+(4) stage the first batch; inside the loop stage batch t+1
        # before blocking on batch t's results => JAX's async dispatch
        # overlaps the H2D copy with device compute (paper Fig. 5(b)).
        plans = [_remap_plan(idx, cells, inc, rank, pad_cells=b)
                 for cells in batches]
        staged = self._stage(plans[0]) if plans else None

        transfer_bytes = 0
        for t, plan in enumerate(plans):
            dev = staged
            transfer_bytes += plan.intra.nbytes + plan.inter.nbytes
            if t + 1 < len(plans):
                staged = self._stage(plans[t + 1])   # prefetch next batch

            if len(plan.active_queries) == 0:
                continue
            key, sub = jax.random.split(key)
            got_ids, got_d = self._run_batch(plan, dev, qd, lod, hid,
                                             pool_ids, pool_d, k, ef, sub)
            # (7) merge into carried pool (host, cheap). Seeds re-found in
            # later batches would otherwise duplicate and crowd the pool.
            act = plan.active_queries
            merged_ids = np.concatenate([pool_ids[act], got_ids], axis=1)
            merged_d = np.concatenate([pool_d[act], got_d], axis=1)
            for r, qid in enumerate(act):
                ordr = np.argsort(merged_d[r], kind="stable")
                seen, mi, md = set(), [], []
                for j in ordr:
                    i = int(merged_ids[r, j])
                    if i < 0 or i in seen:
                        continue
                    seen.add(i)
                    mi.append(i)
                    md.append(merged_d[r, j])
                    if len(mi) == ef:
                        break
                pool_ids[qid, :len(mi)] = mi
                pool_ids[qid, len(mi):] = -1
                pool_d[qid, :len(md)] = md
                pool_d[qid, len(md):] = np.inf

        self.stats["transfer_bytes"] = transfer_bytes

        # CPU exact re-rank of survivors (paper step 7)
        out_i = np.full((B, k), -1, np.int64)
        out_d = np.full((B, k), np.inf, np.float32)
        rerank_n = min(ef, max(k * cfg.rerank_mult, k))
        for bqi in range(B):
            cand = pool_ids[bqi][pool_ids[bqi] >= 0][:rerank_n]
            if len(cand) == 0:
                continue
            vecs = idx.vectors[cand]
            d_exact = ((vecs - q[bqi]) ** 2).sum(axis=1)
            ok = ((idx.attrs[cand] >= lo[bqi]) &
                  (idx.attrs[cand] <= hi[bqi])).all(axis=1)
            d_exact = np.where(ok, d_exact, np.inf)
            ordr = np.argsort(d_exact)[:k]
            keep = d_exact[ordr] < np.inf
            ids = np.where(keep, idx.perm[cand[ordr]], -1)
            out_i[bqi, :len(ids)] = ids
            out_d[bqi, :len(ids)] = np.where(keep, d_exact[ordr], np.inf)
        if qmap is not None:
            from repro.core.search import merge_segment_topk
            self.stats["n_boxes"] = B
            out_i, out_d = merge_segment_topk(out_i, out_d, qmap,
                                              n_queries, k)
        self.stats["wall_seconds"] = time.perf_counter() - t_start
        return out_i, out_d

    # -- helpers -------------------------------------------------------------

    def _order_ranks(self, q: np.ndarray, inc: np.ndarray) -> np.ndarray:
        """(B, S) traversal rank per (query, cell) from the cluster vote
        (lower = search earlier; untouched cells get a large rank)."""
        from repro.core.ordering import order_cells
        idx = self.index
        S = idx.n_cells
        order, _ = order_cells(
            jnp.asarray(q), jnp.asarray(idx.centroids), jnp.asarray(idx.hist),
            jnp.asarray(inc), top_m=idx.config.top_m_clusters, T=S)
        order = np.asarray(order)
        rank = np.full((q.shape[0], S), S + 1, np.int32)
        for bqi in range(q.shape[0]):
            sel = order[bqi][order[bqi] >= 0]
            rank[bqi, sel] = np.arange(len(sel))
        return rank

    def _stage(self, plan: BatchPlan):
        """Async H2D staging of one batch's partial index."""
        return {
            "intra": jax.device_put(plan.intra),
            "inter": jax.device_put(plan.inter),
            "local_start": jax.device_put(plan.local_start),
            "rows": jax.device_put(plan.rows.astype(np.int32)),
        }

    def _run_batch(self, plan: BatchPlan, dev, qd, lod, hid,
                   pool_ids, pool_d, k: int, ef: int, key):
        """Device traversal of one batch (step 5-6). Returns candidate
        (global ids, int8 distances) for the active queries."""
        idx = self.index
        cfg = idx.config
        act = plan.active_queries
        nB = len(act)
        # pad active set to pow2 to keep jit cache warm
        padded = 1
        while padded < nB:
            padded *= 2
        sel = np.concatenate([act, np.repeat(act[:1], padded - nB)])

        # seed entries: carried pool's inter edges into batch cells happen
        # via inter_adj remap below; plus the pool's own members that live
        # inside this batch (remapped), plus randoms added device-side.
        seed_global = pool_ids[sel]                       # (padded, ef)
        cell = idx.cell_of[np.maximum(seed_global, 0)]
        # local offset per cell (recompute, small); deltas may be negative
        offset = np.zeros(idx.n_cells, np.int64)
        in_batch = np.zeros(idx.n_cells, bool)
        for li, c in enumerate(plan.cells):
            offset[c] = int(plan.local_start[li]) - int(idx.cell_start[c])
            in_batch[c] = True
        seed_local = np.where((seed_global >= 0) & in_batch[cell],
                              seed_global + offset[cell], -1).astype(np.int32)

        itin = plan.itinerary[
            np.concatenate([np.arange(nB),
                            np.zeros(padded - nB, np.int64)])]

        ids_l, d_l = multi_cell_search_seeded(
            self.vq, self.vscale, self.attrs_dev,
            dev["intra"], dev["inter"], dev["local_start"], dev["rows"],
            qd[sel], lod[sel], hid[sel], jnp.asarray(itin),
            jnp.asarray(seed_local), key,
            k=max(k, min(ef, 2 * k)), ef=ef,
            entry_width=cfg.entry_width, entry_random=cfg.entry_random,
            entry_beam_l=cfg.entry_beam_l,
            max_iters=cfg.max_iters_per_cell)
        ids_l = np.asarray(ids_l[:nB])
        d_l = np.asarray(d_l[:nB])
        ids_g = np.where(ids_l >= 0, plan.rows[np.maximum(ids_l, 0)], -1)
        return ids_g.astype(np.int32), d_l


def multihost_plan(incidence: np.ndarray, n_hosts: int, batch_size: int):
    """Garfield at fleet scale (DESIGN.md §5): cells shard round-robin
    across hosts; each host runs Alg. 5 over its resident cells. Returns
    (host_of_cell (S,), per-host batch lists, per-host active totals)."""
    S = incidence.shape[1]
    host_of = np.arange(S) % n_hosts
    plans, totals = [], []
    for h in range(n_hosts):
        cells = [c for c in range(S)
                 if host_of[c] == h and incidence[:, c].any()]
        batches = sched_mod.schedule_cells(incidence, batch_size, cells)
        plans.append(batches)
        totals.append(sched_mod.total_active(incidence, batches))
    return host_of, plans, totals
