"""Cell-oriented out-of-core execution (paper Section 5).

Internal layer: the public entry point is ``repro.api.Collection``, which
selects this streaming engine (``mode="ooc"``) when the declared
``device_budget_bytes`` cannot hold either the fully-resident in-core
searcher or a useful hybrid graph cache (the remaining budget becomes the
streamed graph window). Instantiate ``OutOfCoreEngine`` directly only for
engine-level ablations.

Engine-mode matrix (storage x graph residency x seeding) — this module
is the **ooc** row; all three run on the same traversal core via
``repro.core.runtime.CellRuntime``:

  mode    | vector storage        | graph residency        | seeding
  --------+-----------------------+------------------------+--------------
  incore  | fp32 resident         | fully resident         | fresh beam
  hybrid  | int8 resident +rerank | LRU slot cache         | carried pool
  ooc     | int8 resident +rerank | streamed batch window  | carried pool

Memory model (paper Fig. 5, adapted to TPU — DESIGN.md §2):

  host DRAM   : full fp32 vectors, full GMG index, cell metadata
  device HBM  : int8 quantized vectors + per-row scales (always resident)
                + a bounded *cell-batch window* of the graph (streamed)

Per query batch:
  (1) CPU: cell selection -> incidence matrix          (select.py)
  (2) CPU: greedy batch scheduling, Alg. 5             (scheduler.py)
  (3) CPU: gather each batch's partial index (intra edges + inter edges
      *between batch cells*), remapped to batch-local ids
  (4) async device_put of the partial index (JAX dispatch overlaps the
      copy of batch t+1 with the compute of batch t — the paper's
      PCIe/compute double buffering, on the TPU DMA path)
  (5) device: masked multi-cell traversal over the batch-local graph,
      distances on the int8 resident vectors
  (6) candidates flow back; (7) CPU re-ranks survivors with exact fp32
      and merges into the global per-query pool.

Entry-point propagation across batches follows the paper: each query
carries its current global candidate pool (``runtime.CandidatePool``);
when its next cell appears in a later batch, the pool's members are
remapped into the batch and re-seed the device search.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax

from repro.core import runtime as rt_mod
from repro.core import select as select_mod
from repro.core import selectivity as sel_mod
from repro.core import scheduler as sched_mod
from repro.core.runtime import CandidatePool, CellRuntime, round_up
from repro.core.traversal import GraphView
from repro.core.types import GMGIndex, SearchParams
from repro.obs.metrics import MetricsRegistry, PassMetrics
from repro.obs.trace import span


@dataclasses.dataclass
class BatchPlan:
    """One streamed cell batch, host-side."""
    cells: list                     # global cell ids in this batch
    rows: np.ndarray                # global internal ids of batch rows
    local_start: np.ndarray         # (n_batch_cells + 1,) local CSR
    intra: np.ndarray               # (n_rows, d) batch-local adjacency
    inter: np.ndarray               # (n_rows, n_batch_cells, l) batch-local
    active_queries: np.ndarray      # query ids active in this batch
    itinerary: np.ndarray           # (n_active, n_batch_cells) local cell
                                    # order (-1 padded), most-promising first


def _remap_plan(index: GMGIndex, cells: list, incidence: np.ndarray,
                order_rank: np.ndarray, pad_cells: int,
                row_quantum: int = 4096) -> BatchPlan:
    """Gather + remap one batch's partial index (paper step 3).

    Shapes are padded (rows to a quantum, cells to the batch capacity) so
    every batch lowers to the *same* jitted program — the fixed-shape
    analogue of the paper's 'bounded and stable' HBM window."""
    S = index.n_cells
    starts = index.cell_start
    sizes = np.diff(starts)
    n_rows = int(sizes[cells].sum())
    n_pad = round_up(max(n_rows, 1), row_quantum)

    # global->local row remap over the batch cells
    local_start = np.zeros(pad_cells + 1, np.int64)
    np.cumsum(sizes[cells], out=local_start[1:len(cells) + 1])
    local_start[len(cells) + 1:] = local_start[len(cells)]  # empty pad cells
    offset = np.zeros(S, np.int64)             # per-cell local offset delta
    in_batch = np.zeros(S, bool)
    rows = np.zeros(n_pad, np.int64)
    for li, c in enumerate(cells):
        s, e = int(starts[c]), int(starts[c + 1])
        rows[local_start[li]:local_start[li + 1]] = np.arange(s, e)
        offset[c] = local_start[li] - s         # deltas may be negative!
        in_batch[c] = True

    def remap(ids: np.ndarray) -> np.ndarray:
        """global internal ids -> batch-local ids (-1 if outside batch)."""
        safe = np.maximum(ids, 0)
        cell = index.cell_of[safe]
        out = np.where((ids >= 0) & in_batch[cell], safe + offset[cell], -1)
        return out.astype(np.int32)

    l = index.inter_adj.shape[2]
    intra = -np.ones((n_pad, index.intra_adj.shape[1]), np.int32)
    inter = -np.ones((n_pad, pad_cells, l), np.int32)
    real = rows[:n_rows]
    intra[:n_rows] = remap(index.intra_adj[real])
    inter[:n_rows, :len(cells)] = remap(index.inter_adj[real][:, cells, :])

    active = np.nonzero(incidence[:, cells].any(axis=1))[0]
    # per-active-query itinerary over batch-local cells, best rank first
    itin = np.full((len(active), pad_cells), -1, np.int32)
    for i, qid in enumerate(active):
        sel = [li for li, c in enumerate(cells) if incidence[qid, c]]
        sel.sort(key=lambda li: order_rank[qid, cells[li]])
        itin[i, :len(sel)] = sel
    return BatchPlan(cells=list(cells), rows=rows,
                     local_start=local_start.astype(np.int32),
                     intra=intra, inter=inter, active_queries=active,
                     itinerary=itin)


@dataclasses.dataclass
class OutOfCoreEngine:
    """Streaming searcher. Keeps int8 vectors resident; graph streamed."""

    index: GMGIndex
    hbm_budget_bytes: Optional[int] = None   # overrides config.batch_cells
    rerank: str = "device"                   # | "host" (identical ids)

    def __post_init__(self):
        if self.rerank not in rt_mod.RERANKS:
            raise ValueError(f"unknown rerank {self.rerank!r}; "
                             f"expected one of {rt_mod.RERANKS}")
        # NOTE: unlike the hybrid engine, scheduling here deliberately
        # takes no residency hint — the streaming engine keeps no graph
        # state across calls (every batch re-stages and the prefetch
        # pipeline overlaps the copies regardless of order), so a
        # cache-affinity bias would only make identical query batches
        # schedule differently depending on call history, for zero
        # transfer benefit. The cache-aware placement key + wave order
        # live where a cache does: core/hybrid.py's CellCache.
        self.rt = CellRuntime(self.index, storage="int8")
        # engine-level views (ablation benches/tests poke these directly)
        self.vq = self.rt.store.vq                  # resident (paper §5.1)
        self.vscale = self.rt.store.vscale
        self.attrs_dev = self.rt.attrs_dev          # attrs ride along (f32)
        self.stats: dict = {}
        # per-engine obs registry: per-pass stats dicts are views over
        # increments into it (PassMetrics, ISSUE 10)
        self.metrics = MetricsRegistry()

    def refresh_index(self, index: GMGIndex) -> None:
        """Delete path (core.mutable): adopt a same-layout index whose
        attrs carry tombstone NaN masks — one attr re-upload, the int8
        residents and streaming plans are unaffected."""
        self.index = index
        self.rt.refresh_index(index)
        self.attrs_dev = self.rt.attrs_dev

    # -- batch size under an explicit HBM constraint ------------------------

    def cells_per_batch(self) -> int:
        cfg = self.index.config
        if self.hbm_budget_bytes is None:
            return cfg.batch_cells
        sizes = np.diff(self.index.cell_start)
        mean_cell = max(int(sizes.mean()), 1)
        per_cell = mean_cell * (
            self.index.intra_adj.shape[1] * 4          # intra row
            + self.index.inter_adj.shape[1] * self.index.inter_adj.shape[2] * 4)
        return max(1, int(self.hbm_budget_bytes // max(per_cell, 1)))

    # -- the pipeline --------------------------------------------------------

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               use_schedule: bool = True,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None,
               route_k: Optional[np.ndarray] = None,
               routes: Optional[sel_mod.RouteDecision] = None):
        """Returns (ids (B, k) original ids, dists (B, k) exact fp32).

        With ``qmap`` (row -> original-query segment map from a
        disjunctive plan), rows are per-box sub-queries that stream
        through the cell batches as one widened batch; per-box survivors
        fold back to (n_queries, k) after the exact re-rank.

        ``routes`` (or ``route_k`` + ``params.cost``, computed here)
        splits rows by the per-box cost model: ultra-selective rows
        never enter the streaming pipeline — a fused masked scan over
        the resident int8 table fills their candidate pool directly (no
        graph batches staged for them, no transfer), and the exact fp32
        re-rank finishes them as usual. Mid-range rows stream with
        ``ef`` scaled per effort bucket.
        """
        params = params or SearchParams()
        idx = self.index
        cfg = idx.config
        k, ef = params.k, params.ef or cfg.search_ef
        B = q.shape[0]
        if qmap is not None:
            qmap = rt_mod.check_qmap(qmap, B)
            if n_queries is None:
                # inferring from qmap.max() would silently drop trailing
                # queries whose boxes were all pruned by the planner
                raise ValueError("n_queries is required with qmap")
        if B == 0:
            self.stats = {"n_batches": 0, "total_active": 0,
                          "cells_per_batch": self.cells_per_batch(),
                          "transfer_bytes": 0, "rerank": self.rerank,
                          "wall_seconds": 0.0}
            nq = n_queries if qmap is not None else 0
            return rt_mod.empty_topk(nq, k)
        t_start = time.perf_counter()

        # (1) selection + per-box routing (host)
        inc = select_mod.incidence_numpy(lo, hi, idx.cell_lo, idx.cell_hi)
        if routes is None:
            rk = (np.full(B, k, np.int64) if route_k is None
                  else np.asarray(route_k, np.int64))
            routes = sel_mod.route_boxes(idx, lo, hi, rk,
                                         cost=params.cost, inc=inc)
        use_dense = routes.route == sel_mod.ROUTE_DENSE

        # carried per-query candidate pool (global internal ids + dists)
        pool = CandidatePool(B, ef)
        key = jax.random.PRNGKey(params.seed)
        n_batches = total_active = transfer_bytes = 0
        est_err = None

        # dense route: one fused int8 masked scan fills the pool — these
        # rows stage no graph batches and stream no bytes; the exact
        # fp32 re-rank below finishes them like any streamed row
        dense_rows = np.nonzero(use_dense)[0]
        if len(dense_rows) > 0:
            with span("ooc.dense", rows=len(dense_rows)) as dsp:
                ids_d, d_d, n_qual = rt_mod.masked_dense_scan(
                    self.rt, q[dense_rows], lo[dense_rows], hi[dense_rows],
                    inc[dense_rows], ef)
                dsp.attach((ids_d, d_d))
                pool.merge(dense_rows, ids_d, d_d)
            est_err = float(np.mean(
                np.abs(routes.est_rows[dense_rows] - n_qual)
                / np.maximum(n_qual, 1.0)))

        b = self.cells_per_batch()
        graph_rows = ~use_dense & inc.any(axis=1)
        rank = (rt_mod.order_ranks(idx, q, inc)
                if graph_rows.any() else None)
        for mult in np.unique(routes.ef_mult[graph_rows]):
            rows_b = graph_rows & (routes.ef_mult == mult)
            inc_b = inc & rows_b[:, None]
            ef_run = ef * int(mult)

            # (2) scheduling (Alg. 5) vs naive (ablation Table 3)
            if use_schedule:
                batches = sched_mod.schedule_cells(inc_b, b)
            else:
                batches = sched_mod.naive_schedule(inc_b, b)
            n_batches += len(batches)
            total_active += sched_mod.total_active(inc_b, batches)

            # (3)+(4) stage the first batch; inside the loop stage batch
            # t+1 before blocking on batch t's results => JAX's async
            # dispatch overlaps the H2D copy with device compute
            # (paper Fig. 5(b)).
            plans = [_remap_plan(idx, cells, inc_b, rank, pad_cells=b)
                     for cells in batches]
            staged = self._stage(plans[0]) if plans else None

            for t, plan in enumerate(plans):
                dev = staged
                transfer_bytes += plan.intra.nbytes + plan.inter.nbytes
                # the batch span covers dispatch + next-batch staging +
                # the blocking merge, so the prefetched ooc.stage child
                # visibly overlaps batch t's device compute in a trace
                with span("ooc.batch", batch=t, cells=len(plan.cells),
                          active=len(plan.active_queries)) as bsp:
                    if t + 1 < len(plans):
                        staged = self._stage(plans[t + 1])  # prefetch next
                    if len(plan.active_queries) == 0:
                        continue
                    key, sub = jax.random.split(key)
                    got_ids, got_d = self._run_batch(
                        plan, dev, q, lo, hi, pool, k, ef, sub, params,
                        ef_run=ef_run)
                    bsp.attach((got_ids, got_d))
                    # (7) merge into carried pool (host, deterministic
                    # fold). Seeds re-found in later batches would
                    # otherwise duplicate and crowd the pool.
                    pool.merge(plan.active_queries, got_ids, got_d)

        # pass stats as views over the engine registry (ISSUE 10): the
        # same call writes the lifetime counter and the dict entry
        pm = PassMetrics(self.metrics)
        pm.count("n_batches", n_batches)
        pm.count("total_active", total_active)
        pm.put("cells_per_batch", b)
        pm.put("rerank", self.rerank)
        pm.count("transfer_bytes", transfer_bytes)
        pm.update_counts(routes.counts())
        if est_err is not None:
            pm.set("est_rel_err_dense", est_err)
        self.stats = pm.stats()

        # exact re-rank of survivors (paper step 7): fused on device by
        # default, host loop as the legacy/ablation path (identical ids)
        with span("ooc.rerank", rerank=self.rerank) as rsp:
            if self.rerank == "device":
                out_i, out_d = rt_mod.exact_rerank_device(
                    idx, self.rt.attrs_dev, pool, q, lo, hi, k,
                    cfg.rerank_mult)
            else:
                out_i, out_d = rt_mod.exact_rerank(idx, pool, q, lo, hi, k,
                                                   cfg.rerank_mult)
            rsp.attach((out_i, out_d))
        if qmap is not None:
            pm.count("n_boxes", B)
            out_i, out_d = rt_mod.merge_segment_topk(out_i, out_d, qmap,
                                                     n_queries, k)
        pm.set("wall_seconds", time.perf_counter() - t_start)
        return out_i, out_d

    # -- helpers -------------------------------------------------------------

    def _order_ranks(self, q: np.ndarray, inc: np.ndarray) -> np.ndarray:
        """Back-compat shim for engine-level tests; see runtime."""
        return rt_mod.order_ranks(self.index, q, inc)

    def _stage(self, plan: BatchPlan):
        """Async H2D staging of one batch's partial index."""
        with span("ooc.stage", cells=len(plan.cells),
                  bytes=plan.intra.nbytes + plan.inter.nbytes):
            return {
                "intra": jax.device_put(plan.intra),
                "inter": jax.device_put(plan.inter),
                "local_start": jax.device_put(plan.local_start),
                "rows": jax.device_put(plan.rows.astype(np.int32)),
            }

    def _run_batch(self, plan: BatchPlan, dev, q, lo, hi,
                   pool: CandidatePool, k: int, ef: int, key,
                   params: SearchParams, ef_run: Optional[int] = None):
        """Device traversal of one batch (step 5-6). Returns candidate
        (global ids, int8 distances) for the active queries. ``ef_run``
        widens the traversal pool for mid-range effort buckets; the
        carried pool (and with it the re-rank width) stays at ``ef``."""
        idx = self.index
        act = plan.active_queries

        # seed entries: carried pool's inter edges into batch cells happen
        # via inter_adj remap below; plus the pool's own members that live
        # inside this batch (remapped), plus randoms added device-side.
        seed_global = pool.ids[act]                       # (n_act, ef)
        cell = idx.cell_of[np.maximum(seed_global, 0)]
        # local offset per cell (recompute, small); deltas may be negative
        offset = np.zeros(idx.n_cells, np.int64)
        in_batch = np.zeros(idx.n_cells, bool)
        for li, c in enumerate(plan.cells):
            offset[c] = int(plan.local_start[li]) - int(idx.cell_start[c])
            in_batch[c] = True
        seed_local = np.where((seed_global >= 0) & in_batch[cell],
                              seed_global + offset[cell], -1).astype(np.int32)

        graph = GraphView(intra=dev["intra"], inter=dev["inter"],
                          cell_start=dev["local_start"], rows=dev["rows"])
        ids_l, d_l = self.rt.run(
            graph, q[act], lo[act], hi[act], key,
            k=max(k, min(ef, 2 * k)), ef=ef_run or ef,
            cell_order=plan.itinerary, seeds=seed_local,
            pool_reuse=params.pool_reuse)
        ids_g = np.where(ids_l >= 0, plan.rows[np.maximum(ids_l, 0)], -1)
        return ids_g.astype(np.int32), d_l


def multihost_plan(incidence: np.ndarray, n_hosts: int, batch_size: int):
    """Garfield at fleet scale (DESIGN.md §5): cells shard round-robin
    across hosts; each host runs Alg. 5 over its resident cells. Returns
    (host_of_cell (S,), per-host batch lists, per-host active totals)."""
    S = incidence.shape[1]
    host_of = np.arange(S) % n_hosts
    plans, totals = sched_mod.shard_schedules(
        incidence, host_of, n_hosts, batch_size)
    return host_of, plans, totals
