"""Intra-cell graph construction (paper Section 3.2, Alg. 1 lines 6-9).

The paper builds a CAGRA graph per cell (NN-descent -> rank reorder ->
prune). TPU adaptation (see DESIGN.md §2):

- small cells (n_c <= exact_build_threshold): the *exact* kNN graph via the
  streamed fused-topk MXU kernel. At paper scale (n/S ~ 62k, d=128) exact
  kNN is ~n_c^2·dim MACs ≈ 0.5 TFLOP per cell — cheaper on an MXU than
  NN-descent's gather-heavy iterations, and strictly higher quality.
- large cells: vectorized NN-descent with fixed-degree tables (neighbors +
  sampled reverse neighbors joined each round), which is CAGRA's phase 1
  with the irregular per-thread queues replaced by fixed-shape batched
  top-k merges.

Both paths finish with CAGRA-style degree reduction: candidates are taken
in rank order and an edge is kept unless it is "detourable" (Vamana/CAGRA
occlusion rule: exists kept w with alpha*dis(w,v) < dis(u,v)), then
leftover slots are filled with reverse edges — the directed-graph
connectivity fix CAGRA applies.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops


# ---------------------------------------------------------------------------
# exact kNN path
# ---------------------------------------------------------------------------

def exact_knn(vectors: np.ndarray, k: int, chunk: int = 2048) -> np.ndarray:
    """(n_c, k) nearest-neighbor ids (self excluded) via streamed top-k."""
    n = vectors.shape[0]
    v = jnp.asarray(vectors)
    out = np.empty((n, k), dtype=np.int32)
    kk = min(k + 1, n)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        _, idx = ops.topk_l2(v[s:e], v, kk)
        idx = np.asarray(idx)
        rows = []
        for r, gi in enumerate(range(s, e)):
            row = idx[r][idx[r] != gi][:k]
            if len(row) < k:  # degenerate tiny cells: pad with -1
                row = np.concatenate([row, -np.ones(k - len(row), np.int32)])
            rows.append(row)
        out[s:e] = np.stack(rows)
    return out


# ---------------------------------------------------------------------------
# NN-descent path (fixed-shape, batched)
# ---------------------------------------------------------------------------

def _merge_topk_rows(ids_a, d_a, ids_b, d_b, k):
    """Row-wise merge of two (n, *) candidate sets into best-k by distance,
    deduplicating ids (duplicates get +inf)."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    # dedup: sort by id, mark repeats
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((ids.shape[0], 1), bool), ids_s[:, 1:] == ids_s[:, :-1]],
        axis=1)
    d_s = jnp.where(dup | (ids_s < 0), jnp.inf, d_s)
    neg, pos = jax.lax.top_k(-d_s, k)
    return jnp.take_along_axis(ids_s, pos, axis=1), -neg


def nn_descent(vectors: np.ndarray, k: int, iters: int = 10,
               sample: int = 8, seed: int = 0):
    """Fixed-degree NN-descent. Returns (n_c, k) int32 neighbor ids."""
    n, dim = vectors.shape
    v = jnp.asarray(vectors)
    rng = np.random.default_rng(seed)

    ids = rng.integers(0, n, size=(n, k)).astype(np.int32)
    # avoid self-loops in init
    ids = np.where(ids == np.arange(n)[:, None], (ids + 1) % n, ids)
    ids = jnp.asarray(ids)
    dists = ops.gather_l2(v, v, ids)

    @jax.jit
    def step(ids, dists, rkey):
        # forward sample: `sample` random neighbors, then their neighbors
        k1, k2 = jax.random.split(rkey)
        pick = jax.random.randint(k1, (n, sample), 0, k)
        fwd = jnp.take_along_axis(ids, pick, axis=1)          # (n, sample)
        cand_fwd = ids[jnp.maximum(fwd, 0)].reshape(n, sample * k)
        # reverse sample: invert a random slot's edge via scatter
        slot = jax.random.randint(k2, (n,), 0, k)
        tgt = jnp.take_along_axis(ids, slot[:, None], axis=1)[:, 0]  # (n,)
        rev = jnp.full((n, sample), -1, jnp.int32)
        src = jax.random.randint(k2, (n,), 0, sample)
        rev = rev.at[jnp.maximum(tgt, 0), src].set(
            jnp.arange(n, dtype=jnp.int32), mode="drop")
        cand = jnp.concatenate([cand_fwd, rev], axis=1)
        cand = jnp.where(cand == jnp.arange(n, dtype=jnp.int32)[:, None],
                         -1, cand)
        cd = ops.gather_l2(v, v, cand)
        return _merge_topk_rows(ids, dists, cand, cd, k)

    key = jax.random.PRNGKey(seed)
    for _ in range(iters):
        key, sub = jax.random.split(key)
        ids, dists = step(ids, dists, sub)
    return np.asarray(ids)


# ---------------------------------------------------------------------------
# connectivity: long-range candidates + component repair
# ---------------------------------------------------------------------------

def _add_random_candidates(knn: np.ndarray, n_rand: int, seed: int = 0):
    """Append Vamana-style random long-range candidates to each node's
    pruning pool. Under the alpha-occlusion rule a far candidate c is kept
    exactly when no kept near neighbor w 'detours' it (alpha*d(w,c) <
    d(u,c)) — by distance concentration far candidates are rarely
    detourable, so a few survive as long edges, giving the small-world
    property a bare kNN graph lacks (clustered data fragments otherwise)."""
    n = knn.shape[0]
    if n <= 1 or n_rand <= 0:
        return knn
    rng = np.random.default_rng(seed)
    rand = rng.integers(0, n, size=(n, n_rand)).astype(np.int32)
    rand = np.where(rand == np.arange(n)[:, None], (rand + 1) % n, rand)
    return np.concatenate([knn, rand], axis=1)


class _UnionFind:
    def __init__(self, n: int):
        self.p = np.arange(n)

    def find(self, x: int) -> int:
        p = self.p
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[ra] = rb


def repair_connectivity(vectors: np.ndarray, adj: np.ndarray,
                        reps_per_comp: int = 16, seed: int = 0) -> np.ndarray:
    """NSG/DiskANN-style repair: bridge every weakly-connected component to
    the largest one through its closest representative pair, overwriting
    the last (worst-rank) adjacency slot on both endpoints. Guarantees the
    undirected graph is connected, preserving fixed degree."""
    n, deg = adj.shape
    if n <= 1:
        return adj
    uf = _UnionFind(n)
    us, vs = np.nonzero(adj >= 0)
    for u, v_ in zip(us, adj[us, vs]):
        uf.union(int(u), int(v_))
    roots = np.array([uf.find(i) for i in range(n)])
    comps, counts = np.unique(roots, return_counts=True)
    if len(comps) == 1:
        return adj
    adj = adj.copy()
    rng = np.random.default_rng(seed)
    main = comps[np.argmax(counts)]
    main_ids = np.nonzero(roots == main)[0]
    main_reps = main_ids[rng.choice(len(main_ids),
                                    min(len(main_ids), 4 * reps_per_comp),
                                    replace=False)]
    mv = vectors[main_reps]
    for c in comps:
        if c == main:
            continue
        ids = np.nonzero(roots == c)[0]
        reps = ids[rng.choice(len(ids), min(len(ids), reps_per_comp),
                              replace=False)]
        d2 = ((vectors[reps][:, None, :] - mv[None]) ** 2).sum(-1)
        i, j = np.unravel_index(np.argmin(d2), d2.shape)
        u, w = int(reps[i]), int(main_reps[j])
        for a, b in ((u, w), (w, u)):
            slots = np.nonzero(adj[a] < 0)[0]
            slot = slots[0] if len(slots) else deg - 1
            adj[a, slot] = b
    return adj


# ---------------------------------------------------------------------------
# CAGRA-style pruning + reverse-edge fill
# ---------------------------------------------------------------------------

def prune_and_reverse(vectors: np.ndarray, knn: np.ndarray, degree: int,
                      alpha: float = 1.2) -> np.ndarray:
    """Occlusion-prune rank-ordered candidates to `degree`, then fill
    remaining slots with reverse edges (numpy; build-time only)."""
    n = vectors.shape[0]
    kept = -np.ones((n, degree), dtype=np.int32)
    kept_cnt = np.zeros(n, dtype=np.int32)
    v = vectors
    for u in range(n):
        cands = knn[u][knn[u] >= 0]
        if len(cands) == 0:
            continue
        cv = v[cands]
        du = ((cv - v[u]) ** 2).sum(axis=1)
        order = np.argsort(du)
        sel: list[int] = []
        for oi in order:
            if len(sel) >= degree:
                break
            c = cands[oi]
            if sel:
                dw = ((v[sel] - v[c]) ** 2).sum(axis=1)
                if np.any(alpha * dw < du[oi]):
                    continue  # detourable edge — CAGRA/Vamana occlusion
            sel.append(int(c))
        kept[u, :len(sel)] = sel
        kept_cnt[u] = len(sel)

    # reverse-edge fill into leftover slots
    for u in range(n):
        for c in kept[u, :kept_cnt[u]]:
            if c >= 0 and kept_cnt[c] < degree and u not in kept[c, :kept_cnt[c]]:
                kept[c, kept_cnt[c]] = u
                kept_cnt[c] += 1
    return kept


def insert_nodes(vectors: np.ndarray, adj: np.ndarray,
                 new_ids: np.ndarray, cand_ids: np.ndarray,
                 alpha: float = 1.2) -> np.ndarray:
    """Greedy incremental insertion into one cell's local graph.

    ``vectors`` (n_c, dim) holds *all* cell rows (existing + new);
    ``adj`` (n_c, degree) local-id adjacency whose new rows are -1;
    ``new_ids`` (n_new,) local ids to link; ``cand_ids`` (n_new, C)
    neighbor candidates from a nearest-neighbor search (-1 padded).
    Each new node's candidates are occlusion-pruned to ``degree`` (the
    same Vamana/CAGRA rule the builder applies), then reverse edges
    attach it to its kept neighbors — a free slot when one exists, else
    the neighbor's farthest edge is replaced when the new node is
    closer. Existing edges are otherwise untouched, which is what keeps
    the pass cheap; a cell absorbing a large batch should rebuild
    instead (see core.mutable.flush_index's ``graph_mode``).
    """
    n, degree = adj.shape
    adj = adj.copy()
    v = vectors
    for i, u in enumerate(np.asarray(new_ids, np.int64)):
        cands = cand_ids[i][cand_ids[i] >= 0]
        cands = cands[cands != u]
        if len(cands) == 0:
            continue
        du = ((v[cands] - v[u]) ** 2).sum(axis=1)
        order = np.argsort(du, kind="stable")
        sel: list[int] = []
        for oi in order:
            if len(sel) >= degree:
                break
            c = int(cands[oi])
            if c in sel:
                continue
            if sel:
                dw = ((v[sel] - v[c]) ** 2).sum(axis=1)
                if np.any(alpha * dw < du[oi]):
                    continue  # detourable edge — CAGRA/Vamana occlusion
            sel.append(c)
        adj[u, :len(sel)] = sel
        # reverse link: free slot first, else displace the farthest edge
        for c in sel:
            row = adj[c]
            if u in row:
                continue
            slots = np.nonzero(row < 0)[0]
            if len(slots):
                adj[c, slots[0]] = u
                continue
            dc = ((v[row] - v[c]) ** 2).sum(axis=1)
            worst = int(np.argmax(dc))
            d_uc = float(((v[u] - v[c]) ** 2).sum())
            if d_uc < dc[worst]:
                adj[c, worst] = u
    return adj


def build_cell_graph(vectors: np.ndarray, degree: int,
                     exact_threshold: int = 16384,
                     nn_iters: int = 10, alpha: float = 1.2,
                     seed: int = 0) -> np.ndarray:
    """(n_c, degree) int32 local-id adjacency for one cell.

    Candidate pool = kNN (rank-ordered, CAGRA phase 1) ++ random long-range
    candidates (Vamana-style; survive alpha-pruning only where useful),
    then occlusion prune + reverse fill + connectivity repair."""
    n = vectors.shape[0]
    if n <= 1:
        return -np.ones((n, degree), dtype=np.int32)
    k_build = min(2 * degree, n - 1)
    if n <= exact_threshold:
        knn = exact_knn(vectors, k_build)
    else:
        knn = nn_descent(vectors, k_build, iters=nn_iters, seed=seed)
    knn = _add_random_candidates(knn, max(degree // 2, 4), seed=seed + 1)
    adj = prune_and_reverse(vectors, knn, degree, alpha)
    return repair_connectivity(vectors, adj, seed=seed + 2)
