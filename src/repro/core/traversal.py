"""Batched sequential cell traversal (paper Section 4.3, Alg. 4).

GPU -> TPU mapping (DESIGN.md §2): the paper runs one thread block per
query with warp-parallel distance evaluation. Here a *batch* of queries is
one jitted program; each query is a lane of fixed-shape state and every
step is a vectorized op over the whole batch — masked lanes replace warp
divergence. One expansion step = one gather-distance kernel call over the
frontier's neighbor rows (the scalar-prefetch DMA pattern), one predicate
check, and two top-k merges (navigation beam / in-range result pool).

Differences from Alg. 4, documented:
- The paper's R (size-k, mixed in/out-of-range) + recCand (in-range
  evictions) pair is replaced by a navigation beam (size ef, unfiltered)
  and an in-range result pool (size k). The pool ends up holding exactly
  top-k of *all visited in-range nodes*, which is a superset-quality
  equivalent of R∪recCand (Lemma: every in-range node Alg. 4 retains was
  visited; our pool keeps the k best visited in-range nodes).
- Cand admission is top-ef merge rather than "closer than furthest in R";
  with ef >= k this only widens the frontier.

Three entry points share the engine:
  multi_cell_search         — in-core Alg. 4 on fp32 vectors
  global_search             — the adaptive high-selectivity path
  multi_cell_search_seeded  — out-of-core batch variant: int8 resident
                              vectors, batch-local graph with a
                              local->global ``rows`` indirection, beam
                              seeded from the carried candidate pool.

State per query lane:
  beam_ids/beam_d/expanded  (B, ef)  — navigation frontier, ascending
  res_ids/res_d             (B, k)   — in-range results, ascending
  visited                   (B, n)   — scored-marker (bool)
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class TraversalState(NamedTuple):
    beam_ids: jax.Array
    beam_d: jax.Array
    expanded: jax.Array
    res_ids: jax.Array
    res_d: jax.Array
    visited: jax.Array
    key: jax.Array


def _dedup_inf(ids, d):
    """Mask duplicate ids within each row to +inf (keeps first by id-sort)."""
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((ids.shape[0], 1), bool), ids_s[:, 1:] == ids_s[:, :-1]],
        axis=1)
    return ids_s, jnp.where(dup, jnp.inf, d_s)


def _topk_merge(ids_a, d_a, ids_b, d_b, k, extra_a=None, extra_b=None):
    """Row-wise best-k of two (already internally deduped) sets."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    out_ids = jnp.take_along_axis(ids, pos, axis=1)
    if extra_a is None:
        return out_ids, -neg
    extra = jnp.concatenate([extra_a, extra_b], axis=1)
    return out_ids, -neg, jnp.take_along_axis(extra, pos, axis=1)


def _in_range(attrs_rows, lo, hi):
    """attrs_rows (B, nb, m) vs lo/hi (B, m) -> bool (B, nb)."""
    ok = (attrs_rows >= lo[:, None, :]) & (attrs_rows <= hi[:, None, :])
    return ok.all(axis=2)


class _Tables(NamedTuple):
    """Distance/attribute lookup context.

    gather_d2(q, gids) -> (B, nb) squared distances (+inf for gids < 0);
    attrs: (n_global, m); rows: optional (n_local,) local->global map
    (None = ids are already global); packed: bit-packed visited map
    (uint32 words, 8x smaller than TPU byte-wide bools — the visited map
    is the dominant per-query state at fleet scale, see EXPERIMENTS.md
    §Perf garfield iteration).
    """
    gather_d2: object
    attrs: jax.Array
    rows: jax.Array | None
    packed: bool = False


def _visited_init(B: int, n: int, packed: bool):
    if packed:
        return jnp.zeros((B, (n + 31) // 32), jnp.uint32)
    return jnp.zeros((B, n), bool)


def _score(tab: _Tables, lo, hi, q, visited, cand_ids, active):
    """Distance + predicate + visited bookkeeping for a candidate batch.

    cand_ids are *local* ids (== global when tab.rows is None). Returns
    (nav_d, res_d, visited'): nav_d has +inf for invalid/visited ids;
    res_d additionally +inf for out-of-range points.
    """
    B = cand_ids.shape[0]
    safe = jnp.maximum(cand_ids, 0)
    valid = (cand_ids >= 0) & active[:, None]

    gids = safe if tab.rows is None else tab.rows[safe]
    d2 = tab.gather_d2(q, jnp.where(valid, gids, -1))
    rows_b = jnp.arange(B, dtype=jnp.int32)[:, None]
    if tab.packed:
        widx = safe >> 5
        bit = jnp.uint32(1) << (safe & 31).astype(jnp.uint32)
        seen = (visited[rows_b, widx] & bit) != 0
        nb = cand_ids.shape[1]

        def set_bit(j, vis):
            w = vis[rows_b[:, 0], widx[:, j]]
            add = jnp.where(valid[:, j], bit[:, j], jnp.uint32(0))
            return vis.at[rows_b[:, 0], widx[:, j]].set(w | add)
        visited = jax.lax.fori_loop(0, nb, set_bit, visited)
    else:
        seen = visited[rows_b, safe]
        visited = visited.at[rows_b, safe].max(valid)
    nav_d = jnp.where(valid & ~seen, d2, jnp.inf)

    a_rows = tab.attrs[gids]                                # (B, nb, m)
    ok = _in_range(a_rows, lo, hi)
    res_d = jnp.where(ok, nav_d, jnp.inf)
    return nav_d, res_d, visited


def _expand_loop(state: TraversalState, q, tab: _Tables, adj, lo, hi,
                 max_iters: int):
    """Best-first expansion until every lane's beam is exhausted (Alg. 4
    lines 4-13), capped at max_iters."""
    ef = state.beam_ids.shape[1]
    B = q.shape[0]
    rows_b = jnp.arange(B, dtype=jnp.int32)[:, None]

    def has_work(st: TraversalState):
        return jnp.any(~st.expanded & jnp.isfinite(st.beam_d))

    def cond(carry):
        it, st = carry
        return (it < max_iters) & has_work(st)

    def body(carry):
        it, st = carry
        # 1. nearest unexpanded beam slot per lane
        cand_d = jnp.where(st.expanded, jnp.inf, st.beam_d)
        slot = jnp.argmin(cand_d, axis=1)                   # (B,)
        best_d = jnp.take_along_axis(cand_d, slot[:, None], axis=1)[:, 0]
        lane_active = jnp.isfinite(best_d)
        u = jnp.take_along_axis(st.beam_ids, slot[:, None], axis=1)[:, 0]

        # 2. mark expanded
        expanded = st.expanded.at[rows_b[:, 0], slot].max(lane_active)

        # 3. gather fixed-degree neighbor row (the DMA-chase kernel)
        nbrs = adj[jnp.maximum(u, 0)]                       # (B, deg)
        nbrs = jnp.where(((u >= 0) & lane_active)[:, None], nbrs, -1)

        nav_d, res_d, visited = _score(
            tab, lo, hi, q, st.visited, nbrs, lane_active)

        # 4. merge into navigation beam (carry expanded flags) and results
        nbrs_s, nav_s = _dedup_inf(nbrs, nav_d)
        _, res_s = _dedup_inf(nbrs, res_d)
        new_ids, new_d, new_exp = _topk_merge(
            st.beam_ids, st.beam_d, nbrs_s, nav_s, ef,
            expanded, jnp.zeros_like(nbrs_s, dtype=bool))
        r_ids, r_d = _topk_merge(st.res_ids, st.res_d, nbrs_s, res_s,
                                 st.res_ids.shape[1])
        st = TraversalState(new_ids, new_d, new_exp, r_ids, r_d,
                            visited, st.key)
        return it + 1, st

    _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
    return state


def _seed_beam(state: TraversalState, q, tab: _Tables, lo, hi,
               cand_ids, active, entry_width: int):
    """Score entry candidates, reset the beam to the best entry_width of
    them (paper: 'Cand <- the d nearest nodes in CandEntry'), merge
    in-range entries into the result pool. Inactive lanes keep state and
    stay fully expanded."""
    ef = state.beam_ids.shape[1]
    B = q.shape[0]
    nav_d, res_d, visited = _score(
        tab, lo, hi, q, state.visited, cand_ids, active)
    ids_s, nav_s = _dedup_inf(cand_ids, nav_d)
    _, res_s = _dedup_inf(cand_ids, res_d)

    neg, pos = jax.lax.top_k(-nav_s, min(entry_width, nav_s.shape[1]))
    ent_ids = jnp.take_along_axis(ids_s, pos, axis=1)
    ent_d = -neg
    pad = ef - ent_ids.shape[1]
    if pad > 0:
        ent_ids = jnp.pad(ent_ids, ((0, 0), (0, pad)), constant_values=-1)
        ent_d = jnp.pad(ent_d, ((0, 0), (0, pad)), constant_values=jnp.inf)

    beam_ids = jnp.where(active[:, None], ent_ids, state.beam_ids)
    beam_d = jnp.where(active[:, None], ent_d, state.beam_d)
    expanded = jnp.where(active[:, None], ~jnp.isfinite(ent_d),
                         jnp.ones((B, ef), bool))

    r_ids, r_d = _topk_merge(state.res_ids, state.res_d, ids_s, res_s,
                             state.res_ids.shape[1])
    return TraversalState(beam_ids, beam_d, expanded, r_ids, r_d,
                          visited, state.key)


def _init_state(B: int, n: int, k: int, ef: int, key,
                packed: bool = False) -> TraversalState:
    return TraversalState(
        beam_ids=jnp.full((B, ef), -1, jnp.int32),
        beam_d=jnp.full((B, ef), jnp.inf, jnp.float32),
        expanded=jnp.ones((B, ef), bool),
        res_ids=jnp.full((B, k), -1, jnp.int32),
        res_d=jnp.full((B, k), jnp.inf, jnp.float32),
        visited=_visited_init(B, n, packed),
        key=key,
    )


def _cell_itinerary_loop(state, q, tab, adj, inter_adj, cell_start,
                         lo, hi, cell_order, *, entry_width, entry_random,
                         entry_beam_l, max_iters, use_inter):
    """Shared Alg. 4 outer loop over an ordered cell itinerary."""
    B = q.shape[0]
    T = cell_order.shape[1]

    def cell_body(t, state: TraversalState):
        c = cell_order[:, t]                                 # (B,)
        active = c >= 0
        safe_c = jnp.maximum(c, 0)
        start = cell_start[safe_c]
        end = cell_start[safe_c + 1]
        nonempty = end > start

        # --- entry candidates: inter-cell hops + random (Alg. 4 l14-16)
        ent_key = jax.random.fold_in(state.key, t)
        n_rand = entry_random if use_inter else entry_width
        rnd = jax.random.randint(
            ent_key, (B, n_rand), start[:, None],
            jnp.maximum(end, start + 1)[:, None]).astype(jnp.int32)
        rnd = jnp.where((nonempty & active)[:, None], rnd, -1)

        if use_inter:
            hop_src = state.beam_ids[:, :entry_beam_l]       # (B, L)
            hop = inter_adj[jnp.maximum(hop_src, 0), safe_c[:, None]]
            hop = jnp.where((hop_src >= 0)[:, :, None], hop, -1)
            hop = hop.reshape(B, -1)
            cand = jnp.concatenate([hop, rnd], axis=1)
        else:
            cand = rnd
        cand = jnp.where(active[:, None], cand, -1)

        state = _seed_beam(state, q, tab, lo, hi, cand,
                           active & nonempty, entry_width)
        state = _expand_loop(state, q, tab, adj, lo, hi, max_iters)
        return state

    return jax.lax.fori_loop(0, T, cell_body, state)


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "entry_width", "entry_random",
                     "entry_beam_l", "max_iters", "use_inter"))
def multi_cell_search(vectors, attrs, adj, inter_adj, cell_start,
                      q, lo, hi, cell_order, key, *,
                      k: int, ef: int, entry_width: int, entry_random: int,
                      entry_beam_l: int, max_iters: int,
                      use_inter: bool = True):
    """Sequential cell-by-cell traversal (Alg. 4), in-core fp32.

    vectors (n, dim) | attrs (n, m) | adj (n, deg) | inter_adj (n, S, l)
    cell_start (S+1,) | q (B, dim) | lo/hi (B, m)
    cell_order (B, T) int32: per-lane ordered cell ids, -1 padded.
    Returns (res_ids (B, k) int32 internal ids [-1 pad], res_d (B, k)).
    """
    B, n = q.shape[0], vectors.shape[0]
    tab = _Tables(
        gather_d2=lambda qq, gids: ops.gather_l2(qq, vectors, gids),
        attrs=attrs, rows=None)
    state = _init_state(B, n, k, ef, key)
    state = _cell_itinerary_loop(
        state, q, tab, adj, inter_adj, cell_start, lo, hi, cell_order,
        entry_width=entry_width, entry_random=entry_random,
        entry_beam_l=entry_beam_l, max_iters=max_iters, use_inter=use_inter)
    return state.res_ids, state.res_d


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "entry_width", "entry_random",
                     "entry_beam_l", "max_iters", "packed_visited"))
def multi_cell_search_seeded(vq, vscale, attrs, adj, inter_adj, cell_start,
                             rows, q, lo, hi, cell_order, seed_ids, key, *,
                             k: int, ef: int, entry_width: int,
                             entry_random: int, entry_beam_l: int,
                             max_iters: int, packed_visited: bool = False):
    """Out-of-core batch variant (paper Section 5.1 step 5).

    Differences from multi_cell_search: distances come from the *resident
    int8* table (vq (n_glob, dim) i8 + vscale (n_glob,)), graph ids are
    batch-local with ``rows`` (n_local,) mapping local->global, and the
    beam starts from ``seed_ids`` (B, n_seed) — the carried global
    candidate pool remapped into this batch (paper's cross-batch entry
    propagation). Returns batch-local ids.
    """
    B, n_local = q.shape[0], rows.shape[0]
    tab = _Tables(
        gather_d2=lambda qq, gids: ops.gather_l2_q(qq, vq, vscale, gids),
        attrs=attrs, rows=rows, packed=packed_visited)
    state = _init_state(B, n_local, k, ef, key, packed=packed_visited)
    # seed from the carried pool (may be empty: all -1)
    state = _seed_beam(state, q, tab, lo, hi, seed_ids,
                       jnp.ones((B,), bool), entry_width)
    state = _cell_itinerary_loop(
        state, q, tab, adj, inter_adj, cell_start, lo, hi, cell_order,
        entry_width=entry_width, entry_random=entry_random,
        entry_beam_l=entry_beam_l, max_iters=max_iters, use_inter=True)
    return state.res_ids, state.res_d


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "entry_width", "max_iters"))
def global_search(vectors, attrs, adj, q, lo, hi, key, *,
                  k: int, ef: int, entry_width: int, max_iters: int):
    """Adaptive high-selectivity path (Alg. 2 lines 5-8): one greedy
    traversal over the whole graph (adj = intra ++ flattened inter edges),
    predicate enforced on the result pool only."""
    B, n = q.shape[0], vectors.shape[0]
    tab = _Tables(
        gather_d2=lambda qq, gids: ops.gather_l2(qq, vectors, gids),
        attrs=attrs, rows=None)
    state = _init_state(B, n, k, ef, key)
    rnd = jax.random.randint(key, (B, entry_width), 0, n).astype(jnp.int32)
    active = jnp.ones((B,), bool)
    state = _seed_beam(state, q, tab, lo, hi, rnd, active, entry_width)
    state = _expand_loop(state, q, tab, adj, lo, hi, max_iters)
    return state.res_ids, state.res_d
