"""Batched sequential cell traversal (paper Section 4.3, Alg. 4) — the
single parameterized core every engine mode runs on.

GPU -> TPU mapping (DESIGN.md §2): the paper runs one thread block per
query with warp-parallel distance evaluation. Here a *batch* of queries is
one jitted program; each query is a lane of fixed-shape state and every
step is a vectorized op over the whole batch — masked lanes replace warp
divergence.

One expansion step has two executions, dispatched on the static ``fused``
flag (resolved from ``kernels/config.py`` at the ``CellRuntime.run``
boundary so mode flips never go stale in a jit cache):

- ``fused=True`` (Pallas backends): the whole step — neighbor-row gather,
  distance, range predicate, packed-visited test/set, dedup, and the dual
  beam/result top-k merge — is ONE ``kernels/traversal_wave.py`` call.
- ``fused=False`` (ref/CPU): the same math as separate XLA programs — one
  gather-distance kernel call over the frontier's neighbor rows, one
  predicate check, a vectorized visited scatter, and two top-k merges.

Both paths select identical ids (the wave kernel replicates the stable
argsort-dedup + ``lax.top_k`` tie rules exactly); distances may differ in
the last ulp from reduction-order/fusion differences.

Engine-mode matrix (storage x graph residency x seeding), all served by
:func:`traversal_core`:

  mode    | vector storage        | graph residency           | seeding
  --------+-----------------------+---------------------------+---------
  incore  | fp32 resident         | fully resident            | fresh
  hybrid  | int8 resident +rerank | LRU slot cache (cell_base | carried
          |                       | indirection, misses only) | pool
  ooc     | int8 resident +rerank | batch-local window (rows  | carried
          |                       | local->global indirection)| pool

The two pytree axes are :class:`VectorStore` (``vectors`` xor
``vq``/``vscale``) and :class:`GraphView` (``rows`` for batch-local ids,
``cell_of``/``cell_base`` for the hybrid slot cache, both ``None`` for a
fully resident graph). ``seed_ids`` is ``None`` for a fresh beam.
``cell_order=None`` degenerates to one global greedy expansion (the
adaptive high-selectivity path, Alg. 2 lines 5-8).

Cross-cell candidate reuse: with ``pool_reuse`` the in-range result pool
joins the navigation beam as an inter-cell hop source at every cell
seeding (paper Section 5.1's "aggressively reuse candidates as entry
points", previously applied only to the out-of-core carried pool).

Batch-composition independence (serving contract, ISSUE 6): entry
randomization is lane-position-independent — every random draw is one
shared stream per (key, itinerary step) scaled into each lane's own cell
bounds, never a ``(B, ...)``-shaped draw whose rows depend on where a
query happens to sit in the batch. Together with per-lane selection /
ordering / expansion (which were always row-local), a query's result
depends only on (its vector, its box, the knobs, the PRNG key) — so the
serving front-end may coalesce requests into one widened pass and still
return the ids a solo ``Collection.search`` call would.

Differences from Alg. 4, documented:
- The paper's R (size-k, mixed in/out-of-range) + recCand (in-range
  evictions) pair is replaced by a navigation beam (size ef, unfiltered)
  and an in-range result pool (size k). The pool ends up holding exactly
  top-k of *all visited in-range nodes*, which is a superset-quality
  equivalent of R∪recCand (Lemma: every in-range node Alg. 4 retains was
  visited; our pool keeps the k best visited in-range nodes).
- Cand admission is top-ef merge rather than "closer than furthest in R";
  with ef >= k this only widens the frontier.

Legacy entry points (``multi_cell_search``, ``multi_cell_search_seeded``,
``global_search``) are thin jitted wrappers over the core, kept for
engine-level ablations and the fleet dry-run.

State per query lane:
  beam_ids/beam_d/expanded  (B, ef)  — navigation frontier, ascending
  res_ids/res_d             (B, k)   — in-range results, ascending
  visited                   (B, n)   — scored-marker (bool or packed u32)
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels import traversal_wave as twave

# cell_base value marking an uncached cell in the hybrid slot cache
UNCACHED = -(1 << 30)


class VectorStore(NamedTuple):
    """Distance-table residency: exactly one of (vectors) / (vq, vscale).

    vectors: (n, dim) f32 | vq: (n, dim) i8 + vscale: (n,) f32;
    attrs: (n, m) f32 rides along for predicate checks.
    """
    vectors: jax.Array | None
    vq: jax.Array | None
    vscale: jax.Array | None
    attrs: jax.Array


class GraphView(NamedTuple):
    """Adjacency residency.

    intra: (n_rows, deg) i32; inter: (n_rows, S, l) i32;
    cell_start: (S+1,) i32 CSR offsets in the id space of this view.
    rows: optional (n_rows,) local->global map (out-of-core batch window;
    ids fed to the traversal are batch-local).
    cell_of/cell_base: optional hybrid slot-cache indirection — node u's
    adjacency row lives at ``u + cell_base[cell_of[u]]`` in the cache
    buffers, or nowhere when ``cell_base[...] == UNCACHED`` (ids stay
    global; only the adjacency lookup indirects).

    Bounds contract for the indirection: bases are *arbitrary* per-cell
    offsets (the size-aware arena packs variable-length extents, so
    bases are not slot multiples), and a resident cell's extent covers
    at least its row count — every ``u + base`` of a cached node lands
    inside its own extent by construction. Quantum-pad rows inside an
    extent hold -1 adjacency and are never addressed; ``_slot_of``'s
    clip only guards the UNCACHED sentinel arithmetic, whose lanes are
    masked off before use.
    """
    intra: jax.Array
    inter: jax.Array | None
    cell_start: jax.Array | None
    rows: jax.Array | None = None
    cell_of: jax.Array | None = None
    cell_base: jax.Array | None = None


class TraversalState(NamedTuple):
    beam_ids: jax.Array
    beam_d: jax.Array
    expanded: jax.Array
    res_ids: jax.Array
    res_d: jax.Array
    visited: jax.Array
    key: jax.Array


def _dedup_inf(ids, d):
    """Mask duplicate ids within each row to +inf (keeps first by id-sort)."""
    order = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((ids.shape[0], 1), bool), ids_s[:, 1:] == ids_s[:, :-1]],
        axis=1)
    return ids_s, jnp.where(dup, jnp.inf, d_s)


def _topk_merge(ids_a, d_a, ids_b, d_b, k, extra_a=None, extra_b=None):
    """Row-wise best-k of two (already internally deduped) sets."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    out_ids = jnp.take_along_axis(ids, pos, axis=1)
    if extra_a is None:
        return out_ids, -neg
    extra = jnp.concatenate([extra_a, extra_b], axis=1)
    return out_ids, -neg, jnp.take_along_axis(extra, pos, axis=1)


def _in_range(attrs_rows, lo, hi):
    """attrs_rows (B, nb, m) vs lo/hi (B, m) -> bool (B, nb)."""
    ok = (attrs_rows >= lo[:, None, :]) & (attrs_rows <= hi[:, None, :])
    return ok.all(axis=2)


def _gather_d2(store: VectorStore, q, gids):
    """(B, nb) squared distances from whichever table is resident."""
    if store.vectors is not None:
        return ops.gather_l2(q, store.vectors, gids)
    return ops.gather_l2_q(q, store.vq, store.vscale, gids)


def _slot_of(graph: GraphView, safe_ids):
    """Hybrid cache: node id -> adjacency buffer row (clipped) + validity."""
    base = graph.cell_base[graph.cell_of[safe_ids]]
    cached = base != UNCACHED
    slot = jnp.clip(safe_ids + base, 0, graph.intra.shape[0] - 1)
    return slot, cached


def _adj_rows(graph: GraphView, u, lane_ok):
    """Fixed-degree neighbor row per lane for frontier node u (B,).

    Resident/batch-local graphs index directly; the hybrid slot cache
    indirects through cell_base and yields no neighbors (-1) for nodes
    whose cell is not currently cached — traversal degrades gracefully
    instead of faulting."""
    safe = jnp.maximum(u, 0)
    ok = (u >= 0) & lane_ok
    if graph.cell_base is None:
        nbrs = graph.intra[safe]
    else:
        slot, cached = _slot_of(graph, safe)
        nbrs = graph.intra[slot]
        ok = ok & cached
    return jnp.where(ok[:, None], nbrs, -1)


def _inter_rows(graph: GraphView, src, c):
    """Inter-cell hop targets: src (B, L) nodes -> their edges into cell
    c (B,). Returns (B, L*l) candidate ids (-1 where invalid)."""
    B = src.shape[0]
    safe = jnp.maximum(src, 0)
    ok = src >= 0
    if graph.cell_base is None:
        hop = graph.inter[safe, c[:, None]]
    else:
        slot, cached = _slot_of(graph, safe)
        hop = graph.inter[slot, c[:, None]]
        ok = ok & cached
    return jnp.where(ok[:, :, None], hop, -1).reshape(B, -1)


def _visited_init(B: int, n: int, packed: bool):
    if packed:
        return jnp.zeros((B, (n + 31) // 32), jnp.uint32)
    return jnp.zeros((B, n), bool)


def _score(store: VectorStore, graph: GraphView, packed: bool,
           lo, hi, q, visited, cand_ids, active):
    """Distance + predicate + visited bookkeeping for a candidate batch.

    cand_ids are *view-local* ids (== global when graph.rows is None).
    Returns (nav_d, res_d, visited'): nav_d has +inf for invalid/visited
    ids; res_d additionally +inf for out-of-range points.
    """
    B = cand_ids.shape[0]
    safe = jnp.maximum(cand_ids, 0)
    valid = (cand_ids >= 0) & active[:, None]

    gids = safe if graph.rows is None else graph.rows[safe]
    d2 = _gather_d2(store, q, jnp.where(valid, gids, -1))
    rows_b = jnp.arange(B, dtype=jnp.int32)[:, None]
    if packed:
        # vectorized segment-OR scatter (one scatter-add) instead of the
        # former O(nb) fori_loop bit-set; bit-identical (kernels/ref.py)
        seen, visited = kref.set_packed_bits(visited, cand_ids, valid)
    else:
        seen = visited[rows_b, safe]
        visited = visited.at[rows_b, safe].max(valid)
    nav_d = jnp.where(valid & ~seen, d2, jnp.inf)

    a_rows = store.attrs[gids]                              # (B, nb, m)
    ok = _in_range(a_rows, lo, hi)
    res_d = jnp.where(ok, nav_d, jnp.inf)
    return nav_d, res_d, visited


def _view_gids(graph: GraphView, cand_ids):
    """View-local candidate ids -> global vector-table rows (>= 0)."""
    safe = jnp.maximum(cand_ids, 0)
    return safe if graph.rows is None else graph.rows[safe]


def _expand_loop(state: TraversalState, q, store, graph, packed, lo, hi,
                 max_iters: int, fused: bool = False):
    """Best-first expansion until every lane's beam is exhausted (Alg. 4
    lines 4-13), capped at max_iters."""
    ef = state.beam_ids.shape[1]
    B = q.shape[0]
    rows_b = jnp.arange(B, dtype=jnp.int32)[:, None]

    def has_work(st: TraversalState):
        return jnp.any(~st.expanded & jnp.isfinite(st.beam_d))

    def cond(carry):
        it, st = carry
        return (it < max_iters) & has_work(st)

    def body(carry):
        it, st = carry
        # 1. nearest unexpanded beam slot per lane
        cand_d = jnp.where(st.expanded, jnp.inf, st.beam_d)
        slot = jnp.argmin(cand_d, axis=1)                   # (B,)
        best_d = jnp.take_along_axis(cand_d, slot[:, None], axis=1)[:, 0]
        lane_active = jnp.isfinite(best_d)
        u = jnp.take_along_axis(st.beam_ids, slot[:, None], axis=1)[:, 0]

        # 2. mark expanded
        expanded = st.expanded.at[rows_b[:, 0], slot].max(lane_active)

        # 3. frontier neighbor ids (-1 already masked for dead lanes)
        nbrs = _adj_rows(graph, u, lane_active)             # (B, deg)

        if fused:
            # 4. one fused kernel: gather+distance+predicate+visited+merge
            new_ids, new_d, new_exp, r_ids, r_d, visited = twave.wave_expand(
                q, store.vectors, store.vq, store.vscale, store.attrs,
                lo, hi, nbrs, _view_gids(graph, nbrs), st.visited,
                st.beam_ids, st.beam_d, expanded, st.res_ids, st.res_d)
        else:
            nav_d, res_d, visited = _score(
                store, graph, packed, lo, hi, q, st.visited, nbrs,
                lane_active)

            # 4. merge into navigation beam (carry expanded flags) + results
            nbrs_s, nav_s = _dedup_inf(nbrs, nav_d)
            _, res_s = _dedup_inf(nbrs, res_d)
            new_ids, new_d, new_exp = _topk_merge(
                st.beam_ids, st.beam_d, nbrs_s, nav_s, ef,
                expanded, jnp.zeros_like(nbrs_s, dtype=bool))
            r_ids, r_d = _topk_merge(st.res_ids, st.res_d, nbrs_s, res_s,
                                     st.res_ids.shape[1])
        st = TraversalState(new_ids, new_d, new_exp, r_ids, r_d,
                            visited, st.key)
        return it + 1, st

    _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
    return state


def _seed_beam(state: TraversalState, q, store, graph, packed, lo, hi,
               cand_ids, active, entry_width: int, fused: bool = False):
    """Score entry candidates, reset the beam to the best entry_width of
    them (paper: 'Cand <- the d nearest nodes in CandEntry'), merge
    in-range entries into the result pool. Inactive lanes keep state and
    stay fully expanded."""
    ef = state.beam_ids.shape[1]
    entry_width = min(entry_width, ef)  # the beam holds at most ef entries
    B = q.shape[0]
    if fused:
        cand_m = jnp.where(active[:, None], cand_ids, -1)
        beam_ids, beam_d, expanded, r_ids, r_d, visited = twave.wave_seed(
            q, store.vectors, store.vq, store.vscale, store.attrs, lo, hi,
            cand_m, _view_gids(graph, cand_m), state.visited,
            state.beam_ids, state.beam_d, state.res_ids, state.res_d,
            active, entry_width)
        return TraversalState(beam_ids, beam_d, expanded, r_ids, r_d,
                              visited, state.key)
    nav_d, res_d, visited = _score(
        store, graph, packed, lo, hi, q, state.visited, cand_ids, active)
    ids_s, nav_s = _dedup_inf(cand_ids, nav_d)
    _, res_s = _dedup_inf(cand_ids, res_d)

    neg, pos = jax.lax.top_k(-nav_s, min(entry_width, nav_s.shape[1]))
    ent_ids = jnp.take_along_axis(ids_s, pos, axis=1)
    ent_d = -neg
    pad = ef - ent_ids.shape[1]
    if pad > 0:
        ent_ids = jnp.pad(ent_ids, ((0, 0), (0, pad)), constant_values=-1)
        ent_d = jnp.pad(ent_d, ((0, 0), (0, pad)), constant_values=jnp.inf)

    beam_ids = jnp.where(active[:, None], ent_ids, state.beam_ids)
    beam_d = jnp.where(active[:, None], ent_d, state.beam_d)
    expanded = jnp.where(active[:, None], ~jnp.isfinite(ent_d),
                         jnp.ones((B, ef), bool))

    r_ids, r_d = _topk_merge(state.res_ids, state.res_d, ids_s, res_s,
                             state.res_ids.shape[1])
    return TraversalState(beam_ids, beam_d, expanded, r_ids, r_d,
                          visited, state.key)


def _init_state(B: int, n: int, k: int, ef: int, key,
                packed: bool = False) -> TraversalState:
    return TraversalState(
        beam_ids=jnp.full((B, ef), -1, jnp.int32),
        beam_d=jnp.full((B, ef), jnp.inf, jnp.float32),
        expanded=jnp.ones((B, ef), bool),
        res_ids=jnp.full((B, k), -1, jnp.int32),
        res_d=jnp.full((B, k), jnp.inf, jnp.float32),
        visited=_visited_init(B, n, packed),
        key=key,
    )


def _cell_itinerary_loop(state, q, store, graph, packed, lo, hi, cell_order,
                         *, entry_width, entry_random, entry_beam_l,
                         max_iters, use_inter, pool_reuse, fused=False):
    """Shared Alg. 4 outer loop over an ordered cell itinerary."""
    B = q.shape[0]
    T = cell_order.shape[1]

    def cell_body(t, state: TraversalState):
        c = cell_order[:, t]                                 # (B,)
        active = c >= 0
        safe_c = jnp.maximum(c, 0)
        start = graph.cell_start[safe_c]
        end = graph.cell_start[safe_c + 1]
        nonempty = end > start

        # --- entry candidates: inter-cell hops + random (Alg. 4 l14-16)
        # one shared draw per step, scaled into each lane's cell bounds:
        # a lane's randoms depend only on (key, t, its own cell), not on
        # its row position or the batch size (serving contract above)
        ent_key = jax.random.fold_in(state.key, t)
        n_rand = entry_random if use_inter else entry_width
        bits = jax.random.randint(ent_key, (n_rand,), 0,
                                  jnp.iinfo(jnp.int32).max)
        span = jnp.maximum(end - start, 1)
        rnd = (start[:, None] + bits[None, :] % span[:, None]).astype(
            jnp.int32)
        rnd = jnp.where((nonempty & active)[:, None], rnd, -1)

        if use_inter:
            hop_src = state.beam_ids[:, :entry_beam_l]       # (B, L)
            if pool_reuse:
                # cross-cell candidate reuse: the in-range result pool's
                # inter edges also propose entries (paper §5.1, applied
                # to every itinerary, not only the out-of-core carry)
                hop_src = jnp.concatenate(
                    [hop_src, state.res_ids[:, :entry_beam_l]], axis=1)
            hop = _inter_rows(graph, hop_src, safe_c)
            cand = jnp.concatenate([hop, rnd], axis=1)
        else:
            cand = rnd
        cand = jnp.where(active[:, None], cand, -1)

        state = _seed_beam(state, q, store, graph, packed, lo, hi, cand,
                           active & nonempty, entry_width, fused)
        state = _expand_loop(state, q, store, graph, packed, lo, hi,
                             max_iters, fused)
        return state

    return jax.lax.fori_loop(0, T, cell_body, state)


def _traversal_core_impl(store: VectorStore, graph: GraphView,
                         q, lo, hi, cell_order, seed_ids, key, *,
                         k: int, ef: int, entry_width: int,
                         entry_random: int, entry_beam_l: int,
                         max_iters: int, use_inter: bool = True,
                         packed_visited: bool = False,
                         pool_reuse: bool = False,
                         fused: bool = False):
    """The one traversal core (see module docstring for the mode matrix).

    q (B, dim) | lo/hi (B, m) | cell_order (B, T) i32 ordered cell ids
    (-1 padded) or None for one global expansion | seed_ids (B, n_seed)
    view-local entry ids (-1 padded) or None for a fresh beam.
    ``fused`` (static; resolved by the caller from kernels/config.py)
    routes every seed/expand step through the one-call Pallas wave kernel;
    the fused path always uses the packed visited bitset.
    Returns (res_ids (B, k) i32 view-local ids [-1 pad], res_d (B, k)).
    """
    B = q.shape[0]
    n = store.attrs.shape[0] if graph.rows is None else graph.rows.shape[0]
    packed = packed_visited or fused
    state = _init_state(B, n, k, ef, key, packed=packed)
    all_lanes = jnp.ones((B,), bool)

    if seed_ids is None and cell_order is None:
        # global path seeds from uniform randoms over the whole view —
        # one shared draw broadcast across lanes (batch-independent; the
        # fixed-entry-point idiom, randomized only by the key)
        bits = jax.random.randint(
            key, (entry_width,), 0, n).astype(jnp.int32)
        seed_ids = jnp.broadcast_to(bits[None, :], (B, entry_width))
    if seed_ids is not None:
        state = _seed_beam(state, q, store, graph, packed, lo, hi,
                           seed_ids, all_lanes, entry_width, fused)
    if cell_order is None:
        state = _expand_loop(state, q, store, graph, packed,
                             lo, hi, max_iters, fused)
    else:
        state = _cell_itinerary_loop(
            state, q, store, graph, packed, lo, hi, cell_order,
            entry_width=entry_width, entry_random=entry_random,
            entry_beam_l=entry_beam_l, max_iters=max_iters,
            use_inter=use_inter, pool_reuse=pool_reuse, fused=fused)
    return state.res_ids, state.res_d


_STATIC = ("k", "ef", "entry_width", "entry_random", "entry_beam_l",
           "max_iters", "use_inter", "packed_visited", "pool_reuse",
           "fused")

traversal_core = jax.jit(_traversal_core_impl, static_argnames=_STATIC)


# -- legacy entry points: thin wrappers over the core ------------------------

def _multi_cell_search_impl(vectors, attrs, adj, inter_adj, cell_start,
                            q, lo, hi, cell_order, key, *,
                            k: int, ef: int, entry_width: int,
                            entry_random: int, entry_beam_l: int,
                            max_iters: int, use_inter: bool = True,
                            pool_reuse: bool = False):
    """In-core Alg. 4 on fp32 vectors (fresh beam, resident graph)."""
    store = VectorStore(vectors=vectors, vq=None, vscale=None, attrs=attrs)
    graph = GraphView(intra=adj, inter=inter_adj, cell_start=cell_start)
    return _traversal_core_impl(
        store, graph, q, lo, hi, cell_order, None, key,
        k=k, ef=ef, entry_width=entry_width, entry_random=entry_random,
        entry_beam_l=entry_beam_l, max_iters=max_iters, use_inter=use_inter,
        pool_reuse=pool_reuse)


multi_cell_search = jax.jit(
    _multi_cell_search_impl,
    static_argnames=("k", "ef", "entry_width", "entry_random",
                     "entry_beam_l", "max_iters", "use_inter",
                     "pool_reuse"))


def _multi_cell_search_seeded_impl(vq, vscale, attrs, adj, inter_adj,
                                   cell_start, rows, q, lo, hi, cell_order,
                                   seed_ids, key, *,
                                   k: int, ef: int, entry_width: int,
                                   entry_random: int, entry_beam_l: int,
                                   max_iters: int,
                                   packed_visited: bool = False,
                                   pool_reuse: bool = False):
    """Out-of-core batch variant (paper Section 5.1 step 5): int8
    resident distances, batch-local graph with ``rows`` local->global
    indirection, beam seeded from the carried candidate pool. Returns
    batch-local ids."""
    store = VectorStore(vectors=None, vq=vq, vscale=vscale, attrs=attrs)
    graph = GraphView(intra=adj, inter=inter_adj, cell_start=cell_start,
                      rows=rows)
    return _traversal_core_impl(
        store, graph, q, lo, hi, cell_order, seed_ids, key,
        k=k, ef=ef, entry_width=entry_width, entry_random=entry_random,
        entry_beam_l=entry_beam_l, max_iters=max_iters, use_inter=True,
        packed_visited=packed_visited, pool_reuse=pool_reuse)


multi_cell_search_seeded = jax.jit(
    _multi_cell_search_seeded_impl,
    static_argnames=("k", "ef", "entry_width", "entry_random",
                     "entry_beam_l", "max_iters", "packed_visited",
                     "pool_reuse"))


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "entry_width", "max_iters"))
def global_search(vectors, attrs, adj, q, lo, hi, key, *,
                  k: int, ef: int, entry_width: int, max_iters: int):
    """Adaptive high-selectivity path (Alg. 2 lines 5-8): one greedy
    traversal over the whole graph (adj = intra ++ flattened inter edges),
    predicate enforced on the result pool only."""
    store = VectorStore(vectors=vectors, vq=None, vscale=None, attrs=attrs)
    graph = GraphView(intra=adj, inter=None, cell_start=None)
    return _traversal_core_impl(
        store, graph, q, lo, hi, None, None, key,
        k=k, ef=ef, entry_width=entry_width, entry_random=0,
        entry_beam_l=0, max_iters=max_iters)
