"""Shared cell-runtime layer beneath every engine mode (ISSUE 3 tentpole).

Both engines used to reimplement half of the paper's cell-by-cell
execution model; this module owns the common machinery so
``core/search.py`` (in-core), ``core/hybrid.py`` (hybrid-cached) and
``core/pipeline.py`` (out-of-core) shrink to thin orchestrators:

  host side    — pow2/quantum padding (:func:`pad_pow2`, :func:`round_up`),
                 qmap segment handling (:func:`check_qmap`,
                 :func:`merge_segment_topk`), the carried per-query
                 candidate pool (:class:`CandidatePool`), itinerary ranks
                 (:func:`order_ranks`) and the exact fp32 re-rank
                 (:func:`exact_rerank`).
  device side  — vector/graph residency (:class:`CellRuntime` builds the
                 :class:`~repro.core.traversal.VectorStore` /
                 :class:`~repro.core.traversal.GraphView` pytrees and
                 invokes the one jitted traversal core with stable
                 pow2-padded shapes), plus the bounded LRU graph-cell
                 cache (:class:`CellCache`) that gives the hybrid mode
                 its middle memory tier.

Engine-mode matrix (storage x graph residency x seeding):

  mode    | vector storage        | graph residency        | seeding
  --------+-----------------------+------------------------+--------------
  incore  | fp32 resident         | fully resident         | fresh beam
  hybrid  | int8 resident +rerank | LRU slot cache         | carried pool
  ooc     | int8 resident +rerank | streamed batch window  | carried pool
"""

from __future__ import annotations

import collections
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.traversal import (
    UNCACHED, GraphView, VectorStore, traversal_core)
from repro.core.types import GMGIndex


# -- host-side padding helpers (deduplicated from search.py / pipeline.py) --

def pad_pow2(x: np.ndarray, axis: int = 0):
    """Pad axis 0 to the next power of two by repeating row 0 (keeps the
    jitted program cache warm across ragged sub-batches).
    Returns (padded, original_size)."""
    n = x.shape[axis]
    if n == 0:
        raise ValueError(
            "cannot pad an empty batch (callers must early-return on B=0)")
    p = 1
    while p < n:
        p *= 2
    if p == n:
        return x, n
    reps = np.repeat(x[:1], p - n, axis=0)
    return np.concatenate([x, reps], axis=0), n


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= x (row-quantum padding)."""
    return ((x + mult - 1) // mult) * mult


# -- qmap segment handling (disjunctive box-batching) ------------------------

def check_qmap(qmap, B: int) -> np.ndarray:
    """Validate a planner row -> original-query segment map."""
    qmap = np.asarray(qmap, np.int64)
    if qmap.shape != (B,):
        raise ValueError(f"qmap shape {qmap.shape} != batch ({B},)")
    return qmap


def empty_topk(n_queries: int, k: int):
    """Fully-padded (ids, dists) result block."""
    return (np.full((n_queries, k), -1, np.int64),
            np.full((n_queries, k), np.inf, np.float32))


def merge_segment_topk(ids: np.ndarray, dists: np.ndarray,
                       qmap: np.ndarray, n_queries: int, k: int):
    """Fold per-box candidate rows back into per-query top-k.

    ``ids`` (T, kk) with -1 pads and ``dists`` (T, kk) with +inf pads are
    per-box results; ``qmap`` (T,) maps each row to its original query.
    Returns ((n_queries, k) i64 ids, (n_queries, k) f32 dists).

    Deterministic by construction: duplicate ids within a query (a point
    matching several boxes) collapse to their best distance, candidates
    order by (distance, id) so distance ties break toward the smaller
    id, and queries with no boxes/candidates come back fully padded.
    """
    ids = np.asarray(ids)
    dists = np.asarray(dists)
    out_i, out_d = empty_topk(n_queries, k)
    if ids.size == 0:
        return out_i, out_d
    T, kk = ids.shape
    fq = np.repeat(np.asarray(qmap, np.int64), kk)
    fi = ids.ravel().astype(np.int64)
    fd = dists.ravel().astype(np.float32)
    valid = fi >= 0
    fi, fd, fq = fi[valid], fd[valid], fq[valid]
    if fi.size == 0:
        return out_i, out_d
    # dedup: sort by (query, id, dist), keep each (query, id)'s best dist
    o = np.lexsort((fd, fi, fq))
    fi, fd, fq = fi[o], fd[o], fq[o]
    first = np.ones(fi.shape[0], bool)
    first[1:] = (fq[1:] != fq[:-1]) | (fi[1:] != fi[:-1])
    fi, fd, fq = fi[first], fd[first], fq[first]
    # rank survivors by (query, dist, id) and take each query's first k
    o = np.lexsort((fi, fd, fq))
    fi, fd, fq = fi[o], fd[o], fq[o]
    starts = np.searchsorted(fq, np.arange(n_queries))
    rank = np.arange(fq.shape[0]) - starts[fq]
    keep = rank < k
    out_i[fq[keep], rank[keep]] = fi[keep]
    out_d[fq[keep], rank[keep]] = fd[keep]
    return out_i, out_d


# -- carried per-query candidate pool (paper §5.1 entry propagation) ---------

class CandidatePool:
    """Per-query top-``ef`` candidate carry across cell batches/waves.

    Holds view-global internal ids + (approximate) distances; batches
    re-seed their beam from it and fold their survivors back in. The
    merge is the same deterministic (distance, id) fold the disjunctive
    planner uses, so pool contents are reproducible across runs.
    """

    def __init__(self, n_queries: int, ef: int):
        self.ids = np.full((n_queries, ef), -1, np.int32)
        self.d = np.full((n_queries, ef), np.inf, np.float32)
        self.ef = ef

    def merge(self, rows: np.ndarray, got_ids: np.ndarray,
              got_d: np.ndarray) -> None:
        """Fold (len(rows), kk) new candidates into the carried pool."""
        if len(rows) == 0:
            return
        ids = np.concatenate([self.ids[rows], got_ids], axis=1)
        d = np.concatenate([self.d[rows], got_d], axis=1)
        qm = np.arange(len(rows), dtype=np.int64)
        mi, md = merge_segment_topk(ids, d, qm, len(rows), self.ef)
        self.ids[rows] = mi.astype(np.int32)
        self.d[rows] = md


# -- itinerary ranks (shared by the streaming/hybrid schedulers) -------------

def order_ranks(index: GMGIndex, q: np.ndarray,
                inc: np.ndarray) -> np.ndarray:
    """(B, S) traversal rank per (query, cell) from the cluster vote
    (lower = search earlier; untouched cells get a large rank)."""
    from repro.core.ordering import order_cells
    S = index.n_cells
    order, _ = order_cells(
        jnp.asarray(q), jnp.asarray(index.centroids),
        jnp.asarray(index.hist), jnp.asarray(inc),
        top_m=index.config.top_m_clusters, T=S)
    order = np.asarray(order)
    rank = np.full((q.shape[0], S), S + 1, np.int32)
    for bqi in range(q.shape[0]):
        sel = order[bqi][order[bqi] >= 0]
        rank[bqi, sel] = np.arange(len(sel))
    return rank


# -- exact fp32 re-rank of pool survivors (paper §5.1 step 7) ----------------

def exact_rerank(index: GMGIndex, pool: CandidatePool, q: np.ndarray,
                 lo: np.ndarray, hi: np.ndarray, k: int,
                 rerank_mult: int):
    """Host-side exact re-rank of each query's carried candidates.
    Returns ((B, k) i64 *original* ids, (B, k) f32 exact distances)."""
    B = q.shape[0]
    out_i, out_d = empty_topk(B, k)
    rerank_n = min(pool.ef, max(k * rerank_mult, k))
    for bqi in range(B):
        cand = pool.ids[bqi][pool.ids[bqi] >= 0][:rerank_n]
        if len(cand) == 0:
            continue
        vecs = index.vectors[cand]
        d_exact = ((vecs - q[bqi]) ** 2).sum(axis=1)
        ok = ((index.attrs[cand] >= lo[bqi]) &
              (index.attrs[cand] <= hi[bqi])).all(axis=1)
        d_exact = np.where(ok, d_exact, np.inf)
        ordr = np.argsort(d_exact)[:k]
        keep = d_exact[ordr] < np.inf
        ids = np.where(keep, index.perm[cand[ordr]], -1)
        out_i[bqi, :len(ids)] = ids
        out_d[bqi, :len(ids)] = np.where(keep, d_exact[ordr], np.inf)
    return out_i, out_d


# -- the bounded LRU graph-cell cache (hybrid middle tier) -------------------

# donate the buffer: the caller always rebinds to the result, so the
# update happens in place on accelerators instead of copying the cache
@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot(buf, block, start):
    return jax.lax.dynamic_update_slice(
        buf, block, (start,) + (0,) * (buf.ndim - 1))


def cache_slot_rows(index: GMGIndex) -> int:
    """Rows per cache slot: the largest cell, rounded up (quantile
    partitioning keeps cells near-equal sized, so waste is small)."""
    sizes = np.diff(index.cell_start)
    return round_up(max(int(sizes.max()), 1), 8)


def cache_slot_bytes(index: GMGIndex) -> int:
    """Device bytes one cache slot costs (intra + inter adjacency rows);
    used by the engine dispatcher to size/viability-check a hybrid cache
    without building one."""
    deg = index.intra_adj.shape[1]
    S, l = index.inter_adj.shape[1], index.inter_adj.shape[2]
    return cache_slot_rows(index) * (deg + S * l) * 4


def plan_cache_slots(index: GMGIndex, budget_bytes: int | None) -> int:
    """Slots a :class:`CellCache` allocates under ``budget_bytes``
    (None = one per cell). The single sizing rule shared by the cache
    constructor and ``Collection.plan``'s allocation-free introspection."""
    S = index.n_cells
    if budget_bytes is None:
        return S
    return max(1, min(int(budget_bytes // cache_slot_bytes(index)), S))


class CellCache:
    """Device-resident LRU cache of graph cells in fixed-size slots.

    The grid partitions on attribute quantiles, so cells are near-equal
    sized; one slot = ``slot_rows`` adjacency rows (the largest cell,
    rounded up), which keeps every upload the same shape — one jitted
    ``dynamic_update_slice`` program serves all slots.

    Node ids stay *global*: a traversal finds node u's adjacency row at
    ``u + cell_base[cell_of[u]]`` inside the cache buffers (see
    ``GraphView``), so no per-batch remap work and no id translation of
    carried candidates — the whole point of the hybrid tier.
    """

    def __init__(self, index: GMGIndex, budget_bytes: int | None = None,
                 n_slots: int | None = None):
        self.index = index
        self.slot_rows = cache_slot_rows(index)
        deg = index.intra_adj.shape[1]
        S, l = index.inter_adj.shape[1], index.inter_adj.shape[2]
        self.bytes_per_slot = cache_slot_bytes(index)
        if n_slots is None:
            self.n_slots = plan_cache_slots(index, budget_bytes)
        else:
            self.n_slots = max(1, min(int(n_slots), S))
        cap = self.n_slots * self.slot_rows
        self.intra_buf = jnp.full((cap, deg), -1, jnp.int32)
        self.inter_buf = jnp.full((cap, S, l), -1, jnp.int32)
        self._lru: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()           # cell -> slot
        self._free = list(range(self.n_slots))
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_uploaded = 0

    def capacity_bytes(self) -> int:
        return self.n_slots * self.bytes_per_slot

    def ensure(self, cells) -> dict:
        """Make every cell in ``cells`` resident (len <= n_slots),
        evicting least-recently-used cells outside the request. Returns
        per-call stats."""
        cells = list(cells)
        if len(cells) > self.n_slots:
            raise ValueError(
                f"wave of {len(cells)} cells exceeds cache capacity "
                f"{self.n_slots}")
        want = set(cells)
        hits = misses = 0
        for c in cells:
            if c in self._lru:
                self._lru.move_to_end(c)
                hits += 1
                continue
            misses += 1
            if not self._free:
                # evict the LRU cell not needed by this wave
                victim = next(cc for cc in self._lru if cc not in want)
                self._free.append(self._lru.pop(victim))
                self.evictions += 1
            slot = self._free.pop()
            self._upload(c, slot)
            self._lru[c] = slot
            self._lru.move_to_end(c)
        self.hits += hits
        self.misses += misses
        return {"hits": hits, "misses": misses,
                "bytes": misses * self.bytes_per_slot}

    def _upload(self, c: int, slot: int) -> None:
        idx = self.index
        s, e = int(idx.cell_start[c]), int(idx.cell_start[c + 1])
        deg = idx.intra_adj.shape[1]
        S, l = idx.inter_adj.shape[1], idx.inter_adj.shape[2]
        bi = np.full((self.slot_rows, deg), -1, np.int32)
        bx = np.full((self.slot_rows, S, l), -1, np.int32)
        bi[:e - s] = idx.intra_adj[s:e]
        bx[:e - s] = idx.inter_adj[s:e]
        start = jnp.int32(slot * self.slot_rows)
        self.intra_buf = _write_slot(self.intra_buf, jnp.asarray(bi), start)
        self.inter_buf = _write_slot(self.inter_buf, jnp.asarray(bx), start)
        self.bytes_uploaded += bi.nbytes + bx.nbytes

    def cell_base(self) -> np.ndarray:
        """(S,) i32: slot base minus cell_start (UNCACHED when absent)."""
        base = np.full(self.index.n_cells, UNCACHED, np.int32)
        for c, slot in self._lru.items():
            base[c] = slot * self.slot_rows - int(self.index.cell_start[c])
        return base


# -- the runtime: residency + one padded invocation path ---------------------

class CellRuntime:
    """Device residency + the shared traversal-invocation path.

    One instance per engine; ``storage`` picks the resident distance
    table ("f32" for in-core, "int8" for hybrid/out-of-core). Engines
    build a :class:`GraphView` for whatever graph residency they use and
    call :meth:`run`, which pow2-pads the query sub-batch (warm jit
    caches across ragged adaptive splits) and unpads the result.
    """

    def __init__(self, index: GMGIndex, storage: str = "f32"):
        if storage not in ("f32", "int8"):
            raise ValueError(f"unknown storage {storage!r}")
        if storage == "int8" and index.vq is None:
            raise ValueError(
                "int8 storage needs a quantized copy; rebuild with "
                "config.quantize=True")
        self.index = index
        self.storage = storage
        self.attrs_dev = jnp.asarray(index.attrs)
        if storage == "f32":
            self.store = VectorStore(
                vectors=jnp.asarray(index.vectors), vq=None, vscale=None,
                attrs=self.attrs_dev)
        else:
            self.store = VectorStore(
                vectors=None, vq=jnp.asarray(index.vq),
                vscale=jnp.asarray(index.vscale), attrs=self.attrs_dev)
        self.cell_start_dev = jnp.asarray(index.cell_start)
        self.cell_of_dev = jnp.asarray(index.cell_of.astype(np.int32))
        self._resident_graph = None
        self._global_graph = None

    # -- graph views ---------------------------------------------------------

    def resident_graph(self) -> GraphView:
        """Fully device-resident per-cell graph (in-core itinerary)."""
        if self._resident_graph is None:
            idx = self.index
            self._resident_graph = GraphView(
                intra=jnp.asarray(idx.intra_adj),
                inter=jnp.asarray(idx.inter_adj),
                cell_start=self.cell_start_dev)
        return self._resident_graph

    def global_graph(self) -> GraphView:
        """Concatenated intra ++ inter adjacency (adaptive global path)."""
        if self._global_graph is None:
            from repro.core import gmg as gmg_mod
            self._global_graph = GraphView(
                intra=jnp.asarray(gmg_mod.global_adjacency(self.index)),
                inter=None, cell_start=None)
        return self._global_graph

    def cached_graph(self, cache: CellCache) -> GraphView:
        """Hybrid slot-cache view over global ids (see CellCache)."""
        return GraphView(
            intra=cache.intra_buf, inter=cache.inter_buf,
            cell_start=self.cell_start_dev, cell_of=self.cell_of_dev,
            cell_base=jnp.asarray(cache.cell_base()))

    # -- the one invocation path --------------------------------------------

    def run(self, graph: GraphView, q: np.ndarray, lo: np.ndarray,
            hi: np.ndarray, key, *, k: int, ef: int,
            cell_order: np.ndarray | None = None,
            seeds: np.ndarray | None = None,
            use_inter: bool = True, packed_visited: bool = False,
            pool_reuse: bool = False,
            entry_width: int | None = None,
            entry_random: int | None = None,
            entry_beam_l: int | None = None,
            max_iters: int | None = None):
        """Pad, traverse, unpad. Returns ((B, k) i32 view-local ids,
        (B, k) f32 distances) as numpy."""
        cfg = self.index.config
        entry_width = cfg.entry_width if entry_width is None else entry_width
        entry_random = (cfg.entry_random if entry_random is None
                        else entry_random)
        entry_beam_l = (cfg.entry_beam_l if entry_beam_l is None
                        else entry_beam_l)
        max_iters = (cfg.max_iters_per_cell if max_iters is None
                     else max_iters)
        qp, real = pad_pow2(np.asarray(q, np.float32))
        lop, _ = pad_pow2(np.asarray(lo, np.float32))
        hip, _ = pad_pow2(np.asarray(hi, np.float32))
        order_d = None
        if cell_order is not None:
            if isinstance(cell_order, jax.Array):
                # already on device (e.g. straight from order_cells):
                # use as-is instead of a sync + D2H/H2D round-trip; the
                # caller must have computed it on the padded batch
                if cell_order.shape[0] != qp.shape[0]:
                    raise ValueError(
                        f"device cell_order batch {cell_order.shape[0]} "
                        f"!= padded query batch {qp.shape[0]}")
                order_d = cell_order
            else:
                op, _ = pad_pow2(np.asarray(cell_order, np.int32))
                order_d = jnp.asarray(op)
        seeds_d = None
        if seeds is not None:
            sp, _ = pad_pow2(np.asarray(seeds, np.int32))
            seeds_d = jnp.asarray(sp)
        ids, d = traversal_core(
            self.store, graph, jnp.asarray(qp), jnp.asarray(lop),
            jnp.asarray(hip), order_d, seeds_d, key,
            k=k, ef=ef, entry_width=entry_width, entry_random=entry_random,
            entry_beam_l=entry_beam_l, max_iters=max_iters,
            use_inter=use_inter, packed_visited=packed_visited,
            pool_reuse=pool_reuse)
        return np.asarray(ids[:real]), np.asarray(d[:real])
