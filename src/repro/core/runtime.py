"""Shared cell-runtime layer beneath every engine mode (ISSUE 3 tentpole).

Both engines used to reimplement half of the paper's cell-by-cell
execution model; this module owns the common machinery so
``core/search.py`` (in-core), ``core/hybrid.py`` (hybrid-cached) and
``core/pipeline.py`` (out-of-core) shrink to thin orchestrators:

  host side    — pow2/quantum padding (:func:`pad_pow2`, :func:`round_up`),
                 qmap segment handling (:func:`check_qmap`,
                 :func:`merge_segment_topk`), the carried per-query
                 candidate pool (:class:`CandidatePool`), itinerary ranks
                 (:func:`order_ranks`) and the exact fp32 re-rank
                 (:func:`exact_rerank`, host loop).
  device side  — vector/graph residency (:class:`CellRuntime` builds the
                 :class:`~repro.core.traversal.VectorStore` /
                 :class:`~repro.core.traversal.GraphView` pytrees and
                 invokes the one jitted traversal core with stable
                 pow2-padded shapes), the bounded LRU graph-cell cache
                 (:class:`CellCache` — a byte-granular size-aware slot
                 arena by default, fixed largest-cell slots as the legacy
                 policy) that gives the hybrid mode its middle memory
                 tier, and the fused device-side re-rank
                 (:func:`exact_rerank_device`: one jitted
                 gather->distance->k-select pass, bit-identical ids to
                 the host loop).

Engine-mode matrix (storage x graph residency x seeding):

  mode    | vector storage        | graph residency        | seeding
  --------+-----------------------+------------------------+--------------
  incore  | fp32 resident         | fully resident         | fresh beam
  hybrid  | int8 resident +rerank | LRU slot cache         | carried pool
  ooc     | int8 resident +rerank | streamed batch window  | carried pool
"""

from __future__ import annotations

import collections
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.traversal import (
    UNCACHED, GraphView, VectorStore, traversal_core)
from repro.core.types import GMGIndex
from repro.kernels import config as kernel_config
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import span


# -- host-side padding helpers (deduplicated from search.py / pipeline.py) --

def pad_pow2(x: np.ndarray, axis: int = 0):
    """Pad axis 0 to the next power of two by repeating row 0 (keeps the
    jitted program cache warm across ragged sub-batches).
    Returns (padded, original_size)."""
    n = x.shape[axis]
    if n == 0:
        raise ValueError(
            "cannot pad an empty batch (callers must early-return on B=0)")
    p = 1
    while p < n:
        p *= 2
    if p == n:
        return x, n
    reps = np.repeat(x[:1], p - n, axis=0)
    return np.concatenate([x, reps], axis=0), n


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= x (row-quantum padding)."""
    return ((x + mult - 1) // mult) * mult


# -- qmap segment handling (disjunctive box-batching) ------------------------

def check_qmap(qmap, B: int) -> np.ndarray:
    """Validate a planner row -> original-query segment map."""
    qmap = np.asarray(qmap, np.int64)
    if qmap.shape != (B,):
        raise ValueError(f"qmap shape {qmap.shape} != batch ({B},)")
    return qmap


def empty_topk(n_queries: int, k: int):
    """Fully-padded (ids, dists) result block."""
    return (np.full((n_queries, k), -1, np.int64),
            np.full((n_queries, k), np.inf, np.float32))


def merge_segment_topk(ids: np.ndarray, dists: np.ndarray,
                       qmap: np.ndarray, n_queries: int, k: int):
    """Fold per-box candidate rows back into per-query top-k.

    ``ids`` (T, kk) with -1 pads and ``dists`` (T, kk) with +inf pads are
    per-box results; ``qmap`` (T,) maps each row to its original query.
    Returns ((n_queries, k) i64 ids, (n_queries, k) f32 dists).

    Deterministic by construction: duplicate ids within a query (a point
    matching several boxes) collapse to their best distance, candidates
    order by (distance, id) so distance ties break toward the smaller
    id, and queries with no boxes/candidates come back fully padded.
    """
    ids = np.asarray(ids)
    dists = np.asarray(dists)
    out_i, out_d = empty_topk(n_queries, k)
    if ids.size == 0:
        return out_i, out_d
    T, kk = ids.shape
    fq = np.repeat(np.asarray(qmap, np.int64), kk)
    fi = ids.ravel().astype(np.int64)
    fd = dists.ravel().astype(np.float32)
    valid = fi >= 0
    fi, fd, fq = fi[valid], fd[valid], fq[valid]
    if fi.size == 0:
        return out_i, out_d
    # dedup: sort by (query, id, dist), keep each (query, id)'s best dist
    o = np.lexsort((fd, fi, fq))
    fi, fd, fq = fi[o], fd[o], fq[o]
    first = np.ones(fi.shape[0], bool)
    first[1:] = (fq[1:] != fq[:-1]) | (fi[1:] != fi[:-1])
    fi, fd, fq = fi[first], fd[first], fq[first]
    # rank survivors by (query, dist, id) and take each query's first k
    o = np.lexsort((fi, fd, fq))
    fi, fd, fq = fi[o], fd[o], fq[o]
    starts = np.searchsorted(fq, np.arange(n_queries))
    rank = np.arange(fq.shape[0]) - starts[fq]
    keep = rank < k
    out_i[fq[keep], rank[keep]] = fi[keep]
    out_d[fq[keep], rank[keep]] = fd[keep]
    return out_i, out_d


# -- carried per-query candidate pool (paper §5.1 entry propagation) ---------

class CandidatePool:
    """Per-query top-``ef`` candidate carry across cell batches/waves.

    Holds view-global internal ids + (approximate) distances; batches
    re-seed their beam from it and fold their survivors back in. The
    merge is the same deterministic (distance, id) fold the disjunctive
    planner uses, so pool contents are reproducible across runs.
    """

    def __init__(self, n_queries: int, ef: int):
        self.ids = np.full((n_queries, ef), -1, np.int32)
        self.d = np.full((n_queries, ef), np.inf, np.float32)
        self.ef = ef

    def merge(self, rows: np.ndarray, got_ids: np.ndarray,
              got_d: np.ndarray) -> None:
        """Fold (len(rows), kk) new candidates into the carried pool."""
        if len(rows) == 0:
            return
        ids = np.concatenate([self.ids[rows], got_ids], axis=1)
        d = np.concatenate([self.d[rows], got_d], axis=1)
        qm = np.arange(len(rows), dtype=np.int64)
        mi, md = merge_segment_topk(ids, d, qm, len(rows), self.ef)
        self.ids[rows] = mi.astype(np.int32)
        self.d[rows] = md


# -- itinerary ranks (shared by the streaming/hybrid schedulers) -------------

def order_ranks(index: GMGIndex, q: np.ndarray,
                inc: np.ndarray) -> np.ndarray:
    """(B, S) traversal rank per (query, cell) from the cluster vote
    (lower = search earlier; untouched cells get a large rank)."""
    from repro.core.ordering import order_cells
    S = index.n_cells
    order, _ = order_cells(
        jnp.asarray(q), jnp.asarray(index.centroids),
        jnp.asarray(index.hist), jnp.asarray(inc),
        top_m=index.config.top_m_clusters, T=S)
    order = np.asarray(order)
    rank = np.full((q.shape[0], S), S + 1, np.int32)
    for bqi in range(q.shape[0]):
        sel = order[bqi][order[bqi] >= 0]
        rank[bqi, sel] = np.arange(len(sel))
    return rank


# -- exact fp32 re-rank of pool survivors (paper §5.1 step 7) ----------------
#
# Two interchangeable implementations: ``exact_rerank`` (host numpy,
# per-query loop) and ``exact_rerank_device`` (one jitted
# gather->distance->k-select program). Both score the same pool prefix
# and order candidates by exact distance with ties broken toward the
# earlier pool position (host: stable argsort; device: lax.top_k's
# documented lower-index-first tie rule via kernels.ops.k_select), and
# the pool itself is already deterministically ordered by (distance, id)
# — so whenever the two paths compute equal f32 distances the selected
# ids match exactly, and engines may flip ``rerank="device"|"host"``
# freely (enforced across all modes by tests/test_rerank.py).
#
# Caveat on the equality premise: numpy's pairwise summation and XLA's
# reduction order can differ in the last ulp, so two *distinct*
# candidates whose exact distances agree to within f32 summation error
# may swap at the k boundary between the paths. Such a swap exchanges
# candidates of (ulp-)equal exact distance — quality-neutral — but it
# means the id-equality guarantee is exact-float, not cross-backend
# bitwise; comparisons across jax versions/accelerators should treat
# near-tied tails accordingly.

def rerank_width(ef: int, k: int, rerank_mult: int) -> int:
    """Pool prefix both rerank paths score: min(ef, max(k*mult, k))."""
    return min(ef, max(k * rerank_mult, k))


def exact_rerank(index: GMGIndex, pool: CandidatePool, q: np.ndarray,
                 lo: np.ndarray, hi: np.ndarray, k: int,
                 rerank_mult: int):
    """Host-side exact re-rank of each query's carried candidates.
    Returns ((B, k) i64 *original* ids, (B, k) f32 exact distances)."""
    B = q.shape[0]
    out_i, out_d = empty_topk(B, k)
    rerank_n = rerank_width(pool.ef, k, rerank_mult)
    for bqi in range(B):
        cand = pool.ids[bqi][pool.ids[bqi] >= 0][:rerank_n]
        if len(cand) == 0:
            continue
        vecs = index.vectors[cand]
        d_exact = ((vecs - q[bqi]) ** 2).sum(axis=1)
        ok = ((index.attrs[cand] >= lo[bqi]) &
              (index.attrs[cand] <= hi[bqi])).all(axis=1)
        d_exact = np.where(ok, d_exact, np.inf)
        # stable: distance ties keep pool order (device-parity contract)
        ordr = np.argsort(d_exact, kind="stable")[:k]
        keep = d_exact[ordr] < np.inf
        ids = np.where(keep, index.perm[cand[ordr]], -1)
        out_i[bqi, :len(ids)] = ids
        out_d[bqi, :len(ids)] = np.where(keep, d_exact[ordr], np.inf)
    return out_i, out_d


@functools.partial(jax.jit, static_argnames=("k",))
def _rerank_device_core(table, attrs, q, lo, hi, cand, *, k: int):
    """One fused device pass: gathered-row distances (the traversal's own
    scalar-prefetch gather kernel), predicate mask from the resident attr
    table, ascending k-select. cand (B, R) internal ids (-1 pad); table
    (B*R, dim) f32 candidate rows in cand order."""
    from repro.kernels import ops
    B, R = cand.shape
    valid = cand >= 0
    flat = jnp.arange(B * R, dtype=jnp.int32).reshape(B, R)
    d2 = ops.gather_l2(q, table, jnp.where(valid, flat, -1))
    a = attrs[jnp.maximum(cand, 0)]                       # (B, R, m)
    ok = (a >= lo[:, None, :]) & (a <= hi[:, None, :])
    d2 = jnp.where(valid & ok.all(axis=2), d2, jnp.inf)
    vals, pos = ops.k_select(d2, k)
    ids = jnp.take_along_axis(cand, pos, axis=1)
    return jnp.where(jnp.isfinite(vals), ids, -1), vals


def exact_rerank_device(index: GMGIndex, attrs_dev, pool: CandidatePool,
                        q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                        k: int, rerank_mult: int):
    """Device-side exact re-rank: same contract as :func:`exact_rerank`
    without the per-query host loop — one H2D of the candidates' fp32
    rows (they are *not* device-resident in the hybrid/ooc modes, only
    the int8 copy is) and one jitted gather->distance->top-k program;
    only the final (B, k) block returns to the host.
    ``attrs_dev`` is the engine's resident attribute table."""
    B = q.shape[0]
    R = rerank_width(pool.ef, k, rerank_mult)
    candp, real = pad_pow2(pool.ids[:, :R].astype(np.int32))
    qp, _ = pad_pow2(np.asarray(q, np.float32))
    lop, _ = pad_pow2(np.asarray(lo, np.float32))
    hip, _ = pad_pow2(np.asarray(hi, np.float32))
    tbl = index.vectors[np.maximum(candp, 0).reshape(-1)]
    # k may exceed the candidate width (k > ef): select what exists and
    # pad back out, exactly like the host loop's short result rows
    kk = min(k, R)
    ids, vals = _rerank_device_core(
        jnp.asarray(tbl), attrs_dev, jnp.asarray(qp), jnp.asarray(lop),
        jnp.asarray(hip), jnp.asarray(candp), k=kk)
    ids = np.asarray(ids[:real])
    vals = np.asarray(vals[:real])
    out_i, out_d = empty_topk(B, k)
    out_i[:, :kk] = np.where(ids >= 0, index.perm[np.maximum(ids, 0)], -1)
    out_d[:, :kk] = np.where(ids >= 0, vals, np.inf)
    return out_i, out_d


# -- the dense route: fused masked scan over qualifying candidates -----------

def dense_candidates(index: GMGIndex, inc_row: np.ndarray) -> np.ndarray:
    """Ascending internal ids inside the selected cells of one box.

    Cells ascend and rows are cell-contiguous, so the concatenation is
    globally ascending — the property that makes chunked k-select merges
    come out (distance, id)-ordered like ``mutable.scan_buffer``."""
    cells = np.nonzero(inc_row)[0]
    if cells.size == 0:
        return np.empty(0, np.int32)
    cs = index.cell_start
    return np.concatenate(
        [np.arange(cs[c], cs[c + 1], dtype=np.int32) for c in cells])


# per-row candidate count above which the fused gather kernel stops
# paying: it materializes (B, width, d) gathered rows, so a broad box
# over a small corpus (cand ~ n) costs B full-table copies, while the
# cell-batched scan re-slices each selected cell once for every query
# that wants it. True ultra-selective boxes stay under this and keep
# the single-launch gather path.
DENSE_GATHER_MAX = 2048


@functools.partial(jax.jit, static_argnames=("w", "kk"))
def _dense_cell_topk(vectors, attrs, q, lo, hi, start, end,
                     w: int, kk: int):
    """Exact top-kk of one contiguous f32 cell [start, end) for a query
    batch, predicate folded in as +inf. The cell window is *dynamic*
    (one compiled program per batch shape, not per cell): a fixed-width
    slice of ``w`` rows is taken at a clamped offset and rows outside
    [start, end) are masked out. Ties break to the lower row position
    (= lower internal id, rows are cell-contiguous). Returns (vals,
    global row ids)."""
    from repro.kernels import ops
    s0 = jnp.clip(start, 0, vectors.shape[0] - w)
    vcell = jax.lax.dynamic_slice_in_dim(vectors, s0, w)
    acell = jax.lax.dynamic_slice_in_dim(attrs, s0, w)
    gpos = s0 + jnp.arange(w)
    d2 = ops.pairwise_l2(q, vcell)
    ok = (acell[None] >= lo[:, None, :]) & (acell[None] <= hi[:, None, :])
    ok = jnp.all(ok, axis=2) & ((gpos >= start) & (gpos < end))[None]
    d2 = jnp.where(ok, d2, jnp.inf)
    vals, pos = ops.k_select(d2, kk)
    return vals, gpos[pos]


@functools.partial(jax.jit, static_argnames=("w", "kk"))
def _dense_cell_topk_q(vq, vscale, attrs, q, lo, hi, start, end,
                       w: int, kk: int):
    """Int8 twin of :func:`_dense_cell_topk`: dequantizes the cell slice
    (scale * int8) before the same masked exact scan."""
    from repro.kernels import ops
    s0 = jnp.clip(start, 0, vq.shape[0] - w)
    rows = (jax.lax.dynamic_slice_in_dim(vq, s0, w).astype(jnp.float32)
            * jax.lax.dynamic_slice_in_dim(
                vscale.reshape(-1), s0, w).reshape(-1, 1))
    acell = jax.lax.dynamic_slice_in_dim(attrs, s0, w)
    gpos = s0 + jnp.arange(w)
    d2 = ops.pairwise_l2(q, rows)
    ok = (acell[None] >= lo[:, None, :]) & (acell[None] <= hi[:, None, :])
    ok = jnp.all(ok, axis=2) & ((gpos >= start) & (gpos < end))[None]
    d2 = jnp.where(ok, d2, jnp.inf)
    vals, pos = ops.k_select(d2, kk)
    return vals, gpos[pos]


def _cell_scan(rt: "CellRuntime", q, lo, hi, inc, k: int):
    """Shared-slice dense strategy: every cell any row selected is
    scanned once for the whole batch, winners merge on the host. Rows
    that did not select a cell are unaffected — no member of a
    non-selected cell can pass the row's own predicate (cell bounds
    cover members), so the mask alone keeps results exact. Cells ascend
    and the merge argsort is stable, so the output is (distance,
    id)-ordered exactly like the gather strategy."""
    index = rt.index
    B = q.shape[0]
    out_i = np.full((B, k), -1, np.int32)
    out_d = np.full((B, k), np.inf, np.float32)
    starts = index.cell_start
    n = int(starts[-1])
    # static window: pow2 of the widest cell, capped at the table
    w = min(1 << max(3, int(np.diff(starts).max(initial=1) - 1)
                     .bit_length()), n)
    kk = min(k, w)
    qs, real = pad_pow2(np.asarray(q, np.float32))
    los, _ = pad_pow2(np.asarray(lo, np.float32))
    his, _ = pad_pow2(np.asarray(hi, np.float32))
    qd = jnp.asarray(qs)
    lod, hid = jnp.asarray(los), jnp.asarray(his)
    for cell in np.nonzero(inc.any(axis=0))[0]:
        s, e = int(starts[cell]), int(starts[cell + 1])
        if e <= s:
            continue
        if rt.storage == "f32":
            vals, gpos = _dense_cell_topk(rt.store.vectors, rt.attrs_dev,
                                          qd, lod, hid, s, e, w, kk)
        else:
            vals, gpos = _dense_cell_topk_q(rt.store.vq, rt.store.vscale,
                                            rt.attrs_dev, qd, lod, hid,
                                            s, e, w, kk)
        vals = np.asarray(vals[:real])
        ids = np.asarray(gpos[:real], np.int32)
        ids = np.where(np.isfinite(vals), ids, -1)
        vals = np.where(ids >= 0, vals, np.inf).astype(np.float32)
        md = np.concatenate([out_d, vals], axis=1)
        mi = np.concatenate([out_i, ids], axis=1)
        o = np.argsort(md, axis=1, kind="stable")[:, :k]
        out_d = np.take_along_axis(md, o, axis=1)
        out_i = np.take_along_axis(mi, o, axis=1)
    return out_i, out_d


def masked_dense_scan(rt: "CellRuntime", q: np.ndarray, lo: np.ndarray,
                      hi: np.ndarray, inc: np.ndarray, k: int,
                      chunk: int = 8192):
    """Brute-force the dense route's rows over the resident table.

    Each query row enumerates the candidate ids inside its selected
    cells, then one of two exact strategies runs — chosen per row from
    its *own* candidate count (a pure function of (box, index), so batch
    composition can never flip it):

      - cand <= ``DENSE_GATHER_MAX``: the fused gather->predicate->
        distance->k-select scan (``kernels.masked_scan``) in fixed-width
        chunks, merging chunk winners by a stable (distance-first) sort.
      - larger: the cell-batched scan — each selected cell is sliced
        once and scanned for the whole sub-batch selecting it, so broad
        boxes never pay per-row gathered copies of the table.

    Uses whatever table the runtime keeps resident: exact f32 distances
    in-core, dequantized int8 in hybrid/ooc (callers re-rank those in
    fp32 as usual).

    Returns ((B, k) i32 *internal* ids with -1 pads, (B, k) f32
    distances with +inf pads, (B,) i64 exact qualifying-row counts —
    the estimator-error ground truth reported in stats).

    Determinism: candidates ascend per row, chunks/cells ascend,
    ``k_select`` ties break to the lower column, and every merge is a
    stable argsort — both strategies emit the same (distance, id)
    ordering, depending only on (vector, box), never on batch
    composition.
    """
    index = rt.index
    B = q.shape[0]
    out_i = np.full((B, k), -1, np.int32)
    out_d = np.full((B, k), np.inf, np.float32)
    n_qual = np.zeros(B, np.int64)
    if B == 0:
        return out_i, out_d, n_qual
    cands = [dense_candidates(index, inc[t]) for t in range(B)]
    sizes = np.array([c.size for c in cands], np.int64)
    if sizes.max(initial=0) == 0:
        return out_i, out_d, n_qual
    # exact qualifying counts (host, cheap at dense-route sizes); NaN
    # attrs (tombstones) fail the predicate like everywhere else
    for t in range(B):
        if cands[t].size:
            a = index.attrs[cands[t]]
            with np.errstate(invalid="ignore"):
                ok = ((a >= lo[t]) & (a <= hi[t])).all(axis=1)
            n_qual[t] = int(ok.sum())
    big = np.nonzero(sizes > DENSE_GATHER_MAX)[0]
    if len(big):
        ids_b, d_b = _cell_scan(rt, q[big], lo[big], hi[big], inc[big], k)
        out_i[big], out_d[big] = ids_b, d_b
    small = np.nonzero((sizes > 0) & (sizes <= DENSE_GATHER_MAX))[0]
    if len(small) == 0:
        return out_i, out_d, n_qual
    ids_s, d_s = _gather_scan(rt, q[small], lo[small], hi[small],
                              [cands[t] for t in small], k, chunk)
    out_i[small], out_d[small] = ids_s, d_s
    return out_i, out_d, n_qual


def _gather_scan(rt: "CellRuntime", q, lo, hi, cands, k: int, chunk: int):
    """Fused-kernel dense strategy (see :func:`masked_dense_scan`)."""
    from repro.kernels import masked_scan as ms
    B = q.shape[0]
    out_i = np.full((B, k), -1, np.int32)
    out_d = np.full((B, k), np.inf, np.float32)
    max_l = max(c.size for c in cands)
    qp, real = pad_pow2(np.asarray(q, np.float32))
    lop, _ = pad_pow2(np.asarray(lo, np.float32))
    hip, _ = pad_pow2(np.asarray(hi, np.float32))
    P = qp.shape[0]
    qd, lod, hid = jnp.asarray(qp), jnp.asarray(lop), jnp.asarray(hip)
    n_chunks = (max_l + chunk - 1) // chunk
    # pow2 width below one chunk: bounded set of jitted program shapes
    width = chunk if n_chunks > 1 else 1 << max(3, (max_l - 1).bit_length())
    for ci in range(n_chunks):
        idx = np.full((P, width), -1, np.int32)
        for t in range(B):
            part = cands[t][ci * chunk:(ci + 1) * chunk]
            idx[t, :part.size] = part
        if ci and not (idx >= 0).any():
            break
        kk = min(k, width)
        if rt.storage == "f32":
            vals, pos = ms.masked_topk(
                qd, rt.store.vectors, rt.attrs_dev, lod, hid,
                jnp.asarray(idx), kk)
        else:
            vals, pos = ms.masked_topk_q(
                qd, rt.store.vq, rt.store.vscale, rt.attrs_dev, lod, hid,
                jnp.asarray(idx), kk)
        vals = np.asarray(vals[:real])
        pos = np.asarray(pos[:real])
        ids = np.take_along_axis(idx[:real], pos, axis=1)
        ids = np.where(np.isfinite(vals), ids, -1)
        vals = np.where(ids >= 0, vals, np.inf).astype(np.float32)
        if ci == 0 and kk == k:
            out_i, out_d = ids, vals
            continue
        ci_all = np.concatenate([out_i, ids], axis=1)
        cd_all = np.concatenate([out_d, vals], axis=1)
        o = np.argsort(cd_all, axis=1, kind="stable")[:, :k]
        out_i = np.take_along_axis(ci_all, o, axis=1)
        out_d = np.take_along_axis(cd_all, o, axis=1)
    return out_i.astype(np.int32), out_d


# -- the bounded LRU graph-cell cache (hybrid middle tier) -------------------

# donate the buffer: the caller always rebinds to the result, so the
# update happens in place on accelerators instead of copying the cache
@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot(buf, block, start):
    return jax.lax.dynamic_update_slice(
        buf, block, (start,) + (0,) * (buf.ndim - 1))


# arena allocation granularity (rows); bounds fragmentation and the
# number of distinct upload-block shapes the jitted writer compiles
ROW_QUANTUM = 8


def cache_row_bytes(index: GMGIndex) -> int:
    """Device bytes one adjacency row costs (intra + inter columns)."""
    deg = index.intra_adj.shape[1]
    S, l = index.inter_adj.shape[1], index.inter_adj.shape[2]
    return (deg + S * l) * 4


def cell_alloc_rows(index: GMGIndex) -> np.ndarray:
    """(S,) rows each cell occupies in the size-aware arena (its own
    size, quantum-rounded) — the per-cell weight the wave scheduler
    packs against the arena capacity."""
    sizes = np.maximum(np.diff(index.cell_start), 1)
    return ((sizes + ROW_QUANTUM - 1) // ROW_QUANTUM
            * ROW_QUANTUM).astype(np.int64)


def plan_cache_rows(index: GMGIndex, budget_bytes: int | None) -> int:
    """Arena rows a size-aware :class:`CellCache` allocates under
    ``budget_bytes`` (None = every cell resident). Never below the
    largest single cell (a cache that cannot hold its biggest cell
    cannot run any wave touching it)."""
    rows = cell_alloc_rows(index)
    total = int(rows.sum())
    if budget_bytes is None:
        return total
    cap = int(budget_bytes // cache_row_bytes(index))
    return max(int(rows.max()), min(cap, total))


def cache_slot_rows(index: GMGIndex) -> int:
    """Rows per fixed-policy cache slot: the largest cell, rounded up.
    Skewed cell-size distributions pay this padding on *every* slot —
    the waste the size-aware arena exists to reclaim."""
    sizes = np.diff(index.cell_start)
    return round_up(max(int(sizes.max()), 1), ROW_QUANTUM)


def cache_slot_bytes(index: GMGIndex) -> int:
    """Device bytes one fixed-policy cache slot costs (intra + inter
    adjacency rows); used by the engine dispatcher to size/viability-check
    a hybrid cache without building one."""
    return cache_slot_rows(index) * cache_row_bytes(index)


def plan_cache_slots(index: GMGIndex, budget_bytes: int | None) -> int:
    """Slots a fixed-policy :class:`CellCache` allocates under
    ``budget_bytes`` (None = one per cell). The single sizing rule shared
    by the cache constructor and ``Collection.plan``'s allocation-free
    introspection."""
    S = index.n_cells
    if budget_bytes is None:
        return S
    return max(1, min(int(budget_bytes // cache_slot_bytes(index)), S))


CACHE_POLICIES = ("size_aware", "fixed")

# valid exact-rerank paths (see the re-rank section above); shared by the
# engines and the Collection facade so the set lives in one place
RERANKS = ("device", "host")


class CellCache:
    """Device-resident LRU cache of graph cells.

    Two allocation policies over the same contract (``ensure`` a wave of
    cells, read back ``cell_base`` indirection, LRU-evict whole cells):

    ``policy="size_aware"`` (default) — a byte-granular slot *arena*:
    each cell occupies exactly its own rows (quantum-rounded), allocated
    first-fit over a free-extent list with LRU eviction of whole cells.
    Skewed cell-size distributions stop paying largest-cell padding on
    every slot, so the same byte budget keeps more cells resident. When
    first-fit fails on fragmentation (want-pinned extents splitting the
    free space), surviving cells are compacted to the front and the
    allocation retried — ``compactions`` counts those re-uploads.

    ``policy="fixed"`` — the legacy equal-slot layout (one slot = the
    largest cell, rounded up) with cache-blind scheduling upstream; kept
    as the PR-3 baseline the memory-budget bench ablates against.

    Node ids stay *global* under both policies: a traversal finds node
    u's adjacency row at ``u + cell_base[cell_of[u]]`` inside the cache
    buffers (see ``GraphView``), so no per-batch remap work and no id
    translation of carried candidates — the whole point of the hybrid
    tier. The traversal core never addresses a row outside a resident
    cell's extent: ``cell_base`` is ``UNCACHED`` for absent cells and
    in-extent pad rows hold -1 adjacency.
    """

    def __init__(self, index: GMGIndex, budget_bytes: int | None = None,
                 n_slots: int | None = None, policy: str = "size_aware",
                 registry: MetricsRegistry | None = None):
        if policy not in CACHE_POLICIES:
            raise ValueError(f"unknown cache policy {policy!r}; "
                             f"expected one of {CACHE_POLICIES}")
        self.index = index
        self.policy = policy
        S = index.inter_adj.shape[1]
        deg = index.intra_adj.shape[1]
        l = index.inter_adj.shape[2]
        self.row_bytes = cache_row_bytes(index)
        self.slot_rows = cache_slot_rows(index)
        self.bytes_per_slot = cache_slot_bytes(index)
        self.alloc_rows = cell_alloc_rows(index)
        if policy == "fixed":
            if n_slots is None:
                self.n_slots = plan_cache_slots(index, budget_bytes)
            else:
                self.n_slots = max(1, min(int(n_slots), S))
            self.cap_rows = self.n_slots * self.slot_rows
        else:
            if n_slots is not None:
                # back-compat: n_slots expressed in largest-cell units
                self.cap_rows = max(1, min(int(n_slots), S)) * self.slot_rows
            else:
                self.cap_rows = plan_cache_rows(index, budget_bytes)
            self.n_slots = max(1, self.cap_rows // self.slot_rows)
        self.intra_buf = jnp.full((self.cap_rows, deg), -1, jnp.int32)
        self.inter_buf = jnp.full((self.cap_rows, S, l), -1, jnp.int32)
        # cell -> (start_row, rows); insertion order is the LRU order
        self._lru: "collections.OrderedDict[int, tuple[int, int]]" = \
            collections.OrderedDict()
        self._free: list[tuple[int, int]] = [(0, self.cap_rows)]
        # lifetime counters live in the obs registry (ISSUE 10): the
        # owning engine passes its registry in so its per-pass stats are
        # deltas over these same objects; the legacy attribute reads
        # (cache.hits, cache.bytes_uploaded, ...) stay as properties
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._c_hits = self.metrics.counter("cache_hits")
        self._c_misses = self.metrics.counter("cache_misses")
        self._c_evictions = self.metrics.counter("cache_evictions")
        self._c_compactions = self.metrics.counter("cache_compactions")
        self._c_uploaded = self.metrics.counter("bytes_uploaded")
        # double-buffered streaming (ISSUE 8): cells uploaded ahead of
        # their wave by prefetch(); a later ensure() hit on one counts as
        # a prefetch hit, eviction before use as a wasted prefetch
        self._c_prefetches = self.metrics.counter("prefetches")
        self._c_prefetch_hits = self.metrics.counter("prefetch_hits")
        self._c_prefetch_bytes = self.metrics.counter("prefetch_bytes")
        self._prefetched: set[int] = set()

    # registry-backed views of the lifetime counters
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @property
    def evictions(self) -> int:
        return self._c_evictions.value

    @property
    def compactions(self) -> int:
        return self._c_compactions.value

    @property
    def bytes_uploaded(self) -> int:
        return self._c_uploaded.value

    @property
    def prefetches(self) -> int:
        return self._c_prefetches.value

    @property
    def prefetch_hits(self) -> int:
        return self._c_prefetch_hits.value

    @property
    def prefetch_bytes(self) -> int:
        return self._c_prefetch_bytes.value

    def capacity_bytes(self) -> int:
        return self.cap_rows * self.row_bytes

    def resident_cells(self) -> frozenset:
        """Cells currently resident — the scheduler's affinity seed."""
        return frozenset(self._lru)

    def hit_rate(self) -> float:
        """Lifetime hit fraction of ``ensure`` lookups."""
        return self.hits / max(self.hits + self.misses, 1)

    def stats(self) -> dict:
        """One snapshot of every lifetime counter — what the engines
        (and through them ``QueryResult.stats``) export per pass."""
        return {"cache_hits": self.hits, "cache_misses": self.misses,
                "cache_evictions": self.evictions,
                "cache_compactions": self.compactions,
                "bytes_uploaded": self.bytes_uploaded,
                "prefetches": self.prefetches,
                "prefetch_hits": self.prefetch_hits,
                "prefetch_bytes": self.prefetch_bytes,
                "hit_rate": self.hit_rate(),
                "resident_cells": len(self._lru),
                "capacity_bytes": self.capacity_bytes()}

    def _rows_of(self, c: int) -> int:
        return self.slot_rows if self.policy == "fixed" \
            else int(self.alloc_rows[c])

    def ensure(self, cells) -> dict:
        """Make every cell in ``cells`` resident (their summed rows must
        fit the arena), evicting least-recently-used cells outside the
        request. Returns per-call stats."""
        cells = list(cells)
        want = set(cells)
        need = sum(self._rows_of(c) for c in want)
        if need > self.cap_rows:
            raise ValueError(
                f"wave of {len(cells)} cells needs {need} rows, exceeds "
                f"cache capacity {self.cap_rows}")
        hits = misses = 0
        # measure actual H2D traffic via the upload counter so the
        # re-uploads a compaction performs count too — transfer_bytes is
        # a CI-gated metric and must not undercount churn
        bytes_before = self.bytes_uploaded
        with span("cache.ensure", cells=len(cells)) as sp:
            for c in cells:
                if c in self._lru:
                    self._lru.move_to_end(c)
                    hits += 1
                    if c in self._prefetched:
                        self._c_prefetch_hits.inc()
                        self._prefetched.discard(c)
                    continue
                misses += 1
                rows = self._rows_of(c)
                start = self._alloc(rows, want)
                self._upload(c, start, rows)
                self._lru[c] = (start, rows)
                self._lru.move_to_end(c)
            self._c_hits.inc(hits)
            self._c_misses.inc(misses)
            got = {"hits": hits, "misses": misses,
                   "bytes": self.bytes_uploaded - bytes_before}
            sp.annotate(**got)
        return got

    def prefetch(self, cells) -> dict:
        """Best-effort upload of a *future* wave's missing cells while the
        current wave's traversal is still in flight (the double-buffered
        half of the fused-wave PR): device buffers are immutable jnp
        arrays, so the in-flight traversal keeps reading its own snapshot
        while these uploads build the next one. Already-resident cells are
        touched (LRU-promoted) but not re-uploaded; cells that will not
        fit are skipped rather than raised — prefetch is advisory, the
        wave's own ``ensure`` stays authoritative."""
        bytes_before = self.bytes_uploaded
        uploaded = 0
        want = set(c for c in cells if c in self._lru)
        # the span sits INSIDE the enclosing wave-traversal span on the
        # hybrid path, so in a Perfetto timeline these prefetch uploads
        # visibly overlap the in-flight traversal they are hidden behind
        with span("cache.prefetch") as sp:
            for c in cells:
                if c in self._lru:
                    self._lru.move_to_end(c)
                    continue
                rows = self._rows_of(c)
                want.add(c)
                try:
                    start = self._alloc(rows, want)
                except ValueError:
                    want.discard(c)
                    continue
                self._upload(c, start, rows)
                self._lru[c] = (start, rows)
                self._lru.move_to_end(c)
                self._prefetched.add(c)
                uploaded += 1
            self._c_prefetches.inc(uploaded)
            self._c_prefetch_bytes.inc(self.bytes_uploaded - bytes_before)
            got = {"prefetched": uploaded,
                   "bytes": self.bytes_uploaded - bytes_before}
            sp.annotate(**got)
        return got

    # -- arena bookkeeping --------------------------------------------------

    def _try_fit(self, rows: int):
        """Carve ``rows`` from the first free extent that fits, or None."""
        for i, (s, ln) in enumerate(self._free):
            if ln >= rows:
                if ln == rows:
                    self._free.pop(i)
                else:
                    self._free[i] = (s + rows, ln - rows)
                return s
        return None

    def _alloc(self, rows: int, want: set) -> int:
        """First-fit over the free extents; evict LRU cells outside the
        current wave until a fit exists, compacting as a last resort."""
        while True:
            start = self._try_fit(rows)
            if start is not None:
                return start
            victim = next((cc for cc in self._lru if cc not in want), None)
            if victim is not None:
                self._release(victim)
                self._c_evictions.inc()
                continue
            # every resident cell is wanted: free space exists (the
            # capacity check passed) but is fragmented around pinned
            # extents — repack survivors and retry
            self._compact()
            start = self._try_fit(rows)
            if start is not None:
                return start
            raise ValueError(
                f"cannot place {rows} rows in a {self.cap_rows}-row cache")

    def _release(self, c: int) -> None:
        self._prefetched.discard(c)  # evicted before use = wasted prefetch
        start, rows = self._lru.pop(c)
        self._free.append((start, rows))
        # keep extents sorted + coalesced so first-fit stays first-fit
        self._free.sort()
        merged = [self._free[0]]
        for s, ln in self._free[1:]:
            ps, pl = merged[-1]
            if ps + pl == s:
                merged[-1] = (ps, pl + ln)
            else:
                merged.append((s, ln))
        self._free = merged

    def _compact(self) -> None:
        """Repack resident cells to the arena front (LRU order kept),
        re-uploading moved cells; frees one contiguous tail extent."""
        self._c_compactions.inc()
        cursor = 0
        for c in list(self._lru):
            start, rows = self._lru[c]
            if start != cursor:
                self._upload(c, cursor, rows)
                self._lru[c] = (cursor, rows)
            cursor += rows
        self._free = [(cursor, self.cap_rows - cursor)] \
            if cursor < self.cap_rows else []

    def _upload(self, c: int, start: int, rows: int) -> None:
        idx = self.index
        s, e = int(idx.cell_start[c]), int(idx.cell_start[c + 1])
        deg = idx.intra_adj.shape[1]
        S, l = idx.inter_adj.shape[1], idx.inter_adj.shape[2]
        bi = np.full((rows, deg), -1, np.int32)
        bx = np.full((rows, S, l), -1, np.int32)
        bi[:e - s] = idx.intra_adj[s:e]
        bx[:e - s] = idx.inter_adj[s:e]
        at = jnp.int32(start)
        with span("cache.upload", cell=c, bytes=bi.nbytes + bx.nbytes):
            self.intra_buf = _write_slot(self.intra_buf, jnp.asarray(bi), at)
            self.inter_buf = _write_slot(self.inter_buf, jnp.asarray(bx), at)
        self._c_uploaded.inc(bi.nbytes + bx.nbytes)

    def cell_base(self) -> np.ndarray:
        """(S,) i32: arena base minus cell_start (UNCACHED when absent)."""
        base = np.full(self.index.n_cells, UNCACHED, np.int32)
        for c, (start, _) in self._lru.items():
            base[c] = start - int(self.index.cell_start[c])
        return base


# -- the runtime: residency + one padded invocation path ---------------------

class CellRuntime:
    """Device residency + the shared traversal-invocation path.

    One instance per engine; ``storage`` picks the resident distance
    table ("f32" for in-core, "int8" for hybrid/out-of-core). Engines
    build a :class:`GraphView` for whatever graph residency they use and
    call :meth:`run`, which pow2-pads the query sub-batch (warm jit
    caches across ragged adaptive splits) and unpads the result.
    """

    def __init__(self, index: GMGIndex, storage: str = "f32"):
        if storage not in ("f32", "int8"):
            raise ValueError(f"unknown storage {storage!r}")
        if storage == "int8" and index.vq is None:
            raise ValueError(
                "int8 storage needs a quantized copy; rebuild with "
                "config.quantize=True")
        self.index = index
        self.storage = storage
        self.attrs_dev = jnp.asarray(index.attrs)
        if storage == "f32":
            self.store = VectorStore(
                vectors=jnp.asarray(index.vectors), vq=None, vscale=None,
                attrs=self.attrs_dev)
        else:
            self.store = VectorStore(
                vectors=None, vq=jnp.asarray(index.vq),
                vscale=jnp.asarray(index.vscale), attrs=self.attrs_dev)
        self.cell_start_dev = jnp.asarray(index.cell_start)
        self.cell_of_dev = jnp.asarray(index.cell_of.astype(np.int32))
        self._resident_graph = None
        self._global_graph = None

    def refresh_index(self, index: GMGIndex) -> None:
        """Swap to a same-layout index whose *attribute table* changed —
        the delete path: tombstoned rows read NaN, which no range
        admits, so one attr re-upload folds the tombstone bitmap into
        every predicate check. Vectors, graph views and any cell cache
        built on this runtime stay resident (layout is unchanged), so
        deletes never cold-start the engines."""
        if index.attrs.shape != self.index.attrs.shape:
            raise ValueError(
                "refresh_index is for same-layout attr updates; a flush/"
                "compact (row count changed) must rebuild the engine")
        self.index = index
        self.attrs_dev = jnp.asarray(index.attrs)
        self.store = self.store._replace(attrs=self.attrs_dev)

    # -- graph views ---------------------------------------------------------

    def resident_graph(self) -> GraphView:
        """Fully device-resident per-cell graph (in-core itinerary)."""
        if self._resident_graph is None:
            idx = self.index
            self._resident_graph = GraphView(
                intra=jnp.asarray(idx.intra_adj),
                inter=jnp.asarray(idx.inter_adj),
                cell_start=self.cell_start_dev)
        return self._resident_graph

    def global_graph(self) -> GraphView:
        """Concatenated intra ++ inter adjacency (adaptive global path)."""
        if self._global_graph is None:
            from repro.core import gmg as gmg_mod
            self._global_graph = GraphView(
                intra=jnp.asarray(gmg_mod.global_adjacency(self.index)),
                inter=None, cell_start=None)
        return self._global_graph

    def cached_graph(self, cache: CellCache) -> GraphView:
        """Hybrid slot-cache view over global ids (see CellCache)."""
        return GraphView(
            intra=cache.intra_buf, inter=cache.inter_buf,
            cell_start=self.cell_start_dev, cell_of=self.cell_of_dev,
            cell_base=jnp.asarray(cache.cell_base()))

    # -- the one invocation path --------------------------------------------

    def run_launch(self, graph: GraphView, q: np.ndarray, lo: np.ndarray,
                   hi: np.ndarray, key, *, k: int, ef: int,
                   cell_order: np.ndarray | None = None,
                   seeds: np.ndarray | None = None,
                   use_inter: bool = True, packed_visited: bool = False,
                   pool_reuse: bool = False,
                   entry_width: int | None = None,
                   entry_random: int | None = None,
                   entry_beam_l: int | None = None,
                   max_iters: int | None = None):
        """Pad and launch one traversal, returning *device* arrays
        ``(ids, d, real)`` without blocking — the async half of
        :meth:`run`. Engines that overlap streaming with compute (the
        hybrid wave loop) call this, then prefetch the next wave's cells
        while the launched program runs, and only then materialize.

        The kernel dispatch mode (``repro.kernels.config``) is resolved
        *here*, per launch, to a static ``fused`` flag: the whole
        expansion step runs as one Pallas traversal-wave program when the
        mode says pallas, and as the unfused jnp composition otherwise.
        Resolving at the launch boundary keeps the mode out of the jit
        cache key logic inside the core (it is just another static
        argument there)."""
        cfg = self.index.config
        entry_width = cfg.entry_width if entry_width is None else entry_width
        entry_random = (cfg.entry_random if entry_random is None
                        else entry_random)
        entry_beam_l = (cfg.entry_beam_l if entry_beam_l is None
                        else entry_beam_l)
        max_iters = (cfg.max_iters_per_cell if max_iters is None
                     else max_iters)
        qp, real = pad_pow2(np.asarray(q, np.float32))
        lop, _ = pad_pow2(np.asarray(lo, np.float32))
        hip, _ = pad_pow2(np.asarray(hi, np.float32))
        order_d = None
        if cell_order is not None:
            if isinstance(cell_order, jax.Array):
                # already on device (e.g. straight from order_cells):
                # use as-is instead of a sync + D2H/H2D round-trip; the
                # caller must have computed it on the padded batch
                if cell_order.shape[0] != qp.shape[0]:
                    raise ValueError(
                        f"device cell_order batch {cell_order.shape[0]} "
                        f"!= padded query batch {qp.shape[0]}")
                order_d = cell_order
            else:
                op, _ = pad_pow2(np.asarray(cell_order, np.int32))
                order_d = jnp.asarray(op)
        seeds_d = None
        if seeds is not None:
            sp, _ = pad_pow2(np.asarray(seeds, np.int32))
            seeds_d = jnp.asarray(sp)
        # kernels-launch accounting: this span covers the *dispatch* only
        # (the program runs async); the enclosing engine span owns the
        # launch->block window, so dispatch overhead is separable from
        # device wait in a trace
        fused = kernel_config.use_pallas()
        with span("launch.dispatch", rows=int(qp.shape[0]), k=k, ef=ef,
                  fused=fused):
            ids, d = traversal_core(
                self.store, graph, jnp.asarray(qp), jnp.asarray(lop),
                jnp.asarray(hip), order_d, seeds_d, key,
                k=k, ef=ef, entry_width=entry_width,
                entry_random=entry_random, entry_beam_l=entry_beam_l,
                max_iters=max_iters, use_inter=use_inter,
                packed_visited=packed_visited, pool_reuse=pool_reuse,
                fused=fused)
        return ids, d, real

    def run(self, graph: GraphView, q: np.ndarray, lo: np.ndarray,
            hi: np.ndarray, key, *, k: int, ef: int,
            cell_order: np.ndarray | None = None,
            seeds: np.ndarray | None = None,
            use_inter: bool = True, packed_visited: bool = False,
            pool_reuse: bool = False,
            entry_width: int | None = None,
            entry_random: int | None = None,
            entry_beam_l: int | None = None,
            max_iters: int | None = None):
        """Pad, traverse, unpad. Returns ((B, k) i32 view-local ids,
        (B, k) f32 distances) as numpy."""
        ids, d, real = self.run_launch(
            graph, q, lo, hi, key, k=k, ef=ef, cell_order=cell_order,
            seeds=seeds, use_inter=use_inter, packed_visited=packed_visited,
            pool_reuse=pool_reuse, entry_width=entry_width,
            entry_random=entry_random, entry_beam_l=entry_beam_l,
            max_iters=max_iters)
        return np.asarray(ids[:real]), np.asarray(d[:real])
