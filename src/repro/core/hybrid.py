"""Hybrid-cached execution: resident int8 vectors + an LRU graph cache.

The budget regime between the two existing extremes (VecFlow-style,
PAPERS.md): when the whole fp32 index does not fit but the quantized
vectors do, keeping the *hot* graph cells device-resident and streaming
only misses recovers most of the in-core throughput at the out-of-core
memory footprint. ``Collection`` selects this engine (``mode="hybrid"``)
when the declared ``device_budget_bytes`` covers the int8 residents plus
a useful cell cache.

Engine-mode matrix (storage x graph residency x seeding) — this module
is the **hybrid** row; all three run on the same traversal core via
``repro.core.runtime.CellRuntime``:

  mode    | vector storage        | graph residency        | seeding
  --------+-----------------------+------------------------+--------------
  incore  | fp32 resident         | fully resident         | fresh beam
  hybrid  | int8 resident +rerank | LRU slot cache         | carried pool
  ooc     | int8 resident +rerank | streamed batch window  | carried pool

What makes hybrid cheaper than the streaming engine:

  - node ids stay *global*: the traversal finds node u's adjacency row at
    ``u + cell_base[cell_of[u]]`` inside the fixed cache buffers, so
    there is no per-batch gather/remap of the partial index (the
    dominant host cost of the out-of-core path) and carried candidates
    seed the next wave without any id translation;
  - the LRU keeps hot cells resident *across query batches*: repeated
    workloads hit warm slots and transfer nothing, where the streaming
    engine re-uploads its whole window every call;
  - per-query visited state is bit-packed over the global id space.

Per query batch:
  (1) CPU: cell selection -> incidence matrix          (select.py)
  (2) CPU: greedy wave scheduling, Alg. 5 with the cache capacity as the
      batch bound — *cache-aware*: the placement key scores affinity to
      the cells the LRU cache already holds from the previous execution
      (resident cells steer into the earliest wave, so they hit before
      eviction; misses group with co-accessed residents), and with the
      size-aware arena each wave packs against the arena's row capacity
      instead of a fixed slot count                    (scheduler.py)
  (3) per wave, double-buffered: make the wave's cells cache-resident
      (upload misses, evict LRU), *launch* the itinerary traversal over
      global ids seeded from the carried pool, prefetch the next wave's
      missing cells while it runs (``CellCache.prefetch`` — the launched
      program holds an immutable snapshot of the cache buffers), then
      block on the result and fold survivors back into the pool
  (4) exact fp32 re-rank of each query's pool — fused on device by
      default (``rerank="device"``: one gather->distance->k-select
      program), or the legacy host loop (``rerank="host"``); both
      return bit-identical ids                         (runtime.py)

``cache_policy="fixed"`` reproduces the PR-3 baseline wholesale (fixed
largest-cell slots *and* cache-blind scheduling) — the ablation arm the
memory-budget bench compares transfer bytes against.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
import jax

from repro.core import runtime as rt_mod
from repro.core import select as select_mod
from repro.core import selectivity as sel_mod
from repro.core import scheduler as sched_mod
from repro.core.runtime import CandidatePool, CellCache, CellRuntime
from repro.core.types import GMGIndex, SearchParams
from repro.obs.metrics import MetricsRegistry, PassMetrics
from repro.obs.trace import span


@dataclasses.dataclass
class HybridEngine:
    """Resident int8 vectors + bounded LRU cell cache for the graph."""

    index: GMGIndex
    cache_budget_bytes: Optional[int] = None   # device bytes for the cache
    n_slots: Optional[int] = None              # overrides the byte budget
    cache_policy: str = "size_aware"           # | "fixed" (PR-3 baseline)
    rerank: str = "device"                     # | "host" (identical ids)

    def __post_init__(self):
        if self.rerank not in rt_mod.RERANKS:
            raise ValueError(f"unknown rerank {self.rerank!r}; "
                             f"expected one of {rt_mod.RERANKS}")
        self.rt = CellRuntime(self.index, storage="int8")
        # one obs registry per engine: the cache's lifetime counters live
        # in it, so this engine's per-pass stats are deltas over the very
        # objects the cache increments (single-source, ISSUE 10)
        self.metrics = MetricsRegistry()
        self.cache = CellCache(self.index,
                               budget_bytes=self.cache_budget_bytes,
                               n_slots=self.n_slots,
                               policy=self.cache_policy,
                               registry=self.metrics)
        self.stats: dict = {}

    def refresh_index(self, index: GMGIndex) -> None:
        """Delete path (core.mutable): adopt a same-layout index whose
        attrs carry tombstone NaN masks. The LRU cell cache stays warm —
        deletes change no adjacency, only the predicate table."""
        self.index = index
        self.rt.refresh_index(index)

    def resident_bytes(self) -> int:
        """Device footprint: int8 residents + the graph cache buffers."""
        idx = self.index
        resident = idx.vq.nbytes + idx.vscale.nbytes + idx.attrs.nbytes
        return resident + self.cache.capacity_bytes()

    def search(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
               params: Optional[SearchParams] = None,
               qmap: Optional[np.ndarray] = None,
               n_queries: Optional[int] = None,
               route_k: Optional[np.ndarray] = None,
               routes: Optional[sel_mod.RouteDecision] = None):
        """Returns (ids (B, k) original ids, dists (B, k) exact fp32).

        With ``qmap`` (row -> original-query segment map from a
        disjunctive plan), rows are per-box sub-queries; survivors fold
        back to (n_queries, k) after the exact re-rank.

        ``routes`` (or ``route_k`` + ``params.cost``, computed here)
        splits rows by the per-box cost model: ultra-selective rows skip
        the wave pipeline entirely — a fused masked scan over the
        resident *int8* table fills their candidate pool, and the usual
        exact fp32 re-rank finishes them like any traversed row.
        Mid-range rows traverse with ``ef`` scaled per effort bucket.
        """
        params = params or SearchParams()
        idx = self.index
        cfg = idx.config
        k, ef = params.k, params.ef or cfg.search_ef
        B = q.shape[0]
        if qmap is not None:
            qmap = rt_mod.check_qmap(qmap, B)
            if n_queries is None:
                raise ValueError("n_queries is required with qmap")
        if B == 0:
            self.stats = {"n_waves": 0, "total_active": 0,
                          "cache_hits": 0, "cache_misses": 0,
                          "hit_rate": 0.0, "transfer_bytes": 0,
                          "prefetches": 0, "prefetch_hits": 0,
                          "prefetch_bytes": 0, "prefetch_hit_rate": 0.0,
                          "n_slots": self.cache.n_slots,
                          "cache_policy": self.cache.policy,
                          "rerank": self.rerank, "wall_seconds": 0.0}
            nq = n_queries if qmap is not None else 0
            return rt_mod.empty_topk(nq, k)
        t_start = time.perf_counter()
        q = np.asarray(q, np.float32)
        lo = np.asarray(lo, np.float32)
        hi = np.asarray(hi, np.float32)

        # (1) selection + per-box routing (host)
        inc = select_mod.incidence_numpy(lo, hi, idx.cell_lo, idx.cell_hi)
        if routes is None:
            rk = (np.full(B, k, np.int64) if route_k is None
                  else np.asarray(route_k, np.int64))
            routes = sel_mod.route_boxes(idx, lo, hi, rk,
                                         cost=params.cost, inc=inc)
        use_dense = routes.route == sel_mod.ROUTE_DENSE

        pool = CandidatePool(B, ef)
        key = jax.random.PRNGKey(params.seed)
        n_waves = total_active = 0
        est_err = None
        # per-pass deltas off the cache's lifetime counters (one obs
        # registry shared with the cache); the bytes_uploaded delta (not
        # summed ensure() returns) is what transfer_bytes reports, so
        # prefetch uploads count as the real H2D traffic they are
        snap = self.metrics.snapshot()

        # dense route: one fused int8 masked scan fills the pool — no
        # wave scheduling, no cache traffic; the shared exact fp32
        # re-rank below finishes these rows like any traversed row
        dense_rows = np.nonzero(use_dense)[0]
        if len(dense_rows) > 0:
            with span("hybrid.dense", rows=len(dense_rows)):
                ids_d, d_d, n_qual = rt_mod.masked_dense_scan(
                    self.rt, q[dense_rows], lo[dense_rows], hi[dense_rows],
                    inc[dense_rows], ef)
                pool.merge(dense_rows, ids_d, d_d)
            est_err = float(np.mean(
                np.abs(routes.est_rows[dense_rows] - n_qual)
                / np.maximum(n_qual, 1.0)))

        graph_rows = ~use_dense & inc.any(axis=1)
        rank = (rt_mod.order_ranks(idx, q, inc)
                if graph_rows.any() else None)
        for mult in np.unique(routes.ef_mult[graph_rows]):
            rows_b = graph_rows & (routes.ef_mult == mult)
            inc_b = inc & rows_b[:, None]
            ef_run = ef * int(mult)

            # (2) wave scheduling: Alg. 5 bounded by the cache capacity,
            # so every wave's cells are simultaneously resident. The
            # size-aware arena packs waves against its row capacity
            # (per-cell weights) and seeds the placement key with the
            # cells still resident from the previous execution; the
            # fixed policy keeps the PR-3 cache-blind slot-count bound.
            if self.cache.policy == "fixed":
                waves = sched_mod.schedule_cells(inc_b, self.cache.n_slots)
            else:
                resident = self.cache.resident_cells()
                waves = sched_mod.schedule_cells(
                    inc_b, idx.n_cells, resident=resident,
                    weights=self.cache.alloc_rows,
                    capacity=self.cache.cap_rows)
                # total_active is order-invariant; run the most-resident
                # wave first so it hits before later waves evict it
                waves = sched_mod.order_waves(waves, resident,
                                              weights=self.cache.alloc_rows)
            n_waves += len(waves)
            total_active += sched_mod.total_active(inc_b, waves)

            # itinerary width: one jitted program per width — fixed slots
            # pin it to the slot count, the arena pow2-pads the widest wave
            if self.cache.policy == "fixed":
                W = self.cache.n_slots
            else:
                W = max((len(w) for w in waves), default=1)
                W = 1 << (W - 1).bit_length()

            # (3) wave loop, double-buffered: launch this wave's traversal
            # (async dispatch, device arrays), upload the *next* wave's
            # missing cells while it runs — the launched program reads an
            # immutable snapshot of the cache buffers, so prefetch uploads
            # cannot perturb it — then block on the result and fold it
            # into the pool. Waves with no active query are dropped up
            # front so the prefetch target is always the wave that will
            # actually run next.
            runnable = []
            for cells in waves:
                act = np.nonzero(inc_b[:, cells].any(axis=1))[0]
                if len(act) > 0:
                    runnable.append((cells, act))
            for wi, (cells, act) in enumerate(runnable):
                with span("hybrid.wave", wave=wi, cells=len(cells),
                          active=len(act), ef=ef_run):
                    self.cache.ensure(cells)
                    graph = self.rt.cached_graph(self.cache)

                    # per-active-query itinerary over *global* cell ids;
                    # vectorized: selected cells sort by rank (stable, so
                    # rank ties keep ascending cell order), unselected pad
                    # with -1
                    cells_arr = np.asarray(cells, np.int64)
                    sel = inc_b[np.ix_(act, cells_arr)]      # (n_act, W)
                    key_rank = np.where(sel, rank[np.ix_(act, cells_arr)],
                                        np.iinfo(np.int32).max)
                    ordr = np.argsort(key_rank, axis=1, kind="stable")
                    itin = np.full((len(act), W), -1, np.int32)
                    itin[:, :len(cells)] = np.where(
                        np.take_along_axis(sel, ordr, axis=1),
                        cells_arr[ordr], -1).astype(np.int32)

                    key, sub = jax.random.split(key)
                    # this span covers launch -> prefetch -> block, so the
                    # cache.prefetch/cache.upload child spans sit inside
                    # the in-flight traversal's window — the DMA/compute
                    # overlap, visible as overlapping spans in Perfetto
                    with span("hybrid.traverse", active=len(act),
                              ef=ef_run) as tsp:
                        # carried pool seeds directly: global ids, no remap
                        ids_d, d_d, real = self.rt.run_launch(
                            graph, q[act], lo[act], hi[act], sub,
                            k=max(k, min(ef, 2 * k)), ef=ef_run,
                            cell_order=itin, seeds=pool.ids[act],
                            packed_visited=True,
                            pool_reuse=params.pool_reuse)
                        tsp.attach((ids_d, d_d))
                        if wi + 1 < len(runnable):
                            self.cache.prefetch(runnable[wi + 1][0])
                        pool.merge(act, np.asarray(ids_d[:real]),
                                   np.asarray(d_d[:real]))

        # per-pass stats as a view over the obs registry: work counters
        # fold into lifetime totals through PassMetrics, cache counters
        # are this pass's deltas of the registry objects the cache itself
        # incremented — one source, two projections (ISSUE 10)
        dlt = self.metrics.delta(snap)
        pm = PassMetrics(self.metrics)
        pm.count("n_waves", n_waves)
        pm.count("total_active", total_active)
        hits, misses = dlt["cache_hits"], dlt["cache_misses"]
        pm.put("cache_hits", hits)
        pm.put("cache_misses", misses)
        pm.set("hit_rate", hits / max(hits + misses, 1))
        pm.count("transfer_bytes", dlt["bytes_uploaded"])
        pm.put("prefetches", dlt["prefetches"])
        pm.put("prefetch_hits", dlt["prefetch_hits"])
        pm.put("prefetch_bytes", dlt["prefetch_bytes"])
        pm.set("prefetch_hit_rate",
               dlt["prefetch_hits"] / max(dlt["prefetches"], 1))
        pm.put("n_slots", self.cache.n_slots)
        pm.put("cache_policy", self.cache.policy)
        pm.set("resident_cells", len(self.cache.resident_cells()))
        pm.put("rerank", self.rerank)
        # flat keys above are this pass's deltas; the nested block is
        # the cache's lifetime view (CellCache.stats), which a serving
        # front-end can difference across ticks
        pm.put("cache", self.cache.stats())
        pm.update_counts(routes.counts())
        if est_err is not None:
            pm.set("est_rel_err_dense", est_err)
        self.stats = pm.stats()

        # (4) exact re-rank of survivors: fused on device by default,
        # host loop for the legacy/ablation path — bit-identical ids
        with span("hybrid.rerank", rerank=self.rerank):
            if self.rerank == "device":
                out_i, out_d = rt_mod.exact_rerank_device(
                    idx, self.rt.attrs_dev, pool, q, lo, hi, k,
                    cfg.rerank_mult)
            else:
                out_i, out_d = rt_mod.exact_rerank(idx, pool, q, lo, hi, k,
                                                   cfg.rerank_mult)
        if qmap is not None:
            self.stats["n_boxes"] = B
            out_i, out_d = rt_mod.merge_segment_topk(out_i, out_d, qmap,
                                                     n_queries, k)
        self.stats["wall_seconds"] = time.perf_counter() - t_start
        return out_i, out_d
