"""Symmetric per-vector int8 scalar quantization (paper Section 5.1).

The out-of-core pipeline keeps only this representation resident in
accelerator memory; exact fp32 re-ranking happens host-side on survivors.
"""

from __future__ import annotations

import numpy as np


def quantize(v: np.ndarray):
    """(n, d) f32 -> ((n, d) int8, (n,) f32 scales). x ~= scale * q."""
    amax = np.abs(v).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(v / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale[:, None]


def max_abs_error_bound(scale: np.ndarray, dim: int) -> np.ndarray:
    """Per-vector worst-case L2 reconstruction error: 0.5*scale per coord."""
    return 0.5 * scale * np.sqrt(dim)
