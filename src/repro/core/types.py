"""Core datatypes for the GMG index (paper Section 3).

Layout invariant: after build, objects are *reordered so each cell is a
contiguous id range* (internal ids). This turns every per-cell operation —
graph slicing, out-of-core streaming, predicate bias construction — into a
dense slice, which is the whole point of the paper's "static adjacency,
coalesced access" design and maps 1:1 onto TPU-friendly dense rows.
``perm`` maps internal -> original ids for returning results.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

if TYPE_CHECKING:  # cost model lives above types in the import DAG
    from repro.core.selectivity import CostModel


@dataclasses.dataclass(frozen=True)
class GMGConfig:
    """Build + search hyperparameters (paper defaults in parens)."""

    # --- partitioning (Section 3.1) ---
    seg_per_attr: Sequence[int] = (4, 4)   # S_i per partitioned attr; S = prod
    # p = len(seg_per_attr) most-selective attributes are partitioned;
    # remaining attributes are filter-only (paper: p <= 4).

    # --- graph (Section 3.1/3.2) ---
    intra_degree: int = 16                 # d (16; 32 for DBLP/YouTube)
    inter_degree: int = 2                  # l (2)
    build_ef: int = 100                    # EF during construction (100)
    exact_build_threshold: int = 16384     # cells <= this use exact MXU kNN
    nn_descent_iters: int = 10
    prune_alpha: float = 1.2               # Vamana-style occlusion prune

    # --- ordering (Section 4.2) ---
    n_clusters: int = 64                   # k-means clusters for H
    top_m_clusters: int = 8                # clusters voted per query
    kmeans_iters: int = 10

    # --- traversal (Section 4.3) ---
    search_ef: int = 64                    # candidate pool width
    entry_width: int = 16                  # entries kept per cell hop
    entry_random: int = 4                  # random entries added per hop
    entry_beam_l: int = 8                  # L: beam rows expanded via inter
    max_iters_per_cell: int = 96           # expansion cap per cell
    s_thre_frac: float = 0.5               # S_thre = frac * S (Section 4.1)
    dense_threshold: int = 8192            # exact-scan path when the
    # selected cells hold fewer rows than this (TPU adaptation: below this
    # size one MXU pass beats any graph walk; see DESIGN.md §2). 0 = off.

    # --- out-of-core (Section 5) ---
    quantize: bool = True                  # int8 resident vectors
    batch_cells: int = 4                   # b: cells per streamed batch
    rerank_mult: int = 2                   # exact re-rank pool = mult * k

    @property
    def p(self) -> int:
        return len(self.seg_per_attr)

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.seg_per_attr))

    @property
    def s_thre(self) -> int:
        return max(1, int(round(self.s_thre_frac * self.n_cells)))


@dataclasses.dataclass
class GMGIndex:
    """The built index. All arrays are host numpy; device placement is the
    responsibility of the search path (in-core: everything on device;
    out-of-core: only quantized vectors + attrs resident, graph streamed).
    """

    config: GMGConfig

    # data (internal order: cell-contiguous)
    vectors: np.ndarray          # (n, dim) f32
    attrs: np.ndarray            # (n, m) f32
    perm: np.ndarray             # (n,) i64: internal -> original id

    # grid
    seg_bounds: list             # per partitioned attr: (S_i + 1,) f32 edges
    cell_of: np.ndarray          # (n,) i32
    cell_start: np.ndarray       # (S + 1,) i32 CSR offsets
    cell_lo: np.ndarray          # (S, p) f32 cell box lower edges
    cell_hi: np.ndarray          # (S, p) f32 cell box upper edges

    # graph
    intra_adj: np.ndarray        # (n, d) i32 global internal ids, -1 pad
    inter_adj: np.ndarray        # (n, S, l) i32, own-cell column = -1

    # ordering (Section 4.2)
    centroids: np.ndarray        # (n_clusters, dim) f32
    hist: np.ndarray             # (S, n_clusters) f32 counts

    # per-attribute empirical CDF (m, n_grid) — selectivity estimation
    # for the adaptive dense path (beyond-paper; EXPERIMENTS §Perf G2)
    attr_quantiles: Optional[np.ndarray] = None

    # quantized resident copy (Section 5.1)
    vq: Optional[np.ndarray] = None       # (n, dim) int8
    vscale: Optional[np.ndarray] = None   # (n,) f32

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_cells(self) -> int:
        return len(self.cell_start) - 1

    def cell_slice(self, c: int) -> slice:
        return slice(int(self.cell_start[c]), int(self.cell_start[c + 1]))

    def nbytes(self) -> dict:
        """Index-size accounting mirroring the paper's Table 2 columns."""
        graph = self.intra_adj.nbytes + self.inter_adj.nbytes
        order = self.centroids.nbytes + self.hist.nbytes
        grid = sum(b.nbytes for b in self.seg_bounds) + self.cell_start.nbytes
        quant = (self.vq.nbytes + self.vscale.nbytes) if self.vq is not None else 0
        return {
            "graph_bytes": int(graph),
            "ordering_bytes": int(order),
            "grid_bytes": int(grid),
            "quantized_bytes": int(quant),
            "index_bytes": int(graph + order + grid),
            "vector_bytes": int(self.vectors.nbytes),
        }


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Per-query-batch knobs (overrides config defaults where sensible).

    ``cost`` is the per-box route cost model
    (:class:`repro.core.selectivity.CostModel`): None uses the default
    thresholds, ``CostModel.off()`` forces every box onto the traversal
    path (the ablation arm). Knob guidance lives in ``docs/tuning.md``.

    Kernel dispatch is *not* a SearchParams knob: whether each
    beam-expansion hop runs as the one fused Pallas traversal-wave
    kernel or the unfused jnp composition is decided per launch by
    ``repro.kernels.config`` (``set_mode``/``mode``), and tile sizes
    come from ``repro.launch.roofline``. See the "Kernel mode and
    tiles" section of ``docs/tuning.md``.
    """

    k: int = 10
    ef: Optional[int] = None           # None -> config.search_ef
    max_cells: Optional[int] = None    # cap on traversed cells (None = all)
    use_ordering: bool = True          # ablation: Fig 13(b)
    use_inter_edges: bool = True       # ablation: Fig 13(a)
    adaptive_global: bool = True       # Section 4.1 adaptive path
    pool_reuse: bool = True            # cross-cell candidate reuse: the
    # in-range result pool proposes inter-cell entries on every itinerary
    # hop (paper §5.1's entry propagation, applied to all engine modes)
    seed: int = 0
    cost: Optional["CostModel"] = None  # per-box routing (docs/tuning.md)
