"""GMG index construction orchestrator (paper Section 3, Alg. 1).

Pipeline: quantile grid -> per-cell CAGRA-style graphs -> inter-cell top-l
edges -> cluster histogram for ordering -> int8 resident copy. All arrays
land in the cell-contiguous internal layout (see core/types.py); ``perm``
maps back to the caller's original ids.
"""

from __future__ import annotations

import logging
import time

import numpy as np

from repro.core import grid as grid_mod
from repro.core import graph as graph_mod
from repro.core import intercell, ordering, quantize
from repro.core.types import GMGConfig, GMGIndex
from repro.obs.trace import local_trace, span

log = logging.getLogger(__name__)


def cell_graph(vectors_cell: np.ndarray, config: GMGConfig,
               seed: int = 0) -> np.ndarray:
    """Single-cell intra graph (Alg. 1 lines 6-9) under the config's
    build knobs — the per-cell build entry point, shared by the full
    offline build and streaming cell maintenance (core.mutable)."""
    return graph_mod.build_cell_graph(
        vectors_cell, config.intra_degree,
        exact_threshold=config.exact_build_threshold,
        nn_iters=config.nn_descent_iters, alpha=config.prune_alpha,
        seed=seed)


def attr_quantile_grid(attrs: np.ndarray, n_grid: int = 1024) -> np.ndarray:
    """(m, n_grid + 1) empirical per-attribute CDF grid — the
    selectivity estimator's table, recomputed after mutations so the
    adaptive dense path keeps seeing live statistics."""
    qs = np.linspace(0.0, 1.0, n_grid + 1)
    return np.stack(
        [np.quantile(attrs[:, j].astype(np.float64), qs)
         for j in range(attrs.shape[1])]).astype(np.float32)


def build_gmg(vectors: np.ndarray, attrs: np.ndarray,
              config: GMGConfig | None = None, seed: int = 0,
              verbose: bool = False) -> GMGIndex:
    """Build the full GMG index (Alg. 1). vectors (n, dim) f32,
    attrs (n, m) with m >= config.p."""
    config = config or GMGConfig()
    n, dim = vectors.shape
    m = attrs.shape[1]
    if m < config.p:
        raise ValueError(f"need >= p={config.p} attributes, got {m}")

    # phase accounting is span-derived (obs, ISSUE 10): local_trace
    # records the build.* spans even with no user trace active, and
    # nests them into the user's trace when one is (Collection.trace
    # around a build shows the same phases Table 2 reports)
    with local_trace() as tr:
        mark = tr.mark()

        # --- Step 1: attribute partitioning (Alg. 1 lines 1-4) ---
        with span("build.grid", n=n):
            seg_bounds, cell_of, order, cell_start, cell_lo, cell_hi = \
                grid_mod.build_grid(attrs.astype(np.float64),
                                    config.seg_per_attr)
            vectors = np.ascontiguousarray(vectors[order], dtype=np.float32)
            attrs_s = np.ascontiguousarray(attrs[order], dtype=np.float32)
            cell_of = cell_of[order]
            perm = order.astype(np.int64)
            S = config.n_cells

        # --- Step 2: intra-cell graphs (Alg. 1 lines 6-9) ---
        with span("build.intra", cells=S):
            intra = -np.ones((n, config.intra_degree), dtype=np.int32)
            for c in range(S):
                s, e = int(cell_start[c]), int(cell_start[c + 1])
                if e <= s:
                    continue
                adj_local = cell_graph(vectors[s:e], config, seed=seed + c)
                intra[s:e] = np.where(adj_local >= 0, adj_local + s, -1)

        # --- Step 3: inter-cell edges (Alg. 1 lines 10-12) ---
        with span("build.inter", degree=config.inter_degree):
            inter = intercell.build_inter_edges(
                vectors, attrs_s, intra, cell_start, config.inter_degree,
                ef=config.search_ef, seed=seed)

        # --- ordering sketch (Section 4.2 offline half) ---
        with span("build.order", clusters=config.n_clusters):
            centroids = ordering.kmeans(vectors, config.n_clusters,
                                        iters=config.kmeans_iters,
                                        seed=seed)
            hist = ordering.build_histogram(vectors, cell_of, centroids, S)

        # --- per-attribute CDF grid + quantized resident copy (§5.1);
        # one phase, matching the historical "quant" log bucket ---
        with span("build.quantize", quantize=config.quantize):
            attr_quantiles = attr_quantile_grid(attrs_s)
            vq = vscale = None
            if config.quantize:
                vq, vscale = quantize.quantize(vectors)

        phases = build_phase_seconds(tr.spans_since(mark))

    if verbose:
        log.info("GMG build n=%d S=%d: grid %.2fs intra %.2fs inter %.2fs "
                 "order %.2fs quant %.2fs", n, S,
                 phases.get("grid", 0.0), phases.get("intra", 0.0),
                 phases.get("inter", 0.0), phases.get("order", 0.0),
                 phases.get("quantize", 0.0))

    return GMGIndex(
        config=config, vectors=vectors, attrs=attrs_s, perm=perm,
        seg_bounds=seg_bounds, cell_of=cell_of,
        cell_start=np.asarray(cell_start, np.int32),
        cell_lo=cell_lo.astype(np.float32), cell_hi=cell_hi.astype(np.float32),
        intra_adj=intra, inter_adj=inter,
        centroids=centroids.astype(np.float32), hist=hist.astype(np.float32),
        attr_quantiles=attr_quantiles,
        vq=vq, vscale=vscale)


def build_phase_seconds(spans) -> dict:
    """{phase: seconds} over ``build.*`` spans (names with the
    ``build.`` prefix stripped) — the thin dict view build_gmg's verbose
    log and :func:`build_timings` both read."""
    out: dict = {}
    for s in spans:
        if s.name.startswith("build."):
            phase = s.name[len("build."):]
            out[phase] = out.get(phase, 0.0) + s.duration
    return out


def build_timings(vectors: np.ndarray, attrs: np.ndarray,
                  config: GMGConfig | None = None, seed: int = 0) -> dict:
    """Table-2 style build accounting: wall time per phase + sizes.
    Phase walls are the build.* span durations (obs layer) — the same
    numbers a ``Collection.trace`` around the build exports."""
    config = config or GMGConfig()
    t0 = time.perf_counter()
    with local_trace() as tr:
        mark = tr.mark()
        index = build_gmg(vectors, attrs, config, seed=seed)
        phases = build_phase_seconds(tr.spans_since(mark))
    wall = time.perf_counter() - t0
    out = {"build_seconds": wall}
    for phase in ("grid", "intra", "inter", "order", "quantize"):
        out[f"{phase}_seconds"] = phases.get(phase, 0.0)
    out.update(index.nbytes())
    out["n"] = index.n
    out["n_cells"] = index.n_cells
    return out


def global_adjacency(index: GMGIndex) -> np.ndarray:
    """Adjacency for the adaptive global path (Alg. 2 lines 5-8): intra
    edges ++ the flattened inter edges, giving every node degree
    d + (S-1)*l over the *whole* dataset. Built once, cached by search."""
    n = index.n
    inter_flat = index.inter_adj.reshape(n, -1)
    return np.concatenate([index.intra_adj, inter_flat], axis=1)
