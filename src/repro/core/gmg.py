"""GMG index construction orchestrator (paper Section 3, Alg. 1).

Pipeline: quantile grid -> per-cell CAGRA-style graphs -> inter-cell top-l
edges -> cluster histogram for ordering -> int8 resident copy. All arrays
land in the cell-contiguous internal layout (see core/types.py); ``perm``
maps back to the caller's original ids.
"""

from __future__ import annotations

import logging
import time

import numpy as np

from repro.core import grid as grid_mod
from repro.core import graph as graph_mod
from repro.core import intercell, ordering, quantize
from repro.core.types import GMGConfig, GMGIndex

log = logging.getLogger(__name__)


def cell_graph(vectors_cell: np.ndarray, config: GMGConfig,
               seed: int = 0) -> np.ndarray:
    """Single-cell intra graph (Alg. 1 lines 6-9) under the config's
    build knobs — the per-cell build entry point, shared by the full
    offline build and streaming cell maintenance (core.mutable)."""
    return graph_mod.build_cell_graph(
        vectors_cell, config.intra_degree,
        exact_threshold=config.exact_build_threshold,
        nn_iters=config.nn_descent_iters, alpha=config.prune_alpha,
        seed=seed)


def attr_quantile_grid(attrs: np.ndarray, n_grid: int = 1024) -> np.ndarray:
    """(m, n_grid + 1) empirical per-attribute CDF grid — the
    selectivity estimator's table, recomputed after mutations so the
    adaptive dense path keeps seeing live statistics."""
    qs = np.linspace(0.0, 1.0, n_grid + 1)
    return np.stack(
        [np.quantile(attrs[:, j].astype(np.float64), qs)
         for j in range(attrs.shape[1])]).astype(np.float32)


def build_gmg(vectors: np.ndarray, attrs: np.ndarray,
              config: GMGConfig | None = None, seed: int = 0,
              verbose: bool = False) -> GMGIndex:
    """Build the full GMG index (Alg. 1). vectors (n, dim) f32,
    attrs (n, m) with m >= config.p."""
    config = config or GMGConfig()
    n, dim = vectors.shape
    m = attrs.shape[1]
    if m < config.p:
        raise ValueError(f"need >= p={config.p} attributes, got {m}")
    t0 = time.perf_counter()

    # --- Step 1: attribute partitioning (Alg. 1 lines 1-4) ---
    seg_bounds, cell_of, order, cell_start, cell_lo, cell_hi = \
        grid_mod.build_grid(attrs.astype(np.float64), config.seg_per_attr)
    vectors = np.ascontiguousarray(vectors[order], dtype=np.float32)
    attrs_s = np.ascontiguousarray(attrs[order], dtype=np.float32)
    cell_of = cell_of[order]
    perm = order.astype(np.int64)
    S = config.n_cells
    t_grid = time.perf_counter()

    # --- Step 2: intra-cell graphs (Alg. 1 lines 6-9) ---
    intra = -np.ones((n, config.intra_degree), dtype=np.int32)
    for c in range(S):
        s, e = int(cell_start[c]), int(cell_start[c + 1])
        if e <= s:
            continue
        adj_local = cell_graph(vectors[s:e], config, seed=seed + c)
        intra[s:e] = np.where(adj_local >= 0, adj_local + s, -1)
    t_intra = time.perf_counter()

    # --- Step 3: inter-cell edges (Alg. 1 lines 10-12) ---
    inter = intercell.build_inter_edges(
        vectors, attrs_s, intra, cell_start, config.inter_degree,
        ef=config.search_ef, seed=seed)
    t_inter = time.perf_counter()

    # --- ordering sketch (Section 4.2 offline half) ---
    centroids = ordering.kmeans(vectors, config.n_clusters,
                                iters=config.kmeans_iters, seed=seed)
    hist = ordering.build_histogram(vectors, cell_of, centroids, S)
    t_order = time.perf_counter()

    # --- per-attribute CDF grid (selectivity estimator for the adaptive
    # dense path; covers ALL m attributes, not just the p partitioned) ---
    attr_quantiles = attr_quantile_grid(attrs_s)

    # --- quantized resident copy (Section 5.1) ---
    vq = vscale = None
    if config.quantize:
        vq, vscale = quantize.quantize(vectors)
    t_end = time.perf_counter()

    if verbose:
        log.info("GMG build n=%d S=%d: grid %.2fs intra %.2fs inter %.2fs "
                 "order %.2fs quant %.2fs", n, S, t_grid - t0,
                 t_intra - t_grid, t_inter - t_intra, t_order - t_inter,
                 t_end - t_order)

    return GMGIndex(
        config=config, vectors=vectors, attrs=attrs_s, perm=perm,
        seg_bounds=seg_bounds, cell_of=cell_of,
        cell_start=np.asarray(cell_start, np.int32),
        cell_lo=cell_lo.astype(np.float32), cell_hi=cell_hi.astype(np.float32),
        intra_adj=intra, inter_adj=inter,
        centroids=centroids.astype(np.float32), hist=hist.astype(np.float32),
        attr_quantiles=attr_quantiles,
        vq=vq, vscale=vscale)


def build_timings(vectors: np.ndarray, attrs: np.ndarray,
                  config: GMGConfig | None = None, seed: int = 0) -> dict:
    """Table-2 style build accounting: wall time per phase + sizes."""
    config = config or GMGConfig()
    t0 = time.perf_counter()
    index = build_gmg(vectors, attrs, config, seed=seed)
    wall = time.perf_counter() - t0
    out = {"build_seconds": wall}
    out.update(index.nbytes())
    out["n"] = index.n
    out["n_cells"] = index.n_cells
    return out


def global_adjacency(index: GMGIndex) -> np.ndarray:
    """Adjacency for the adaptive global path (Alg. 2 lines 5-8): intra
    edges ++ the flattened inter edges, giving every node degree
    d + (S-1)*l over the *whole* dataset. Built once, cached by search."""
    n = index.n
    inter_flat = index.inter_adj.reshape(n, -1)
    return np.concatenate([index.intra_adj, inter_flat], axis=1)
