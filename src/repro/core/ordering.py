"""Cluster-guided cell ordering (paper Section 4.2, Alg. 3).

Offline: k-means over the whole dataset; a (S, n_clusters) histogram H
counts each cell's members per cluster — a discrete sketch of where each
cell's vectors live in embedding space.

Online: query->centroid distances on the MXU (the paper's Tensor-Core
GEMM), top-m nearest clusters (the paper's register bitonic sort -> our
fused-topk kernel), then Card(C_i) = sum_m H[C_i, cs] — a (B, S) gather+
reduce that the paper assigns to warps and we run as one vectorized
einsum over a one-hot cluster mask (lane-parallel, no divergence analogue
needed). Cells sort descending by estimated cardinality.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops


def kmeans(vectors: np.ndarray, n_clusters: int, iters: int = 10,
           seed: int = 0, sample: int = 65536) -> np.ndarray:
    """Plain Lloyd's on a subsample; returns (n_clusters, dim) centroids."""
    rng = np.random.default_rng(seed)
    n = vectors.shape[0]
    if n > sample:
        vecs = vectors[rng.choice(n, sample, replace=False)]
    else:
        vecs = vectors
    n_clusters = min(n_clusters, len(vecs))
    cent = jnp.asarray(vecs[rng.choice(len(vecs), n_clusters, replace=False)])
    v = jnp.asarray(vecs)

    @jax.jit
    def step(cent):
        d = ops.pairwise_l2(v, cent)                  # (n, C)
        assign = jnp.argmin(d, axis=1)
        one_hot = jax.nn.one_hot(assign, cent.shape[0], dtype=v.dtype)
        counts = one_hot.sum(axis=0)                  # (C,)
        sums = one_hot.T @ v                          # (C, dim)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old centroid for empty clusters
        return jnp.where(counts[:, None] > 0, new, cent)

    for _ in range(iters):
        cent = step(cent)
    return np.asarray(cent)


def assign_clusters(vectors: np.ndarray, centroids: np.ndarray,
                    chunk: int = 16384) -> np.ndarray:
    """(n,) nearest-centroid id per vector — the histogram's assignment
    half, exposed so streaming inserts can count new rows into ``H``
    without rebuilding it."""
    cent = jnp.asarray(centroids)
    out = np.empty(len(vectors), np.int64)
    for s in range(0, len(vectors), chunk):
        v = jnp.asarray(vectors[s:s + chunk])
        out[s:s + chunk] = np.asarray(
            jnp.argmin(ops.pairwise_l2(v, cent), axis=1))
    return out


def build_histogram(vectors: np.ndarray, cell_of: np.ndarray,
                    centroids: np.ndarray, n_cells: int,
                    chunk: int = 16384) -> np.ndarray:
    """H[cell, cluster] = #vectors of `cell` whose NN centroid is `cluster`."""
    C = centroids.shape[0]
    H = np.zeros((n_cells, C), dtype=np.float32)
    cent = jnp.asarray(centroids)
    for s in range(0, len(vectors), chunk):
        v = jnp.asarray(vectors[s:s + chunk])
        assign = np.asarray(jnp.argmin(ops.pairwise_l2(v, cent), axis=1))
        np.add.at(H, (cell_of[s:s + chunk], assign), 1.0)
    return H


@functools.partial(jax.jit, static_argnames=("top_m", "T"))
def order_cells(q, centroids, hist, cell_mask, *, top_m: int, T: int):
    """Alg. 3, batched. q (B, dim); cell_mask (B, S) bool from cell
    selection. Returns (cell_order (B, T) int32 -1-padded descending by
    estimated cardinality, n_sel (B,))."""
    B, S = cell_mask.shape[0], cell_mask.shape[1]
    d = ops.pairwise_l2(q, centroids)                 # (B, C) — MXU GEMM
    top_m = min(top_m, centroids.shape[0])
    _, top_idx = jax.lax.top_k(-d, top_m)             # (B, m)
    # Card(C_i) = sum over top clusters of H[C_i, cs]  (Alg. 3 lines 3-5)
    mask = jax.nn.one_hot(top_idx, centroids.shape[0],
                          dtype=hist.dtype).sum(axis=1)        # (B, C)
    card = mask @ hist.T                              # (B, S)
    # selected cells sort descending by card; unselected sink with -inf
    score = jnp.where(cell_mask, card, -jnp.inf)
    order = jnp.argsort(-score, axis=1)[:, :T].astype(jnp.int32)
    n_sel = cell_mask.sum(axis=1).astype(jnp.int32)
    ranks = jnp.arange(T, dtype=jnp.int32)[None, :]
    order = jnp.where(ranks < n_sel[:, None], order, -1)
    return order, n_sel
