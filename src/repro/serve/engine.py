"""Batched serving engine: continuous batching over a fixed lane count.

The engine owns a decode state of `lanes` sequences. Requests queue up;
free lanes are prefilled (one jitted prefill per prompt-length bucket)
and their KV/state caches written into the batched decode cache; every
engine step decodes ALL lanes in one jitted call (the GPU-paper analogue:
fixed-shape batched execution, no per-request kernels). Finished lanes
(EOS or max_tokens) free up and the queue refills them.

This is deliberately the same fixed-lane design the Garfield OOC engine
uses for queries — both follow the paper's "minimize live per-request
state, keep shapes static" principle.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (T,) i32
    max_new: int = 32
    eos: int = -1                    # -1: never
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: lm.LMConfig, lanes: int = 8,
                 max_seq: int = 512, sampler: str = "greedy",
                 temperature: float = 1.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.lanes = lanes
        self.max_seq = max_seq
        self.sampler = sampler
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.caches = lm.init_caches(cfg, lanes, max_seq)
        self.lane_req: list[Optional[Request]] = [None] * lanes
        self.lane_pos = np.zeros(lanes, np.int32)
        # deque: admission pops from the head every step, and a deep
        # backlog would make list.pop(0) O(queue) per admitted request
        self.queue: collections.deque[Request] = collections.deque()
        self.steps = 0

        self._decode = jax.jit(
            lambda p, tok, caches: lm.decode_step(p, cfg, tok, caches))
        # per-bucket prefill jits (powers of two)
        self._prefill_cache = {}

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def step(self):
        """Admit queued requests into free lanes, then one decode step."""
        self._admit()
        active = [i for i, r in enumerate(self.lane_req) if r is not None]
        if not active:
            return []
        tok = np.zeros((self.lanes, 1), np.int32)
        for i in active:
            r = self.lane_req[i]
            tok[i, 0] = r.out[-1] if r.out else int(r.prompt[-1])
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(tok), self.caches)
        nxt = self._sample(logits)
        finished = []
        for i in active:
            r = self.lane_req[i]
            t = int(nxt[i])
            r.out.append(t)
            self.lane_pos[i] += 1
            if t == r.eos or len(r.out) >= r.max_new \
                    or self.lane_pos[i] >= self.max_seq - 1:
                r.done = True
                finished.append(r)
                self.lane_req[i] = None
        self.steps += 1
        return finished

    def run(self, max_steps: int = 10000):
        """Drain the queue; returns completed requests."""
        done = []
        while (self.queue or any(self.lane_req)) and max_steps > 0:
            done.extend(self.step())
            max_steps -= 1
        return done

    # -- internals ----------------------------------------------------------

    def _sample(self, logits):
        if self.sampler == "greedy":
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1))

    def _bucket(self, n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg = self.cfg

            def fn(params, tokens):
                # single-lane prefill into a fresh cache
                return lm.prefill(params, cfg, tokens=tokens,
                                  max_seq=self.max_seq)
            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    def _admit(self):
        for i in range(self.lanes):
            if self.lane_req[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            T = len(req.prompt)
            bucket = self._bucket(T)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, bucket - T:] = req.prompt      # left-pad into bucket
            logits, fresh = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks))
            # copy lane 0 of fresh cache into lane i of batched cache
            self.caches = jax.tree.map(
                lambda big, small: (big.at[:, i].set(small[:, 0])
                                    if big.ndim >= 2 and
                                    big.shape[1] == self.lanes
                                    else big) if hasattr(big, "at") else big,
                self.caches, fresh)
            # invalidate the left-pad slots (pos -> -1) so padding KV can
            # never be attended (RoPE is relative: the offset is harmless)
            pad = bucket - T
            if pad > 0:
                new_caches = []
                for c in self.caches:
                    c = dict(c)
                    if "pos" in c:
                        c["pos"] = c["pos"].at[:, i, :pad].set(-1)
                    new_caches.append(c)
                self.caches = new_caches
            # note: cache leading axis is (layers_in_run, batch, ...)
            self.lane_pos[i] = bucket
            req.out.append(int(np.asarray(jnp.argmax(logits[0]))))
            self.lane_req[i] = req
            # indices advance globally; set shared index to max lane pos
            self.caches = _set_index(self.caches, int(self.lane_pos.max()))


def _set_index(caches, value: int):
    out = []
    for c in caches:
        c = dict(c)
        c["index"] = jnp.full_like(c["index"], value)
        out.append(c)
    return out
