"""RAG bridge: the LM stack ⇄ Garfield (the paper's deployment context).

A deployed Garfield serves range-filtered vector retrieval for a
generation stack (the paper's motivating RAG/video-search scenarios,
§1). This module wires the two pillars of this repo together:

  embed   — mean-pooled final hidden state of an LM over the text tokens
            (the embedding producer),
  retrieve— Garfield RFANNS with structured predicates (e.g. timestamp
            range) against a ``repro.api.Collection``, which picks the
            in-core or out-of-core engine from its device budget,
  answer  — retrieved ids feed the generation prompt (demo-level).

examples/rag_serving.py runs this end-to-end with a reduced LM.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.collection import Collection
from repro.api.result import QueryResult
from repro.core.types import SearchParams
from repro.models import lm


@dataclasses.dataclass
class RagPipeline:
    params: dict
    cfg: lm.LMConfig
    collection: Collection

    def __post_init__(self):
        def embed_fn(params, tokens):
            h, _, _ = lm.forward(params, self.cfg, tokens=tokens)
            return h.mean(axis=1)                      # (B, D) mean pool
        self._embed = jax.jit(embed_fn)
        dim = self.collection.dim
        # project LM hidden -> index dim with a fixed random map (stands
        # in for a trained embedding head; deterministic per run)
        key = jax.random.PRNGKey(7)
        self._proj = jax.random.normal(
            key, (self.cfg.d_model, dim), jnp.float32) / np.sqrt(
                self.cfg.d_model)

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        h = self._embed(self.params, jnp.asarray(tokens))
        return np.asarray(h.astype(jnp.float32) @ self._proj)

    def retrieve(self, tokens: np.ndarray, filters=None, k: int = 5,
                 params: SearchParams | None = None) -> QueryResult:
        """Embed the token batch and run filtered retrieval. ``filters``
        is anything ``Collection.search`` accepts: a filter expression
        (``F("ts") >= t0``), an explicit ``(lo, hi)`` pair, or None."""
        q = self.embed(tokens)
        return self.collection.search(q, filters=filters, k=k,
                                      params=params)
