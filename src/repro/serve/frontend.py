"""Continuous-batching query front-end over ``Collection`` (ISSUE 6).

Offline benches measure batch QPS; live traffic arrives one request at a
time with mixed filters, k's and deadlines. Running each request as its
own engine pass wastes the device (a pow2-padded batch of one costs
nearly what a batch of 64 does), so this module coalesces: a
:class:`VectorFrontend` owns a request queue, and every :meth:`tick`
folds ALL admitted in-flight requests into ONE widened engine pass via
``Collection.search_many`` — each request is planned on its own, the
plans concatenate (box rows + shifted ``qmap`` segments, exactly the
machinery the disjunctive planner already uses per batch), the engine
runs once at the max k, and the segment-aware top-k merge folds each
request's rows back out. VecFlow (PAPERS.md) makes the same argument
for GPU filtered search: heterogeneous filtered queries only pay off
coalesced into large fixed-shape batches.

Correctness contract: on the in-core engine a coalesced request returns
ids bit-identical to a solo ``Collection.search`` call — the engine's
batch-composition-independence contract (``repro.core.search``); the
streamed modes (hybrid/ooc) schedule waves over the union incidence of
the whole tick, so they match solo calls in recall, not id-for-id.

Scheduling is SLO-aware:

  - admission is earliest-deadline-first (ties: arrival order), bounded
    by ``max_batch_queries`` query rows per tick;
  - a microbatching knob (``max_wait``) lets a sub-full queue wait for
    more arrivals before paying a pass, bounding the coalescing latency
    tax at light load;
  - requests whose deadline already expired are shed at tick start —
    never admitted into a pass whose answer nobody will read;
  - mutation work interleaves *between* query ticks: ``insert`` lands
    rows in the collection's append buffers immediately (searchable at
    once — every pass folds the buffered rows in), but the expensive
    graph splice (``Collection.flush``) runs only when the queue is
    idle or the flush budget has elapsed — and a budget-forced flush
    additionally yields while its last measured cost (unknown on the
    first flush: assume it won't fit) would expire a queued deadline,
    so writes never stall reads. Freshness is a soft target; the
    latency SLO is the hard contract, and buffered rows stay
    searchable either way.

Time is injectable (``clock=``) — :class:`VirtualClock` advances by the
measured real cost of each pass, which makes open-loop latency harnesses
(benchmarks/bench_serving.py) deterministic in arrival pattern while
still measuring real service time.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.api.collection import Collection
from repro.api.result import QueryResult
from repro.core.types import SearchParams
from repro.obs.export import prometheus_text
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import span


@dataclasses.dataclass
class SearchRequest:
    """One queued retrieval request (a query batch + filter + k + SLO)."""

    rid: int
    q: np.ndarray                       # (B, d) f32
    filters: Any = None
    k: int = 10
    deadline: Optional[float] = None    # absolute, in the frontend clock
    t_submit: float = 0.0
    # filled on completion
    result: Optional[QueryResult] = None
    t_done: Optional[float] = None
    shed: bool = False

    @property
    def n_queries(self) -> int:
        return self.q.shape[0]

    @property
    def latency(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


class VirtualClock:
    """Callable clock for open-loop harnesses: reads return ``t``;
    the frontend advances it by each pass's measured real cost."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class VectorFrontend:
    """Continuous-batching, SLO-aware serving loop over one Collection.

    Drive it as ``submit(...) -> tick() -> take(rid)`` (or ``drain()``
    to run ticks until the queue empties). ``tick`` returns a per-tick
    stats dict; lifetime aggregates come from :meth:`metrics`.
    """

    def __init__(self, collection: Collection, *,
                 max_batch_queries: int = 64,
                 max_wait: float = 0.0,
                 flush_budget: float = 0.25,
                 idle_grace: float = 0.0,
                 params: Optional[SearchParams] = None,
                 engine: Optional[str] = None,
                 clock=time.monotonic):
        if max_batch_queries < 1:
            raise ValueError("max_batch_queries must be >= 1")
        self.collection = collection
        self.max_batch_queries = int(max_batch_queries)
        self.max_wait = float(max_wait)
        self.flush_budget = float(flush_budget)
        # an empty queue is not quiescence under open-loop traffic: idle
        # flushes additionally wait until no submission has arrived for
        # this many seconds (0 = flush on any empty-queue tick)
        self.idle_grace = float(idle_grace)
        self.params = params
        self.engine = engine
        self._clock = clock
        # deque from day one — see serve/engine.py's _admit for the
        # O(queue) pop this avoids under a deep backlog
        self.queue: "collections.deque[SearchRequest]" = collections.deque()
        self.completed: dict[int, SearchRequest] = {}
        self._next_rid = 0
        self._last_flush = self._clock()
        self._last_submit = self._clock()
        # lifetime counters + latency/occupancy quantiles live in the
        # obs registry (ISSUE 10): metrics() and prometheus() read the
        # same objects; the n_* names stay as read-only properties
        self.metrics_registry = MetricsRegistry()
        self._c_ticks = self.metrics_registry.counter("ticks")
        self._c_passes = self.metrics_registry.counter("passes")
        self._c_served = self.metrics_registry.counter("served")
        self._c_shed = self.metrics_registry.counter("shed")
        self._c_flushes = self.metrics_registry.counter("flushes")
        self._c_deferrals = self.metrics_registry.counter("flush_deferrals")
        self._h_latency = self.metrics_registry.histogram("latency_seconds")
        self._h_occupancy = self.metrics_registry.histogram(
            "batch_occupancy")
        self._flush_cost: Optional[float] = None  # last measured wall time
        self.last_tick_stats: dict = {}

    # registry-backed views of the historical counter attributes
    @property
    def n_ticks(self) -> int:
        return self._c_ticks.value

    @property
    def n_passes(self) -> int:
        return self._c_passes.value

    @property
    def n_served(self) -> int:
        return self._c_served.value

    @property
    def n_shed(self) -> int:
        return self._c_shed.value

    @property
    def n_flushes(self) -> int:
        return self._c_flushes.value

    @property
    def n_flush_deferrals(self) -> int:
        return self._c_deferrals.value

    # -- intake --------------------------------------------------------------

    def submit(self, q: np.ndarray, filters=None, k: int = 10,
               deadline: Optional[float] = None,
               timeout: Optional[float] = None) -> int:
        """Queue a request; returns its rid. ``deadline`` is absolute in
        the frontend clock; ``timeout`` is relative sugar for it."""
        now = self._clock()
        if timeout is not None:
            deadline = now + timeout if deadline is None \
                else min(deadline, now + timeout)
        req = SearchRequest(
            rid=self._next_rid, q=np.atleast_2d(np.asarray(q, np.float32)),
            filters=filters, k=int(k), deadline=deadline, t_submit=now)
        self._next_rid += 1
        self._last_submit = now
        self.queue.append(req)
        return req.rid

    def insert(self, vectors: np.ndarray, attrs) -> np.ndarray:
        """Background ingest: rows land in the collection's append
        buffers now (immediately searchable); the graph splice waits for
        :meth:`_maintain` (queue idle, or flush budget elapsed and the
        measured flush cost fits before the tightest queued SLO)."""
        return self.collection.insert(vectors, attrs)

    def take(self, rid: int) -> SearchRequest:
        """Pop a completed (served or shed) request by rid."""
        return self.completed.pop(rid)

    def pending_queries(self) -> int:
        return sum(r.n_queries for r in self.queue)

    # -- the scheduling loop -------------------------------------------------

    def _shed_expired(self, now: float) -> int:
        live, shed = [], 0
        for r in self.queue:
            if r.deadline is not None and r.deadline < now:
                r.shed = True
                r.t_done = now
                self.completed[r.rid] = r
                shed += 1
            else:
                live.append(r)
        if shed:
            self.queue.clear()
            self.queue.extend(live)
            self._c_shed.inc(shed)
        return shed

    def _admit(self, now: float) -> "list[SearchRequest]":
        """Earliest-deadline-first admission up to the batch bound
        (always at least one request, however wide)."""
        order = sorted(self.queue,
                       key=lambda r: (np.inf if r.deadline is None
                                      else r.deadline, r.t_submit, r.rid))
        batch, rows = [], 0
        for r in order:
            if batch and rows + r.n_queries > self.max_batch_queries:
                continue
            batch.append(r)
            rows += r.n_queries
            if rows >= self.max_batch_queries:
                break
        taken = {r.rid for r in batch}
        remaining = [r for r in self.queue if r.rid not in taken]
        self.queue.clear()
        self.queue.extend(remaining)
        return batch

    def _timed(self, fn, *a, **kw):
        """Run ``fn`` and advance an advance-capable (virtual) clock by
        its measured real cost, so virtual-time latencies include real
        service time."""
        t0 = time.perf_counter()
        out = fn(*a, **kw)
        if hasattr(self._clock, "advance"):
            self._clock.advance(time.perf_counter() - t0)
        return out

    def _maintain(self, now: float, idle: bool) -> None:
        mut = self.collection._mut
        pending = 0 if mut is None else mut.pending_rows
        if not pending:
            return
        if idle:
            # empty queue != quiescence: under open-loop traffic arrivals
            # are imminent, so idle flushes wait out the grace window
            if now - self._last_submit < self.idle_grace:
                self._c_deferrals.inc()
                return
        elif now - self._last_flush < self.flush_budget:
            return
        else:
            # A budget-forced flush competes with live SLOs, and the graph
            # splice is stop-the-world for its duration: yield while the
            # last measured flush cost (unknown -> assume it won't fit)
            # would expire the tightest queued deadline. Buffered rows are
            # searchable regardless, so only freshness-of-structure waits.
            deadlines = [r.deadline for r in self.queue
                         if r.deadline is not None]
            if deadlines and (self._flush_cost is None
                              or now + self._flush_cost > min(deadlines)):
                self._c_deferrals.inc()
                return
        t0 = time.perf_counter()
        with span("tick.flush", pending=pending):
            self._timed(self.collection.flush)
        self._flush_cost = time.perf_counter() - t0
        self._last_flush = self._clock()
        self._c_flushes.inc()

    def tick(self) -> dict:
        """One scheduling step: shed -> (maybe wait) -> admit -> one
        widened pass -> fold results -> maintenance. Returns tick stats.
        Under an active trace each sub-phase is its own span
        (tick.shed / tick.admit / tick.engine / tick.fold /
        tick.maintain / tick.flush)."""
        self._c_ticks.inc()
        with span("tick", n=self.n_ticks) as tick_sp:
            return self._tick_body(tick_sp)

    def _tick_body(self, tick_sp) -> dict:
        now = self._clock()
        with span("tick.shed"):
            shed = self._shed_expired(now)
        stats = {"t": now, "shed": shed, "admitted": 0, "served_queries": 0,
                 "queue_depth": len(self.queue), "waited": False,
                 "occupancy": 0.0}
        if not self.queue:
            with span("tick.maintain", idle=True):
                self._maintain(now, idle=True)
            self.last_tick_stats = stats
            return stats
        oldest = min(r.t_submit for r in self.queue)
        if (self.pending_queries() < self.max_batch_queries
                and now - oldest < self.max_wait):
            # microbatching: under-full and young — let arrivals pile up
            stats["waited"] = True
            with span("tick.maintain", idle=False):
                self._maintain(now, idle=False)
            self.last_tick_stats = stats
            return stats
        with span("tick.admit", queued=len(self.queue)):
            batch = self._admit(now)
        with span("tick.engine", requests=len(batch),
                  rows=sum(r.n_queries for r in batch)):
            results = self._timed(
                self.collection.search_many,
                [(r.q, r.filters, r.k) for r in batch],
                params=self.params, engine=self.engine)
        t_end = self._clock()
        with span("tick.fold", requests=len(batch)):
            for r, res in zip(batch, results):
                r.result = res
                r.t_done = t_end
                self.completed[r.rid] = r
                self._h_latency.observe(r.latency)
        self._c_passes.inc()
        self._c_served.inc(len(batch))
        occ = sum(r.n_queries for r in batch) / self.max_batch_queries
        self._h_occupancy.observe(occ)
        tick_sp.annotate(admitted=len(batch), occupancy=occ)
        # the typed per-pass engine view (EngineStats keeps mapping-style
        # access, so dict consumers of stats["engine"] keep working)
        stats.update(admitted=len(batch), occupancy=occ,
                     served_queries=sum(r.n_queries for r in batch),
                     queue_depth=len(self.queue),
                     engine=self.collection.engine_stats)
        with span("tick.maintain", idle=not self.queue):
            self._maintain(t_end, idle=not self.queue)
        self.last_tick_stats = stats
        return stats

    def drain(self, max_ticks: int = 100000) -> "list[SearchRequest]":
        """Tick until the queue empties (microbatch waits are forced
        through by disabling the wait once everything has arrived).
        Returns the requests completed during the drain, rid order."""
        before = set(self.completed)
        saved, self.max_wait = self.max_wait, 0.0
        try:
            while self.queue and max_ticks > 0:
                self.tick()
                max_ticks -= 1
        finally:
            self.max_wait = saved
        done = [r for rid, r in self.completed.items() if rid not in before]
        return sorted(done, key=lambda r: r.rid)

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict:
        """Lifetime aggregates: latency quantiles (seconds), shed rate,
        mean batch occupancy, pass/tick counts — every value read from
        the obs registry (``metrics_registry``), the same objects
        :meth:`prometheus` exports."""
        total = self.n_served + self.n_shed
        return {"served": self.n_served, "shed": self.n_shed,
                "shed_rate": self.n_shed / max(total, 1),
                "p50_latency": self._h_latency.percentile(50),
                "p95_latency": self._h_latency.percentile(95),
                "p99_latency": self._h_latency.percentile(99),
                "mean_batch_occupancy": self._h_occupancy.mean(),
                "n_ticks": self.n_ticks, "n_passes": self.n_passes,
                "n_flushes": self.n_flushes,
                "n_flush_deferrals": self.n_flush_deferrals,
                "queue_depth": len(self.queue)}

    def prometheus(self, prefix: str = "repro_serve_") -> str:
        """Prometheus text exposition of the frontend's lifetime
        counters and latency/occupancy quantiles, plus live gauges
        (queue depth, pending buffered rows). Serve it from any HTTP
        handler; see ``docs/observability.md`` for a scrape example."""
        mut = self.collection._mut
        extra = {"queue_depth": len(self.queue),
                 "pending_queries": self.pending_queries(),
                 "pending_buffered_rows":
                     0 if mut is None else mut.pending_rows}
        return prometheus_text(self.metrics_registry, prefix=prefix,
                               extra=extra)
