"""Named-attribute schema for a collection.

The kernels only ever see dense positional ``(lo, hi)`` arrays; the schema
is the thin naming layer that lets callers write ``F("price") <= 50``
instead of remembering which column is which. It also fixes the column
order used when attributes arrive as a mapping.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence


@dataclasses.dataclass(frozen=True)
class AttrSchema:
    """Ordered attribute names; position = column in the (n, m) array."""

    names: tuple

    def __init__(self, names: Sequence[str]):
        names = tuple(str(n) for n in names)
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate attribute names: {names}")
        object.__setattr__(self, "names", names)

    @classmethod
    def generic(cls, m: int) -> "AttrSchema":
        """Positional fallback: attr0..attr{m-1}."""
        return cls([f"attr{j}" for j in range(m)])

    def index(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(
                f"unknown attribute {name!r}; schema has {list(self.names)}"
            ) from None

    def __len__(self) -> int:
        return len(self.names)

    def __contains__(self, name: str) -> bool:
        return name in self.names

    def __iter__(self) -> Iterator[str]:
        return iter(self.names)
