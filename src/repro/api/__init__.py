"""Public user-facing API for the Garfield reproduction.

Everything a vector-database caller needs lives here; the ``repro.core``
modules (grid build, graph build, searchers, out-of-core streaming) are
internal layers beneath this facade.

    from repro.api import Collection, AttrSchema, F

    col = Collection.build(vectors, attrs,
                           schema=AttrSchema(["price", "ts"]))
    res = col.search(q, filters=F("price").between(10, 50) & (F("ts") >= t0),
                     k=10)
    # filters compose with | too; the planner box-batches the union
    res = col.search(q, filters=(F("price") < 10) | (F("price") > 90), k=10)
    col.save("index.npz")
    col2 = Collection.load("index.npz")

Engine modes: every collection runs the same traversal core under one of
three residency tiers — ``mode="auto"`` (default) picks from the declared
``device_budget_bytes``, or force one with ``mode=`` / ``search(engine=)``:

    mode    | vectors       | graph              | seeding
    --------+---------------+--------------------+--------------
    incore  | fp32 resident | fully resident     | fresh beam
    hybrid  | int8 +rerank  | LRU cell cache     | carried pool
    ooc     | int8 +rerank  | streamed batches   | carried pool

and, orthogonally, on one device or a JAX mesh: ``shards=`` (an int or
``ShardSpec``) places cells across ``jax.devices()`` and runs any of the
modes per-shard, folding per-shard top-k through the same deterministic
merge. Every result carries a typed ``EngineStats`` snapshot in
``res.stats`` with stable fields across all four tiers.
"""

from repro.api.schema import AttrSchema  # noqa: F401
from repro.api.filters import (  # noqa: F401
    F, FilterExpr, compile_dnf, compile_filters)
from repro.api.planner import QueryPlan, plan_queries  # noqa: F401
from repro.api.result import EngineStats, QueryResult, ShardStats  # noqa: F401
from repro.api.collection import Collection  # noqa: F401
from repro.core.shard import ShardSpec  # noqa: F401
