"""Composable range-filter expressions over named attributes.

``F("price").between(10, 50) & (F("ts") >= t0)`` builds a conjunction of
per-attribute interval constraints. ``compile_filters`` lowers it to the
dense ``(lo, hi)`` float32 batch arrays the kernels expect: one row per
query, one column per schema attribute, with ``-inf``/``+inf`` sentinels
for unconstrained sides — exactly the hand-built arrays callers used to
write by hand.

Semantics match the device predicate (``attr >= lo & attr <= hi``,
inclusive on both sides); strict ``<``/``>`` are realized by nudging the
bound one float32 ulp. Bounds may be scalars (broadcast over the batch)
or per-query arrays of shape (B,). Disjunction is deliberately absent:
it cannot lower to one interval box per attribute, and pretending it
can would silently drop results.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.api.schema import AttrSchema

Bound = Union[float, int, np.ndarray, Sequence[float]]


class FilterExpr:
    """Base class: a conjunction-composable predicate."""

    def __and__(self, other: "FilterExpr") -> "FilterExpr":
        if not isinstance(other, FilterExpr):
            return NotImplemented
        return And(tuple(self._terms()) + tuple(other._terms()))

    def __or__(self, other):
        raise NotImplementedError(
            "disjunction does not lower to one (lo, hi) box per attribute; "
            "run one search per branch and merge the QueryResults")

    def _terms(self):
        raise NotImplementedError

    def compile(self, schema: AttrSchema, batch_size: int):
        """Lower to dense (lo, hi) float32 arrays of shape (B, m)."""
        m = len(schema)
        lo = np.full((batch_size, m), -np.inf, np.float32)
        hi = np.full((batch_size, m), np.inf, np.float32)
        for t in self._terms():
            j = schema.index(t.name)
            if t.lo is not None:
                lo[:, j] = np.maximum(lo[:, j],
                                      _as_col(t.lo, batch_size, t.name))
            if t.hi is not None:
                hi[:, j] = np.minimum(hi[:, j],
                                      _as_col(t.hi, batch_size, t.name))
        return lo, hi


def _as_col(v: Bound, batch_size: int, name: str) -> np.ndarray:
    arr = np.asarray(v, np.float32)
    if arr.ndim == 0:
        return np.broadcast_to(arr, (batch_size,))
    if arr.shape != (batch_size,):
        raise ValueError(
            f"filter bound for {name!r} has shape {arr.shape}; expected a "
            f"scalar or per-query shape ({batch_size},)")
    return arr


@dataclasses.dataclass(frozen=True)
class RangeFilter(FilterExpr):
    """One attribute's interval constraint; None = unbounded side."""

    name: str
    lo: Optional[Bound] = None
    hi: Optional[Bound] = None

    def _terms(self):
        return (self,)


@dataclasses.dataclass(frozen=True)
class And(FilterExpr):
    terms: tuple

    def _terms(self):
        return self.terms


def _ulp_up(v: Bound) -> np.ndarray:
    return np.nextafter(np.asarray(v, np.float32), np.float32(np.inf))


def _ulp_down(v: Bound) -> np.ndarray:
    return np.nextafter(np.asarray(v, np.float32), np.float32(-np.inf))


class F:
    """Field reference: ``F("price")`` starts a filter expression."""

    def __init__(self, name: str):
        self.name = str(name)

    def between(self, lo: Bound, hi: Bound) -> RangeFilter:
        """Inclusive interval: lo <= attr <= hi."""
        return RangeFilter(self.name, lo=lo, hi=hi)

    def __ge__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, lo=v)

    def __le__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, hi=v)

    def __gt__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, lo=_ulp_up(v))

    def __lt__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, hi=_ulp_down(v))

    def __eq__(self, v) -> RangeFilter:           # type: ignore[override]
        return RangeFilter(self.name, lo=v, hi=v)

    def __hash__(self):
        return hash(("F", self.name))


def compile_filters(filters, schema: AttrSchema, batch_size: int):
    """Normalize any accepted filter form to dense (lo, hi) arrays.

    Accepts a FilterExpr, an explicit ``(lo, hi)`` array pair (passed
    through, validated), or None (unconstrained).
    """
    m = len(schema)
    if filters is None:
        return (np.full((batch_size, m), -np.inf, np.float32),
                np.full((batch_size, m), np.inf, np.float32))
    if isinstance(filters, FilterExpr):
        return filters.compile(schema, batch_size)
    if isinstance(filters, (tuple, list)) and len(filters) == 2:
        lo = np.asarray(filters[0], np.float32)
        hi = np.asarray(filters[1], np.float32)
        if lo.shape != (batch_size, m) or hi.shape != (batch_size, m):
            raise ValueError(
                f"explicit (lo, hi) must each be shape ({batch_size}, {m}); "
                f"got {lo.shape} and {hi.shape}")
        return lo, hi
    raise TypeError(f"unsupported filters object: {type(filters).__name__}")
