"""Composable range-filter expressions over named attributes.

``F("price").between(10, 50) & (F("ts") >= t0)`` builds a conjunction of
per-attribute interval constraints; ``|`` composes disjunctions, so the
filter language is closed under and/or:

    (F("price") < 10) | (F("price") > 90)
    ((F("ts") >= t0) | (F("ts") <= t1)) & (F("views") > 100)

Conjunctive expressions lower (``compile``/``compile_filters``) to the
dense ``(lo, hi)`` float32 batch arrays the kernels expect: one row per
query, one column per schema attribute, with ``-inf``/``+inf`` sentinels
for unconstrained sides. Arbitrary and/or trees lower (``compile_dnf``)
to disjunctive normal form — a *stack* of such boxes, one slab per DNF
conjunction — which ``repro.api.planner`` canonicalizes and flattens
into one box-batched engine pass.

Semantics match the device predicate (``attr >= lo & attr <= hi``,
inclusive on both sides); strict ``<``/``>`` are realized by nudging the
bound one float32 ulp. Bounds may be scalars (broadcast over the batch)
or per-query arrays of shape (B,).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence, Union

import numpy as np

from repro.api.schema import AttrSchema

Bound = Union[float, int, np.ndarray, Sequence[float]]

# Cap on the DNF expansion: and-over-or distribution is multiplicative,
# and a plan past this size means the caller should restructure the
# predicate (or the planner's flattening would swamp the device batch).
MAX_DNF_CONJUNCTIONS = 128


class FilterExpr:
    """Base class: an and/or-composable range predicate."""

    def __and__(self, other: "FilterExpr") -> "FilterExpr":
        if not isinstance(other, FilterExpr):
            return NotImplemented
        return And(_flatten(And, (self, other)))

    def __or__(self, other: "FilterExpr") -> "FilterExpr":
        if not isinstance(other, FilterExpr):
            return NotImplemented
        return Or(_flatten(Or, (self, other)))

    def dnf(self):
        """Disjunctive normal form: a tuple of conjunctions, each a
        tuple of :class:`RangeFilter` leaves."""
        raise NotImplementedError

    def compile(self, schema: AttrSchema, batch_size: int):
        """Lower to one dense (lo, hi) box pair of shape (B, m).

        Only defined for conjunctive expressions; a disjunction cannot
        lower to one box per attribute (use ``compile_dnf`` — the
        ``Collection`` search path routes through it automatically).
        """
        conjs = self.dnf()
        if len(conjs) != 1:
            raise ValueError(
                f"disjunctive filter ({len(conjs)} DNF branches) cannot "
                "lower to one (lo, hi) box per attribute; compile_dnf / "
                "repro.api.planner handle it (Collection.search does this "
                "automatically)")
        return compile_conjunction(conjs[0], schema, batch_size)


def _flatten(node_cls, children):
    """Associativity: fold nested same-type nodes into one n-ary node."""
    out = []
    for c in children:
        if isinstance(c, node_cls):
            out.extend(c.children)
        else:
            out.append(c)
    return tuple(out)


def compile_conjunction(terms, schema: AttrSchema, batch_size: int):
    """One conjunction of RangeFilters -> dense (lo, hi) of shape (B, m),
    intersecting repeated constraints on the same attribute."""
    m = len(schema)
    lo = np.full((batch_size, m), -np.inf, np.float32)
    hi = np.full((batch_size, m), np.inf, np.float32)
    for t in terms:
        j = schema.index(t.name)
        if t.lo is not None:
            lo[:, j] = np.maximum(lo[:, j], _as_col(t.lo, batch_size, t.name))
        if t.hi is not None:
            hi[:, j] = np.minimum(hi[:, j], _as_col(t.hi, batch_size, t.name))
    return lo, hi


def _as_col(v: Bound, batch_size: int, name: str) -> np.ndarray:
    arr = np.asarray(v, np.float32)
    if arr.ndim == 0:
        return np.broadcast_to(arr, (batch_size,))
    if arr.shape != (batch_size,):
        raise ValueError(
            f"filter bound for {name!r} has shape {arr.shape}; expected a "
            f"scalar or per-query shape ({batch_size},)")
    return arr


@dataclasses.dataclass(frozen=True)
class RangeFilter(FilterExpr):
    """One attribute's interval constraint; None = unbounded side."""

    name: str
    lo: Optional[Bound] = None
    hi: Optional[Bound] = None

    def dnf(self):
        return ((self,),)


@dataclasses.dataclass(frozen=True)
class And(FilterExpr):
    children: tuple

    def dnf(self):
        child_dnfs = [c.dnf() for c in self.children]
        total = 1
        for d in child_dnfs:
            total *= len(d)
        if total > MAX_DNF_CONJUNCTIONS:
            raise ValueError(
                f"filter expands to {total} DNF conjunctions "
                f"(cap {MAX_DNF_CONJUNCTIONS}); restructure the predicate")
        return tuple(tuple(itertools.chain.from_iterable(combo))
                     for combo in itertools.product(*child_dnfs))


@dataclasses.dataclass(frozen=True)
class Or(FilterExpr):
    children: tuple

    def dnf(self):
        out = tuple(itertools.chain.from_iterable(
            c.dnf() for c in self.children))
        if len(out) > MAX_DNF_CONJUNCTIONS:
            raise ValueError(
                f"filter expands to {len(out)} DNF conjunctions "
                f"(cap {MAX_DNF_CONJUNCTIONS}); restructure the predicate")
        return out


def _ulp_up(v: Bound) -> np.ndarray:
    return np.nextafter(np.asarray(v, np.float32), np.float32(np.inf))


def _ulp_down(v: Bound) -> np.ndarray:
    return np.nextafter(np.asarray(v, np.float32), np.float32(-np.inf))


class F:
    """Field reference: ``F("price")`` starts a filter expression."""

    def __init__(self, name: str):
        self.name = str(name)

    def between(self, lo: Bound, hi: Bound) -> RangeFilter:
        """Inclusive interval: lo <= attr <= hi."""
        return RangeFilter(self.name, lo=lo, hi=hi)

    def __ge__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, lo=v)

    def __le__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, hi=v)

    def __gt__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, lo=_ulp_up(v))

    def __lt__(self, v: Bound) -> RangeFilter:
        return RangeFilter(self.name, hi=_ulp_down(v))

    def __eq__(self, v) -> RangeFilter:           # type: ignore[override]
        return RangeFilter(self.name, lo=v, hi=v)

    def __hash__(self):
        return hash(("F", self.name))


def compile_filters(filters, schema: AttrSchema, batch_size: int):
    """Normalize any accepted *conjunctive* filter form to dense (lo, hi).

    Accepts a FilterExpr, an explicit ``(lo, hi)`` array pair (passed
    through, validated), or None (unconstrained). Disjunctive
    expressions raise — route those through ``compile_dnf``.
    """
    m = len(schema)
    if filters is None:
        return (np.full((batch_size, m), -np.inf, np.float32),
                np.full((batch_size, m), np.inf, np.float32))
    if isinstance(filters, FilterExpr):
        return filters.compile(schema, batch_size)
    if isinstance(filters, (tuple, list)) and len(filters) == 2:
        lo = np.asarray(filters[0], np.float32)
        hi = np.asarray(filters[1], np.float32)
        if lo.shape != (batch_size, m) or hi.shape != (batch_size, m):
            raise ValueError(
                f"explicit (lo, hi) must each be shape ({batch_size}, {m}); "
                f"got {lo.shape} and {hi.shape}")
        return lo, hi
    raise TypeError(f"unsupported filters object: {type(filters).__name__}")


def compile_dnf(filters, schema: AttrSchema, batch_size: int):
    """Lower any accepted filter form to a DNF box stack.

    Returns ``(lo, hi)`` float32 arrays of shape (n_boxes, B, m): one
    (B, m) slab per DNF conjunction. Conjunctive forms (None, explicit
    arrays, and-only expressions) yield n_boxes = 1.
    """
    if filters is None or isinstance(filters, (tuple, list)):
        lo, hi = compile_filters(filters, schema, batch_size)
        return lo[None], hi[None]
    if isinstance(filters, FilterExpr):
        slabs = [compile_conjunction(c, schema, batch_size)
                 for c in filters.dnf()]
        return (np.stack([s[0] for s in slabs]),
                np.stack([s[1] for s in slabs]))
    raise TypeError(f"unsupported filters object: {type(filters).__name__}")
