"""Search result container returned by ``Collection.search``, plus the
typed :class:`EngineStats` schema every engine mode reports through."""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardStats:
    """Per-shard counters for one sharded pass (mesh tier)."""

    shard: int = 0
    device: str = ""
    n_cells: int = 0           # cells resident on the shard
    n_rows: int = 0            # rows resident on the shard
    active_rows: int = 0       # query rows the shard actually served
    total_active: int = 0      # selected (row, cell) incidences served
    replica_hits: int = 0      # incidences served away from the home shard
    transfer_bytes: int = 0
    wall_seconds: float = 0.0
    extras: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_raw(cls, raw: dict) -> "ShardStats":
        known = {f.name for f in dataclasses.fields(cls)} - {"extras"}
        kw = {k: v for k, v in raw.items() if k in known}
        extras = {k: v for k, v in raw.items() if k not in known}
        return cls(extras=extras, **kw)

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out.update(out.pop("extras"))
        return out


# Fields with a typed default are *stable across every engine mode*
# (incore / hybrid / ooc / sharded): benches and the recall gate read
# them without probing which mode served the batch. Optional fields are
# populated only by the modes they describe and drop out of to_dict().
@dataclasses.dataclass(frozen=True)
class EngineStats:
    """Typed per-pass engine counters (replaces the ad-hoc stats dict).

    Mapping-style access (``stats["n_dense"]``, ``"cache" in stats``,
    ``stats.get(...)``) is kept for the transition so existing callers
    and notebooks keep working; new code should read the fields.
    """

    engine: str = "incore"     # "incore" | "hybrid" | "ooc" | "mixed"
    n_rows: int = 0            # query rows in the pass (boxes, not queries)
    # route split (cost-based planner; stable across modes)
    n_dense: int = 0
    n_mid: int = 0
    n_broad: int = 0
    # incore path split
    n_itinerary: int = 0
    n_global: int = 0
    # streamed-mode work counters
    n_waves: int = 0           # hybrid
    n_batches: int = 0         # ooc
    total_active: int = 0      # Eq. 3 objective actually executed
    transfer_bytes: int = 0
    buffered_rows: int = 0     # mutation buffer rows folded host-side
    wall_seconds: float = 0.0
    # cache block (hybrid only)
    cache_hits: Optional[int] = None
    cache_misses: Optional[int] = None
    hit_rate: Optional[float] = None
    prefetches: Optional[int] = None
    prefetch_hits: Optional[int] = None
    prefetch_hit_rate: Optional[float] = None
    cache: Optional[dict] = None       # nested CellCache.stats() snapshot
    # planner block (disjunctive / multi-box plans)
    planner: Optional[dict] = None
    n_boxes: Optional[int] = None
    est_rel_err_dense: Optional[float] = None
    # mesh tier (sharded execution only)
    sharded: bool = False
    n_shards: Optional[int] = None
    replicated_cells: Optional[int] = None
    replica_hits: Optional[int] = None
    shards: tuple = ()                 # per-shard ShardStats
    # anything mode-specific that has no typed slot yet
    extras: dict = dataclasses.field(default_factory=dict)
    # keys the engine actually reported this pass (from_raw records
    # them); raw_dict() filters to these so dict-compat consumers see
    # exactly the engine's dict, not typed defaults for other modes
    reported: tuple = ()

    @classmethod
    def from_raw(cls, raw: dict) -> "EngineStats":
        """Build from an engine's raw stats dict; unrecognized keys land
        in ``extras`` so nothing an engine reports is ever dropped."""
        known = {f.name for f in dataclasses.fields(cls)} - {"extras",
                                                             "shards",
                                                             "reported"}
        kw = {k: v for k, v in raw.items() if k in known}
        shards = tuple(
            s if isinstance(s, ShardStats) else ShardStats.from_raw(s)
            for s in raw.get("shards", ()))
        extras = {k: v for k, v in raw.items()
                  if k not in known and k != "shards"}
        return cls(shards=shards, extras=extras,
                   reported=tuple(raw.keys()), **kw)

    def to_dict(self) -> dict:
        """Flat dict for benches / JSON export: typed fields (Nones and
        empty mesh fields dropped), shards as dicts, extras merged."""
        out = {}
        for f in dataclasses.fields(self):
            if f.name in ("extras", "shards", "reported"):
                continue
            v = getattr(self, f.name)
            if v is None:
                continue
            if f.name == "sharded" and not v:
                continue
            out[f.name] = v
        if self.shards:
            out["shards"] = [s.to_dict() for s in self.shards]
        out.update(self.extras)
        return out

    def raw_dict(self) -> dict:
        """to_dict() filtered to the keys the engine reported — the
        exact dict-compat view ``Collection.last_stats`` exposes (typed
        defaults for other modes never leak in)."""
        if not self.reported:
            return {}
        d = self.to_dict()
        rep = set(self.reported)
        return {k: v for k, v in d.items() if k in rep}

    # -- transitional mapping access ------------------------------------
    def __getitem__(self, key: str):
        d = self.to_dict()
        if key in d:
            return d[key]
        if hasattr(self, key) and not key.startswith("_"):
            return getattr(self, key)      # typed default (e.g. n_waves=0)
        raise KeyError(key)

    def get(self, key: str, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __contains__(self, key: str) -> bool:
        return key in self.to_dict()

    def keys(self):
        return self.to_dict().keys()


def _pad_k(arr: np.ndarray, k: int, fill) -> np.ndarray:
    """Widen a (B, k') result array to k columns with pad values."""
    if arr.shape[1] == k:
        return arr
    pad = np.full((arr.shape[0], k - arr.shape[1]), fill, arr.dtype)
    return np.concatenate([arr, pad], axis=1)


# eq=False: a generated __eq__ would compare ndarray fields elementwise
# and raise on bool() — identity comparison is the only sane default
@dataclasses.dataclass(frozen=True, eq=False)
class QueryResult:
    """Batched top-k answer. Rows are padded with id -1 / distance +inf
    when a query's predicate admits fewer than k points."""

    ids: np.ndarray          # (B, k) i64 original ids, -1 pad
    distances: np.ndarray    # (B, k) f32 squared L2, +inf pad
    engine: str = "incore"   # engine mode that served the batch
    # ("incore" | "hybrid" | "ooc" | "mixed")
    # typed engine counters for the pass that produced this batch
    # (planner fanout, wave/cache/transfer counters on the streamed
    # modes, path split on incore, per-shard counters on a mesh) — the
    # serving front-end exports these per tick. A raw dict passed here
    # is coerced through EngineStats.from_raw.
    stats: EngineStats = dataclasses.field(default_factory=EngineStats)

    def __post_init__(self):
        if isinstance(self.stats, dict):
            object.__setattr__(self, "stats",
                               EngineStats.from_raw(self.stats))

    @classmethod
    def empty(cls, k: int, engine: str = "incore") -> "QueryResult":
        return cls(ids=np.zeros((0, k), np.int64),
                   distances=np.zeros((0, k), np.float32), engine=engine)

    def __len__(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]

    @property
    def valid_counts(self) -> np.ndarray:
        """(B,) number of real (non-pad) results per query."""
        return (self.ids >= 0).sum(axis=1)

    def recall(self, true_ids: np.ndarray) -> float:
        """Recall against exact ground-truth ids (paper's metric)."""
        from repro.core.search import recall_at_k
        return recall_at_k(self.ids, true_ids)

    def merge(self, other: "QueryResult") -> "QueryResult":
        """Row-wise union of two result sets over the same query batch
        (e.g. two filter branches searched separately).

        Deterministic: per query, duplicate ids collapse to their best
        (smallest) distance, candidates order by (distance, id) so ties
        break toward the smaller id, and the union's top-k is kept
        (k = max of the two operands). Prefer a single disjunctive
        ``Collection.search`` call — the planner runs all branches in
        one box-batched device pass; this is the host-side fallback.
        """
        from repro.core.runtime import merge_segment_topk
        if len(self) != len(other):
            raise ValueError(
                f"cannot merge results over different batches "
                f"({len(self)} vs {len(other)} queries)")
        B = len(self)
        k = max(self.k, other.k)
        ids = np.concatenate([_pad_k(self.ids, k, -1),
                              _pad_k(other.ids, k, -1)], axis=0)
        d = np.concatenate([_pad_k(self.distances, k, np.inf),
                            _pad_k(other.distances, k, np.inf)], axis=0)
        qmap = np.concatenate([np.arange(B), np.arange(B)])
        mi, md = merge_segment_topk(ids, d, qmap, B, k)
        engine = self.engine if self.engine == other.engine else "mixed"
        return QueryResult(ids=mi, distances=md, engine=engine)

    def __iter__(self):
        """Yield (ids, distances) per query, pads trimmed."""
        for b in range(len(self)):
            keep = self.ids[b] >= 0
            yield self.ids[b][keep], self.distances[b][keep]
