"""Search result container returned by ``Collection.search``."""

from __future__ import annotations

import dataclasses

import numpy as np


def _pad_k(arr: np.ndarray, k: int, fill) -> np.ndarray:
    """Widen a (B, k') result array to k columns with pad values."""
    if arr.shape[1] == k:
        return arr
    pad = np.full((arr.shape[0], k - arr.shape[1]), fill, arr.dtype)
    return np.concatenate([arr, pad], axis=1)


# eq=False: a generated __eq__ would compare ndarray fields elementwise
# and raise on bool() — identity comparison is the only sane default
@dataclasses.dataclass(frozen=True, eq=False)
class QueryResult:
    """Batched top-k answer. Rows are padded with id -1 / distance +inf
    when a query's predicate admits fewer than k points."""

    ids: np.ndarray          # (B, k) i64 original ids, -1 pad
    distances: np.ndarray    # (B, k) f32 squared L2, +inf pad
    engine: str = "incore"   # engine mode that served the batch
    # ("incore" | "hybrid" | "ooc" | "mixed")
    # engine counters for the pass that produced this batch (a snapshot
    # of Collection.last_stats: planner fanout, wave/cache/transfer
    # counters on the streamed modes, path split on incore) — the
    # serving front-end exports these per tick
    stats: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def empty(cls, k: int, engine: str = "incore") -> "QueryResult":
        return cls(ids=np.zeros((0, k), np.int64),
                   distances=np.zeros((0, k), np.float32), engine=engine)

    def __len__(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]

    @property
    def valid_counts(self) -> np.ndarray:
        """(B,) number of real (non-pad) results per query."""
        return (self.ids >= 0).sum(axis=1)

    def recall(self, true_ids: np.ndarray) -> float:
        """Recall against exact ground-truth ids (paper's metric)."""
        from repro.core.search import recall_at_k
        return recall_at_k(self.ids, true_ids)

    def merge(self, other: "QueryResult") -> "QueryResult":
        """Row-wise union of two result sets over the same query batch
        (e.g. two filter branches searched separately).

        Deterministic: per query, duplicate ids collapse to their best
        (smallest) distance, candidates order by (distance, id) so ties
        break toward the smaller id, and the union's top-k is kept
        (k = max of the two operands). Prefer a single disjunctive
        ``Collection.search`` call — the planner runs all branches in
        one box-batched device pass; this is the host-side fallback.
        """
        from repro.core.runtime import merge_segment_topk
        if len(self) != len(other):
            raise ValueError(
                f"cannot merge results over different batches "
                f"({len(self)} vs {len(other)} queries)")
        B = len(self)
        k = max(self.k, other.k)
        ids = np.concatenate([_pad_k(self.ids, k, -1),
                              _pad_k(other.ids, k, -1)], axis=0)
        d = np.concatenate([_pad_k(self.distances, k, np.inf),
                            _pad_k(other.distances, k, np.inf)], axis=0)
        qmap = np.concatenate([np.arange(B), np.arange(B)])
        mi, md = merge_segment_topk(ids, d, qmap, B, k)
        engine = self.engine if self.engine == other.engine else "mixed"
        return QueryResult(ids=mi, distances=md, engine=engine)

    def __iter__(self):
        """Yield (ids, distances) per query, pads trimmed."""
        for b in range(len(self)):
            keep = self.ids[b] >= 0
            yield self.ids[b][keep], self.distances[b][keep]
