"""Search result container returned by ``Collection.search``."""

from __future__ import annotations

import dataclasses

import numpy as np


# eq=False: a generated __eq__ would compare ndarray fields elementwise
# and raise on bool() — identity comparison is the only sane default
@dataclasses.dataclass(frozen=True, eq=False)
class QueryResult:
    """Batched top-k answer. Rows are padded with id -1 / distance +inf
    when a query's predicate admits fewer than k points."""

    ids: np.ndarray          # (B, k) i64 original ids, -1 pad
    distances: np.ndarray    # (B, k) f32 squared L2, +inf pad
    engine: str = "in_core"  # which execution path served the batch

    @classmethod
    def empty(cls, k: int, engine: str = "in_core") -> "QueryResult":
        return cls(ids=np.zeros((0, k), np.int64),
                   distances=np.zeros((0, k), np.float32), engine=engine)

    def __len__(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]

    @property
    def valid_counts(self) -> np.ndarray:
        """(B,) number of real (non-pad) results per query."""
        return (self.ids >= 0).sum(axis=1)

    def recall(self, true_ids: np.ndarray) -> float:
        """Recall against exact ground-truth ids (paper's metric)."""
        from repro.core.search import recall_at_k
        return recall_at_k(self.ids, true_ids)

    def __iter__(self):
        """Yield (ids, distances) per query, pads trimmed."""
        for b in range(len(self)):
            keep = self.ids[b] >= 0
            yield self.ids[b][keep], self.distances[b][keep]
