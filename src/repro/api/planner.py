"""Query planner: disjunctive filters -> one box-batched engine pass.

``F(...)`` expressions are closed under ``&``/``|`` and compile to
disjunctive normal form — per query, a union of dense ``(lo, hi)``
boxes (``repro.api.filters.compile_dnf``). This module turns that union
into something the engines can serve in a *single* device pass:

1. **Canonicalize** each query's box set (:func:`canonicalize_boxes`):
   prune empty boxes (``lo > hi`` on any attribute), drop duplicates and
   boxes contained in another, and merge boxes that differ on exactly
   one attribute whose intervals overlap or are adjacent (adjacency at
   one float32 ulp — the same granularity strict bounds are encoded
   with, so ``price < 10 | price >= 10`` collapses to unbounded).
2. **Flatten** all boxes across all queries in the batch
   (:func:`plan_queries`): query vectors are replicated per box and a
   ``qmap`` row->original-query segment map rides along, so cell
   selection, ordering and traversal run once over the widened batch —
   no per-box Python loop over ``Searcher.search``.
3. **Merge** per-box top-k candidates back into per-query results with
   the segment-aware, id-deduplicating fold
   (``repro.core.runtime.merge_segment_topk``), which every engine mode
   applies when handed a ``qmap``.

Conjunctive filters (including explicit ``(lo, hi)`` arrays and None)
produce a *trivial* plan — one box per query, identity ``qmap`` — which
``Collection.search`` serves on the unwidened fast path, byte-identical
to the pre-planner behavior.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.api.filters import (FilterExpr, compile_conjunction,
                               compile_filters)
from repro.api.schema import AttrSchema


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Flattened box-batched execution plan for one query batch.

    ``est_rows`` is the planner's per-box qualifying-row estimate
    (:func:`annotate_plan` — per-attribute CDF product refined by
    per-cell attribute histograms); engines use it through the per-box
    cost model (``repro.core.selectivity.route_boxes``) to pick each
    box's execution route. None on un-annotated plans (engines then
    estimate from the index's global CDF grid themselves).
    """

    lo: np.ndarray        # (T, m) f32 — all boxes, grouped by query
    hi: np.ndarray        # (T, m) f32
    qmap: np.ndarray      # (T,) i64 — original query index per box row
    n_queries: int        # B of the original batch
    trivial: bool         # conjunctive fast path: identity qmap, T == B
    stats: dict = dataclasses.field(default_factory=dict)
    est_rows: Optional[np.ndarray] = None  # (T,) f64 planner annotation

    @property
    def n_boxes(self) -> int:
        return self.lo.shape[0]


def annotate_plan(plan: QueryPlan, index, estimator=None) -> QueryPlan:
    """Annotate each plan box with an estimated qualifying-row count.

    ``estimator`` (a ``repro.core.selectivity.SelectivityEstimator``)
    refines the global per-attribute CDF product with per-cell attribute
    histograms, so correlated attributes don't blow the estimate; without
    one the global product (times the row count) is used. Idempotent on
    already-annotated plans.
    """
    from repro.core import selectivity as sel_mod
    if plan.est_rows is not None:
        return plan
    if estimator is not None:
        from repro.core import select as select_mod
        inc = select_mod.incidence_numpy(plan.lo, plan.hi,
                                         index.cell_lo, index.cell_hi)
        est_rows = estimator.estimate_rows(plan.lo, plan.hi, inc)
    else:
        est_rows = sel_mod.estimate_selectivity(
            index, plan.lo, plan.hi) * index.n
    return dataclasses.replace(plan, est_rows=est_rows)


def shard_routing(plan: QueryPlan, index, spec) -> dict:
    """Introspect how a plan's boxes would fan out across a mesh.

    Runs the same placement + per-pass cell assignment the sharded
    engine uses (``repro.core.shard``) over the plan's box incidence —
    no search is executed. Returns per-shard box counts and served
    (box, cell) incidences plus the replica-rebalance tally, so callers
    can inspect work-partition balance before committing a workload.
    """
    from repro.core import select as select_mod
    from repro.core import shard as shard_mod
    spec = shard_mod.ShardSpec.canon(spec)
    if spec is None:
        raise ValueError("shard_routing needs a ShardSpec (or int)")
    placement = shard_mod.plan_placement(index, spec)
    inc = select_mod.incidence_numpy(plan.lo, plan.hi,
                                     index.cell_lo, index.cell_hi)
    assign, replica_hits = shard_mod.assign_cells(inc, placement)
    per_shard = []
    for s in range(spec.n_shards):
        cols = np.nonzero(assign == s)[0]
        sub = inc[:, cols]
        per_shard.append({
            "shard": s,
            "cells": int((sub.any(axis=0)).sum()),
            "boxes": int((sub.any(axis=1)).sum()),
            "total_active": int(sub.sum()),
        })
    active = [st["total_active"] for st in per_shard]
    mean = float(np.mean(active)) if active else 0.0
    return {"n_shards": spec.n_shards, "n_boxes": plan.n_boxes,
            "replica_hits": int(replica_hits),
            "replicated_cells": int(placement.replicated.sum()),
            "balance": (float(max(active)) / max(mean, 1e-12)
                        if active else 0.0),
            "shards": per_shard}


def canonicalize_boxes(lo: np.ndarray, hi: np.ndarray):
    """Canonicalize one query's box union; returns (n_canon, m) arrays.

    Dropped: empty boxes (lo > hi on any attribute), exact duplicates,
    and boxes contained in another. Merged: box pairs that differ on a
    single attribute whose intervals overlap or are adjacent within one
    float32 ulp. Runs to fixpoint, then orders boxes lexicographically
    so the plan (and hence the merged result under distance ties) is
    deterministic.
    """
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    m = lo.shape[1]
    keep = ~(lo > hi).any(axis=1)
    boxes = [(lo[i].copy(), hi[i].copy()) for i in np.nonzero(keep)[0]]
    changed = True
    while changed:
        changed = False
        out: list = []
        for blo, bhi in boxes:
            absorbed = False
            for j, (olo, ohi) in enumerate(out):
                if (olo <= blo).all() and (bhi <= ohi).all():
                    absorbed = True                 # contained (or dup)
                    break
                if (blo <= olo).all() and (ohi <= bhi).all():
                    out[j] = (blo, bhi)             # contains -> replace
                    absorbed = changed = True
                    break
                diff = (blo != olo) | (bhi != ohi)
                if diff.sum() == 1:
                    a = int(np.argmax(diff))
                    gap_lo = max(blo[a], olo[a])
                    gap_hi = min(bhi[a], ohi[a])
                    if gap_lo <= np.nextafter(gap_hi, np.float32(np.inf)):
                        nlo, nhi = olo.copy(), ohi.copy()
                        nlo[a] = min(blo[a], olo[a])
                        nhi[a] = max(bhi[a], ohi[a])
                        out[j] = (nlo, nhi)
                        absorbed = changed = True
                        break
            if not absorbed:
                out.append((blo, bhi))
        boxes = out
    if not boxes:
        return np.empty((0, m), np.float32), np.empty((0, m), np.float32)
    order = sorted(range(len(boxes)),
                   key=lambda i: (boxes[i][0].tolist(), boxes[i][1].tolist()))
    return (np.stack([boxes[i][0] for i in order]),
            np.stack([boxes[i][1] for i in order]))


def concat_plans(plans: "list[QueryPlan]"):
    """Concatenate per-request plans into one cross-request plan.

    The serving front-end (repro.serve.frontend) plans every in-flight
    request independently, then coalesces the whole tick into ONE
    widened engine pass: box rows concatenate, each plan's ``qmap``
    shifts by the running query offset, and the same segment-aware
    top-k merge that folds a disjunction's boxes folds the cross-request
    batch — request boundaries are just more segments.

    Returns ``(plan, q_offsets)`` where ``q_offsets`` is an
    (n_plans + 1,) int64 prefix array: plan r's queries occupy rows
    ``q_offsets[r]:q_offsets[r+1]`` of the combined result block.
    """
    if not plans:
        raise ValueError("concat_plans needs at least one plan")
    q_offsets = np.zeros(len(plans) + 1, np.int64)
    q_offsets[1:] = np.cumsum([p.n_queries for p in plans])
    lo = np.concatenate([p.lo for p in plans], axis=0)
    hi = np.concatenate([p.hi for p in plans], axis=0)
    qmap = np.concatenate(
        [p.qmap + q_offsets[r] for r, p in enumerate(plans)])
    # a concat of trivial plans is itself trivial: offset identity qmaps
    # chain into one identity qmap
    trivial = all(p.trivial for p in plans)
    # planner annotations survive the concat only when every constituent
    # carries one (a single un-annotated plan would misalign the rows)
    est_rows = None
    if all(p.est_rows is not None for p in plans):
        est_rows = np.concatenate([p.est_rows for p in plans])
    stats = {"n_requests": len(plans),
             "n_queries": int(q_offsets[-1]),
             "n_boxes": int(lo.shape[0]),
             "max_fanout": max((p.stats.get("max_fanout", 1)
                                for p in plans), default=0)}
    return QueryPlan(lo=lo, hi=hi, qmap=qmap,
                     n_queries=int(q_offsets[-1]), trivial=trivial,
                     stats=stats, est_rows=est_rows), q_offsets


def plan_queries(filters, schema: AttrSchema, batch_size: int) -> QueryPlan:
    """Compile + canonicalize + flatten one batch's filters into a plan."""
    conjs = filters.dnf() if isinstance(filters, FilterExpr) else None
    if conjs is None or len(conjs) == 1:
        if conjs is None:     # explicit (lo, hi) arrays or None
            lo, hi = compile_filters(filters, schema, batch_size)
        else:
            lo, hi = compile_conjunction(conjs[0], schema, batch_size)
        return QueryPlan(lo=lo, hi=hi,
                         qmap=np.arange(batch_size, dtype=np.int64),
                         n_queries=batch_size, trivial=True,
                         stats={"n_queries": batch_size,
                                "n_boxes": batch_size, "max_fanout": 1})

    slabs = [compile_conjunction(c, schema, batch_size) for c in conjs]
    blo = np.stack([s[0] for s in slabs])                 # (nb, B, m)
    bhi = np.stack([s[1] for s in slabs])
    m = blo.shape[2]
    if batch_size == 0:
        lo = np.empty((0, m), np.float32)
        return QueryPlan(lo=lo, hi=lo.copy(),
                         qmap=np.empty(0, np.int64), n_queries=0,
                         trivial=False,
                         stats={"n_queries": 0, "n_boxes": 0,
                                "n_dnf_branches": blo.shape[0],
                                "max_fanout": 0})

    # scalar-bound filters compile to boxes constant across the batch:
    # canonicalize once and tile, instead of B identical passes
    uniform = bool((blo == blo[:, :1]).all() and (bhi == bhi[:, :1]).all())
    if uniform:
        clo, chi = canonicalize_boxes(blo[:, 0], bhi[:, 0])
        nbc = clo.shape[0]
        lo = np.tile(clo, (batch_size, 1))
        hi = np.tile(chi, (batch_size, 1))
        qmap = np.repeat(np.arange(batch_size, dtype=np.int64), nbc)
        fanout = np.full(batch_size, nbc, np.int64)
    else:
        los, his, maps = [], [], []
        fanout = np.zeros(batch_size, np.int64)
        for b in range(batch_size):
            clo, chi = canonicalize_boxes(blo[:, b], bhi[:, b])
            fanout[b] = clo.shape[0]
            if clo.shape[0]:
                los.append(clo)
                his.append(chi)
                maps.append(np.full(clo.shape[0], b, np.int64))
        lo = (np.concatenate(los, axis=0) if los
              else np.empty((0, m), np.float32))
        hi = (np.concatenate(his, axis=0) if his
              else np.empty((0, m), np.float32))
        qmap = (np.concatenate(maps) if maps else np.empty(0, np.int64))
    return QueryPlan(
        lo=lo, hi=hi, qmap=qmap, n_queries=batch_size, trivial=False,
        stats={"n_queries": batch_size,
               "n_boxes": int(lo.shape[0]),
               "n_dnf_branches": int(blo.shape[0]),
               "max_fanout": int(fanout.max()) if batch_size else 0})
