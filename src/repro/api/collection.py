"""``Collection`` — the index-lifecycle facade (build / search / persist).

One object owns a built GMG index plus its attribute schema and picks the
execution engine per batch, so callers never touch ``build_gmg``,
``Searcher``, ``HybridEngine`` or ``OutOfCoreEngine`` directly:

  - build     — ``Collection.build(vectors, attrs, schema=..., config=...)``
  - search    — ``col.search(q, filters=F("price") <= 50, k=10)``; the
                filter expression (or an explicit ``(lo, hi)`` pair)
                compiles to the dense batch arrays the kernels expect.
                Filters compose with ``&`` *and* ``|``: disjunctions are
                planned (repro.api.planner) into one box-batched engine
                pass plus a segment-aware top-k merge. Each planned box
                is routed ONCE by the per-box cost model (annotated
                qualifying-row estimate -> dense masked scan / scaled-ef
                traversal / plain traversal; repro.core.selectivity) and
                every engine mode consumes the same decision — knobs and
                regime guidance in ``docs/tuning.md``.
  - dispatch  — an explicit ``mode`` ("auto" | "incore" | "hybrid" |
                "ooc"); ``"auto"`` picks from the declared
                ``device_budget_bytes``. All modes run the same
                traversal core (repro.core.runtime), differing only in
                the storage x graph-residency x seeding matrix:

                  mode    | vectors       | graph          | seeding
                  --------+---------------+----------------+-------------
                  incore  | fp32 resident | fully resident | fresh beam
                  hybrid  | int8 +rerank  | LRU cell cache | carried pool
                  ooc     | int8 +rerank  | streamed batch | carried pool

                ``shards=`` (an int or :class:`ShardSpec`) adds the
                orthogonal mesh tier: cells are placed across
                ``jax.devices()`` (balanced by resident bytes, hottest
                cells optionally replicated) and every mode above —
                including "auto" — runs per-shard, folding per-shard
                top-k through the same deterministic segment merge
                (repro.core.shard).

                Two knobs tune the streamed tiers: ``cache_policy``
                ("size_aware" byte-granular arena + cache-aware wave
                scheduling, or the legacy "fixed" slots) and ``rerank``
                ("device" fused gather->distance->top-k, or the "host"
                numpy loop — bit-identical ids either way).

  - mutate    — ``col.insert(vectors, attrs)`` routes new rows through
                the frozen quantile grid into bounded per-cell append
                buffers (immediately searchable — every query folds a
                brute-force scan of the few buffered rows into the
                engine's top-k); ``col.delete(ids)`` tombstones rows
                (the bitmap is ANDed into the predicate mask at query
                time, zero traversal change); ``col.flush()`` splices
                buffers into the cell-contiguous index (local graph
                link/rebuild + cross-cell edge repair, core.mutable);
                ``col.compact()`` reclaims tombstones by rebuilding on
                the surviving rows. An overflowing cell buffer flushes
                itself (cell maintenance).

  - persist   — ``col.save(path)`` / ``Collection.load(path)`` round-trip
                the entire built index, the chosen engine mode, device
                budget, cache policy, rerank path, pending append
                buffers, tombstones and the mutation epoch through one
                ``.npz`` file.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Callable, Mapping, Optional, Union

import numpy as np

from repro.api.planner import plan_queries
from repro.api.result import EngineStats, QueryResult
from repro.api.schema import AttrSchema
from repro.core import gmg as gmg_mod
from repro.core import mutable as mut_mod
# the engines own the valid knob-value sets; imported for validation
from repro.core.runtime import CACHE_POLICIES as _CACHE_POLICIES
from repro.core.runtime import RERANKS as _RERANKS
from repro.core.shard import ShardSpec
from repro.core.types import GMGConfig, GMGIndex, SearchParams
from repro.obs.export import write_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, span, tracing

# v4: + shard spec (mesh tier, ISSUE 9); v3: + append buffers,
# tombstones, mutation epoch (ISSUE 5); older files still load (v3 with
# no sharding, v2 with a fresh mutation state)
_FORMAT_VERSION = 4

# sentinel: Collection.load(shards=...) must distinguish "not given"
# (restore the saved spec) from an explicit None (disable sharding)
_UNSET = object()

# GMGIndex array fields persisted 1:1 (seg_bounds, being a list, is
# handled separately; None-able fields are skipped when absent).
_INDEX_ARRAYS = ("vectors", "attrs", "perm", "cell_of", "cell_start",
                 "cell_lo", "cell_hi", "intra_adj", "inter_adj",
                 "centroids", "hist", "attr_quantiles", "vq", "vscale")

_MODES = ("auto", "incore", "hybrid", "ooc")
# historical engine names accepted by Collection.search(engine=...);
# deprecated since the mesh-tier API redesign — use the canonical names
_MODE_ALIASES = {"in_core": "incore", "out_of_core": "ooc"}


def _canon_mode(mode: str) -> str:
    if mode in _MODE_ALIASES:
        import warnings
        canon = _MODE_ALIASES[mode]
        warnings.warn(
            f"engine mode {mode!r} is deprecated; use {canon!r}",
            DeprecationWarning, stacklevel=3)
        mode = canon
    if mode not in _MODES:
        raise ValueError(f"unknown engine mode {mode!r}; "
                         f"expected one of {_MODES}")
    return mode


@dataclasses.dataclass
class Collection:
    """A built, queryable, persistable vector collection."""

    index: GMGIndex
    schema: AttrSchema
    device_budget_bytes: Optional[int] = None
    mode: str = "auto"
    # hybrid graph-cache layout: "size_aware" (byte-granular slot arena +
    # cache-aware wave scheduling) | "fixed" (legacy largest-cell slots,
    # cache-blind waves — the PR-3 ablation baseline)
    cache_policy: str = "size_aware"
    # exact fp32 re-rank of the hybrid/ooc candidate pool: "device" (one
    # fused gather->distance->k-select program) | "host" (numpy loop);
    # both return bit-identical ids
    rerank: str = "device"
    # cell-maintenance bound: a cell holding more pending rows than this
    # flushes itself at the end of the insert() that overflowed it
    buffer_rows_per_cell: int = 256
    # mesh tier: None = single device; an int or ShardSpec shards cells
    # across jax.devices() and composes with every mode (incl. "auto") —
    # the one-seam convention, no parallel entry points
    shards: Union[None, int, ShardSpec] = None

    def __post_init__(self):
        if len(self.schema) != self.index.attrs.shape[1]:
            raise ValueError(
                f"schema has {len(self.schema)} attributes but index stores "
                f"{self.index.attrs.shape[1]}")
        self.mode = _canon_mode(self.mode)
        if self.cache_policy not in _CACHE_POLICIES:
            raise ValueError(f"unknown cache_policy {self.cache_policy!r}; "
                             f"expected one of {_CACHE_POLICIES}")
        if self.rerank not in _RERANKS:
            raise ValueError(f"unknown rerank {self.rerank!r}; "
                             f"expected one of {_RERANKS}")
        if int(self.buffer_rows_per_cell) < 1:
            raise ValueError("buffer_rows_per_cell must be >= 1")
        self.shards = ShardSpec.canon(self.shards)
        if self.shards is not None \
                and self.shards.n_shards > self.index.n_cells:
            raise ValueError(
                f"shards.n_shards={self.shards.n_shards} exceeds the "
                f"index's {self.index.n_cells} cells")
        self._in_core = None        # lazily-built Searcher
        self._hybrid = None         # lazily-built HybridEngine
        self._hybrid_key = None     # (budget, policy, rerank) it was built for
        self._out_of_core = None    # lazily-built OutOfCoreEngine
        self._out_of_core_key = None      # (budget, rerank) it was built for
        self._inv_perm = None       # lazily-built sorted-perm lookup
        self._mut = None            # MutationState, created on first use
        self._masked = None         # tombstone-masked engine index replica
        self._masked_epoch = -1     # mutation epoch the replica reflects
        self._sel_est = None        # per-cell selectivity estimator ...
        self._sel_est_for = None    # ... and the engine index it profiles
        self._sharded = None        # lazily-built ShardedEngine
        self._sharded_key = None    # (mode, spec, budget, policy, rerank)
        # typed per-pass counters (obs satellite, ISSUE 10): engines
        # report raw dicts (themselves views over their obs registries),
        # _execute_plan accumulates them and freezes one EngineStats per
        # pass; `last_stats` is the dict-compat adapter over it
        self._stats_acc: dict = {}
        self.engine_stats = EngineStats()
        # collection-level obs registry: search-pass + mutation-verb
        # lifetime counters (the per-engine work counters live in each
        # engine's own registry)
        self.metrics = MetricsRegistry()

    @property
    def last_stats(self) -> dict:
        """Raw stats dict of the last search pass — the one dict-compat
        adapter over the typed :class:`~repro.api.result.EngineStats`
        (``engine_stats``); keys are exactly what the engines reported."""
        return self.engine_stats.raw_dict()

    def _reset_stats(self) -> None:
        """Never report a previous batch's stats."""
        self._stats_acc = {}
        self.engine_stats = EngineStats()

    # -- lifecycle: build ---------------------------------------------------

    @classmethod
    def build(cls, vectors: np.ndarray,
              attrs: Union[np.ndarray, Mapping[str, np.ndarray]],
              schema: Optional[AttrSchema] = None,
              config: Optional[GMGConfig] = None, seed: int = 0,
              device_budget_bytes: Optional[int] = None,
              mode: str = "auto",
              shards: Union[None, int, ShardSpec] = None,
              verbose: bool = False) -> "Collection":
        """Build a collection from raw vectors + attributes.

        ``attrs`` is either an (n, m) array (column order = schema order)
        or a mapping name -> (n,) column; with a mapping the schema is
        optional and defaults to the mapping's key order. ``shards``
        (an int or a :class:`repro.core.shard.ShardSpec`) partitions the
        cells across the process's JAX devices.
        """
        vectors = np.asarray(vectors, np.float32)
        if isinstance(attrs, Mapping):
            if schema is None:
                schema = AttrSchema(list(attrs.keys()))
            cols = [np.asarray(attrs[name], np.float32) for name in schema]
            attr_arr = np.stack(cols, axis=1)
        else:
            attr_arr = np.asarray(attrs, np.float32)
            if schema is None:
                schema = AttrSchema.generic(attr_arr.shape[1])
        index = gmg_mod.build_gmg(vectors, attr_arr, config, seed=seed,
                                  verbose=verbose)
        return cls(index=index, schema=schema,
                   device_budget_bytes=device_budget_bytes, mode=mode,
                   shards=shards)

    # -- properties ---------------------------------------------------------

    @property
    def n(self) -> int:
        return self.index.n

    @property
    def dim(self) -> int:
        return self.index.dim

    def in_core_bytes(self) -> int:
        """Device footprint of the fully-resident in-core engine: fp32
        vectors + attrs + the graph twice (per-cell adjacency and the
        concatenated global adjacency) + the ordering sketch."""
        idx = self.index
        graph = idx.intra_adj.nbytes + idx.inter_adj.nbytes
        order = idx.centroids.nbytes + idx.hist.nbytes
        return (idx.vectors.nbytes + idx.attrs.nbytes + 2 * graph + order)

    def out_of_core_resident_bytes(self) -> int:
        """Always-resident part of the streaming/hybrid engines (int8
        copy + attrs)."""
        idx = self.index
        if idx.vq is None:
            return 0
        return idx.vq.nbytes + idx.vscale.nbytes + idx.attrs.nbytes

    def hybrid_min_bytes(self) -> int:
        """Smallest budget the hybrid mode is worth running under: the
        int8 residents plus a two-slot graph cache (one slot would
        re-upload on every wave and degenerate to streaming)."""
        from repro.core.runtime import cache_slot_bytes
        return (self.out_of_core_resident_bytes()
                + 2 * cache_slot_bytes(self.index))

    # -- engine dispatch ----------------------------------------------------

    def _resolve_engine(self, engine: Optional[str] = None) -> str:
        # re-canonicalize self.mode too: mutating col.mode after
        # construction is a supported pattern and may use legacy names
        mode = _canon_mode(engine if engine is not None else self.mode)
        if mode != "auto":
            if mode in ("hybrid", "ooc") and self.index.vq is None:
                raise ValueError(
                    f"mode {mode!r} needs a quantized copy; rebuild with "
                    "config.quantize=True")
            return mode
        budget = self.device_budget_bytes
        # the budget is per-device: a mesh of n shards holds ~1/n of the
        # in-core footprint each (replicated hot cells add a little)
        scale = 1 if self.shards is None else self.shards.n_shards
        if budget is None or self.in_core_bytes() // scale <= budget:
            return "incore"
        if self.index.vq is None:
            raise ValueError(
                "device budget excludes the in-core engine but the index "
                "has no quantized copy; rebuild with config.quantize=True")
        if self.out_of_core_resident_bytes() >= budget:
            raise ValueError(
                f"device budget {budget}B cannot hold even the quantized "
                f"residents ({self.out_of_core_resident_bytes()}B)")
        if budget >= self.hybrid_min_bytes():
            return "hybrid"
        return "ooc"

    def _engine_index(self) -> GMGIndex:
        """The index engines should run on: the pristine one, or (when
        rows are tombstoned) a shallow replica whose attrs mask deleted
        rows to NaN so no predicate can admit them."""
        mut = self._mut
        if mut is None or mut.tombstone is None or not mut.tombstone.any():
            return self.index
        if self._masked is None or self._masked_epoch != mut.epoch:
            self._masked = dataclasses.replace(
                self.index, attrs=mut_mod.masked_attrs(self.index,
                                                       mut.tombstone))
            self._masked_epoch = mut.epoch
        return self._masked

    def _estimator(self):
        """Per-cell attribute-histogram selectivity estimator over the
        current engine index (repro.core.selectivity); cached by index
        identity, so it rebuilds exactly when the rows it profiled
        change — flush/compact swap the index object, and the delete
        path swaps the tombstone-masked replica (NaN attr rows drop out
        of the histograms, keeping estimates live-row accurate)."""
        idx = self._engine_index()
        if self._sel_est is None or self._sel_est_for is not idx:
            from repro.core.selectivity import SelectivityEstimator
            self._sel_est = SelectivityEstimator(idx)
            self._sel_est_for = idx
        return self._sel_est

    def _plan_routes(self, plan, params: SearchParams, route_k=None):
        """Annotate ``plan`` with per-box qualifying-row estimates and
        compute the ONE RouteDecision every engine mode consumes (the
        tentpole contract: routing is planner-level, engines only
        execute it). Returns ``(annotated_plan, routes)``."""
        from repro.api import planner as planner_mod
        from repro.core import selectivity as sel_mod
        idx = self._engine_index()
        est = self._estimator()
        plan = planner_mod.annotate_plan(plan, idx, estimator=est)
        rk = (np.full(plan.n_queries, params.k, np.int64)
              if route_k is None else np.asarray(route_k, np.int64))
        routes = sel_mod.route_boxes(
            idx, plan.lo, plan.hi, rk[plan.qmap], cost=params.cost,
            estimator=est, est_rows=plan.est_rows)
        return plan, routes

    def _searcher(self):
        if self._in_core is None:
            from repro.core.search import Searcher
            self._in_core = Searcher(self._engine_index())
        return self._in_core

    def _hybrid_cache_budget(self) -> Optional[int]:
        """Bytes left for the hybrid graph cache after the int8
        residents (None = unbounded)."""
        if self.device_budget_bytes is None:
            return None
        return max(self.device_budget_bytes
                   - self.out_of_core_resident_bytes(), 1)

    def _hybrid_engine(self):
        # rebuilt when the declared budget / cache policy / rerank path
        # changes (the cell cache is sized and laid out at construction)
        key = (self.device_budget_bytes, self.cache_policy, self.rerank)
        if self._hybrid is None or self._hybrid_key != key:
            from repro.core.hybrid import HybridEngine
            self._hybrid = HybridEngine(
                self._engine_index(),
                cache_budget_bytes=self._hybrid_cache_budget(),
                cache_policy=self.cache_policy, rerank=self.rerank)
            self._hybrid_key = key
        return self._hybrid

    def _streamer(self):
        # rebuilt when the declared budget or rerank path changes (the
        # graph window is derived from the budget at construction)
        key = (self.device_budget_bytes, self.rerank)
        if self._out_of_core is None or self._out_of_core_key != key:
            from repro.core.pipeline import OutOfCoreEngine
            window = None
            if self.device_budget_bytes is not None:
                window = max(self.device_budget_bytes
                             - self.out_of_core_resident_bytes(), 1)
            self._out_of_core = OutOfCoreEngine(
                self._engine_index(), hbm_budget_bytes=window,
                rerank=self.rerank)
            self._out_of_core_key = key
        return self._out_of_core

    def _sharded_engine(self, which: str):
        # the mesh tier wraps whichever mode dispatch resolved: rebuilt
        # when the mode, spec, budget, cache policy or rerank changes
        key = (which, self.shards, self.device_budget_bytes,
               self.cache_policy, self.rerank)
        if self._sharded is None or self._sharded_key != key:
            from repro.core.shard import ShardedEngine
            self._sharded = ShardedEngine(
                self._engine_index(), self.shards, mode=which,
                device_budget_bytes=self.device_budget_bytes,
                cache_policy=self.cache_policy, rerank=self.rerank)
            self._sharded_key = key
        return self._sharded

    def _engine_for(self, which: str):
        if self.shards is not None:
            return self._sharded_engine(which)
        if which == "incore":
            return self._searcher()
        if which == "hybrid":
            return self._hybrid_engine()
        if which == "ooc":
            return self._streamer()
        raise ValueError(f"unresolved engine mode {which!r}")

    def plan(self, engine: Optional[str] = None) -> dict:
        """Introspect the dispatch decision under the current budget and
        mode (no search is run)."""
        which = self._resolve_engine(engine)
        # re-canonicalize: col.mode may have been mutated to a legacy name
        info = {"engine": which, "mode": _canon_mode(self.mode),
                "in_core_bytes": self.in_core_bytes(),
                "device_budget_bytes": self.device_budget_bytes}
        if which in ("hybrid", "ooc"):
            info["resident_bytes"] = self.out_of_core_resident_bytes()
            info["rerank"] = self.rerank
        if which == "hybrid":
            # the cache's own sizing rules, evaluated allocation-free —
            # introspection never builds the engine or its buffers
            from repro.core.runtime import (
                cache_row_bytes, cache_slot_bytes, cache_slot_rows,
                plan_cache_rows, plan_cache_slots)
            budget = self._hybrid_cache_budget()
            info["cache_policy"] = self.cache_policy
            if self.cache_policy == "size_aware":
                rows = plan_cache_rows(self.index, budget)
                info["cache_rows"] = rows
                info["cache_bytes"] = rows * cache_row_bytes(self.index)
                # largest-cell-slot equivalent, matching the engine's
                # own n_slots = cap_rows // slot_rows
                info["cache_slots"] = max(
                    1, rows // cache_slot_rows(self.index))
            else:
                n_slots = plan_cache_slots(self.index, budget)
                info["cache_slots"] = n_slots
                info["cache_bytes"] = n_slots * cache_slot_bytes(self.index)
        if which == "ooc":
            info["cells_per_batch"] = self._streamer().cells_per_batch()
        if self.shards is not None:
            # placement is a pure function of (index, spec) — introspect
            # it without building the per-shard engines
            import jax
            from repro.core.shard import plan_placement
            pl = plan_placement(self._engine_index(), self.shards)
            info["sharding"] = {
                "n_shards": self.shards.n_shards,
                "balance_by": self.shards.balance_by,
                "replicated_cells": int(pl.replicated.sum()),
                "owned_weight_balance": pl.balance(),
                "devices": min(self.shards.n_shards, len(jax.devices())),
            }
        mut = self._mut
        info["mutation_epoch"] = 0 if mut is None else mut.epoch
        info["pending_rows"] = 0 if mut is None else mut.pending_rows
        info["deleted_rows"] = 0 if mut is None else mut.deleted_rows
        info["oversized_cells"] = mut_mod.oversized_cells(self.index, mut)
        return info

    # -- streaming mutability (ISSUE 5; machinery in repro.core.mutable) ----

    def _mutation(self) -> "mut_mod.MutationState":
        if self._mut is None:
            self._mut = mut_mod.MutationState.fresh(self.index)
        return self._mut

    def live_count(self) -> int:
        """Rows a query can currently return: base rows minus tombstones
        plus pending buffered rows."""
        mut = self._mut
        if mut is None:
            return self.index.n
        return self.index.n - mut.deleted_rows + mut.pending_rows

    def _drop_engines(self) -> None:
        """Layout changed (flush/compact): every engine and cached view
        is stale and rebuilds lazily."""
        self._in_core = None
        self._hybrid = None
        self._hybrid_key = None
        self._out_of_core = None
        self._out_of_core_key = None
        self._inv_perm = None
        self._masked = None
        self._masked_epoch = -1
        self._sel_est = None
        self._sel_est_for = None
        self._sharded = None
        self._sharded_key = None

    def _refresh_engine_attrs(self) -> None:
        """Delete path: push the tombstone-masked attr table into every
        already-built engine in place — caches stay warm, nothing else
        re-uploads (the sharded engine slices the table per shard)."""
        replica = self._engine_index()
        for eng in (self._in_core, self._hybrid, self._out_of_core,
                    self._sharded):
            if eng is not None:
                eng.refresh_index(replica)

    def _perm_lookup(self):
        """(sorted original ids, internal rows in that order); cached —
        invalidated whenever the layout changes."""
        if self._inv_perm is None:
            order = np.argsort(self.index.perm, kind="stable")
            self._inv_perm = (self.index.perm[order], order)
        return self._inv_perm

    def insert(self, vectors: np.ndarray,
               attrs: Union[np.ndarray, Mapping[str, np.ndarray]]
               ) -> np.ndarray:
        """Add rows; returns their newly-assigned ids ((nb,) int64).

        Rows route through the frozen quantile grid into per-cell append
        buffers and are immediately searchable (the buffered few are
        brute-force folded into every query's top-k). A cell whose
        buffer exceeds ``buffer_rows_per_cell`` flushes itself before
        this call returns.
        """
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        if isinstance(attrs, Mapping):
            cols = [np.atleast_1d(np.asarray(attrs[name], np.float32))
                    for name in self.schema]
            attr_arr = np.stack(cols, axis=1)
        else:
            attr_arr = np.atleast_2d(np.asarray(attrs, np.float32))
        if vectors.shape[0] != attr_arr.shape[0]:
            raise ValueError(
                f"{vectors.shape[0]} vectors vs {attr_arr.shape[0]} "
                "attribute rows")
        if vectors.shape[1] != self.dim:
            raise ValueError(
                f"vector dim {vectors.shape[1]} != index dim {self.dim}")
        if attr_arr.shape[1] != len(self.schema):
            raise ValueError(
                f"{attr_arr.shape[1]} attribute columns vs schema of "
                f"{len(self.schema)}")
        with span("collection.insert", rows=int(vectors.shape[0])):
            mut = self._mutation()
            cells = mut_mod.route_rows(self.index, attr_arr)
            ids = mut.append(vectors, attr_arr, cells)
            self.metrics.counter("insert_rows").inc(int(vectors.shape[0]))
            # cell maintenance: flush any cell whose buffer overflowed
            counts = mut.pending_per_cell(self.index.n_cells)
            over = np.nonzero(counts > int(self.buffer_rows_per_cell))[0]
            if len(over):
                self.flush(cells=[int(c) for c in over])
        return ids

    def delete(self, ids) -> int:
        """Tombstone rows by original id; returns how many were newly
        deleted (already-deleted ids are a no-op, unknown ids raise).

        Base rows stay in the graph as navigation waypoints — their
        attrs read NaN on every engine, which no range admits, so they
        can never re-enter a result. Space is reclaimed by compact().
        """
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        if ids.size == 0:
            return 0
        mut = self._mutation()
        # classify every id BEFORE mutating anything, so a bad batch
        # raises without partially applying: never-allocated ids are the
        # only error; allocated-but-gone ids (tombstoned, previously
        # dropped from the buffer, or reclaimed by compact) are no-ops
        if ids.min() < 0 or ids.max() >= mut.next_id:
            bad = ids[(ids < 0) | (ids >= mut.next_id)]
            raise KeyError(f"unknown ids {bad[:8].tolist()}")
        with span("collection.delete", ids=int(ids.size)):
            in_buf = np.isin(ids, mut.buf_ids)
            rest = ids[~in_buf]
            sorted_ids, rows = self._perm_lookup()
            pos = np.searchsorted(sorted_ids, rest)
            in_base = (pos < len(sorted_ids)) & (sorted_ids[np.minimum(
                pos, len(sorted_ids) - 1)] == rest)
            # pending buffered rows: physically dropped, no engine change
            newly = int(in_buf.sum())
            if newly:
                mut.drop_buffered(~np.isin(mut.buf_ids, ids[in_buf]))
            if in_base.any():
                tomb = mut.ensure_tombstone(self.index.n)
                target = rows[pos[in_base]]
                fresh = ~tomb[target]
                if fresh.any():
                    tomb[target[fresh]] = True
                    newly += int(fresh.sum())
                    mut.epoch += 1
                    self._refresh_engine_attrs()
            self.metrics.counter("delete_rows").inc(newly)
        return newly

    def flush(self, cells=None, graph: str = "auto") -> int:
        """Splice pending buffered rows (of ``cells``, default all) into
        the cell-contiguous index: int8-quantized, linked into their
        cell's local graph (device-side batched greedy insert, or a
        local rebuild for large batches — ``graph``: "auto" | "greedy" |
        "rebuild"), cross-cell edges repaired for the touched cells.
        Returns the number of rows flushed."""
        mut = self._mut
        if mut is None or mut.pending_rows == 0:
            return 0
        if cells is None:
            sel = np.ones(mut.pending_rows, bool)
        else:
            sel = np.isin(mut.buf_cells, np.asarray(list(cells), np.int32))
        n_flush = int(sel.sum())
        if n_flush == 0:
            return 0
        with span("collection.flush", rows=n_flush):
            new_index, old_to_new = mut_mod.flush_index(
                self.index, mut.buf_vectors[sel], mut.buf_attrs[sel],
                mut.buf_ids[sel], mut.buf_cells[sel],
                seed=mut.epoch, graph_mode=graph)
            if mut.tombstone is not None:
                tomb2 = np.zeros(new_index.n, bool)
                tomb2[old_to_new] = mut.tombstone
                mut.tombstone = tomb2
            self.index = new_index
            mut.drop_buffered(~sel)
            mut.epoch += 1
            self._drop_engines()
            self.metrics.counter("flushes").inc()
            self.metrics.counter("flush_rows").inc(n_flush)
        return n_flush

    def compact(self, seed: int = 0) -> dict:
        """Reclaim tombstones and fold in any pending buffers by
        rebuilding on the surviving rows — behaviorally identical to a
        fresh build on them (same row order/config/seed), ids preserved.
        Also the rebalance point for cells that outgrew the cache
        arena's slot quantum. Returns a summary dict."""
        mut = self._mutation()
        dropped, pending = mut.deleted_rows, mut.pending_rows
        with span("collection.compact", reclaimed=dropped,
                  flushed=pending):
            self.index = mut_mod.compact_index(self.index, mut, seed=seed)
            mut.drop_buffered(np.zeros(mut.pending_rows, bool))
            mut.tombstone = None
            mut.epoch += 1
            self._drop_engines()
            self.metrics.counter("compacts").inc()
        return {"rows": self.index.n, "reclaimed": dropped,
                "flushed": pending, "epoch": mut.epoch}

    def _fold_buffer(self, q: np.ndarray, plan, ids: np.ndarray,
                     d: np.ndarray, k: int):
        """Fold the brute-force scan of pending buffered rows into the
        engine's per-query top-k — same deterministic segment merge the
        disjunctive planner uses, one extra candidate row per plan box."""
        mut = self._mut
        if mut is None or mut.pending_rows == 0 or plan.n_boxes == 0:
            return ids, d
        from repro.core.runtime import merge_segment_topk
        qrows = q if plan.trivial else q[plan.qmap]
        bi, bd = mut_mod.scan_buffer(mut, qrows, plan.lo, plan.hi, k)
        B = plan.n_queries
        all_ids = np.concatenate([ids, bi], axis=0)
        all_d = np.concatenate([d, bd], axis=0)
        qmap = np.concatenate([np.arange(B, dtype=np.int64), plan.qmap])
        self._stats_acc["buffered_rows"] = mut.pending_rows
        with span("collection.fold_buffer", rows=mut.pending_rows):
            return merge_segment_topk(all_ids, all_d, qmap, B, k)

    # -- observability ------------------------------------------------------

    @contextlib.contextmanager
    def trace(self, path: Optional[str] = None, *,
              sync: bool = False,
              clock: Callable[[], float] = time.perf_counter,
              tracer: Optional[Tracer] = None):
        """Record every span the stack emits for the duration of the
        block — engine waves, cache uploads, per-shard launches, buffer
        folds — and (with ``path``) write a Perfetto-loadable Chrome
        trace JSON on exit::

            with col.trace("results/trace/search.trace.json"):
                col.search(q, filters=F("price") <= 50)

        ``sync=True`` blocks on each span's attached device arrays at
        span close, attributing async device work to the span that
        launched it (slower, but the span tree then accounts for the
        true device timeline). ``clock`` injects a monotonic clock (the
        serving harness passes its ``VirtualClock``). Yields the
        :class:`~repro.obs.trace.Tracer` for programmatic inspection.
        See ``docs/observability.md``."""
        tr = tracer if tracer is not None else Tracer(clock=clock,
                                                      sync=sync)
        with tracing(tr):
            yield tr
        if path is not None:
            write_chrome_trace(tr, path)

    # -- search -------------------------------------------------------------

    def search(self, q: np.ndarray, filters=None, k: int = 10,
               ef: Optional[int] = None,
               params: Optional[SearchParams] = None,
               engine: Optional[str] = None) -> QueryResult:
        """Top-k range-filtered search over a query batch.

        ``filters`` is a filter expression (``F("price") <= 50``,
        and/or-composable: ``(F("price") < 10) | (F("price") > 90)``),
        an explicit ``(lo, hi)`` array pair, or None. ``params``
        overrides (k, ef) wholesale when given. ``engine`` overrides the
        collection's ``mode`` for this one batch ("incore" | "hybrid" |
        "ooc"; historical "in_core"/"out_of_core" accepted).

        Disjunctive filters go through the query planner: the whole
        batch's DNF boxes flatten into one widened engine pass (query
        vectors replicated per box) and a segment-aware top-k merge
        folds per-box candidates back to one row per query — never a
        per-box Python loop over the engine.
        """
        q = np.atleast_2d(np.asarray(q, np.float32))
        if params is None:
            params = SearchParams(k=k, ef=ef)
        which = self._resolve_engine(engine)
        self._reset_stats()
        B = q.shape[0]
        # plan before the empty-batch return so invalid filters (unknown
        # attribute, bad shapes, DNF blowup) raise regardless of B
        plan = plan_queries(filters, self.schema, B)
        if B == 0:
            return QueryResult.empty(params.k, engine=which)
        with span("collection.search", engine=which, rows=B, k=params.k):
            ids, d = self._execute_plan(q, plan, params, which)
        self.metrics.counter("searches").inc()
        return QueryResult(ids=ids, distances=d, engine=which,
                           stats=self.engine_stats)

    def _execute_plan(self, q: np.ndarray, plan, params: SearchParams,
                      which: str, route_k=None):
        """Run one planned batch on the resolved engine and fold pending
        buffers; accumulates engine/planner counters into ``last_stats``.

        The per-box cost model runs HERE, once: the plan is annotated
        with histogram-refined qualifying-row estimates
        (``planner.annotate_plan``) and routed
        (``selectivity.route_boxes``); every engine mode consumes the
        same ``RouteDecision``. ``route_k`` carries per-row request k's
        from coalesced multi-request passes so each row routes as its
        solo call would."""
        eng = self._engine_for(which)
        B = plan.n_queries
        if not plan.trivial:
            # box-batched disjunctive pass
            self._stats_acc["planner"] = dict(plan.stats)
            if plan.n_boxes == 0:     # every branch of every query is empty
                self.engine_stats = EngineStats.from_raw(self._stats_acc)
                return (np.full((B, params.k), -1, np.int64),
                        np.full((B, params.k), np.inf, np.float32))
        with span("collection.plan", boxes=plan.n_boxes):
            plan, routes = self._plan_routes(plan, params, route_k=route_k)
        if plan.trivial:
            ids, d = eng.search(q, plan.lo, plan.hi, params, routes=routes)
        else:
            ids, d = eng.search(q[plan.qmap], plan.lo, plan.hi, params,
                                qmap=plan.qmap, n_queries=B, routes=routes)
        self._stats_acc.update(eng.stats)
        ids, d = self._fold_buffer(q, plan, ids, d, params.k)
        # freeze the typed per-pass view AFTER the buffer fold so
        # buffered_rows (when any) is part of the reported keys
        self.engine_stats = EngineStats.from_raw(self._stats_acc)
        return ids, d

    def search_many(self, requests, ef: Optional[int] = None,
                    params: Optional[SearchParams] = None,
                    engine: Optional[str] = None) -> "list[QueryResult]":
        """Serve many independent requests as ONE widened engine pass.

        ``requests`` is a sequence of ``(q, filters, k)`` triples —
        heterogeneous filters (conjunctive and disjunctive mixed) and
        heterogeneous k's are fine. Each request is planned on its own,
        the plans concatenate (``planner.concat_plans``) into one
        cross-request box batch, the engine runs once at
        ``k = max over requests``, and the same segment-aware merge that
        folds a disjunction's boxes folds each request's rows back out.
        Returns one ``QueryResult`` per request, in order.

        On the in-core engine the returned ids are bit-identical to
        calling :meth:`search` once per request (the engine's
        batch-composition-independence contract; see
        ``repro.core.search``); the streamed modes (hybrid/ooc) schedule
        waves over the union incidence of the whole batch, so they match
        serial calls in recall but not necessarily id-for-id.
        """
        from repro.api.planner import concat_plans
        requests = [(np.atleast_2d(np.asarray(q, np.float32)), f, int(kk))
                    for (q, f, kk) in requests]
        which = self._resolve_engine(engine)
        self._reset_stats()
        if not requests:
            return []
        plans = [plan_queries(f, self.schema, q.shape[0])
                 for (q, f, _) in requests]
        plan, q_offsets = concat_plans(plans)
        q_all = np.concatenate([q for (q, _, _) in requests], axis=0)
        kmax = max(kk for (_, _, kk) in requests)
        if params is None:
            run_params = SearchParams(k=kmax, ef=ef)
        else:
            run_params = dataclasses.replace(params, k=kmax)
        if q_all.shape[0] == 0:
            return [QueryResult.empty(kk, engine=which)
                    for (_, _, kk) in requests]
        route_k = np.concatenate([np.full(q.shape[0], kk, np.int64)
                                  for (q, _, kk) in requests])
        # never let the trivial fast path skip the segment merge here: a
        # request's rows must come back (distance, id)-normalized exactly
        # as its solo disjunctive/buffered call would produce them
        if plan.trivial:
            plan = dataclasses.replace(plan, trivial=False)
        with span("collection.search_many", requests=len(requests),
                  engine=which, rows=int(q_all.shape[0])):
            ids, d = self._execute_plan(q_all, plan, run_params, which,
                                        route_k=route_k)
        self.metrics.counter("searches").inc()
        stats = self.engine_stats
        out = []
        for r, (_, _, kk) in enumerate(requests):
            s, e = int(q_offsets[r]), int(q_offsets[r + 1])
            out.append(QueryResult(ids=ids[s:e, :kk],
                                   distances=d[s:e, :kk],
                                   engine=which, stats=stats))
        return out

    def ground_truth(self, q: np.ndarray, filters=None,
                     k: int = 10) -> np.ndarray:
        """Exact answer ids for recall measurement (brute force).

        Disjunctive filters are served exactly as well: brute force per
        canonical box, folded with the same segment-aware merge the
        approximate path uses.
        """
        from repro.core.runtime import merge_segment_topk
        from repro.core.search import ground_truth
        q = np.atleast_2d(np.asarray(q, np.float32))
        B = q.shape[0]
        plan = plan_queries(filters, self.schema, B)
        v, a, id_of = self._live_view()
        if plan.trivial:
            ids, _ = ground_truth(v, a, q, plan.lo, plan.hi, k)
            return np.where(ids >= 0, id_of[np.maximum(ids, 0)], -1)
        if plan.n_boxes == 0:
            return np.full((B, k), -1, np.int64)
        ids, d = ground_truth(v, a, q[plan.qmap], plan.lo, plan.hi, k)
        ids = np.where(ids >= 0, id_of[np.maximum(ids, 0)], -1)
        ids, _ = merge_segment_topk(ids, d, plan.qmap, B, k)
        return ids

    def _live_view(self):
        """(vectors, attrs, original ids) over every live row — base
        rows minus tombstones plus pending buffers, in original-id
        order (== the pre-mutation original layout when untouched)."""
        return mut_mod.live_rows(self.index, self._mut)

    # -- lifecycle: persist -------------------------------------------------

    def save(self, path: str) -> None:
        """Serialize the built index + schema + engine-mode choice +
        mutation state (pending buffers, tombstones, epoch) to one
        ``.npz`` file."""
        idx = self.index
        payload = {}
        for name in _INDEX_ARRAYS:
            arr = getattr(idx, name)
            if arr is not None:
                payload[name] = np.asarray(arr)
        for i, b in enumerate(idx.seg_bounds):
            payload[f"seg_bounds_{i}"] = np.asarray(b)
        meta = {
            "format_version": _FORMAT_VERSION,
            "schema": list(self.schema.names),
            "config": dataclasses.asdict(idx.config),
            "n_seg_bounds": len(idx.seg_bounds),
            "mode": _canon_mode(self.mode),
            "device_budget_bytes": self.device_budget_bytes,
            "cache_policy": self.cache_policy,
            "rerank": self.rerank,
            "buffer_rows_per_cell": int(self.buffer_rows_per_cell),
        }
        if self.shards is not None:
            # v4: the shard spec rides along (hot_cells tuple -> list
            # for json; restored to a tuple on load)
            meta["shards"] = dataclasses.asdict(self.shards)
        mut = self._mut
        if mut is not None:
            meta["next_id"] = int(mut.next_id)
            meta["mutation_epoch"] = int(mut.epoch)
            if mut.pending_rows:
                payload["mut_buf_vectors"] = mut.buf_vectors
                payload["mut_buf_attrs"] = mut.buf_attrs
                payload["mut_buf_ids"] = mut.buf_ids
                payload["mut_buf_cells"] = mut.buf_cells
            if mut.tombstone is not None and mut.tombstone.any():
                payload["mut_tombstone"] = mut.tombstone.astype(np.uint8)
        payload["meta_json"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
        np.savez(path, **payload)

    @classmethod
    def load(cls, path: str,
             device_budget_bytes: Optional[int] = None,
             mode: Optional[str] = None,
             cache_policy: Optional[str] = None,
             rerank: Optional[str] = None,
             shards=_UNSET) -> "Collection":
        """Restore a collection saved by :meth:`save`.

        The saved engine mode, device budget, cache policy and rerank
        path are restored so the loaded collection rebuilds the same
        engine; pass ``device_budget_bytes`` / ``mode`` /
        ``cache_policy`` / ``rerank`` / ``shards`` to override (files
        written before these knobs existed load with today's defaults;
        ``shards=None`` explicitly disables a saved shard spec). v4
        files round-trip the shard spec; v3 files also restore the
        mutation state — pending append buffers, tombstones and the
        mutation epoch; v2 files load with a fresh one.
        """
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(bytes(z["meta_json"].tobytes()).decode())
            if meta["format_version"] > _FORMAT_VERSION:
                raise ValueError(
                    f"index file written by a newer format "
                    f"({meta['format_version']} > {_FORMAT_VERSION})")
            cfg_d = dict(meta["config"])
            cfg_d["seg_per_attr"] = tuple(cfg_d["seg_per_attr"])
            config = GMGConfig(**cfg_d)
            fields = {"config": config,
                      "seg_bounds": [z[f"seg_bounds_{i}"]
                                     for i in range(meta["n_seg_bounds"])]}
            for name in _INDEX_ARRAYS:
                fields[name] = z[name] if name in z.files else None
            index = GMGIndex(**fields)
            buf = {name: z[f"mut_{name}"] for name in
                   ("buf_vectors", "buf_attrs", "buf_ids", "buf_cells")
                   if f"mut_{name}" in z.files}
            tomb = (z["mut_tombstone"].astype(bool)
                    if "mut_tombstone" in z.files else None)
        if device_budget_bytes is None:
            device_budget_bytes = meta.get("device_budget_bytes")
        if mode is None:
            mode = meta.get("mode", "auto")
        if cache_policy is None:
            # pre-knob files load with today's dataclass defaults
            cache_policy = meta.get("cache_policy", cls.cache_policy)
        if rerank is None:
            rerank = meta.get("rerank", cls.rerank)
        if shards is _UNSET:
            saved = meta.get("shards")
            shards = None if saved is None else ShardSpec(
                n_shards=saved["n_shards"],
                replicate_hot=saved["replicate_hot"],
                balance_by=saved["balance_by"],
                hot_cells=(None if saved["hot_cells"] is None
                           else tuple(saved["hot_cells"])))
        col = cls(index=index, schema=AttrSchema(meta["schema"]),
                  device_budget_bytes=device_budget_bytes, mode=mode,
                  cache_policy=cache_policy, rerank=rerank,
                  buffer_rows_per_cell=meta.get("buffer_rows_per_cell",
                                                cls.buffer_rows_per_cell),
                  shards=shards)
        if "next_id" in meta or buf or tomb is not None:
            mut = col._mutation()
            mut.next_id = max(mut.next_id, int(meta.get("next_id", 0)))
            mut.epoch = int(meta.get("mutation_epoch", 0))
            if buf:
                mut.buf_vectors = buf["buf_vectors"].astype(np.float32)
                mut.buf_attrs = buf["buf_attrs"].astype(np.float32)
                mut.buf_ids = buf["buf_ids"].astype(np.int64)
                mut.buf_cells = buf["buf_cells"].astype(np.int32)
            if tomb is not None:
                mut.tombstone = tomb
        return col
