from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig  # noqa: F401
from repro.optim.schedules import cosine_with_warmup  # noqa: F401
