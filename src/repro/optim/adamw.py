"""AdamW over bf16 params with fp32 (or int8-compressed) moments.

Pure pytree implementation (no optax dependency). The int8 moment mode
halves-to-quarters optimizer HBM (per-tensor symmetric scales, the
8-bit-Adam recipe simplified to per-tensor blocks) — an option for the
memory-bound large archs; accuracy is validated in tests against fp32
moments on a small model.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_moments: bool = False


def _q8(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    return jnp.clip(jnp.rint(x / scale), -127, 127).astype(jnp.int8), scale


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def adamw_init(params, cfg: AdamWConfig):
    def one(p):
        if cfg.int8_moments:
            z8 = jnp.zeros(p.shape, jnp.int8)
            s = jnp.ones((), jnp.float32)
            return {"m": z8, "ms": s, "v": z8, "vs": s}
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}
    return {"mu": jax.tree.map(one, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(grads):
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state). grads may be bf16; math in f32."""
    count = state["count"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    def one(p, g, mu):
        g = g.astype(jnp.float32) * clip
        if cfg.int8_moments:
            m = cfg.b1 * _dq8(mu["m"], mu["ms"]) + (1 - cfg.b1) * g
            v = cfg.b2 * _dq8(mu["v"], mu["vs"]) + (1 - cfg.b2) * g * g
        else:
            m = cfg.b1 * mu["m"] + (1 - cfg.b1) * g
            v = cfg.b2 * mu["v"] + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 \
            else 0.0
        new_p = (p.astype(jnp.float32) - lr * (step + decay)).astype(p.dtype)
        if cfg.int8_moments:
            m8, ms = _q8(m)
            v8, vs = _q8(v)
            return new_p, {"m": m8, "ms": ms, "v": v8, "vs": vs}
        return new_p, {"m": m, "v": v}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    out = [one(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}
