"""mamba2-1.3b [arXiv:2405.21060] — attention-free SSD.

48L d_model=2048 vocab=50280 ssm_state=128, expand=2 (d_inner=4096,
64 heads of P=64). d_ff=0 (no FFN blocks). O(1) decode state ->
runs long_500k.
"""

from repro.models.lm import LayerSpec, LMConfig
from repro.models.ssm import SSMConfig

CONFIG = LMConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, vocab=50280, d_ff=0,
    pattern=(LayerSpec("ssm", ffn="none"),),
    ssm=SSMConfig(d_model=2048, d_state=128, head_dim=64, expand=2),
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="mamba2-reduced",
    n_layers=2, d_model=64, vocab=256, d_ff=0,
    pattern=(LayerSpec("ssm", ffn="none"),),
    ssm=SSMConfig(d_model=64, d_state=16, head_dim=16, expand=2, chunk=32),
    tie_embeddings=True,
)
