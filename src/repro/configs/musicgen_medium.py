"""musicgen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048. The EnCodec
frontend is a STUB: inputs are precomputed frame embeddings (B, T, D)
(embed_inputs=False); labels are codec token ids.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig

CONFIG = LMConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, vocab=2048, d_ff=6144,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=1536, n_heads=24, n_kv_heads=24, d_head=64),
    embed_inputs=False,
    tie_embeddings=False,
)

REDUCED = LMConfig(
    name="musicgen-reduced",
    n_layers=2, d_model=64, vocab=128, d_ff=160,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=4, d_head=16),
    embed_inputs=False,
    tie_embeddings=False,
)
