"""llama3.2-3b [hf:meta-llama/Llama-3.2-3B].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, tied embeddings.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig

CONFIG = LMConfig(
    name="llama3.2-3b",
    n_layers=28, d_model=3072, vocab=128256, d_ff=8192,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
                    rope_theta=500000.0),
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="llama3.2-reduced",
    n_layers=2, d_model=64, vocab=256, d_ff=160,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
    tie_embeddings=True,
)
