"""yi-6b [arXiv:2403.04652] — llama-arch GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig

CONFIG = LMConfig(
    name="yi-6b",
    n_layers=32, d_model=4096, vocab=64000, d_ff=11008,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=4096, n_heads=32, n_kv_heads=4, d_head=128,
                    rope_theta=5000000.0),
    tie_embeddings=False,
)

REDUCED = LMConfig(
    name="yi-reduced",
    n_layers=2, d_model=64, vocab=256, d_ff=160,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
    tie_embeddings=False,
)
