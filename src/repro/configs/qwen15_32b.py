"""qwen1.5-32b [hf:Qwen/Qwen1.5-* family].

64L d_model=5120 40H (MHA: kv=40) d_ff=27392 vocab=152064, QKV bias.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig

CONFIG = LMConfig(
    name="qwen1.5-32b",
    n_layers=64, d_model=5120, vocab=152064, d_ff=27392,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=5120, n_heads=40, n_kv_heads=40, d_head=128,
                    qkv_bias=True),
    tie_embeddings=False,
)

REDUCED = LMConfig(
    name="qwen1.5-reduced",
    n_layers=2, d_model=64, vocab=256, d_ff=160,
    pattern=(LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
                    qkv_bias=True),
    tie_embeddings=False,
)
