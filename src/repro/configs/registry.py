"""Architecture registry + assigned input shapes (--arch / --shape).

Shapes (LM family; seq_len x global_batch):
  train_4k     seq 4096,   batch 256   -> train_step
  prefill_32k  seq 32768,  batch 32    -> serve prefill
  decode_32k   cache 32768, batch 128  -> serve decode (1 new token)
  long_500k    cache 524288, batch 1   -> long-context decode

long_500k needs sub-quadratic attention: runs for mamba2 (SSM),
recurrentgemma (hybrid) and gemma3 (5/6 sliding-window layers); skipped
for pure full-attention archs (documented in DESIGN.md §Arch-applic.).
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

ARCHS = {
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "deepseek-v3-671b": "repro.configs.deepseek_v3",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "yi-6b": "repro.configs.yi_6b",
    "llama3.2-3b": "repro.configs.llama32_3b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
}

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

_SUBQUADRATIC = {"mamba2-1.3b", "recurrentgemma-2b", "gemma3-4b"}


def get_config(arch: str):
    return importlib.import_module(ARCHS[arch]).CONFIG


def get_reduced(arch: str):
    return importlib.import_module(ARCHS[arch]).REDUCED


def shape_applicable(arch: str, shape: str) -> bool:
    """long_500k only for sub-quadratic archs; no encoder-only archs."""
    if shape == "long_500k":
        return arch in _SUBQUADRATIC
    return True


def input_specs(arch: str, shape: str, cfg=None):
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape).

    Returns (kind, dict). For train: tokens/embeds + labels (+ ctx).
    For prefill: prompt inputs. For decode: one-token inputs (the KV/state
    caches are built separately — see launch/dryrun.py)."""
    cfg = cfg or get_config(arch)
    spec = SHAPES[shape]
    B, T = spec["batch"], spec["seq"]
    sds = jax.ShapeDtypeStruct
    out = {}
    if spec["kind"] in ("train", "prefill"):
        if cfg.embed_inputs:
            out["tokens"] = sds((B, T), jnp.int32)
        else:
            out["embeds"] = sds((B, T, cfg.d_model), cfg.dtype)
        if spec["kind"] == "train":
            out["labels"] = sds((B, T), jnp.int32)
    else:  # decode: one new token against a seq-long cache
        if cfg.embed_inputs:
            out["token"] = sds((B, 1), jnp.int32)
        else:
            out["token"] = sds((B, 1, cfg.d_model), cfg.dtype)
    if cfg.d_ctx > 0:
        out["ctx"] = sds((B, cfg.n_ctx_tokens, cfg.d_ctx), cfg.dtype)
    return spec["kind"], out


def all_cells():
    """Every (arch, shape) pair in the assignment — 40 total, of which
    the inapplicable long_500k cells are flagged skip."""
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            cells.append((arch, shape, shape_applicable(arch, shape)))
    return cells
