"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert vocab=32064,
MoE 16 experts top-2, every layer MoE.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig
from repro.models.moe import MoEConfig

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, vocab=32064,
    pattern=(LayerSpec("attn", ffn="moe"),),
    attn=AttnConfig(d_model=4096, n_heads=32, n_kv_heads=8, d_head=128),
    moe=MoEConfig(d_model=4096, n_experts=16, top_k=2, d_ff=6400),
    tie_embeddings=False,
)

REDUCED = LMConfig(
    name="phi3.5-moe-reduced",
    n_layers=2, d_model=64, vocab=256,
    pattern=(LayerSpec("attn", ffn="moe"),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
    moe=MoEConfig(d_model=64, n_experts=4, top_k=2, d_ff=96),
    tie_embeddings=False,
)
