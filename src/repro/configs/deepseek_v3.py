"""deepseek-v3-671b [arXiv:2412.19437].

61L d_model=7168 128H MLA (q_lora 1536, kv_lora 512, rope 64, nope 128,
v 128), vocab=129280. First 3 layers dense FFN (d_ff=18432); remaining 58
layers MoE: 1 shared + 256 routed experts (d_ff=2048 each), top-8,
aux-free sigmoid routing. MTP depth 1.
"""

from repro.models.lm import LayerSpec, LMConfig
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, vocab=129280, d_ff=18432,
    prefix=(LayerSpec("mla", ffn="dense"),) * 3,
    pattern=(LayerSpec("mla", ffn="moe"),),
    mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                  kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
    moe=MoEConfig(d_model=7168, n_experts=256, top_k=8, d_ff=2048,
                  n_shared=1, d_ff_shared=2048, routing="sigmoid_topk"),
    tie_embeddings=False,
    mtp_depth=1,
)

REDUCED = LMConfig(
    name="deepseek-v3-reduced",
    n_layers=3, d_model=64, vocab=256, d_ff=128,
    prefix=(LayerSpec("mla", ffn="dense"),) * 1,
    pattern=(LayerSpec("mla", ffn="moe"),),
    mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16),
    moe=MoEConfig(d_model=64, n_experts=8, top_k=2, d_ff=32, n_shared=1,
                  d_ff_shared=32, routing="sigmoid_topk"),
    tie_embeddings=False,
    mtp_depth=1,
)
