"""recurrentgemma-2b [arXiv:2402.19427 Griffin].

26L d_model=2560 10H (MQA kv=1, d_head=256) d_ff=7680 vocab=256000.
Temporal pattern 2 recurrent (RG-LRU) : 1 local attention (window 2048).
Constant-size recurrent state -> runs long_500k.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig
from repro.models.rglru import RGLRUConfig

_R = LayerSpec("rglru", ffn="dense")
_A = LayerSpec("attn", ffn="dense", window=2048)

CONFIG = LMConfig(
    name="recurrentgemma-2b",
    n_layers=26, d_model=2560, vocab=256000, d_ff=7680,
    pattern=(_R, _R, _A),
    attn=AttnConfig(d_model=2560, n_heads=10, n_kv_heads=1, d_head=256),
    rglru=RGLRUConfig(d_model=2560, d_rnn=2560),
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="recurrentgemma-reduced",
    n_layers=3, d_model=64, vocab=256, d_ff=160,
    pattern=(LayerSpec("rglru", ffn="dense"),
             LayerSpec("rglru", ffn="dense"),
             LayerSpec("attn", ffn="dense", window=32)),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=1, d_head=16),
    rglru=RGLRUConfig(d_model=64, d_rnn=64),
    tie_embeddings=True,
)
