from repro.configs.registry import (  # noqa: F401
    ARCHS, SHAPES, get_config, get_reduced, input_specs, shape_applicable)
