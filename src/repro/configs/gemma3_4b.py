"""gemma3-4b [hf:google/gemma-3-4b-pt].

34L d_model=2560 8H (GQA kv=4, d_head=256) d_ff=10240 vocab=262144.
5:1 local(1024-window):global attention interleave; 128k context.
Sub-quadratic-dominant (5/6 layers have O(window) KV) -> runs long_500k.
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig

_LOCAL = LayerSpec("attn", ffn="dense", window=1024)
_GLOBAL = LayerSpec("attn", ffn="dense", window=None)

CONFIG = LMConfig(
    name="gemma3-4b",
    n_layers=34, d_model=2560, vocab=262144, d_ff=10240,
    pattern=(_LOCAL,) * 5 + (_GLOBAL,),
    attn=AttnConfig(d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
                    rope_theta=1000000.0),
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="gemma3-reduced",
    n_layers=6, d_model=64, vocab=256, d_ff=160,
    pattern=(LayerSpec("attn", ffn="dense", window=32),) * 5
    + (LayerSpec("attn", ffn="dense"),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
    tie_embeddings=True,
)
