"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer
carries a gated cross-attention sublayer over vision patch embeddings
(frontend STUB: ctx = precomputed patch embeddings (B, 1024, 4096)).
"""

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig

_S = LayerSpec("attn", ffn="dense")
_X = LayerSpec("attn", ffn="dense", cross_attn=True)

CONFIG = LMConfig(
    name="llama-3.2-vision-11b",
    n_layers=40, d_model=4096, vocab=128256, d_ff=14336,
    pattern=(_S, _S, _S, _S, _X),
    attn=AttnConfig(d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
                    rope_theta=500000.0),
    d_ctx=4096, n_ctx_tokens=1024,
    tie_embeddings=False,
)

REDUCED = LMConfig(
    name="llama-vision-reduced",
    n_layers=5, d_model=64, vocab=256, d_ff=160,
    pattern=(LayerSpec("attn", ffn="dense"),) * 4
    + (LayerSpec("attn", ffn="dense", cross_attn=True),),
    attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
    d_ctx=64, n_ctx_tokens=16,
    tie_embeddings=False,
)
