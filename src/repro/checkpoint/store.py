"""Fault-tolerant sharded checkpointing.

Layout per step:
  <dir>/step_<n>/shard_<host>.npz     flat {path: local shard array}
  <dir>/step_<n>/META.json            logical shapes/dtypes + mesh + specs
  <dir>/step_<n>/COMMITTED            empty marker, written LAST

Crash safety: restore only considers directories with the COMMITTED
marker (a torn write never becomes a restore candidate). Elastic
reshard: arrays are saved as *logical* (unsharded) values with their
logical-axis names; restore re-shards onto whatever mesh/rules the new
job brings up — a checkpoint written on (16,16) restores onto (2,16,16)
or a single CPU. On real multi-host fleets each host writes only its
addressable shards; here (single-process) host 0 writes everything, but
the format and commit protocol are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import ml_dtypes
import numpy as np

# numpy can't natively savez bfloat16/fp8 — store them as same-width
# unsigned views and reinterpret on load from META's logical dtype.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat):
    def rebuild(t, prefix=""):
        if isinstance(t, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, list):
            return [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(t)]
        if isinstance(t, tuple):
            return tuple(rebuild(v, f"{prefix}{i}/")
                         for i, v in enumerate(t))
        return flat[prefix[:-1]]
    return rebuild(template)


def save_checkpoint(ckpt_dir: str, step: int, state, host: int = 0):
    """Write state (pytree of arrays) with commit marker."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in arrays.items()}
    stored = {k: (v.view(_EXOTIC[str(v.dtype)][1])
                  if str(v.dtype) in _EXOTIC else v)
              for k, v in arrays.items()}
    np.savez(os.path.join(tmp, f"shard_{host}.npz"), **stored)
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump({"step": step, "arrays": meta}, f)
    if os.path.exists(d):
        shutil.rmtree(d)
    os.replace(tmp, d)
    # commit marker LAST: restore ignores uncommitted step dirs
    with open(os.path.join(d, "COMMITTED"), "w"):
        pass
    return d


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(ckpt_dir: str, template, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``template``. ``shardings``: optional
    parallel tree of NamedShardings — the elastic-reshard path (arrays are
    device_put with the *new* sharding regardless of the mesh they were
    saved under)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "META.json")) as f:
        meta = json.load(f)["arrays"]
    flat = {}
    for name in sorted(os.listdir(d)):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(d, name)) as z:
                for k in z.files:
                    arr = z[k]
                    logical = meta.get(k, {}).get("dtype", str(arr.dtype))
                    if logical in _EXOTIC:
                        arr = arr.view(_EXOTIC[logical][0])
                    flat[k] = arr
    state = _unflatten_into(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, step
