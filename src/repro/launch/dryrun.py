import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: build ShapeDtypeStruct
inputs, resolve shardings from logical axes, ``jit(...).lower().compile()``
on the production mesh, and record memory/cost/collective-schedule
analysis for the roofline (launch/roofline.py reads the JSON this writes).

The two XLA_FLAGS lines above MUST precede any jax import: jax locks the
device count on first backend init. Do not set this flag globally —
tests/benches must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import registry
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.train import step as train_step_mod

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the compiled HLO.

    Each line looks like:  %x = f32[..]{..} all-reduce(...), replica_groups=…
    For tuple-shaped fused collectives, all element shapes count.
    These are per-*shard* logical bytes — roofline divides by link BW.
    """
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if f"{op}-done(" in rhs:
            continue  # counted at -start
        type_part = rhs.split(op)[0]
        b = _shape_bytes(type_part)
        out[op]["bytes"] += b
        out[op]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def sharded_bytes(shapes_tree, shardings_tree, mesh) -> int:
    """Per-device resident bytes of a (shapes, shardings) tree."""
    total = 0
    flat_s = jax.tree.leaves(shapes_tree)
    flat_h = jax.tree.leaves(
        shardings_tree, is_leaf=lambda x: hasattr(x, "spec"))
    for sds, sh in zip(flat_s, flat_h):
        n = int(np.prod(sds.shape)) if sds.shape else 1
        shard = sh.num_devices_sharded_over(sds.shape) \
            if hasattr(sh, "num_devices_sharded_over") else None
        if shard is None:
            # compute shard factor from the spec
            factor = 1
            for dim, entry in zip(sds.shape,
                                  tuple(sh.spec) + (None,) * len(sds.shape)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                factor *= int(np.prod([mesh.shape[a] for a in axes]))
            shard = factor
        total += (n // max(shard, 1)) * sds.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

_FSDP = False      # set by --fsdp: ZeRO-3 param sharding for huge archs
_INT8_OPT = False  # set by --int8-opt: 8-bit AdamW moments


def _rules_for(shape_name: str):
    from repro.dist import lm_rules
    if shape_name == "train_4k":
        return lm_rules.FSDP_TRAIN_RULES if _FSDP else lm_rules.TRAIN_RULES
    return lm_rules.DECODE_RULES


def _axes_to_shardings(shapes, axes, mesh, rules):
    return jax.tree.map(
        lambda sds, ax: shd.sharding_for(sds.shape, ax, mesh, rules),
        shapes, axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def build_cell(arch: str, shape_name: str, mesh, *, reduced: bool = False,
               remat: bool = True, tcfg=None, cfg_override=None):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings,
    out_shardings, cfg, resident_bytes) for one dry-run cell.
    cfg_override: roofline's depth variants swap in a modified config."""
    cfg = cfg_override if cfg_override is not None else (
        registry.get_reduced(arch) if reduced else registry.get_config(arch))
    kind, inputs = registry.input_specs(arch, shape_name, cfg)
    rules = _rules_for(shape_name)
    spec = registry.SHAPES[shape_name]
    B = spec["batch"]

    in_batch_shard = {}
    for k, sds in inputs.items():
        in_batch_shard[k] = shd.batch_sharding(mesh, sds.shape[0])

    if kind == "train":
        if tcfg is None:
            from repro.optim import AdamWConfig
            tcfg = train_step_mod.TrainConfig(
                remat=remat, opt=AdamWConfig(int8_moments=_INT8_OPT))
        fn = train_step_mod.make_train_step(cfg, tcfg)
        state_sh, state_ax = train_step_mod.state_shapes(cfg, tcfg)
        is_ax = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        p_shard = jax.tree.map(
            lambda sds, ax: shd.sharding_for(sds.shape, ax, mesh, rules),
            state_sh["params"], state_ax["params"], is_leaf=is_ax)
        mu_shard = jax.tree.map(
            lambda sds, ax: shd.zero1_sharding(sds.shape, ax, mesh, rules),
            state_sh["opt"]["mu"], state_ax["opt"]["mu"], is_leaf=is_ax)
        state_shard = {"params": p_shard,
                       "opt": {"mu": mu_shard,
                               "count": shd.replicated(mesh)},
                       "step": shd.replicated(mesh)}
        in_sh = (state_shard, in_batch_shard)
        out_sh = (state_shard, None)
        args = (state_sh, inputs)
        resident = (sharded_bytes(state_sh["params"], p_shard, mesh)
                    + sharded_bytes(state_sh["opt"]["mu"], mu_shard, mesh))
        return fn, args, in_sh, out_sh, cfg, resident

    # serving paths need param + cache shapes
    specs = lm.lm_specs(cfg)
    from repro.models.common import param_logical_axes, param_shapes
    p_shapes = param_shapes(specs)
    p_axes = param_logical_axes(specs)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    p_shard = jax.tree.map(
        lambda sds, ax: shd.sharding_for(sds.shape, ax, mesh, rules),
        p_shapes, p_axes, is_leaf=is_ax)

    max_seq = spec["seq"]
    cache_sh = jax.eval_shape(
        lambda: lm.init_caches(cfg, B, max_seq))
    cache_ax = lm.cache_logical_axes(cfg)
    c_shard = jax.tree.map(
        lambda sds, ax: shd.sharding_for(sds.shape, ax, mesh, rules),
        cache_sh, cache_ax, is_leaf=is_ax)
    resident = (sharded_bytes(p_shapes, p_shard, mesh)
                + sharded_bytes(cache_sh, c_shard, mesh))

    if kind == "prefill":
        fn0 = train_step_mod.make_serve_prefill(cfg, max_seq)
        def fn(params, batch, caches):
            return fn0(params, batch, caches)
        logits_shard = shd.batch_sharding(mesh, B)
        in_sh = (p_shard, in_batch_shard, c_shard)
        out_sh = (logits_shard, c_shard)
        args = (p_shapes, inputs, cache_sh)
        return fn, args, in_sh, out_sh, cfg, resident

    # decode
    fn0 = train_step_mod.make_serve_decode(cfg)
    token = inputs.pop("token")
    ctx = inputs.pop("ctx", None)
    logits_shard = shd.batch_sharding(mesh, B)
    if ctx is not None:
        def fn(params, token, caches, ctx):
            return fn0(params, token, caches, ctx=ctx)
        in_sh = (p_shard, shd.batch_sharding(mesh, B), c_shard,
                 shd.batch_sharding(mesh, B))
        args = (p_shapes, token, cache_sh, ctx)
    else:
        def fn(params, token, caches):
            return fn0(params, token, caches)
        in_sh = (p_shard, shd.batch_sharding(mesh, B), c_shard)
        args = (p_shapes, token, cache_sh)
    out_sh = (logits_shard, c_shard)
    return fn, args, in_sh, out_sh, cfg, resident


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             reduced: bool = False, save: bool = True,
             remat: bool = True, tag: str = "") -> dict:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi else "16x16",
           "n_devices": int(np.prod(list(mesh.shape.values())))}
    if not registry.shape_applicable(arch, shape_name):
        rec["status"] = "skip"
        rec["reason"] = "long_500k needs sub-quadratic attention " \
                        "(documented in DESIGN.md)"
        return _save(rec, tag) if save else rec
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, cfg, resident = build_cell(
            arch, shape_name, mesh, reduced=reduced, remat=remat)
        with mesh, shd.activation_rules(mesh, _rules_for(shape_name)):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["status"] = "ok"
        rec["lower_seconds"] = round(t1 - t0, 1)
        rec["compile_seconds"] = round(t2 - t1, 1)
        rec["resident_bytes_per_device"] = int(resident)
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k)) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # pragma: no cover
            rec["memory_analysis"] = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            rec["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if k in ("flops", "bytes accessed", "transcendentals",
                         "utilization operand 0 {}", "optimal_seconds")
                or k.startswith("bytes accessed")}
        except Exception as e:  # pragma: no cover
            rec["cost_analysis"] = {"error": str(e)}
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        n_params = _count_params(cfg)
        rec["n_params"] = n_params
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_seconds"] = round(time.time() - t0, 1)
    return _save(rec, tag) if save else rec


def _count_params(cfg) -> int:
    from repro.models.common import count_params
    return count_params(lm.lm_specs(cfg))


def _save(rec: dict, tag: str = "") -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    choices=["all"] + list(registry.ARCHS))
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(registry.SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    global _FSDP, _INT8_OPT
    _FSDP = args.fsdp
    _INT8_OPT = args.int8_opt

    archs = list(registry.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(registry.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name, reduced=args.reduced,
                               remat=not args.no_remat, tag=args.tag)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    fl = rec.get("cost_analysis", {}).get("flops", 0)
                    cb = rec.get("collectives", {}).get("total_bytes", 0)
                    extra = (f" flops={fl:.3g} coll={cb / 1e6:.1f}MB"
                             f" compile={rec['compile_seconds']}s")
                elif status == "fail":
                    extra = " " + rec.get("error", "")[:120]
                print(f"[{status:4s}] {arch} x {shape} x {rec['mesh']}"
                      f"{extra}", flush=True)


if __name__ == "__main__":
    main()
