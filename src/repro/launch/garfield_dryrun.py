import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Garfield-at-scale dry-run: the paper's own technique on the production
mesh (DESIGN.md §5 'Garfield at scale').

Placement: GMG cells shard round-robin over the `model` axis (each chip
is resident for S/16 cells: vectors int8 + graph), queries shard over
(`pod`,) `data`. One serve step, shard_map'd:

  1. every chip runs the sequential cell traversal over ITS resident
     cells for ITS query shard (the per-host Alg. 5 batch = the resident
     shard; itinerary masks non-selected cells),
  2. per-query candidates all-gather over `model` (16 shards x k ids),
  3. top-k merge -> global answer.

This is the multi-host generalization of the paper's batch model: "batch"
becomes "resident shard", entry propagation stays intra-shard, and the
cross-shard merge is one all-gather of k candidates — NOT the index.

Usage:
  PYTHONPATH=src python -m repro.launch.garfield_dryrun [--mesh single]
      [--n-per-shard 4194304] [--batch 4096] [--tag _opt]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = dr.RESULTS_DIR


def garfield_step_fn(mesh, *, k: int, ef: int, n_local: int,
                     s_local: int, dim: int, m_attrs: int,
                     packed_visited: bool = False):
    """Builds the shard_map'd serve step. packed_visited: bit-packed
    (B, n/32) uint32 visited words instead of byte-wide bools — 8x less
    per-query traversal state (the dominant live memory at fleet scale;
    §Perf garfield iteration)."""
    from repro.core import traversal as tv

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def local_search(vq, vscale, attrs, adj, inter, cell_start, rows,
                     q, lo, hi, order, seed):
        raw = tv.multi_cell_search_seeded.__wrapped__
        ids, d = raw(vq, vscale, attrs, adj, inter, cell_start, rows,
                     q, lo, hi, order, seed,
                     jax.random.PRNGKey(0),
                     k=k, ef=ef, entry_width=16, entry_random=4,
                     entry_beam_l=8, max_iters=96,
                     packed_visited=packed_visited)
        # local ids -> global ids via the shard offset
        shard = jax.lax.axis_index("model")
        gids = jnp.where(ids >= 0, ids + shard * n_local, -1)
        # merge across the model axis: (16, B, k) -> top-k
        all_ids = jax.lax.all_gather(gids, "model")        # (M, B, k)
        all_d = jax.lax.all_gather(d, "model")
        M = all_ids.shape[0]
        B = all_ids.shape[1]
        flat_i = jnp.transpose(all_ids, (1, 0, 2)).reshape(B, M * k)
        flat_d = jnp.transpose(all_d, (1, 0, 2)).reshape(B, M * k)
        neg, pos = jax.lax.top_k(-flat_d, k)
        out_i = jnp.take_along_axis(flat_i, pos, axis=1)
        return out_i, -neg

    in_specs = (
        P("model", None),       # vq         (n_local*M, d) -> local rows
        P("model"),             # vscale
        P("model", None),       # attrs
        P("model", None),       # adj
        P("model", None, None),  # inter
        P(None),                # cell_start (replicated, local offsets)
        P("model"),             # rows (identity map local->local here)
        P(data_axes, None),     # q
        P(data_axes, None),     # lo
        P(data_axes, None),     # hi
        P(data_axes, None),     # order
        P(data_axes, None),     # seed
    )
    out_specs = (P(data_axes, None), P(data_axes, None))

    fn = jax.shard_map(local_search, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn


def input_structs(mesh, *, n_per_shard: int, batch: int, s_local: int,
                  dim: int, m_attrs: int, intra_deg: int, inter_l: int,
                  ef: int):
    M = mesh.shape["model"]
    n_total = n_per_shard * M
    sds = jax.ShapeDtypeStruct
    return dict(
        vq=sds((n_total, dim), jnp.int8),
        vscale=sds((n_total,), jnp.float32),
        attrs=sds((n_total, m_attrs), jnp.float32),
        adj=sds((n_total, intra_deg), jnp.int32),
        inter=sds((n_total, s_local, inter_l), jnp.int32),
        cell_start=sds((s_local + 1,), jnp.int32),
        rows=sds((n_total,), jnp.int32),
        q=sds((batch, dim), jnp.float32),
        lo=sds((batch, m_attrs), jnp.float32),
        hi=sds((batch, m_attrs), jnp.float32),
        order=sds((batch, s_local), jnp.int32),
        seed=sds((batch, ef), jnp.int32),
    )


def run(mesh_name: str = "single", *, n_per_shard: int = 1 << 22,
        batch: int = 4096, s_local: int = 1, dim: int = 128,
        m_attrs: int = 4, k: int = 10, ef: int = 64, intra_deg: int = 16,
        inter_l: int = 2, save: bool = True, tag: str = "",
        packed_visited: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rec = {"arch": "garfield", "shape": f"serve_n{n_per_shard}x{batch}q",
           "mesh": "2x16x16" if mesh_name == "multi" else "16x16",
           "packed_visited": packed_visited}
    t0 = time.time()
    try:
        fn = garfield_step_fn(mesh, k=k, ef=ef, n_local=n_per_shard,
                              s_local=s_local, dim=dim, m_attrs=m_attrs,
                              packed_visited=packed_visited)
        structs = input_structs(mesh, n_per_shard=n_per_shard, batch=batch,
                                s_local=s_local, dim=dim, m_attrs=m_attrs,
                                intra_deg=intra_deg, inter_l=inter_l, ef=ef)
        with mesh:
            jitted = jax.jit(fn)
            lowered = jitted.lower(*structs.values())
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec.update(
            status="ok",
            compile_seconds=round(time.time() - t0, 1),
            cost_analysis={k_: float(v) for k_, v in cost.items()
                           if k_ in ("flops", "bytes accessed")},
            collectives=dr.collective_bytes(compiled.as_text()),
        )
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                kk: int(getattr(mem, kk)) for kk in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes")
                if hasattr(mem, kk)}
        except Exception as e:
            rec["memory_analysis"] = {"error": str(e)}
        # resident accounting (per model shard)
        resident = (n_per_shard * (dim + 4 + m_attrs * 4 + intra_deg * 4
                                   + s_local * inter_l * 4 + 4))
        rec["resident_bytes_per_device"] = int(resident)
    except Exception as e:
        import traceback
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_seconds"] = round(time.time() - t0, 1)
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(
                RESULTS_DIR,
                f"garfield_{rec['shape']}_{rec['mesh']}{tag}.json"),
                "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--n-per-shard", type=int, default=1 << 22)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--packed-visited", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rec = run(args.mesh, n_per_shard=args.n_per_shard, batch=args.batch,
              tag=args.tag, packed_visited=args.packed_visited)
    if rec["status"] == "ok":
        print(f"[ok  ] garfield x {rec['shape']} x {rec['mesh']} "
              f"flops={rec['cost_analysis'].get('flops', 0):.3g} "
              f"coll={rec['collectives']['total_bytes'] / 1e6:.1f}MB "
              f"compile={rec['compile_seconds']}s")
    else:
        print(f"[fail] {rec['error']}\n{rec.get('traceback', '')[-800:]}")


if __name__ == "__main__":
    main()
