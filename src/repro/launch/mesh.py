"""Production meshes. A FUNCTION (not module-level constant) so importing
never touches jax device state — required for the dry-run's forced
512-device host platform to initialize first."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:   (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
