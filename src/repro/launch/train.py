"""Training entry point.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 50 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt]

Full (non-reduced) configs at production shapes are exercised through the
dry-run (this host has one CPU device); --reduced trains for real.
"""

from __future__ import annotations

import argparse
import logging

from repro.configs import registry
from repro.data.tokens import TokenPipeline
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, run
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} trains on frontend embeddings; use "
                         "the dry-run for its production shapes")
    tcfg = TrainConfig(opt=AdamWConfig(lr=args.lr,
                                       int8_moments=args.int8_moments),
                       grad_accum=args.grad_accum,
                       peak_lr=args.lr, total_steps=args.steps,
                       remat=not args.reduced)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=5)
    state, hist = run(cfg, tcfg, loop, pipe)
    if hist:
        print(f"final loss: {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
