"""Serving entry point: batched generation on a (reduced) arch.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import registry
from repro.models import lm
from repro.models.common import init_params
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} decodes over frontend embeddings; "
                         "see examples for the stub-frontend path")
    params = init_params(lm.lm_specs(cfg), jax.random.PRNGKey(args.seed))
    eng = Engine(params, cfg, lanes=args.lanes, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(1, cfg.vocab,
                                               size=rng.integers(4, 24)),
                           max_new=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s, {eng.steps} engine steps)")


if __name__ == "__main__":
    main()
