"""Roofline analysis (deliverable g) + kernel tile selection.

This module is imported from two very different places:

- the CLI (``python -m repro.launch.roofline``) lowers whole model
  variants and needs the full ``repro.configs`` / ``repro.models``
  stack plus a 512-way fake device mesh;
- the Pallas kernel layer (``repro.kernels``) only needs the hardware
  constants and the tile choosers below.

So everything heavy — jax, the model registry, the mesh env var — is
imported/applied lazily inside the CLI entry points, and the module
itself stays import-light.

Terms per (arch x shape x mesh), on TPU v5e constants:

  compute    = HLO_FLOPs_per_device / 197e12        [s]
  memory     = HLO_bytes_per_device / 819e9         [s]
  collective = collective_bytes_per_device / 50e9   [s]

The compiled per-device HLO gives FLOPs/bytes — but XLA's cost analysis
counts while-loop bodies ONCE, so scan-over-layers models undercount by
~n_layers. We therefore use a *differential unrolled* method, exact for
depth-linear costs:

  f(total) = f(prefix + 1 cycle)                      [base, unrolled]
           + (n_cycles - 1) * [f(2 cycles) - f(1)]    [per-cycle delta]
           + [f(1 cycle + remainder) - f(1)]          [remainder delta]

Each variant is a real lower+compile on the production mesh with scans
fully unrolled (small depth => fast compiles). Collective bytes are
parsed from each unrolled HLO the same way.

MODEL_FLOPS uses the 6·N·D convention (6·N_active·D for MoE; decode =
2·N_active·B per token) — the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat recompute + masked-block attention waste + routing overhead.
"""

import dataclasses
import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)
VMEM_BYTES = 64 * 2**20    # v5e VMEM per core (usable scratch budget)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "roofline")


# ---------------------------------------------------------------------------
# kernel tile selection
# ---------------------------------------------------------------------------
#
# The Pallas kernels used to hard-code their tile sizes (bq=128, bn=128,
# one gathered row per grid step).  These choosers derive them from the
# v5e constants instead, with two regimes:
#
# - compiled (TPU): MXU/VPU-aligned tiles sized so all live blocks plus
#   scratch fit comfortably in VMEM (<= 1/4 of it, leaving room for the
#   pipeline's double buffering);
# - interpret (CPU CI): the sort networks and per-row loops are traced
#   *unrolled*, so compile cost scales with grid x body size.  Tiles drop
#   to the smallest shape that still exercises the kernel logic.

def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def fused_topk_tiles(B: int, N: int, k: int, d: int = 128, *,
                     interpret: bool = False) -> tuple[int, int]:
    """(bq, bn) for ``kernels.fused_topk`` / ``ops.topk_l2``.

    The kernel keeps a (bq, d) query block, a (bn, d) vector block and a
    (bq, K) running top-k scratch resident.  Under interpret the bitonic
    network over bn lanes is unrolled into the jaxpr, so bn collapses to
    the smallest pow2 that still holds K.
    """
    K = _next_pow2(max(k, 2))
    if interpret:
        bq = max(8, min(_next_pow2(max(B, 1)), 8))
        bn = max(16, K)
        return bq, bn
    bn = max(128, K)
    bq = min(128, max(8, _next_pow2(max(B, 1))))
    # VMEM: q block + v block + out/scratch top-k rows (f32 + i32).
    while bq > 8 and (bq * d + bn * d + 2 * bq * K) * 4 > VMEM_BYTES // 4:
        bq //= 2
    return bq, bn


def traversal_wave_tiles(nb: int, d: int, m: int, *, int8: bool = False,
                         interpret: bool = False) -> int:
    """Gather width g (rows DMA'd per grid step) for the traversal-wave
    kernel.  nb candidate rows stream through nb/g sequential steps; a
    wider g means fewer, larger DMAs against the HBM stream at the cost
    of g resident row blocks.  Under interpret each row's distance +
    visited update is traced unrolled, so g drops to 1.
    """
    if interpret:
        return 1
    row_bytes = d * (1 if int8 else 4) + m * 4 + (4 if int8 else 0)
    g = 1
    # Widen until a step moves >= 2KB (amortizes per-DMA issue cost on
    # the scalar-prefetch gather path) or VMEM pressure says stop.
    while (g < nb and g * row_bytes < 2048
           and (2 * g * row_bytes) * 2 <= VMEM_BYTES // 8):
        g *= 2
    while nb % g:
        g //= 2
    return max(1, g)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def active_params(cfg) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    from repro.models import lm
    from repro.models.common import count_params
    total = count_params(lm.lm_specs(cfg))
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * m.d_model * m.d_ff
    n_moe_layers = sum(1 for s in cfg.layer_list() if s.ffn == "moe")
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive


def model_flops(cfg, shape_name: str) -> float:
    """6·N_active·D for training; 2·N_active per generated token for
    decode; 2·N_active·prompt_tokens for prefill."""
    from repro.configs import registry
    spec = registry.SHAPES[shape_name]
    n_act = active_params(cfg)
    tokens = spec["batch"] * spec["seq"]
    if spec["kind"] == "train":
        return 6.0 * n_act * tokens
    if spec["kind"] == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * spec["batch"]        # decode: one token per lane


def analytic_hbm_bytes(cfg, shape_name: str, chips: int,
                       remat: bool = True) -> float:
    """First-principles per-device HBM traffic per step (the credibility
    check next to the HLO-derived memory term, which on the CPU backend
    is an unfused upper bound):

    train : params 2x read (fwd+bwd) + grad write/read + AdamW moment
            r/w (fp32) + activations write+read (x2 with remat recompute)
    serve : active params read once per token batch + KV/state cache
            read (+write of the new slot) + activations streamed once.
    """
    from repro.configs import registry
    from repro.models import lm
    from repro.models.common import count_params
    spec = registry.SHAPES[shape_name]
    n_total = count_params(lm.lm_specs(cfg))
    n_act = active_params(cfg)
    tokens_dev = spec["batch"] * spec["seq"] / chips
    d = cfg.d_model
    L = cfg.n_layers
    p_total_dev = n_total / chips
    p_act_dev = n_act / chips
    if spec["kind"] == "train":
        act_factor = 2.0 if remat else 1.5
        acts = tokens_dev * d * 2 * L * 8 * act_factor  # ~8 tensors/layer
        params_traffic = p_act_dev * 2 * 3              # bf16: fwd+bwd+bwd
        opt = p_total_dev * (4 + 4) * 2 + p_total_dev * 2 * 2 \
            + p_total_dev * 4                           # m,v r/w + p r/w + g
        return params_traffic + opt + acts
    if spec["kind"] == "prefill":
        acts = tokens_dev * d * 2 * L * 6
        cache_w = _cache_bytes(cfg, spec) / chips
        return p_act_dev * 2 + acts + cache_w
    # decode: one token; params + full cache read dominate
    cache_rw = _cache_bytes(cfg, spec) / chips
    acts = spec["batch"] / chips * d * 2 * L * 6
    return p_act_dev * 2 + cache_rw + acts


def _cache_bytes(cfg, spec) -> float:
    """Global KV/state cache bytes for a serve shape."""
    import jax
    import numpy as np

    from repro.models import lm
    cache_sh = jax.eval_shape(
        lambda: lm.init_caches(cfg, spec["batch"], spec["seq"]))
    return float(sum(np.prod(x.shape) * x.dtype.itemsize
                     for x in jax.tree.leaves(cache_sh)))


# ---------------------------------------------------------------------------
# differential unrolled accounting
# ---------------------------------------------------------------------------

def _variant(cfg, n_cycles: int, remainder: int):
    n = len(cfg.prefix) + n_cycles * len(cfg.pattern) + remainder
    return dataclasses.replace(cfg, n_layers=n, unroll=True)


def measure_cell(arch: str, shape_name: str, mesh, *, remat: bool = True,
                 cfg=None, tcfg=None):
    """Differential roofline numbers for one cell. Returns dict."""
    from repro.configs import registry
    cfg = cfg or registry.get_config(arch)
    n_pref, n_pat = len(cfg.prefix), len(cfg.pattern)
    n_body = cfg.n_layers - n_pref
    n_cycles, remainder = divmod(n_body, n_pat)
    assert n_cycles >= 1, (arch, cfg.n_layers)

    base = _lower_variant(arch, shape_name, mesh, _variant(cfg, 1, 0),
                          remat=remat, tcfg=tcfg)
    two = _lower_variant(arch, shape_name, mesh, _variant(cfg, 2, 0),
                         remat=remat, tcfg=tcfg)
    delta = {k: two[k] - base[k] for k in base}
    if remainder:
        rem = _lower_variant(arch, shape_name, mesh,
                             _variant(cfg, 1, remainder),
                             remat=remat, tcfg=tcfg)
        delta_rem = {k: rem[k] - base[k] for k in base}
    else:
        delta_rem = {k: 0.0 for k in base}

    total = {k: base[k] + (n_cycles - 1) * delta[k] + delta_rem[k]
             for k in base}
    return total


def _lower_variant(arch, shape_name, mesh, cfg_variant, *, remat, tcfg):
    """Lower+compile one unrolled variant; per-device flops/bytes/coll."""
    import jax

    from repro.dist import sharding as shd_mod
    from repro.launch import dryrun as dr
    from repro.models import attention as attn_mod
    fn, args, in_sh, out_sh, _, resident = dr.build_cell(
        arch, shape_name, mesh, reduced=False, remat=remat, tcfg=tcfg,
        cfg_override=cfg_variant)
    attn_mod.UNROLL_SCANS = True
    try:
        with mesh, shd_mod.activation_rules(mesh,
                                            dr._rules_for(shape_name)):
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
    finally:
        attn_mod.UNROLL_SCANS = False
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = dr.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "ag_bytes": float(coll["all-gather"]["bytes"]),
        "ar_bytes": float(coll["all-reduce"]["bytes"]),
        "rs_bytes": float(coll["reduce-scatter"]["bytes"]),
        "a2a_bytes": float(coll["all-to-all"]["bytes"]),
        "cp_bytes": float(coll["collective-permute"]["bytes"]),
    }


def roofline_row(arch: str, shape_name: str, mesh_name: str = "single",
                 *, remat: bool = True, tcfg=None, tag: str = "",
                 save: bool = True) -> dict:
    import numpy as np

    from repro.configs import registry
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = registry.get_config(arch)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if mesh_name == "multi" else "16x16"}
    if not registry.shape_applicable(arch, shape_name):
        row["status"] = "skip"
        return row
    try:
        tot = measure_cell(arch, shape_name, mesh, remat=remat, tcfg=tcfg)
        mf = model_flops(cfg, shape_name)
        t_comp = tot["flops"] / PEAK_FLOPS
        t_mem = tot["bytes"] / HBM_BW
        t_mem_analytic = analytic_hbm_bytes(cfg, shape_name, chips,
                                            remat) / HBM_BW
        t_coll = tot["coll_bytes"] / LINK_BW
        dom = max((t_comp, "compute"), (t_mem, "memory"),
                  (t_coll, "collective"))[1]
        row.update(
            status="ok", chips=chips,
            hlo_flops_per_device=tot["flops"],
            hlo_bytes_per_device=tot["bytes"],
            coll_bytes_per_device=tot["coll_bytes"],
            coll_breakdown={k: tot[k] for k in
                            ("ag_bytes", "ar_bytes", "rs_bytes",
                             "a2a_bytes", "cp_bytes")},
            t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
            t_memory_analytic=t_mem_analytic,
            bottleneck=dom,
            bottleneck_analytic=max(
                (t_comp, "compute"), (t_mem_analytic, "memory"),
                (t_coll, "collective"))[1],
            model_flops_global=mf,
            model_flops_per_device=mf / chips,
            useful_ratio=(mf / chips) / max(tot["flops"], 1.0),
            roofline_fraction=(mf / chips / PEAK_FLOPS) /
            max(t_comp, t_mem, t_coll),
        )
    except Exception as e:
        import traceback
        row["status"] = "fail"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-1500:]
    if save:
        os.makedirs(RESULTS, exist_ok=True)
        name = f"{arch}_{shape_name}_{row['mesh']}{tag}.json"
        with open(os.path.join(RESULTS, name), "w") as f:
            json.dump(row, f, indent=1, default=str)
    return row


def main():
    import argparse

    # The differential method lowers against the 512-chip production
    # mesh; fake that device count before jax initializes.
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.configs import registry
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    choices=["all"] + list(registry.ARCHS))
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(registry.SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    archs = list(registry.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(registry.SHAPES) if args.shape == "all" else [args.shape]
    for arch in archs:
        for shape in shapes:
            row = roofline_row(arch, shape, args.mesh,
                               remat=not args.no_remat, tag=args.tag)
            if row["status"] == "ok":
                print(f"[ok  ] {arch} x {shape}: "
                      f"C={row['t_compute']:.4f}s M={row['t_memory']:.4f}s "
                      f"X={row['t_collective']:.4f}s -> {row['bottleneck']}"
                      f" useful={row['useful_ratio']:.2f}"
                      f" frac={row['roofline_fraction']:.3f}", flush=True)
            else:
                print(f"[{row['status']:4s}] {arch} x {shape} "
                      f"{row.get('error', '')[:120]}", flush=True)


if __name__ == "__main__":
    main()
