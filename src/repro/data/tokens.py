"""Deterministic, shardable, checkpointable synthetic token pipeline.

Every (step, host_shard) batch is a pure function of (seed, step), so:
- resuming from step s reproduces exactly the stream a no-crash run sees
  (checkpoint stores only `step`),
- each data-parallel shard draws only its slice (host never materializes
  the global batch at scale),
- no file I/O: the "corpus" is a Zipf-ish unigram stream with a short
  Markov flavor so the loss has something learnable.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    batch: int           # global batch
    seq: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def _rng(self, step: int):
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))

    def batch_at(self, step: int) -> dict:
        """{'tokens': (b_local, T) i32, 'labels': (b_local, T) i32}."""
        assert self.batch % self.n_shards == 0
        b = self.batch // self.n_shards
        rng = self._rng(step)
        # Zipf unigram + repetition structure (learnable bigrams)
        base = rng.zipf(1.3, size=(b, self.seq + 1)).astype(np.int64)
        tokens = (base % (self.vocab - 2)) + 1
        # inject copy structure: 25% of positions repeat t-2
        mask = rng.random((b, self.seq + 1)) < 0.25
        tokens[:, 2:] = np.where(mask[:, 2:], tokens[:, :-2], tokens[:, 2:])
        x = tokens[:, :-1].astype(np.int32)
        y = tokens[:, 1:].astype(np.int32)
        return {"tokens": x, "labels": y}
