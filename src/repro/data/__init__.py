from repro.data.datasets import (  # noqa: F401
    make_dataset, make_queries, DATASETS, Workload)
