"""Synthetic datasets + range-query workloads (paper Section 6.1).

The paper's corpora are SIFT/Deep (vectors + *uniform random* synthetic
attributes) and DBLP/YouTube (real vectors + *skewed* numeric attributes:
year, counts, durations). At repo scale we synthesize both regimes with
matched statistics:

- ``uniform``  — i.i.d. Gaussian-mixture vectors (so ANN structure exists;
                 pure iid Gaussian has no neighbors to find), attributes
                 U[0, 1).
- ``skewed``   — same vectors; attributes drawn per-column from the
                 DBLP/YouTube shapes: discrete years (truncated geometric —
                 recent years dominate), log-normal counts (views/citations
                 style heavy tail), correlated-with-cluster column (time
                 correlates with content drift).

Query ranges follow the paper: per attribute an independent selectivity
s ~ U[s_min, s_max] (paper: 1%-100%) realized *by empirical quantile*, so
per-attribute selectivity is exact regardless of skew; fixed-width modes
(1/64, 1/16, 1/4) reproduce Figure 8.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

DATASETS = {
    # name: (dim, attr regime, #attrs) — scaled-down stand-ins, same shapes
    "deep":    dict(dim=96,  regime="uniform", m=4),
    "sift":    dict(dim=128, regime="uniform", m=4),
    "dblp":    dict(dim=768, regime="skewed",  m=4),
    "youtube": dict(dim=1024, regime="skewed", m=4),
}


def _mixture_vectors(n: int, dim: int, n_modes: int, rng,
                     intrinsic_dim: int = 12) -> np.ndarray:
    """Low-intrinsic-dimension Gaussian mixture embedded in `dim`.

    Real ANN corpora (SIFT, deep descriptors, text embeddings) live on
    low-ID manifolds (~10-20), which is what makes graph ANNS work; an
    iid high-dim Gaussian is the degenerate worst case (distance
    concentration makes all points near-equidistant and graphs
    non-navigable). We sample a cluster mixture in a latent space and
    project through a random linear map, plus small ambient noise."""
    centers = rng.normal(size=(n_modes, intrinsic_dim)).astype(np.float32)
    assign = rng.integers(0, n_modes, size=n)
    z = centers[assign] + 0.6 * rng.normal(
        size=(n, intrinsic_dim)).astype(np.float32)
    lift = rng.normal(size=(intrinsic_dim, dim)).astype(np.float32)
    lift /= np.sqrt(intrinsic_dim)
    v = z @ lift + 0.05 * rng.normal(size=(n, dim)).astype(np.float32)
    return v.astype(np.float32), assign


def _skewed_attrs(n: int, m: int, assign: np.ndarray, rng) -> np.ndarray:
    """DBLP/YouTube-shaped attribute columns."""
    cols = []
    for j in range(m):
        kind = j % 3
        if kind == 0:     # year: truncated geometric over ~30 values
            y = 2025 - np.minimum(rng.geometric(0.15, size=n) - 1, 29)
            cols.append(y.astype(np.float32))
        elif kind == 1:   # counts: heavy-tailed log-normal
            cols.append(np.exp(rng.normal(2.0, 1.5, size=n)).astype(np.float32))
        else:             # content-correlated: cluster id + noise
            cols.append((assign + rng.normal(0, 0.5, size=n)).astype(np.float32))
    return np.stack(cols, axis=1)


def make_dataset(name: str, n: int, seed: int = 0,
                 n_modes: int = 64, m: int | None = None):
    """Returns (vectors (n, dim) f32, attrs (n, m) f32)."""
    spec = DATASETS[name]
    rng = np.random.default_rng(seed)
    v, assign = _mixture_vectors(n, spec["dim"], n_modes, rng)
    m = m or spec["m"]
    if spec["regime"] == "uniform":
        attrs = rng.uniform(size=(n, m)).astype(np.float32)
    else:
        attrs = _skewed_attrs(n, m, assign, rng)
    return v, attrs


@dataclasses.dataclass
class Workload:
    q: np.ndarray      # (B, dim) query vectors
    lo: np.ndarray     # (B, m) range lows  (-inf for unconstrained attrs)
    hi: np.ndarray     # (B, m) range highs (+inf for unconstrained attrs)
    sel: np.ndarray    # (B,) product of per-attribute selectivities


def make_queries(vectors: np.ndarray, attrs: np.ndarray, n_queries: int,
                 n_filtered: int, seed: int = 0,
                 sel_range: tuple[float, float] = (0.01, 1.0),
                 fixed_width: float | None = None,
                 attr_subset: Sequence[int] | None = None) -> Workload:
    """Range-filtered query workload.

    n_filtered: how many attributes carry predicates (paper's m ∈ {1,2,4});
    fixed_width: if set (e.g. 1/16), every predicate spans exactly that
    quantile width (Figure 8 mode); otherwise widths ~ U[sel_range].
    attr_subset: which attribute columns carry predicates (default: the
    first n_filtered) — Figure 10's partial-attribute mode.
    """
    rng = np.random.default_rng(seed + 1)
    n, dim = vectors.shape
    m = attrs.shape[1]
    cols = list(attr_subset) if attr_subset is not None \
        else list(range(n_filtered))
    assert len(cols) == n_filtered <= m

    # query vectors: perturbed base points (paper queries come from held-out
    # files of the same distribution)
    base = vectors[rng.integers(0, n, size=n_queries)]
    q = base + rng.normal(0, 0.3, size=base.shape).astype(np.float32)

    lo = np.full((n_queries, m), -np.inf, np.float32)
    hi = np.full((n_queries, m), np.inf, np.float32)
    sel = np.ones(n_queries, np.float64)
    qs = np.linspace(0.0, 1.0, 1025)
    for j in cols:
        quant = np.quantile(attrs[:, j].astype(np.float64), qs)
        if fixed_width is not None:
            w = np.full(n_queries, fixed_width)
        else:
            w = rng.uniform(*sel_range, size=n_queries)
        start = rng.uniform(0, 1, size=n_queries) * (1 - w)
        l_idx = np.clip((start * 1024).astype(int), 0, 1024)
        r_idx = np.clip(((start + w) * 1024).astype(int), 0, 1024)
        lo[:, j] = quant[l_idx]
        hi[:, j] = quant[r_idx]
        # realized per-attribute selectivity (ties can inflate it)
        sel *= np.maximum((r_idx - l_idx) / 1024.0, 1e-6)
    return Workload(q=q.astype(np.float32), lo=lo, hi=hi,
                    sel=sel.astype(np.float32))
