"""Distributed substrate.

``sharding`` — generic logical-axis -> mesh placement machinery
(``partition_spec`` / ``sharding_for`` / ``batch_sharding`` /
``zero1_sharding`` / ``activation_rules`` + ``constrain``), used by the
serving engine and the launch dry-run. ``lm_rules`` quarantines the
LM-stack rule tables (TRAIN/FSDP/DECODE) the ANN engine never touches.
``straggler`` — per-host EWMA step-time monitor; the ANN mesh tier
(``repro.core.shard.ShardedEngine``) records per-shard wall times into
it every pass. ``compression`` — error-feedback gradient compression
for the train loop."""
