"""Distributed substrate. Currently provides ``sharding`` (logical-axis
-> mesh placement rules used by the models, serving engine and dry-run).
``straggler`` / ``compression`` are referenced by the train loop and
tests but not yet restored — see ROADMAP open items."""
