"""Logical-axis -> mesh placement machinery (GSPMD).

Params and activations carry *logical* axis names; a rules table maps
each name to mesh axes. Placement never changes values — every helper
falls back to replication when a mesh axis is absent, has size 1, or
does not divide the array dimension — so a single-device run lowers to
the unsharded program.

This module holds only the generic machinery the engine uses (the ANN
mesh tier pins device arrays per shard via ``jax.default_device`` —
see ``repro.core.shard`` — and the serving/dry-run paths resolve
shardings through the helpers here). The LM-stack rule *tables*
(TRAIN/FSDP/DECODE) are quarantined in ``repro.dist.lm_rules``.

``constrain`` is the activation-pinning hook used inside model code. It
is a no-op unless the caller entered ``activation_rules(mesh, rules)``,
which is how the dry-run/roofline paths opt in while tests and CPU
serving run the exact same model code unpinned.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

# mesh axes: ("data", "model") single pod, ("pod", "data", "model")
# multi. Batch-like logical axes spread over every non-model axis.
_BATCH_AXES = ("pod", "data")


def _mesh_axes(entry, mesh) -> tuple:
    """Normalize a rule entry to the tuple of axes present in the mesh."""
    if entry is None:
        return ()
    axes = entry if isinstance(entry, tuple) else (entry,)
    return tuple(a for a in axes if a in mesh.shape)


def _axes_size(axes: tuple, mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def partition_spec(shape, logical_axes, mesh, rules) -> PartitionSpec:
    """Resolve one array's logical axes to a PartitionSpec.

    A dim shards only if its mesh axes exist, their combined size
    exceeds 1, divides the dim, and none of them is already used by an
    earlier dim (GSPMD forbids reuse); otherwise it replicates."""
    used: set = set()
    entries = []
    for dim, name in zip(shape, logical_axes):
        axes = _mesh_axes(rules.get(name), mesh) if name else ()
        size = _axes_size(axes, mesh)
        if (size > 1 and dim % size == 0
                and not any(a in used for a in axes)):
            used.update(axes)
            entries.append(axes[0] if len(axes) == 1 else axes)
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def sharding_for(shape, logical_axes, mesh, rules) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(shape, logical_axes, mesh,
                                              rules))


def shardings_for(shapes_tree, axes_tree, mesh, rules):
    """Tree-map ``sharding_for`` over matching (shapes, logical-axes)
    trees (leaves of ``axes_tree`` are tuples of str | None)."""
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(
        lambda sds, ax: sharding_for(sds.shape, ax, mesh, rules),
        shapes_tree, axes_tree, is_leaf=is_ax)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def batch_sharding(mesh, batch_size: int) -> NamedSharding:
    """Shard dim 0 over the non-model axes when they divide the batch;
    replicate otherwise (odd batches must still run, just slower)."""
    axes = _mesh_axes(_BATCH_AXES, mesh)
    size = _axes_size(axes, mesh)
    if size > 1 and batch_size % size == 0:
        return NamedSharding(
            mesh, PartitionSpec(axes[0] if len(axes) == 1 else axes))
    return replicated(mesh)


def zero1_sharding(shape, logical_axes, mesh, rules) -> NamedSharding:
    """ZeRO-1 optimizer-moment placement: the param's own rule-derived
    spec, plus the largest still-replicated dim sharded over the data
    axes — moments never need gathering inside the step, so the extra
    split is free bandwidth-wise."""
    spec = partition_spec(shape, logical_axes, mesh, rules)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    data_axes = tuple(a for a in _mesh_axes(_BATCH_AXES, mesh)
                      if a not in used)
    size = _axes_size(data_axes, mesh)
    if size > 1:
        # largest replicated, divisible dim first
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is None and shape[i] % size == 0:
                entries[i] = (data_axes[0] if len(data_axes) == 1
                              else data_axes)
                break
    return NamedSharding(mesh, PartitionSpec(*entries))


# -- activation pinning (opt-in context) ------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def activation_rules(mesh, rules):
    """Enable ``constrain`` with this (mesh, rules) for the enclosed
    lowering/compile; nests, restores on exit."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def constrain(x, logical_axes):
    """Pin an activation to its logical layout. Outside an
    ``activation_rules`` context this is the identity, so model code can
    call it unconditionally (CPU tests, single-device serving)."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    sh = sharding_for(x.shape, tuple(logical_axes), mesh, rules)
    return jax.lax.with_sharding_constraint(x, sh)
