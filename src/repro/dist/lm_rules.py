"""LM-stack logical-axis rule tables, quarantined.

These tables drive GSPMD placement for the *language-model* side of the
repo (``repro.models`` / ``repro.launch.dryrun`` / roofline): the ANN
engine never consumes them — its mesh tier places whole cells per shard
(``repro.core.shard``), not tensor dimensions. They live here so
``repro.dist.sharding`` stays the engine-facing machinery module and a
grep for TRAIN/DECODE rules can't suggest the ANN path uses them.

Contracting / head-like param axes go to "model"; batch-like axes spread
over every non-model axis; FSDP adds "embed" over the data axes
(ZeRO-3 style).
"""

from __future__ import annotations

from repro.dist.sharding import _BATCH_AXES

TRAIN_RULES = {
    "batch": _BATCH_AXES,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
}

FSDP_TRAIN_RULES = dict(TRAIN_RULES, embed=_BATCH_AXES)

DECODE_RULES = {
    "batch": _BATCH_AXES,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
}
