"""Gradient compression for cross-pod sync (error feedback).

``compressed_psum`` quantizes each shard's (gradient + carried residual)
to int8 with a per-tensor scale before the collective — 4x less traffic
than fp32 — and returns the quantization error as the next residual.
Error feedback makes the *accumulated* compressed gradient telescope to
the true sum (the dropped mass is retransmitted next step), so training
trajectories stay within quantization noise of uncompressed sync.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(grad, residual, axis_name: str):
    """One compressed mean-reduction step inside shard_map/pmap.

    grad, residual: this shard's local arrays (same shape). Returns
    (mean-reduced dequantized gradient, new residual).
    """
    comp = grad + residual
    scale = jnp.maximum(jnp.max(jnp.abs(comp)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(comp / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale          # what actually syncs
    new_residual = comp - deq                    # error feedback carry
    out = jax.lax.pmean(deq, axis_name)
    return out, new_residual
