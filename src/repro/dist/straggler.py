"""Straggler detection + step watchdog (host-side, dependency-free).

A host is a straggler when its EWMA step time exceeds a multiple of the
fleet median EWMA. Detection is relative, so uniform slowdowns (bigger
batch, compiler change) never alarm; recovery is automatic as the EWMA
decays back toward the fleet.
"""

from __future__ import annotations

import time
from typing import Optional


class StragglerMonitor:
    """Per-host EWMA of step wall time vs. the fleet median."""

    def __init__(self, n_hosts: int, min_steps: int = 5,
                 alpha: float = 0.3, ratio: float = 2.0):
        self.n_hosts = n_hosts
        self.min_steps = min_steps      # EWMA warm-up before judging
        self.alpha = alpha              # EWMA weight of the new sample
        self.ratio = ratio              # alarm at ratio x fleet median
        self._ewma = [None] * n_hosts
        self._count = [0] * n_hosts

    def record(self, host: int, seconds: float) -> None:
        prev = self._ewma[host]
        self._ewma[host] = seconds if prev is None else (
            self.alpha * seconds + (1.0 - self.alpha) * prev)
        self._count[host] += 1

    def ingest(self, spans, key: str = "host") -> dict:
        """Fold obs spans into the EWMA: span durations are summed per
        ``key`` attribute (one step sample per host present — hosts that
        did no work emit no spans and are not penalized with zeros).
        Returns the {host: wall_seconds} walls that were recorded, so
        callers (e.g. the sharded engine's per-shard stats) reuse the
        same numbers the monitor judged."""
        from repro.obs.trace import sum_walls
        walls = sum_walls(spans, key)
        for host, w in sorted(walls.items()):
            self.record(int(host), float(w))
        return walls

    def is_straggler(self, host: int) -> bool:
        if self._count[host] < self.min_steps or self._ewma[host] is None:
            return False
        seen = sorted(e for e in self._ewma if e is not None)
        if not seen:
            return False
        median = seen[(len(seen) - 1) // 2]   # lower median: with 2
        # hosts the comparison must be against the faster one
        return self._ewma[host] > self.ratio * max(median, 1e-9)


class StepWatchdog:
    """Wall-clock timer for one step: ``start()`` then ``expired()``.
    timeout None/0 disables (never expires)."""

    def __init__(self, timeout_s: Optional[float] = None):
        self.timeout_s = timeout_s
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def expired(self) -> bool:
        if not self.timeout_s or self._t0 is None:
            return False
        return (time.perf_counter() - self._t0) > self.timeout_s
