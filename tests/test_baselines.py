"""Paper baselines: GPU-Pre (exact), CAGRA-Post, inline filtering."""

import numpy as np
import pytest

from repro.core.baselines import (FlatBaseline, inline_filter_search,
                                  postfilter_search, prefilter_search)
from repro.core.search import recall_at_k


@pytest.fixture(scope="module")
def flat(small_data):
    v, a = small_data
    return FlatBaseline.build(v, a, degree=12)


def test_prefilter_is_exact(flat, small_queries, small_truth):
    wl = small_queries
    ids, d = prefilter_search(flat, wl.q, wl.lo, wl.hi, 10, chunk=1024)
    assert recall_at_k(ids, small_truth[0]) == 1.0


def test_postfilter_good_at_high_selectivity(flat, small_data):
    """Wide-open ranges: post-filtering ~= vanilla ANNS (paper §2.2.3)."""
    v, a = small_data
    rng = np.random.default_rng(5)
    q = v[rng.integers(0, len(v), 16)] + 0.05 * rng.normal(
        size=(16, v.shape[1])).astype(np.float32)
    lo = np.full((16, 4), -np.inf, np.float32)
    hi = np.full((16, 4), np.inf, np.float32)
    ids, _ = postfilter_search(flat, q, lo, hi, 10)
    tids, _ = prefilter_search(flat, q, lo, hi, 10)
    assert recall_at_k(ids, tids) >= 0.9


def test_postfilter_degrades_at_low_selectivity(flat, small_data):
    """Selective predicates starve post-filtering (the paper's motivation
    for a dedicated index)."""
    v, a = small_data
    from repro.data import make_queries
    wl = make_queries(v, a, 16, 2, seed=6, sel_range=(0.02, 0.1))
    tids, _ = prefilter_search(flat, wl.q, wl.lo, wl.hi, 10)
    ids, _ = postfilter_search(flat, wl.q, wl.lo, wl.hi, 10, expand=2)
    # not asserting a specific number — asserting it LOSES to exact
    assert recall_at_k(ids, tids) < 1.0


def test_inline_filter_returns_valid(flat, small_data, small_queries):
    v, a = small_data
    wl = small_queries
    ids, d = inline_filter_search(flat, wl.q, wl.lo, wl.hi, 10)
    for b in range(len(ids)):
        got = ids[b][ids[b] >= 0]
        assert ((a[got] >= wl.lo[b]) & (a[got] <= wl.hi[b])).all()
