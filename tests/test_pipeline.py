"""Out-of-core pipeline (paper Section 5): recall parity with in-core,
HBM-bounded batching, schedule effectiveness, quantize bounds."""

import numpy as np
import pytest

from repro.core.pipeline import OutOfCoreEngine
from repro.core.search import Searcher, recall_at_k
from repro.core.types import SearchParams
from repro.core import quantize


@pytest.fixture(scope="module")
def engine(small_index):
    return OutOfCoreEngine(small_index)


def test_ooc_recall_matches_incore(engine, small_index, small_queries,
                                   small_truth):
    wl = small_queries
    params = SearchParams(k=10, ef=64)
    ids, d = engine.search(wl.q, wl.lo, wl.hi, params)
    rec_ooc = recall_at_k(ids, small_truth[0])
    ids_ic, _ = Searcher(small_index).search(wl.q, wl.lo, wl.hi, params)
    rec_ic = recall_at_k(ids_ic, small_truth[0])
    assert rec_ooc >= rec_ic - 0.05, (rec_ooc, rec_ic)
    assert engine.stats["n_batches"] >= 2     # actually streamed


def test_ooc_results_exact_distances(engine, small_data, small_queries):
    """Re-rank must return exact fp32 distances and in-range ids."""
    v, a = small_data
    wl = small_queries
    ids, d = engine.search(wl.q, wl.lo, wl.hi, SearchParams(k=5, ef=64))
    for b in range(len(ids)):
        got = ids[b][ids[b] >= 0]
        if len(got) == 0:
            continue
        np.testing.assert_allclose(
            ((v[got] - wl.q[b]) ** 2).sum(1), d[b][:len(got)],
            rtol=1e-4, atol=1e-3)
        assert ((a[got] >= wl.lo[b]) & (a[got] <= wl.hi[b])).all()


def test_schedule_reduces_active(engine, small_queries):
    wl = small_queries
    engine.search(wl.q, wl.lo, wl.hi, SearchParams(k=5), use_schedule=True)
    act_sched = engine.stats["total_active"]
    engine.search(wl.q, wl.lo, wl.hi, SearchParams(k=5), use_schedule=False)
    act_naive = engine.stats["total_active"]
    assert act_sched <= act_naive


def test_hbm_budget_controls_batch(small_index):
    eng = OutOfCoreEngine(small_index, hbm_budget_bytes=1 << 18)
    assert 1 <= eng.cells_per_batch() <= small_index.n_cells
    eng_big = OutOfCoreEngine(small_index, hbm_budget_bytes=1 << 34)
    assert eng_big.cells_per_batch() >= eng.cells_per_batch()


def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    v = rng.normal(size=(100, 32)).astype(np.float32)
    q, s = quantize.quantize(v)
    rec = quantize.dequantize(q, s)
    err = np.abs(rec - v).max(axis=1)
    assert (err <= s * 0.5 + 1e-6).all()
    bound = quantize.max_abs_error_bound(s, 32)
    assert (np.linalg.norm(rec - v, axis=1) <= bound + 1e-5).all()


def test_packed_visited_matches_unpacked(small_index, small_queries):
    """Bit-packed visited words must not change search results."""
    import jax
    import jax.numpy as jnp
    from repro.core import pipeline as pl
    from repro.core import select as sel
    from repro.core.traversal import multi_cell_search_seeded
    idx = small_index
    wl = small_queries
    eng = pl.OutOfCoreEngine(idx)
    inc = sel.incidence_numpy(wl.lo, wl.hi, idx.cell_lo, idx.cell_hi)
    rank = eng._order_ranks(wl.q, inc)
    cells = list(range(idx.n_cells))
    plan = pl._remap_plan(idx, cells, inc, rank, pad_cells=len(cells))
    dev = eng._stage(plan)
    B = 8
    act = plan.active_queries[:B]
    i_map = {q: i for i, q in enumerate(plan.active_queries)}
    itin = plan.itinerary[[i_map[q] for q in act]]
    seed = -np.ones((B, 64), np.int32)
    args = (eng.vq, eng.vscale, eng.attrs_dev, dev["intra"], dev["inter"],
            dev["local_start"], dev["rows"],
            jnp.asarray(wl.q[act]), jnp.asarray(wl.lo[act]),
            jnp.asarray(wl.hi[act]), jnp.asarray(itin), jnp.asarray(seed),
            jax.random.PRNGKey(3))
    kw = dict(k=10, ef=64, entry_width=16, entry_random=4, entry_beam_l=8,
              max_iters=96)
    ids_u, d_u = multi_cell_search_seeded(*args, packed_visited=False, **kw)
    ids_p, d_p = multi_cell_search_seeded(*args, packed_visited=True, **kw)
    np.testing.assert_array_equal(np.asarray(ids_u), np.asarray(ids_p))
    np.testing.assert_allclose(np.asarray(d_u), np.asarray(d_p), rtol=1e-6)
