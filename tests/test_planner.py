"""DNF compiler, query planner, and segment-aware merge.

Covers: and/or tree lowering to DNF boxes, canonicalization (dedup,
containment, interval merging, empty-box pruning), box-batched execution
through both engines in ONE engine call per batch, and deterministic
duplicate-id folding in the merge.
"""

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, F, QueryResult, plan_queries
from repro.api.filters import MAX_DNF_CONJUNCTIONS, compile_dnf
from repro.api.planner import canonicalize_boxes
from repro.core.search import merge_segment_topk
from repro.core.types import SearchParams

SCHEMA = AttrSchema(["price", "ts", "views", "duration"])


# -- DNF lowering -----------------------------------------------------------

def test_dnf_or_of_two_boxes():
    expr = (F("price") < 10) | (F("price") > 90)
    assert len(expr.dnf()) == 2
    lo, hi = compile_dnf(expr, SCHEMA, 3)
    assert lo.shape == hi.shape == (2, 3, 4)
    # branch boxes carry only their own constraint; other attrs open
    assert np.isposinf(hi[1, :, 0]).all() and (lo[1, :, 0] > 90).all()
    assert np.isneginf(lo[0, :, 0]).all() and (hi[0, :, 0] < 10).all()
    assert np.isneginf(lo[:, :, 1:]).all() and np.isposinf(hi[:, :, 1:]).all()


def test_dnf_distributes_and_over_or():
    expr = ((F("price") < 10) | (F("price") > 90)) \
        & ((F("ts") < 0.2) | (F("ts") > 0.8))
    assert len(expr.dnf()) == 4            # 2 x 2 cross product
    nested = (F("views") > 5) | ((F("price") < 10) &
                                 ((F("ts") < 0.2) | (F("ts") > 0.8)))
    assert len(nested.dnf()) == 3          # 1 + 2, or/and nest freely


def test_dnf_is_associative_and_flattens():
    a, b, c = F("price") < 1, F("ts") < 2, F("views") < 3
    assert len(((a | b) | c).dnf()) == len((a | (b | c)).dnf()) == 3
    assert len(((a & b) & c).dnf()) == len((a & (b & c)).dnf()) == 1


def test_dnf_blowup_capped():
    expr = (F("price") < 1) | (F("price") > 2)
    big = expr
    for _ in range(8):                     # 2^9 conjunctions if expanded
        big = big & expr
    assert 2 ** 9 > MAX_DNF_CONJUNCTIONS
    with pytest.raises(ValueError):
        big.dnf()


# -- canonicalization -------------------------------------------------------

def _boxes(*pairs):
    lo = np.array([p[0] for p in pairs], np.float32)
    hi = np.array([p[1] for p in pairs], np.float32)
    return lo, hi


def test_canonicalize_merges_overlapping_same_attr():
    inf = np.inf
    lo, hi = _boxes(([0, -inf], [5, inf]), ([3, -inf], [8, inf]))
    clo, chi = canonicalize_boxes(lo, hi)
    assert clo.shape == (1, 2)
    assert clo[0, 0] == 0 and chi[0, 0] == 8


def test_canonicalize_merges_ulp_adjacent_strict_bounds():
    # price < 10 | price >= 10 differ by one ulp: contiguous -> unbounded
    expr = (F("price") < 10) | (F("price") >= 10)
    plan = plan_queries(expr, SCHEMA, 2)
    assert plan.stats["max_fanout"] == 1 and plan.n_boxes == 2
    assert np.isneginf(plan.lo).all() and np.isposinf(plan.hi).all()


def test_canonicalize_keeps_disjoint_and_cross_attr_boxes():
    inf = np.inf
    lo, hi = _boxes(([0, -inf], [2, inf]), ([5, -inf], [8, inf]))
    clo, _ = canonicalize_boxes(lo, hi)
    assert clo.shape == (1 + 1, 2)         # disjoint intervals stay apart
    # boxes differing on two attributes never merge (union isn't a box)
    lo, hi = _boxes(([0, 0], [2, 2]), ([1, 1], [5, 5]))
    clo, _ = canonicalize_boxes(lo, hi)
    assert clo.shape == (2, 2)


def test_canonicalize_dedup_containment_and_empty():
    inf = np.inf
    lo, hi = _boxes(
        ([0, -inf], [5, inf]),     # keeper
        ([0, -inf], [5, inf]),     # exact duplicate
        ([1, -inf], [3, inf]),     # contained
        ([7, -inf], [4, inf]),     # empty (lo > hi)
    )
    clo, chi = canonicalize_boxes(lo, hi)
    assert clo.shape == (1, 2)
    assert clo[0, 0] == 0 and chi[0, 0] == 5


def test_canonicalize_all_empty_returns_zero_boxes():
    lo, hi = _boxes(([5, 0], [1, 1]))
    clo, chi = canonicalize_boxes(lo, hi)
    assert clo.shape == (0, 2) and chi.shape == (0, 2)


# -- planning ---------------------------------------------------------------

def test_plan_conjunctive_is_trivial():
    for filt in (None, F("price").between(1, 2) & (F("ts") >= 0)):
        plan = plan_queries(filt, SCHEMA, 5)
        assert plan.trivial and plan.n_boxes == 5
        np.testing.assert_array_equal(plan.qmap, np.arange(5))


def test_plan_flattens_boxes_grouped_by_query():
    expr = (F("price") < 10) | (F("price") > 90)
    plan = plan_queries(expr, SCHEMA, 3)
    assert not plan.trivial
    assert plan.n_boxes == 6 and plan.stats["max_fanout"] == 2
    np.testing.assert_array_equal(plan.qmap, [0, 0, 1, 1, 2, 2])
    # every query gets the same canonical (sorted) box pair
    np.testing.assert_array_equal(plan.lo[:2], plan.lo[2:4])


def test_plan_per_query_bounds_heterogeneous_fanout():
    # per-query hi for branch 2: query 0's branches overlap (merge to one
    # box), query 1's stay disjoint -> ragged fanout across the batch
    hi2 = np.array([60.0, 10.0], np.float32)
    expr = (F("price").between(50, 70)) | (F("price") <= hi2)
    plan = plan_queries(expr, SCHEMA, 2)
    assert not plan.trivial
    fan = np.bincount(plan.qmap, minlength=2)
    assert fan.tolist() == [1, 2]
    assert plan.stats["max_fanout"] == 2


def test_plan_contradictory_branches_drop_to_zero_boxes():
    expr = ((F("price") > 5) & (F("price") < 3)) \
        | ((F("ts") > 9) & (F("ts") < 1))
    plan = plan_queries(expr, SCHEMA, 4)
    assert not plan.trivial and plan.n_boxes == 0


# -- segment-aware merge ----------------------------------------------------

def test_merge_dedups_and_keeps_best_distance():
    ids = np.array([[5, 7, -1], [5, 9, 2]])
    d = np.array([[0.1, 0.2, np.inf], [0.12, 0.15, 0.3]], np.float32)
    mi, md = merge_segment_topk(ids, d, np.array([0, 0]), 1, 4)
    np.testing.assert_array_equal(mi[0], [5, 9, 7, 2])   # 5 kept at 0.1
    np.testing.assert_allclose(md[0], [0.1, 0.15, 0.2, 0.3])


def test_merge_distance_ties_break_toward_smaller_id():
    ids = np.array([[9], [3]])
    d = np.array([[0.5], [0.5]], np.float32)
    mi, _ = merge_segment_topk(ids, d, np.array([0, 0]), 1, 2)
    np.testing.assert_array_equal(mi[0], [3, 9])


def test_merge_respects_segments_and_pads_empty_queries():
    ids = np.array([[1, 2], [3, 4]])
    d = np.array([[0.1, 0.2], [0.3, 0.4]], np.float32)
    mi, md = merge_segment_topk(ids, d, np.array([0, 2]), 3, 2)
    np.testing.assert_array_equal(mi, [[1, 2], [-1, -1], [3, 4]])
    assert np.isposinf(md[1]).all()


def test_query_result_merge_regression_point_in_two_boxes():
    """A point matching two boxes must appear once, at its best distance,
    in deterministic order."""
    r1 = QueryResult(ids=np.array([[11, 4]]),
                     distances=np.array([[0.2, 0.9]], np.float32))
    r2 = QueryResult(ids=np.array([[11, 8, -1]]),
                     distances=np.array([[0.2, 0.5, np.inf]], np.float32))
    merged = r1.merge(r2)
    assert merged.k == 3
    np.testing.assert_array_equal(merged.ids, [[11, 8, 4]])
    np.testing.assert_allclose(merged.distances, [[0.2, 0.5, 0.9]])
    with pytest.raises(ValueError):
        r1.merge(QueryResult.empty(3))


# -- box-batched execution through Collection -------------------------------

def test_disjunction_single_in_core_engine_pass(small_collection,
                                                small_queries, monkeypatch):
    """Acceptance: one planner flatten -> ONE Searcher.search call for the
    whole disjunctive batch, not a per-box Python loop."""
    col = small_collection
    s = col._searcher()
    calls = []
    orig = s.search

    def spy(*a, **kw):
        calls.append(kw.get("qmap"))
        return orig(*a, **kw)

    monkeypatch.setattr(s, "search", spy)
    expr = (F("price") < 0.25) | (F("price") > 0.75) \
        | (F("ts").between(0.4, 0.6))
    q = small_queries.q[:8]
    res = col.search(q, filters=expr, k=5)
    assert len(calls) == 1                 # single box-batched pass
    assert calls[0] is not None and len(calls[0]) == 3 * 8
    assert col.last_stats["planner"]["n_boxes"] == 24
    assert res.ids.shape == (8, 5)
    # no duplicate ids within a row (points can match several boxes)
    for row, _ in res:
        assert len(set(row.tolist())) == len(row)


def test_disjunction_single_out_of_core_engine_pass(small_collection,
                                                    small_queries,
                                                    monkeypatch):
    col = small_collection
    ooc = Collection(index=col.index, schema=col.schema, mode="ooc")
    eng = ooc._streamer()
    calls = []
    orig = eng.search

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(eng, "search", spy)
    expr = (F("price") < 0.25) | (F("price") > 0.75)
    res = ooc.search(small_queries.q[:4], filters=expr,
                     params=SearchParams(k=5, ef=64))
    assert len(calls) == 1
    assert res.engine == "ooc"
    assert ooc.last_stats["n_boxes"] == 8
    assert ooc.last_stats["planner"]["n_boxes"] == 8


def test_disjunction_all_empty_filter_returns_padded(small_collection):
    expr = ((F("price") > 5) & (F("price") < 3)) \
        | ((F("ts") > 9) & (F("ts") < 1))
    res = small_collection.search(
        np.zeros((3, small_collection.dim), np.float32), filters=expr, k=4)
    assert (res.ids == -1).all() and np.isposinf(res.distances).all()
    assert res.ids.shape == (3, 4)


def test_disjunction_matches_per_branch_merge(small_collection,
                                              small_queries):
    """Box-batched union == the two branches searched separately and
    host-merged (same index, same params)."""
    col = small_collection
    q = small_queries.q[:8]
    b1, b2 = F("price") < 0.2, F("price") > 0.8
    p = SearchParams(k=10, ef=64)
    union = col.search(q, filters=b1 | b2, params=p)
    merged = col.search(q, filters=b1, params=p).merge(
        col.search(q, filters=b2, params=p))
    # both paths are exact here (dense path over selected cells), so the
    # id sets agree; order may differ only under exact distance ties
    truth = col.ground_truth(q, filters=b1 | b2, k=10)
    assert union.recall(truth) >= 0.95
    assert merged.recall(truth) >= 0.95
