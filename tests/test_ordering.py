"""Cluster-guided cell ordering (paper Section 4.2 / Alg. 3)."""

import numpy as np
import jax.numpy as jnp

from repro.core import ordering


def test_histogram_counts_cell_sizes(small_index):
    idx = small_index
    # H row sums = cell sizes
    np.testing.assert_array_equal(
        idx.hist.sum(axis=1).astype(np.int64), np.diff(idx.cell_start))


def test_order_cells_ranks_by_estimated_cardinality(small_index):
    idx = small_index
    rng = np.random.default_rng(0)
    B, S = 8, idx.n_cells
    q = jnp.asarray(idx.vectors[rng.integers(0, idx.n, B)])
    mask = jnp.asarray(rng.random((B, S)) < 0.7)
    order, n_sel = ordering.order_cells(
        q, jnp.asarray(idx.centroids), jnp.asarray(idx.hist), mask,
        top_m=4, T=S)
    order = np.asarray(order)
    n_sel = np.asarray(n_sel)
    # selected count and -1 padding
    for b in range(B):
        sel = order[b][order[b] >= 0]
        assert len(sel) == n_sel[b] == int(np.asarray(mask)[b].sum())
        assert len(set(sel.tolist())) == len(sel)
        # every emitted cell was selected
        assert np.asarray(mask)[b, sel].all()
    # descending estimated cardinality (recompute the estimator)
    d = np.asarray(((q[:, None, :] - jnp.asarray(idx.centroids)[None]) ** 2
                    ).sum(-1))
    top = np.argsort(d, axis=1)[:, :4]
    for b in range(B):
        card = idx.hist[:, top[b]].sum(axis=1)
        sel = order[b][order[b] >= 0]
        got = card[sel]
        assert (np.diff(got) <= 1e-6).all(), got


def test_kmeans_reduces_quantization_error():
    rng = np.random.default_rng(0)
    v = rng.normal(size=(2000, 16)).astype(np.float32)
    c0 = v[rng.choice(2000, 8, replace=False)]
    c = ordering.kmeans(v, 8, iters=8, seed=0)

    def qerr(cent):
        d = ((v[:, None, :] - cent[None]) ** 2).sum(-1)
        return d.min(axis=1).mean()
    assert qerr(c) < qerr(np.asarray(c0))
