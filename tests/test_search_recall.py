"""End-to-end RFANNS recall (paper Fig. 7 behaviour) + result invariants."""

import numpy as np
import pytest

from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.types import SearchParams
from repro.data import make_queries


@pytest.fixture(scope="module")
def searcher(small_index):
    return Searcher(small_index)


def test_recall_m2(searcher, small_data, small_queries, small_truth):
    wl = small_queries
    ids, d = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=64))
    rec = recall_at_k(ids, small_truth[0])
    assert rec >= 0.9, rec


@pytest.mark.parametrize("m,ef,bar", [(1, 64, 0.85), (4, 96, 0.75)])
def test_recall_other_attr_counts(searcher, small_data, m, ef, bar):
    """m=4 conjunctions at n=4k leave very sparse in-range sets; the
    session fixture deliberately uses a tiny dense_threshold (256) to
    exercise the *traversal* path where production would take the dense
    exact path (threshold 8192), so the m=4 bar is lower here."""
    v, a = small_data
    wl = make_queries(v, a, 24, m, seed=10 + m)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    ids, _ = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=ef))
    assert recall_at_k(ids, tids) >= bar


def test_results_in_range_sorted_nodup(searcher, small_data, small_queries):
    v, a = small_data
    wl = small_queries
    ids, d = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=64))
    for b in range(len(ids)):
        got = ids[b][ids[b] >= 0]
        # in-range (results are original ids)
        assert ((a[got] >= wl.lo[b]) & (a[got] <= wl.hi[b])).all()
        # ascending distances
        dd = d[b][np.isfinite(d[b])]
        assert (np.diff(dd) >= -1e-5).all()
        # no duplicates
        assert len(set(got.tolist())) == len(got)
        # distances correct
        np.testing.assert_allclose(
            ((v[got] - wl.q[b]) ** 2).sum(1), d[b][:len(got)],
            rtol=1e-4, atol=1e-3)


def test_partial_attribute_queries(searcher, small_data):
    """Fig. 10: predicates on a subset of indexed attrs still work."""
    v, a = small_data
    wl = make_queries(v, a, 16, 1, seed=21, attr_subset=[1])
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    ids, _ = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=64))
    assert recall_at_k(ids, tids) >= 0.85


def test_ablation_flags_run(searcher, small_queries):
    """Fig. 13 ablation paths execute and degrade gracefully."""
    wl = small_queries
    p_noorder = SearchParams(k=10, ef=64, use_ordering=False)
    p_nointer = SearchParams(k=10, ef=64, use_inter_edges=False)
    ids1, _ = searcher.search(wl.q, wl.lo, wl.hi, p_noorder)
    ids2, _ = searcher.search(wl.q, wl.lo, wl.hi, p_nointer)
    assert (ids1 >= -1).all() and (ids2 >= -1).all()


def test_wide_open_range_uses_global_path(searcher, small_data,
                                          small_queries):
    v, a = small_data
    B = 8
    lo = np.full((B, 4), -np.inf, np.float32)
    hi = np.full((B, 4), np.inf, np.float32)
    q = small_queries.q[:B]
    ids, _ = searcher.search(q, lo, hi, SearchParams(k=10, ef=64))
    tids, _ = ground_truth(v, a, q, lo, hi, 10)
    assert recall_at_k(ids, tids) >= 0.9
