"""End-to-end RFANNS recall (paper Fig. 7 behaviour) + result invariants."""

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, F
from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.selectivity import CostModel
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_dataset, make_queries


@pytest.fixture(scope="module")
def searcher(small_index):
    return Searcher(small_index)


def test_recall_m2(searcher, small_data, small_queries, small_truth):
    wl = small_queries
    ids, d = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=64))
    rec = recall_at_k(ids, small_truth[0])
    assert rec >= 0.9, rec


@pytest.mark.parametrize("m,ef,bar", [(1, 64, 0.85), (4, 96, 0.75)])
def test_recall_other_attr_counts(searcher, small_data, m, ef, bar):
    """m=4 conjunctions at n=4k leave very sparse in-range sets; the
    session fixture deliberately uses a tiny dense_threshold (256) to
    exercise the *traversal* path where production would take the dense
    exact path (threshold 8192), so the m=4 bar is lower here."""
    v, a = small_data
    wl = make_queries(v, a, 24, m, seed=10 + m)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    ids, _ = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=ef))
    assert recall_at_k(ids, tids) >= bar


def test_results_in_range_sorted_nodup(searcher, small_data, small_queries):
    v, a = small_data
    wl = small_queries
    ids, d = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=64))
    for b in range(len(ids)):
        got = ids[b][ids[b] >= 0]
        # in-range (results are original ids)
        assert ((a[got] >= wl.lo[b]) & (a[got] <= wl.hi[b])).all()
        # ascending distances
        dd = d[b][np.isfinite(d[b])]
        assert (np.diff(dd) >= -1e-5).all()
        # no duplicates
        assert len(set(got.tolist())) == len(got)
        # distances correct
        np.testing.assert_allclose(
            ((v[got] - wl.q[b]) ** 2).sum(1), d[b][:len(got)],
            rtol=1e-4, atol=1e-3)


def test_partial_attribute_queries(searcher, small_data):
    """Fig. 10: predicates on a subset of indexed attrs still work."""
    v, a = small_data
    wl = make_queries(v, a, 16, 1, seed=21, attr_subset=[1])
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    ids, _ = searcher.search(wl.q, wl.lo, wl.hi, SearchParams(k=10, ef=64))
    assert recall_at_k(ids, tids) >= 0.85


def test_ablation_flags_run(searcher, small_queries):
    """Fig. 13 ablation paths execute and degrade gracefully."""
    wl = small_queries
    p_noorder = SearchParams(k=10, ef=64, use_ordering=False)
    p_nointer = SearchParams(k=10, ef=64, use_inter_edges=False)
    ids1, _ = searcher.search(wl.q, wl.lo, wl.hi, p_noorder)
    ids2, _ = searcher.search(wl.q, wl.lo, wl.hi, p_nointer)
    assert (ids1 >= -1).all() and (ids2 >= -1).all()


# -- disjunctive recall (acceptance: union predicate == brute force) --------

@pytest.fixture(scope="module")
def disj_collection():
    """5k points with price scaled to [0, 100): the acceptance dataset
    for ``(price < 10) | (price > 90)``."""
    v, a = make_dataset("deep", 5000, seed=7, m=2)
    a = a.copy()
    a[:, 0] *= 100.0
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=12, n_clusters=16,
                    build_ef=48)
    col = Collection.build(v, a, schema=AttrSchema(["price", "ts"]),
                           config=cfg, seed=0)
    rng = np.random.default_rng(1)
    q = v[rng.integers(0, len(v), 24)] \
        + rng.normal(0, 0.3, (24, v.shape[1])).astype(np.float32)
    return col, v, a, q


def _brute_union_ids(v, a, q, mask, k):
    d = ((v[None] - q[:, None]) ** 2).sum(-1)
    d[:, ~mask] = np.inf
    order = np.argsort(d, axis=1)[:, :k]
    return np.where(np.take_along_axis(d, order, 1) < np.inf, order, -1)


def test_disjunction_recall_in_core(disj_collection):
    col, v, a, q = disj_collection
    expr = (F("price") < 10) | (F("price") > 90)
    res = col.search(q, filters=expr, k=10, ef=64)
    tids = _brute_union_ids(v, a, q, (a[:, 0] < 10) | (a[:, 0] > 90), 10)
    assert res.recall(tids) >= 0.95
    # Collection.ground_truth serves the same union exactly
    assert recall_at_k(col.ground_truth(q, filters=expr, k=10), tids) == 1.0
    # every returned id satisfies the *disjunction* (not one fixed box)
    for ids_b, _ in res:
        assert ((a[ids_b, 0] < 10) | (a[ids_b, 0] > 90)).all()


def test_disjunction_recall_out_of_core(disj_collection):
    col, v, a, q = disj_collection
    ooc = Collection(index=col.index, schema=col.schema, mode="ooc")
    expr = (F("price") < 10) | (F("price") > 90)
    res = ooc.search(q, filters=expr, params=SearchParams(k=10, ef=128))
    assert res.engine == "ooc"
    tids = _brute_union_ids(v, a, q, (a[:, 0] < 10) | (a[:, 0] > 90), 10)
    assert res.recall(tids) >= 0.95
    # at this scale every box's candidate set fits under dense_threshold,
    # so the cost model answers all of them with the fused masked scan
    # and the streaming pipeline stages no graph batches at all
    assert ooc.last_stats["n_dense"] == 2 * len(q)
    assert ooc.last_stats["n_batches"] == 0
    assert ooc.last_stats["planner"]["n_boxes"] == 2 * len(q)
    # with routing off the same plan streams through cell batches
    off = ooc.search(q, filters=expr,
                     params=SearchParams(k=10, ef=128, cost=CostModel.off()))
    assert off.recall(tids) >= 0.95
    assert ooc.last_stats["n_dense"] == 0
    assert ooc.last_stats["n_batches"] >= 1


# -- engine parity: in-core / hybrid / out-of-core on one 5k dataset --------

ENGINE_PARITY_TOL = 0.08


def test_engine_parity_conjunctive(disj_collection):
    """All three engine modes run the same traversal core; their recall
    on identical conjunctive workloads must agree within tolerance."""
    col, v, a, q = disj_collection
    wl = make_queries(v, a, 24, 1, seed=31)
    lo, hi = wl.lo, wl.hi
    tids, _ = ground_truth(v, a, wl.q, lo, hi, 10)
    recalls = {}
    for mode in ("incore", "hybrid", "ooc"):
        res = col.search(wl.q, filters=(lo, hi),
                         params=SearchParams(k=10, ef=96), engine=mode)
        assert res.engine == mode
        recalls[mode] = res.recall(tids)
    assert min(recalls.values()) >= 0.9, recalls
    spread = max(recalls.values()) - min(recalls.values())
    assert spread <= ENGINE_PARITY_TOL, recalls


def test_engine_parity_disjunctive(disj_collection):
    """The planner's box-batched disjunctive pass reaches equivalent
    recall through every engine mode."""
    col, v, a, q = disj_collection
    expr = (F("price") < 10) | (F("price") > 90)
    tids = _brute_union_ids(v, a, q, (a[:, 0] < 10) | (a[:, 0] > 90), 10)
    recalls = {}
    for mode in ("incore", "hybrid", "ooc"):
        res = col.search(q, filters=expr,
                         params=SearchParams(k=10, ef=128), engine=mode)
        assert res.engine == mode
        assert col.last_stats["planner"]["n_boxes"] == 2 * len(q)
        recalls[mode] = res.recall(tids)
    assert min(recalls.values()) >= 0.9, recalls
    spread = max(recalls.values()) - min(recalls.values())
    assert spread <= ENGINE_PARITY_TOL, recalls


def test_wide_open_range_uses_global_path(searcher, small_data,
                                          small_queries):
    v, a = small_data
    B = 8
    lo = np.full((B, 4), -np.inf, np.float32)
    hi = np.full((B, 4), np.inf, np.float32)
    q = small_queries.q[:B]
    ids, _ = searcher.search(q, lo, hi, SearchParams(k=10, ef=64))
    tids, _ = ground_truth(v, a, q, lo, hi, 10)
    assert recall_at_k(ids, tids) >= 0.9


# -- fused traversal wave: engine-level kernel/oracle parity ----------------

@pytest.fixture(scope="module")
def wave_collection():
    """Small enough that the Pallas wave kernel is tractable under
    interpret mode (CPU CI), with the dense route suppressed so every
    query actually traverses."""
    from repro.data import make_dataset
    v, a = make_dataset("deep", 600, seed=3, m=2)
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=8, n_clusters=8,
                    build_ef=32, quantize=True, dense_threshold=64)
    col = Collection.build(v, a, schema=AttrSchema(["x", "y"]),
                           config=cfg, seed=0)
    from repro.data import make_queries
    wl = make_queries(v, a, 4, 2, seed=5)
    return col, wl


@pytest.mark.parametrize("mode", ["incore", "hybrid", "ooc"])
def test_fused_wave_matches_unfused_ids(wave_collection, mode):
    """The fused one-kernel expansion step (kernel mode "pallas") must
    return the same ids as the unfused jnp composition (mode "ref") on
    every engine — the traversal-wave kernel's end-to-end contract.
    Distances may differ in the last ulp (different FMA contraction of
    the distance chain), which cannot reorder ids off exact ties."""
    from repro.kernels import config as kcfg
    col, wl = wave_collection
    c = Collection(index=col.index, schema=col.schema, mode=mode)
    out = {}
    for km in ("ref", "pallas"):
        with kcfg.mode(km):
            res = c.search(wl.q, filters=(wl.lo, wl.hi),
                           params=SearchParams(k=4, ef=8))
        out[km] = np.asarray(res.ids)
    np.testing.assert_array_equal(out["ref"], out["pallas"])
