"""Model-stack correctness: per-arch reduced smoke tests (deliverable f),
prefill/decode consistency, SSD chunked-vs-sequential equivalence,
blockwise-vs-direct attention, ring-buffer cache semantics, MoE
invariants, RoPE properties."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import attention as attn_mod
from repro.models import lm, moe as moe_mod, ssm as ssm_mod
from repro.models.common import init_params, apply_rope, count_params

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, T=32):
    kw = {}
    if cfg.embed_inputs:
        kw["tokens"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    else:
        kw["embeds"] = (jax.random.normal(KEY, (B, T, cfg.d_model),
                                          jnp.float32) * 0.1).astype(cfg.dtype)
    if cfg.d_ctx:
        kw["ctx"] = (jax.random.normal(KEY, (B, cfg.n_ctx_tokens, cfg.d_ctx),
                                       jnp.float32) * 0.1).astype(cfg.dtype)
    return kw


# ---------------------------------------------------------------------------
# per-arch smoke (reduced configs; full configs exercised by the dry-run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(ARCHS))
def test_arch_smoke_forward_and_train(arch):
    cfg = get_reduced(arch)
    params = init_params(lm.lm_specs(cfg), KEY)
    kw = _inputs(cfg)
    h, _, aux = lm.forward(params, cfg, tokens=kw.get("tokens"),
                           embeds=kw.get("embeds"), ctx=kw.get("ctx"))
    assert h.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all()), "NaN in forward"
    labels = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, cfg, labels=labels, **kw))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", list(ARCHS))
def test_arch_full_config_param_count_sane(arch):
    """Full configs: spec-tree param counts in the published ballpark
    (no allocation — shapes only)."""
    cfg = get_config(arch)
    n = count_params(lm.lm_specs(cfg))
    expected = {
        "phi3.5-moe-42b-a6.6b": (40e9, 45e9),
        "deepseek-v3-671b": (640e9, 700e9),
        "qwen1.5-32b": (30e9, 36e9),
        "yi-6b": (5.5e9, 6.6e9),
        "llama3.2-3b": (2.8e9, 3.7e9),
        "gemma3-4b": (3.5e9, 4.9e9),
        "musicgen-medium": (1.3e9, 2.1e9),
        "recurrentgemma-2b": (2.3e9, 3.2e9),
        "llama-3.2-vision-11b": (9e9, 11.5e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n / 1e9:.2f}B"


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma3-4b",
                                  "recurrentgemma-2b", "mamba2-1.3b",
                                  "deepseek-v3-671b"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits == full forward logits at the same positions.

    MoE note: capacity-based token dropping is batch-dependent BY DESIGN
    (GShard semantics): a token's expert slot depends on its competitors.
    The equivalence only holds dropless, so the MoE arch runs with a
    capacity factor high enough to never drop."""
    cfg = get_reduced(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(lm.lm_specs(cfg), KEY)
    B, T = 2, 16
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    # teacher-forced full forward
    h, _, _ = lm.forward(params, cfg, tokens=toks)
    full_logits = lm.logits_of(params, cfg, h)        # (B, T, V)
    # prefill on the first half, decode the second half token by token
    half = T // 2
    logits, caches = lm.prefill(params, cfg, tokens=toks[:, :half],
                                max_seq=T + 4)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, half - 1]),
        rtol=2e-2, atol=2e-2)
    for t in range(half, T):
        logits, caches = lm.decode_step(params, cfg, toks[:, t:t + 1],
                                        caches)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# component equivalences
# ---------------------------------------------------------------------------

def test_ssd_chunked_equals_sequential():
    cfg = ssm_mod.SSMConfig(d_model=32, d_state=16, head_dim=8, expand=2,
                            chunk=16)
    params = init_params(ssm_mod.ssm_specs(cfg), KEY)
    x = (jax.random.normal(KEY, (2, 64, 32), jnp.float32) * 0.5
         ).astype(jnp.float32)
    y_chunk, _ = ssm_mod.ssm_block(params, cfg, x)              # 64 % 16 == 0
    cfg2 = dataclasses.replace(cfg, chunk=77)                   # force scan
    y_seq, _ = ssm_mod.ssm_block(params, cfg2, x)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_blockwise_attention_equals_direct():
    B, T, H, dh = 2, 256, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, T, H, dh))
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    scale = 1.0 / np.sqrt(dh)
    mask = pos[:, None, :] <= pos[:, :, None]
    want = attn_mod._sdpa(q, k, v, mask, scale)
    got = attn_mod._sdpa_blockwise(q, k, v, pos, pos, None, scale,
                                   blk_q=64, blk_k=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
    # sliding window agreement
    maskw = mask & (pos[:, None, :] > pos[:, :, None] - 64)
    want_w = attn_mod._sdpa(q, k, v, maskw, scale)
    got_w = attn_mod._sdpa_blockwise(q, k, v, pos, pos, 64, scale,
                                     blk_q=64, blk_k=64)
    np.testing.assert_allclose(np.asarray(got_w, np.float32),
                               np.asarray(want_w, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_ring_cache_window_attention():
    """Decode with a window-sized ring cache == full attention restricted
    to the window."""
    cfg = attn_mod.AttnConfig(d_model=32, n_heads=2, n_kv_heads=1,
                              d_head=16, window=8)
    params = init_params(attn_mod.attn_specs(cfg), KEY)
    B, T = 1, 24
    x = (jax.random.normal(KEY, (B, T, 32), jnp.float32) * 0.3
         ).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    full, _ = attn_mod.attention(params, cfg, x, pos)   # windowed, no cache
    cache = attn_mod.init_cache(cfg, B, max_seq=T)      # S = window = 8
    outs = []
    for t in range(T):
        y, cache = attn_mod.attention(params, cfg, x[:, t:t + 1],
                                      pos[:, t:t + 1], cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_moe_routing_invariants():
    cfg = moe_mod.MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff=16)
    params = init_params(moe_mod.moe_specs(cfg), KEY)
    x = (jax.random.normal(KEY, (2, 16, 32), jnp.float32) * 0.5
         ).astype(jnp.bfloat16)
    out, aux = moe_mod.moe_ffn(params, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) >= 0.0
    # sigmoid routing path (deepseek)
    cfg2 = moe_mod.MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff=16,
                             n_shared=1, d_ff_shared=16,
                             routing="sigmoid_topk")
    params2 = init_params(moe_mod.moe_specs(cfg2), KEY)
    out2, aux2 = moe_mod.moe_ffn(params2, cfg2, x)
    assert float(aux2) == 0.0                 # aux-free
    assert bool(jnp.isfinite(out2.astype(jnp.float32)).all())


def test_moe_grad_flows_to_router():
    cfg = moe_mod.MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff=8)
    params = init_params(moe_mod.moe_specs(cfg), KEY)
    x = jax.random.normal(KEY, (1, 8, 16), jnp.float32).astype(jnp.bfloat16)

    def loss(p):
        out, aux = moe_mod.moe_ffn(p, cfg, x)
        return jnp.sum(out.astype(jnp.float32) ** 2) + aux
    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0


@given(st.integers(0, 2**31 - 1), st.integers(1, 512))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm_and_relativity(seed, offset):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 4, 2, 16), jnp.float32)
    pos = jnp.arange(4, dtype=jnp.int32)[None, :]
    y0 = apply_rope(x, pos)
    y1 = apply_rope(x, pos + offset)
    # norm preservation (rotation)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y0), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4, atol=1e-4)
    # relativity: q.k depends only on position difference
    q0, k0 = np.asarray(y0[0, 1, 0]), np.asarray(y0[0, 3, 0])
    q1, k1 = np.asarray(y1[0, 1, 0]), np.asarray(y1[0, 3, 0])
    np.testing.assert_allclose(q0 @ k0, q1 @ k1, rtol=1e-3, atol=1e-3)
