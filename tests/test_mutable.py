"""Streaming mutability (ISSUE 5): insert / delete / flush / compact,
engine-mode parity under mutation, persistence v3 round-trips."""

import json
import os

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, F
from repro.core import mutable as mut_mod
from repro.core.search import ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams


MODES = ("incore", "hybrid", "ooc")
# parity slack for the test-scale dataset; the 5k bench holds the
# acceptance 0.02 bound
PARITY_TOL = 0.05


@pytest.fixture(scope="module")
def stream_data():
    from repro.data import make_dataset
    v, a = make_dataset("deep", 3000, seed=2, m=2)
    return v, a


@pytest.fixture(scope="module")
def stream_cfg():
    return GMGConfig(seg_per_attr=(2, 2), intra_degree=12, n_clusters=16,
                     build_ef=48, batch_cells=2, dense_threshold=0)


@pytest.fixture(scope="module")
def stream_workload(stream_data):
    from repro.data import make_queries
    v, a = stream_data
    wl = make_queries(v, a, 24, 1, seed=9)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    return wl, tids


def _build(v, a, cfg, seed=0, **kw):
    return Collection.build(v, a, schema=AttrSchema(["price", "ts"]),
                            config=cfg, seed=seed, **kw)


# -- insert: buffered rows are immediately searchable ------------------------


def test_insert_routes_and_is_searchable(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:2000], a[:2000], stream_cfg)
    ids = col.insert(v[2000:2050], a[2000:2050])
    np.testing.assert_array_equal(ids, np.arange(2000, 2050))
    assert col.plan()["pending_rows"] == 50
    assert col.live_count() == 2050
    # a query at a buffered vector must return that row first, exactly
    res = col.search(v[2010][None], k=1)
    assert res.ids[0, 0] == 2010
    assert res.distances[0, 0] <= 1e-5
    assert col.last_stats["buffered_rows"] == 50


def test_insert_validates_shapes(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:500], a[:500], stream_cfg)
    with pytest.raises(ValueError):
        col.insert(v[:3], a[:2])
    with pytest.raises(ValueError):
        col.insert(v[:2, :10], a[:2])
    with pytest.raises(ValueError):
        col.insert(v[:2], a[:2, :1])
    # mapping form routes through the schema order
    ids = col.insert(v[500:502], {"price": a[500:502, 0],
                                  "ts": a[500:502, 1]})
    assert len(ids) == 2


def test_buffer_routing_matches_grid(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:2000], a[:2000], stream_cfg)
    col.insert(v[2000:2100], a[2000:2100])
    mut = col._mut
    expect = mut_mod.route_rows(col.index, a[2000:2100])
    np.testing.assert_array_equal(mut.buf_cells, expect)


# -- incremental parity: 20% inserted vs from-scratch rebuild ----------------


@pytest.fixture(scope="module")
def incremental_pair(stream_data, stream_cfg):
    v, a = stream_data
    n80 = 2400
    inc = _build(v[:n80], a[:n80], stream_cfg)
    inc.insert(v[n80:], a[n80:])
    inc.flush()
    full = _build(v, a, stream_cfg)
    return inc, full


@pytest.mark.parametrize("mode", MODES)
def test_incremental_recall_parity(incremental_pair, stream_workload, mode):
    """After inserting 20% incrementally (and flushing), every engine
    mode stays within tolerance of the from-scratch rebuild."""
    inc, full = incremental_pair
    wl, tids = stream_workload
    p = SearchParams(k=10, ef=96)
    r_inc = inc.search(wl.q, filters=(wl.lo, wl.hi), params=p, engine=mode)
    r_full = full.search(wl.q, filters=(wl.lo, wl.hi), params=p,
                         engine=mode)
    assert r_inc.engine == mode
    assert r_full.recall(tids) - r_inc.recall(tids) <= PARITY_TOL, (
        mode, r_inc.recall(tids), r_full.recall(tids))


def test_buffered_parity_without_flush(stream_data, stream_cfg,
                                       stream_workload):
    """Un-flushed buffers reach the same recall: the brute-force fold is
    exact over the buffered rows."""
    v, a = stream_data
    wl, tids = stream_workload
    n80 = 2400
    col = _build(v[:n80], a[:n80], stream_cfg)
    col.insert(v[n80:], a[n80:])
    assert col.plan()["pending_rows"] == 600
    for mode in MODES:
        res = col.search(wl.q, filters=(wl.lo, wl.hi),
                         params=SearchParams(k=10, ef=96), engine=mode)
        assert recall_at_k(res.ids, tids) >= 0.9, mode


def test_greedy_flush_links_new_rows(stream_data, stream_cfg):
    """graph='greedy' exercises the batched greedy-insert pass (device
    kernels propose neighbors, occlusion prune + reverse link attach);
    new rows must be reachable at high recall."""
    v, a = stream_data
    col = _build(v[:2900], a[:2900], stream_cfg)
    col.insert(v[2900:], a[2900:])
    col.flush(graph="greedy")
    assert col.plan()["pending_rows"] == 0
    # each inserted vector must find itself post-flush (graph-reachable)
    res = col.search(v[2900:], k=1, ef=64)
    hit = (res.ids[:, 0] == np.arange(2900, 3000)).mean()
    assert hit >= 0.9, hit
    # adjacency invariants: intra edges stay inside their cell
    idx = col.index
    for c in range(idx.n_cells):
        s, e = idx.cell_slice(c).start, idx.cell_slice(c).stop
        nbrs = idx.intra_adj[s:e]
        ok = (nbrs == -1) | ((nbrs >= s) & (nbrs < e))
        assert ok.all()


def test_greedy_flush_into_empty_cell_rebuilds(stream_cfg):
    """The explicit graph='greedy' override must not leave rows flushed
    into a build-time-empty cell disconnected: there are no old rows to
    link into, so the cell rebuilds instead."""
    rng = np.random.default_rng(3)
    v = rng.normal(size=(800, 24)).astype(np.float32)
    a = rng.uniform(size=(800, 2)).astype(np.float32)
    a[:, 0] = 0.0                       # segment 0 of attr0 stays empty
    col = _build(v, a, stream_cfg)
    sizes = np.diff(col.index.cell_start)
    assert (sizes == 0).any()
    new_a = a[:40].copy()
    new_a[:, 0] = -1.0                  # routes into the empty cells
    col.insert(v[:40] + 0.5, new_a)
    col.flush(graph="greedy")
    idx = col.index
    for c in np.nonzero(np.diff(idx.cell_start) > 1)[0]:
        s, e = int(idx.cell_start[c]), int(idx.cell_start[c + 1])
        assert (idx.intra_adj[s:e] >= 0).any(axis=1).all(), (
            f"cell {c} holds disconnected rows after greedy flush")


def test_auto_maintenance_flushes_overflowing_cell(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:2000], a[:2000], stream_cfg, )
    col.buffer_rows_per_cell = 16
    col.insert(v[2000:2200], a[2000:2200])    # ~50 rows/cell >> 16
    # overflowing cells flushed themselves; leftovers are under the cap
    counts = (np.bincount(col._mut.buf_cells, minlength=col.index.n_cells)
              if col._mut.pending_rows else np.zeros(1, int))
    assert counts.max() <= 16
    assert col.live_count() == 2200
    res = col.search(v[2100][None], k=1)
    assert res.ids[0, 0] == 2100


# -- deletes -----------------------------------------------------------------


def test_delete_never_returns_deleted(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v, a, stream_cfg)
    rng = np.random.default_rng(4)
    dead = rng.choice(len(v), 150, replace=False)
    assert col.delete(dead) == 150
    assert col.plan()["deleted_rows"] == 150
    assert col.live_count() == len(v) - 150
    from repro.data import make_queries
    wl = make_queries(v, a, 24, 1, seed=13)
    expr = (F("price") < 0.35) | (F("price") > 0.65)
    for mode in MODES:
        res = col.search(wl.q, filters=(wl.lo, wl.hi),
                         params=SearchParams(k=10, ef=64), engine=mode)
        assert np.intersect1d(res.ids[res.ids >= 0], dead).size == 0, mode
        # disjunctive plans fold per-box candidates through qmap; the
        # tombstone mask must hold there too
        res = col.search(wl.q, filters=expr,
                         params=SearchParams(k=10, ef=64), engine=mode)
        assert np.intersect1d(res.ids[res.ids >= 0], dead).size == 0, mode
    # ground truth honors tombstones as well
    gt = col.ground_truth(wl.q, filters=(wl.lo, wl.hi), k=10)
    assert np.intersect1d(gt[gt >= 0], dead).size == 0


def test_delete_keeps_engines_warm_and_correct(stream_data, stream_cfg):
    """Deleting after engines are built refreshes their attr tables in
    place (the cell cache stays resident) instead of cold rebuilding."""
    v, a = stream_data
    col = _build(v, a, stream_cfg, mode="hybrid")
    wl_q = v[:8] + 0.01
    col.search(wl_q, k=5, ef=64)
    eng = col._hybrid
    cache_before = eng.cache
    dead = np.arange(0, 60)
    col.delete(dead)
    assert col._hybrid is eng and eng.cache is cache_before
    res = col.search(wl_q, k=5, ef=64)
    assert np.intersect1d(res.ids[res.ids >= 0], dead).size == 0


def test_delete_buffered_and_errors(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:1000], a[:1000], stream_cfg)
    ids = col.insert(v[1000:1010], a[1000:1010])
    assert col.delete(ids[:4]) == 4           # buffered: dropped outright
    assert col.plan()["pending_rows"] == 6
    assert col.delete(ids[4]) == 1
    assert col.delete(ids[4]) == 0            # already gone: no-op
    with pytest.raises(KeyError):
        col.delete([10**9])                   # never allocated: error
    assert col.delete([3]) == 1
    assert col.delete([3]) == 0               # tombstoned: no-op
    # a batch with a never-allocated id raises WITHOUT partial effects
    before = col.plan()["pending_rows"]
    with pytest.raises(KeyError):
        col.delete([int(ids[5]), 10**9])
    assert col.plan()["pending_rows"] == before
    assert col.delete(ids[5]) == 1            # still present, deletable


# -- compaction --------------------------------------------------------------


def test_compact_equals_fresh_build(stream_data, stream_cfg):
    """compact() == build_gmg on the surviving rows: identical search
    results (ids and distances) under identical params."""
    v, a = stream_data
    col = _build(v[:2800], a[:2800], stream_cfg)
    col.insert(v[2800:], a[2800:])
    rng = np.random.default_rng(8)
    dead = rng.choice(3000, 140, replace=False)
    col.delete(dead)
    live_v, live_a, live_ids = col._live_view()
    stats = col.compact(seed=11)
    # deleted *buffered* rows drop outright; only base rows tombstone
    assert stats["reclaimed"] == (dead < 2800).sum()
    assert stats["flushed"] == 200 - (dead >= 2800).sum()
    assert col.plan()["pending_rows"] == 0
    assert col.plan()["deleted_rows"] == 0
    assert col.n == 3000 - 140
    fresh = _build(live_v, live_a, stream_cfg, seed=11)
    q = v[:16] + 0.02
    p = SearchParams(k=10, ef=64)
    rc = col.search(q, filters=F("price") >= 0.2, params=p)
    rf = fresh.search(q, filters=F("price") >= 0.2, params=p)
    mapped = np.where(rf.ids >= 0, live_ids[np.maximum(rf.ids, 0)], -1)
    np.testing.assert_array_equal(rc.ids, mapped)
    np.testing.assert_allclose(rc.distances, rf.distances)


def test_oversized_cells_reported(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:1000], a[:1000], stream_cfg)
    assert col.plan()["oversized_cells"] == []
    # pile everything onto one cell's range: route duplicates of one row
    big = np.repeat(a[:1], 900, axis=0)
    col.buffer_rows_per_cell = 10**6          # keep them buffered
    col.insert(np.repeat(v[:1], 900, axis=0), big)
    assert col.plan()["oversized_cells"] != []


# -- persistence v3 ----------------------------------------------------------


def test_save_load_roundtrips_mutation_state(stream_data, stream_cfg,
                                             tmp_path):
    v, a = stream_data
    col = _build(v[:2500], a[:2500], stream_cfg)
    col.insert(v[2500:2600], a[2500:2600])
    col.delete([7, 11, 2550])
    path = os.path.join(tmp_path, "mut.npz")
    col.save(path)
    col2 = Collection.load(path)
    assert col2.plan()["pending_rows"] == col.plan()["pending_rows"]
    assert col2.plan()["deleted_rows"] == col.plan()["deleted_rows"]
    assert col2.plan()["mutation_epoch"] == col.plan()["mutation_epoch"]
    assert col2._mut.next_id == col._mut.next_id
    q = v[:12] + 0.01
    r1 = col.search(q, filters=(F("ts") >= 0.1), k=10, ef=64)
    r2 = col2.search(q, filters=(F("ts") >= 0.1), k=10, ef=64)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    # next insert on the loaded collection continues the id sequence
    ids = col2.insert(v[2600:2601], a[2600:2601])
    assert ids[0] == col._mut.next_id


def test_load_v2_file_still_works(stream_data, stream_cfg, tmp_path):
    """Regression: pre-mutability (v2) files load with a fresh mutation
    state and identical search behavior."""
    v, a = stream_data
    col = _build(v[:1500], a[:1500], stream_cfg)
    path = os.path.join(tmp_path, "v3.npz")
    col.save(path)
    # rewrite the file as a faithful v2: strip mutation arrays + fields
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files if not k.startswith("mut_")
                   and k != "meta_json"}
        meta = json.loads(bytes(z["meta_json"].tobytes()).decode())
    meta["format_version"] = 2
    for key in ("next_id", "mutation_epoch", "buffer_rows_per_cell"):
        meta.pop(key, None)
    payload["meta_json"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    v2_path = os.path.join(tmp_path, "v2.npz")
    np.savez(v2_path, **payload)
    col2 = Collection.load(v2_path)
    q = v[:8] + 0.01
    r1 = col.search(q, k=5, ef=64)
    r2 = col2.search(q, k=5, ef=64)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    # and the loaded collection is fully mutable
    ids = col2.insert(v[1500:1502], a[1500:1502])
    assert ids.tolist() == [1500, 1501]


# -- core helpers ------------------------------------------------------------


def test_scan_buffer_orders_by_distance_then_id():
    st = mut_mod.MutationState(next_id=100)
    st.buf_vectors = np.zeros((3, 4), np.float32)
    st.buf_vectors[1] += 1.0
    st.buf_attrs = np.array([[0.5], [0.5], [2.0]], np.float32)
    st.buf_ids = np.array([100, 101, 102], np.int64)
    st.buf_cells = np.zeros(3, np.int32)
    q = np.zeros((1, 4), np.float32)
    lo = np.array([[0.0]], np.float32)
    hi = np.array([[1.0]], np.float32)
    ids, d = mut_mod.scan_buffer(st, q, lo, hi, 3)
    # row 2 fails the predicate; rows 0,1 order by distance
    assert ids[0].tolist() == [100, 101, -1]
    assert np.isinf(d[0, 2])


def test_flush_index_preserves_untouched_cells(stream_data, stream_cfg):
    v, a = stream_data
    col = _build(v[:2000], a[:2000], stream_cfg)
    before = col.index
    new = v[2000:2010]
    cells = mut_mod.route_rows(before, a[2000:2010])
    idx2, old_to_new = mut_mod.flush_index(
        before, new, a[2000:2010], np.arange(2000, 2010), cells, seed=0)
    assert idx2.n == 2010
    # every old row keeps its vector/attr/perm under the remap
    np.testing.assert_array_equal(idx2.vectors[old_to_new], before.vectors)
    np.testing.assert_array_equal(idx2.perm[old_to_new], before.perm)
    np.testing.assert_array_equal(idx2.cell_of[old_to_new], before.cell_of)
    # quantized copy spliced consistently
    np.testing.assert_array_equal(idx2.vq[old_to_new], before.vq)
    # cell CSR still consistent
    sizes = np.diff(idx2.cell_start)
    assert sizes.sum() == 2010
    assert (np.bincount(idx2.cell_of, minlength=idx2.n_cells)
            == sizes).all()
