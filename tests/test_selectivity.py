"""Cost-model routing + selectivity estimation (repro.core.selectivity).

Covers the planner-level cost model end to end: estimator accuracy on
independent and correlated attributes, the public clamped
``estimate_selectivity`` helper on degenerate (constant-attribute)
grids, route boundaries incl. per-row k sensitivity and the
``CostModel.off()`` ablation, and cross-mode parity — the same
RouteDecision consumed by incore / hybrid / ooc, on pure-dense and
mixed-route disjunctive plans.
"""

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, F
from repro.core import selectivity as sel_mod
from repro.core.search import recall_at_k
from repro.core.selectivity import (CostModel, SelectivityEstimator,
                                    estimate_selectivity, route_boxes)
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_dataset, make_queries

MODES = ("incore", "hybrid", "ooc")


def _qbox(attrs, cols, widths, center=0.5):
    """One (1, m) box: per-attr quantile windows around ``center``."""
    m = attrs.shape[1]
    lo = np.full((1, m), -np.inf, np.float32)
    hi = np.full((1, m), np.inf, np.float32)
    for j, w in zip(cols, widths):
        qs = np.quantile(attrs[:, j].astype(np.float64),
                         [center - w / 2, center + w / 2])
        lo[0, j], hi[0, j] = qs[0], qs[1]
    return lo, hi


# -- estimator accuracy --------------------------------------------------


def test_estimator_rows_independent(small_index, small_data):
    """Refined per-cell estimate tracks exact counts on independent
    uniform attributes (where the global product is already right)."""
    v, a = small_data
    wl = make_queries(v, a, 24, 2, seed=11, sel_range=(0.05, 0.6))
    est = SelectivityEstimator(small_index)
    got = est.estimate_rows(wl.lo, wl.hi)
    exact = np.array([np.all((a >= lo) & (a <= hi), axis=1).sum()
                      for lo, hi in zip(wl.lo, wl.hi)], np.float64)
    n = small_index.n
    assert np.mean(np.abs(got - exact)) / n < 0.02
    assert np.max(np.abs(got - exact)) / n < 0.08


def test_estimator_beats_independence_on_correlated():
    """a1 == a0: the independence product underestimates 5x; the
    per-cell histograms recover most of the correlated mass."""
    v, a = make_dataset("deep", 3000, seed=1, m=2)
    a = a.copy()
    a[:, 1] = a[:, 0]
    col = Collection.build(
        v, a, schema=AttrSchema.generic(2),
        config=GMGConfig(seg_per_attr=(4, 4), intra_degree=8,
                         n_clusters=8, build_ef=32), seed=0)
    idx = col.index
    lo, hi = _qbox(a, (0, 1), (0.2, 0.2))
    exact = float(np.all((a >= lo[0]) & (a <= hi[0]), axis=1).sum())
    indep = float(estimate_selectivity(idx, lo, hi)[0] * idx.n)
    refined = float(SelectivityEstimator(idx).estimate_rows(lo, hi)[0])
    assert exact == pytest.approx(0.2 * idx.n, rel=0.1)   # truth ~ P(a0)
    assert indep == pytest.approx(0.04 * idx.n, rel=0.2)  # product ~ P^2
    assert abs(refined - exact) < abs(indep - exact)      # strictly better
    assert refined > indep                                # from below


def test_estimate_selectivity_degenerate_constant_attr():
    """Regression (satellite fix): a constant attribute collapses its
    quantile grid to duplicate edges — the estimator must stay clamped
    and NaN-free, and search must still work."""
    v, a = make_dataset("deep", 600, seed=2, m=2)
    a = a.copy()
    a[:, 0] = 5.0
    col = Collection.build(
        v, a, schema=AttrSchema.generic(2),
        config=GMGConfig(seg_per_attr=(2, 2), intra_degree=8,
                         n_clusters=8, build_ef=32), seed=0)
    idx = col.index
    m = a.shape[1]
    inf_lo = np.full((1, m), -np.inf, np.float32)
    inf_hi = np.full((1, m), np.inf, np.float32)
    # box containing the constant -> everything qualifies on that attr
    sel_all = estimate_selectivity(idx, inf_lo, inf_hi)
    # box excluding it -> nothing does
    lo2, hi2 = inf_lo.copy(), inf_hi.copy()
    lo2[0, 0], hi2[0, 0] = 6.0, 7.0
    sel_none = estimate_selectivity(idx, lo2, hi2)
    for s in (sel_all, sel_none):
        assert np.all(np.isfinite(s)) and np.all((s >= 0) & (s <= 1))
    assert sel_all[0] == pytest.approx(1.0, abs=1e-6)
    assert sel_none[0] == pytest.approx(0.0, abs=1e-2)
    # the estimator variant survives it too, and search end-to-end
    rows = SelectivityEstimator(idx).estimate_rows(inf_lo, inf_hi)
    assert np.all(np.isfinite(rows))
    res = col.search(v[:4] + 0.01, k=5)
    assert (res.ids[:, 0] >= 0).all()


# -- route boundaries ----------------------------------------------------


def test_route_boundaries(small_index, small_data):
    """dense / mid / broad land where the thresholds say; empty
    candidate sets never route dense."""
    v, a = small_data
    tiny_lo, tiny_hi = _qbox(a, (0, 1), (0.01, 0.01))    # est ~ 1e-4
    mid_lo, mid_hi = _qbox(a, (0, 1), (0.17, 0.17))      # est ~ 0.03
    broad_lo, broad_hi = _qbox(a, (), ())                # est = 1
    # empty: an inverted box (lo > hi) selects no cells — the planner
    # prunes these, but engines can be handed raw (lo, hi) directly
    empty_lo, empty_hi = broad_lo.copy(), broad_hi.copy()
    empty_lo[0, 0], empty_hi[0, 0] = 1.0, 0.0
    lo = np.concatenate([tiny_lo, mid_lo, broad_lo, empty_lo])
    hi = np.concatenate([tiny_hi, mid_hi, broad_hi, empty_hi])
    rk = np.full(4, 10, np.int64)
    r = route_boxes(small_index, lo, hi, rk)
    assert r.route[0] == sel_mod.ROUTE_DENSE
    assert r.route[1] == sel_mod.ROUTE_MID and r.ef_mult[1] == 2
    assert r.route[2] == sel_mod.ROUTE_BROAD and r.ef_mult[2] == 1
    assert r.cand_rows[3] == 0
    assert r.route[3] != sel_mod.ROUTE_DENSE             # nothing to scan
    assert r.counts() == {"n_dense": 1, "n_mid": 1, "n_broad": 2}

    # ablation arm: everything broad, no effort scaling
    r_off = route_boxes(small_index, lo, hi, rk, cost=CostModel.off())
    assert (r_off.route == sel_mod.ROUTE_BROAD).all()
    assert (r_off.ef_mult == 1).all()

    with pytest.raises(ValueError):
        route_boxes(small_index, lo, hi, np.full(3, 10, np.int64))


def test_route_k_sensitivity(small_index, small_data):
    """The rows-per-k dense bound sees each row's own k: the same box
    can be dense for a k=20 request and mid for a k=10 one."""
    v, a = small_data
    lo, hi = _qbox(a, (0, 1), (0.158, 0.158))   # est_rows ~ 100 at n=4000
    lo2, hi2 = np.tile(lo, (2, 1)), np.tile(hi, (2, 1))
    r = route_boxes(small_index, lo2, hi2, np.array([10, 20], np.int64))
    assert 64 < r.est_rows[0] < 160              # in the k-sensitive band
    assert r.route[0] == sel_mod.ROUTE_MID       # 100 > max(8*10, 64)
    assert r.route[1] == sel_mod.ROUTE_DENSE     # 100 <= 8*20


def test_mid_effort_doubling_band():
    """Deep-mid rows (est below sqrt(mid_frac * dense_frac)) get the
    4x effort bucket when the dense route is fenced off."""
    v, a = make_dataset("deep", 2000, seed=3, m=2)
    col = Collection.build(
        v, a, schema=AttrSchema.generic(2),
        config=GMGConfig(seg_per_attr=(2, 2), intra_degree=8,
                         n_clusters=8, build_ef=32, dense_threshold=8),
        seed=0)
    cost = CostModel(dense_rows_per_k=0, dense_rows_min=0,
                     dense_cand_mult=0)          # est-driven dense off
    lo, hi = _qbox(a, (0, 1), (0.06, 0.06))      # est ~ 0.0036 < 0.00707
    r = route_boxes(col.index, lo, hi, np.array([10], np.int64),
                    cost=cost)
    assert r.route[0] == sel_mod.ROUTE_MID
    assert r.ef_mult[0] == 4


# -- cross-mode parity ---------------------------------------------------


def test_dense_route_parity_across_modes(small_collection, small_data):
    """An ultra-selective workload routes dense in every engine mode,
    beats the forced-traversal arm on recall, and all three modes see
    the same (planner-computed) route split."""
    v, a = small_data
    wl = make_queries(v, a, 16, 2, seed=21, fixed_width=0.02)
    truth = small_collection.ground_truth(wl.q, (wl.lo, wl.hi), k=10)
    splits = []
    for mode in MODES:
        res = small_collection.search(wl.q, (wl.lo, wl.hi), k=10,
                                      engine=mode)
        st = res.stats
        assert st["n_dense"] == len(wl.q), (mode, st)
        assert "est_rel_err_dense" in st
        splits.append((st["n_dense"], st["n_mid"], st["n_broad"]))
        assert recall_at_k(res.ids, truth) >= 0.95, mode
        off = small_collection.search(
            wl.q, (wl.lo, wl.hi),
            params=SearchParams(k=10, cost=CostModel.off()), engine=mode)
        assert small_collection.last_stats["n_dense"] == 0
        assert (recall_at_k(res.ids, truth)
                >= recall_at_k(off.ids, truth) - 1e-9), mode
    assert len(set(splits)) == 1                 # same RouteDecision


def test_mixed_route_disjunctive_plan(small_collection, small_data):
    """A DNF filter whose branches land on different routes: the box
    batch carries dense AND broad rows through one engine pass, every
    mode, and still merges to the exact answer's neighborhood."""
    v, a = small_data
    q10 = float(np.quantile(a[:, 0], 0.01))
    t50 = float(np.quantile(a[:, 1], 0.5))
    filt = (F("price") <= q10) | (F("ts") >= t50)
    q = v[:16] + 0.01
    truth = small_collection.ground_truth(q, filt, k=10)
    for mode in MODES:
        res = small_collection.search(q, filt, k=10, engine=mode)
        st = res.stats
        assert st["n_dense"] >= 16, (mode, st)   # the narrow branch
        assert st["n_broad"] >= 16, (mode, st)   # the broad branch
        assert st["planner"]["n_boxes"] == 32
        assert recall_at_k(res.ids, truth) >= 0.9, mode
