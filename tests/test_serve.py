"""Serving engine + RAG bridge."""

import collections

import numpy as np
import pytest
import jax

from repro.configs import get_reduced
from repro.models import lm
from repro.models.common import init_params
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_reduced("llama3.2-3b")
    params = init_params(lm.lm_specs(cfg), jax.random.PRNGKey(0))
    return params, cfg


def test_engine_serves_batched_requests(small_lm):
    params, cfg = small_lm
    eng = Engine(params, cfg, lanes=4, max_seq=64)
    assert isinstance(eng.queue, collections.deque)   # O(1) head pops
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, size=5 + i),
                    max_new=6) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_steps=200)
    assert len(done) == 6
    for r in done:
        assert len(r.out) == 6
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_engine_greedy_deterministic(small_lm):
    params, cfg = small_lm
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab, size=8)

    def gen():
        eng = Engine(params, cfg, lanes=2, max_seq=64)
        eng.submit(Request(rid=0, prompt=prompt, max_new=5))
        return eng.run(max_steps=100)[0].out
    assert gen() == gen()


def test_rag_pipeline_end_to_end(small_lm, small_collection):
    from repro.api import F
    from repro.serve.rag import RagPipeline
    params, cfg = small_lm
    rag = RagPipeline(params=params, cfg=cfg, collection=small_collection)
    rng = np.random.default_rng(2)
    tokens = rng.integers(1, cfg.vocab, size=(3, 12))
    attrs = small_collection.index.attrs
    lo0 = float(np.quantile(attrs[:, 0], 0.2))
    hi0 = float(np.quantile(attrs[:, 0], 0.8))
    res = rag.retrieve(tokens, filters=F("price").between(lo0, hi0), k=5)
    assert res.ids.shape == (3, 5)
    assert (res.valid_counts > 0).any()
    # retrieved docs satisfy the range predicate
    inv = np.argsort(small_collection.index.perm)
    for got, _ in res:
        a = attrs[inv[got]][:, 0]
        assert ((a >= lo0) & (a <= hi0)).all()
