"""Public `repro.api` surface: schema, filter compilation, Collection
lifecycle (search / engine dispatch / persist), QueryResult invariants."""

import os

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, F, QueryResult
from repro.api.filters import compile_filters
from repro.core.types import SearchParams


SCHEMA = AttrSchema(["price", "ts", "views", "duration"])


# -- schema -----------------------------------------------------------------

def test_schema_basics():
    assert len(SCHEMA) == 4
    assert SCHEMA.index("ts") == 1
    assert "views" in SCHEMA and "bogus" not in SCHEMA
    assert AttrSchema.generic(2).names == ("attr0", "attr1")
    with pytest.raises(KeyError):
        SCHEMA.index("bogus")
    with pytest.raises(ValueError):
        AttrSchema(["a", "a"])


# -- filter expression compilation ------------------------------------------

def test_compile_between_and_one_sided():
    lo, hi = (F("price").between(10, 50)).compile(SCHEMA, 3)
    assert lo.shape == hi.shape == (3, 4)
    assert (lo[:, 0] == 10).all() and (hi[:, 0] == 50).all()
    # untouched attributes stay unbounded
    assert np.isneginf(lo[:, 1:]).all() and np.isposinf(hi[:, 1:]).all()

    lo, hi = (F("ts") >= 7.0).compile(SCHEMA, 2)
    assert (lo[:, 1] == 7.0).all() and np.isposinf(hi[:, 1]).all()
    lo, hi = (F("ts") <= 7.0).compile(SCHEMA, 2)
    assert np.isneginf(lo[:, 1]).all() and (hi[:, 1] == 7.0).all()


def test_compile_strict_and_eq():
    lo, _ = (F("views") > 1.0).compile(SCHEMA, 1)
    assert lo[0, 2] > 1.0                      # one ulp above
    assert lo[0, 2] == np.nextafter(np.float32(1.0), np.float32(np.inf))
    _, hi = (F("views") < 1.0).compile(SCHEMA, 1)
    assert hi[0, 2] < 1.0
    lo, hi = (F("duration") == 3.0).compile(SCHEMA, 1)
    assert lo[0, 3] == hi[0, 3] == 3.0


def test_compile_conjunction_intersects_same_attr():
    expr = (F("price") >= 2) & (F("price") <= 9) & (F("price") >= 5)
    lo, hi = expr.compile(SCHEMA, 2)
    assert (lo[:, 0] == 5).all() and (hi[:, 0] == 9).all()


def test_compile_per_query_bounds_and_shape_errors():
    t0 = np.array([1.0, 2.0, 3.0], np.float32)
    lo, _ = (F("ts") >= t0).compile(SCHEMA, 3)
    np.testing.assert_array_equal(lo[:, 1], t0)
    with pytest.raises(ValueError):
        (F("ts") >= t0).compile(SCHEMA, 4)     # batch mismatch
    with pytest.raises(KeyError):
        (F("bogus") >= 0).compile(SCHEMA, 1)
    # disjunctions build fine but cannot lower to ONE box — single-box
    # compile raises; the DNF path (compile_dnf / planner) serves them
    with pytest.raises(ValueError):
        ((F("ts") >= 0) | (F("price") <= 1)).compile(SCHEMA, 1)


def test_compile_filters_normalization():
    lo, hi = compile_filters(None, SCHEMA, 2)
    assert np.isneginf(lo).all() and np.isposinf(hi).all()
    lo2, hi2 = compile_filters((lo, hi), SCHEMA, 2)
    np.testing.assert_array_equal(lo, lo2)
    with pytest.raises(ValueError):
        compile_filters((lo[:1], hi), SCHEMA, 2)
    with pytest.raises(TypeError):
        compile_filters("price < 3", SCHEMA, 1)


# -- Collection: search + equivalence ---------------------------------------

def test_one_sided_filter_matches_hand_built(small_collection, small_data,
                                             small_queries):
    """Acceptance: F("ts") >= t0 == the hand-built ±inf (lo, hi) arrays."""
    v, a = small_data
    t0 = float(np.quantile(a[:, 1], 0.5))
    q = small_queries.q[:16]
    res_expr = small_collection.search(q, filters=F("ts") >= t0, k=10)
    B, m = 16, a.shape[1]
    lo = np.full((B, m), -np.inf, np.float32)
    hi = np.full((B, m), np.inf, np.float32)
    lo[:, 1] = t0
    res_raw = small_collection.search(q, filters=(lo, hi), k=10)
    np.testing.assert_array_equal(res_expr.ids, res_raw.ids)
    np.testing.assert_allclose(res_expr.distances, res_raw.distances)


def test_partial_attribute_filter_recall(small_collection, small_data):
    """Predicate on one non-leading attribute through the expression
    layer reaches the same recall as the raw-array path."""
    from repro.data import make_queries
    v, a = small_data
    wl = make_queries(v, a, 16, 1, seed=21, attr_subset=[1])
    res = small_collection.search(
        wl.q, filters=F("ts").between(wl.lo[:, 1], wl.hi[:, 1]), k=10)
    truth = small_collection.ground_truth(wl.q, filters=(wl.lo, wl.hi),
                                          k=10)
    assert res.recall(truth) >= 0.85


def test_search_deterministic_given_seed(small_collection, small_queries):
    wl = small_queries
    r1 = small_collection.search(wl.q, filters=(wl.lo, wl.hi),
                                 params=SearchParams(k=10, seed=4))
    r2 = small_collection.search(wl.q, filters=(wl.lo, wl.hi),
                                 params=SearchParams(k=10, seed=4))
    np.testing.assert_array_equal(r1.ids, r2.ids)


def test_empty_batch_returns_empty_result(small_collection):
    res = small_collection.search(
        np.zeros((0, small_collection.dim), np.float32), k=7)
    assert isinstance(res, QueryResult) and len(res) == 0
    assert res.ids.shape == (0, 7) and res.distances.shape == (0, 7)


def test_query_result_helpers(small_collection, small_queries):
    wl = small_queries
    res = small_collection.search(wl.q, filters=(wl.lo, wl.hi), k=10)
    assert len(res) == len(wl.q) and res.k == 10
    assert (res.valid_counts == (res.ids >= 0).sum(axis=1)).all()
    for ids_b, d_b in res:
        assert (ids_b >= 0).all() and np.isfinite(d_b).all()


def test_build_from_attr_mapping(small_data):
    v, a = small_data
    col = Collection.build(
        v[:512], {"price": a[:512, 0], "ts": a[:512, 1]},
        seed=0)
    assert col.schema.names == ("price", "ts")
    res = col.search(v[:4], filters=F("price") >= 0.0, k=3)
    assert res.ids.shape == (4, 3)


# -- engine dispatch --------------------------------------------------------

def test_dispatch_by_device_budget(small_collection, small_queries,
                                   small_truth):
    wl = small_queries
    col = small_collection
    assert col.plan()["engine"] == "incore"
    resident = col.out_of_core_resident_bytes()
    # budget above the residents but below the hybrid floor -> streaming
    budget = (resident + col.hybrid_min_bytes()) // 2
    assert resident < budget < col.hybrid_min_bytes() < col.in_core_bytes()
    ooc = Collection(index=col.index, schema=col.schema,
                     device_budget_bytes=budget)
    assert ooc.plan()["engine"] == "ooc"
    res = ooc.search(wl.q, filters=(wl.lo, wl.hi),
                     params=SearchParams(k=10, ef=64))
    assert res.engine == "ooc"
    assert ooc.last_stats["n_batches"] >= 1
    assert res.recall(small_truth[0]) >= 0.8
    # explicit override wins over the budget (legacy engine names keep
    # working), and stats never carry over: last_stats reflects the
    # incore pass only, no leftover streaming counters
    res_ic = ooc.search(wl.q[:4], filters=(wl.lo[:4], wl.hi[:4]),
                        k=10, engine="in_core")
    assert res_ic.engine == "incore"
    assert ooc.last_stats["engine"] == "incore"
    assert ooc.last_stats["n_rows"] == 4
    assert "n_batches" not in ooc.last_stats
    # a budget change rebuilds the streamer with the new graph window
    first = ooc._streamer()
    ooc.device_budget_bytes = budget * 2
    assert ooc._streamer() is not first


def test_dispatch_hybrid_budget_tier(small_collection, small_queries,
                                     small_truth):
    """A budget that fits the int8 residents plus a useful cell cache
    resolves to the hybrid middle tier."""
    wl = small_queries
    col = small_collection
    budget = col.hybrid_min_bytes() + (1 << 18)
    assert budget < col.in_core_bytes()
    hyb = Collection(index=col.index, schema=col.schema,
                     device_budget_bytes=budget)
    plan = hyb.plan()
    assert plan["engine"] == "hybrid"
    assert plan["cache_slots"] >= 2
    res = hyb.search(wl.q, filters=(wl.lo, wl.hi),
                     params=SearchParams(k=10, ef=64))
    assert res.engine == "hybrid"
    assert hyb.last_stats["cache_misses"] >= 1
    assert res.recall(small_truth[0]) >= 0.8
    # warm repeat: the LRU keeps hot cells resident across query batches
    hyb.search(wl.q, filters=(wl.lo, wl.hi),
               params=SearchParams(k=10, ef=64))
    assert hyb.last_stats["cache_hits"] >= 1
    # unknown mode names are rejected at construction
    with pytest.raises(ValueError):
        Collection(index=col.index, schema=col.schema, mode="bogus")


def test_explicit_mode_requires_quantized_copy(small_data):
    """hybrid/ooc modes need the int8 copy; an index built with
    quantize=False must fail fast at resolve time, not deep in the
    runtime."""
    from repro.core.types import GMGConfig
    v, a = small_data
    cfg = GMGConfig(seg_per_attr=(2,), intra_degree=8, n_clusters=8,
                    build_ef=32, quantize=False)
    col = Collection.build(v[:512], a[:512, :1], config=cfg, seed=0)
    assert col.index.vq is None
    for mode in ("hybrid", "ooc"):
        with pytest.raises(ValueError, match="quantize"):
            col.plan(engine=mode)


def test_dispatch_budget_too_small_raises(small_collection):
    col = Collection(index=small_collection.index,
                     schema=small_collection.schema,
                     device_budget_bytes=16)
    with pytest.raises(ValueError):
        col.search(np.zeros((1, col.dim), np.float32), k=1)


# -- persistence ------------------------------------------------------------

def test_save_load_roundtrip_identical(small_collection, small_queries,
                                       tmp_path):
    wl = small_queries
    path = os.path.join(tmp_path, "col.npz")
    small_collection.save(path)
    col2 = Collection.load(path)
    assert col2.schema.names == small_collection.schema.names
    assert col2.index.config == small_collection.index.config
    r1 = small_collection.search(wl.q, filters=(wl.lo, wl.hi), k=10)
    r2 = col2.search(wl.q, filters=(wl.lo, wl.hi), k=10)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    np.testing.assert_allclose(r1.distances, r2.distances)


def test_save_load_roundtrips_engine_mode(small_collection, tmp_path):
    """Regression (ISSUE 3): a loaded collection must rebuild the same
    engine — mode AND budget round-trip, not just the index arrays."""
    path = os.path.join(tmp_path, "mode.npz")
    budget = small_collection.hybrid_min_bytes() + (1 << 18)
    col = Collection(index=small_collection.index,
                     schema=small_collection.schema,
                     device_budget_bytes=budget)
    assert col.plan()["engine"] == "hybrid"
    col.save(path)
    col2 = Collection.load(path)
    assert col2.mode == "auto"
    assert col2.device_budget_bytes == budget
    assert col2.plan()["engine"] == "hybrid"
    # an explicit (non-auto) mode survives the round-trip too
    col.mode = "ooc"
    col.save(path)
    col3 = Collection.load(path)
    assert col3.mode == "ooc" and col3.plan()["engine"] == "ooc"
    # and load-time overrides still win
    col4 = Collection.load(path, mode="incore")
    assert col4.plan()["engine"] == "incore"


# -- selectivity estimator --------------------------------------------------

def test_estimate_selectivity_matches_empirical(small_collection,
                                                small_data):
    """CDF-product estimate vs. the true in-range fraction: uniform
    independent attributes, so the conjunction-independence assumption
    holds and the estimate should track closely."""
    from repro.data import make_queries
    v, a = small_data
    s = small_collection._searcher()
    wl = make_queries(v, a, 48, 2, seed=11)
    est = s._estimate_selectivity(wl.lo, wl.hi)
    emp = np.stack([((a >= wl.lo[b]) & (a <= wl.hi[b])).all(axis=1).mean()
                    for b in range(len(wl.q))])
    assert est.shape == (48,)
    assert np.abs(est - emp).mean() < 0.02
    assert np.abs(est - emp).max() < 0.08


def test_estimate_selectivity_one_sided_and_open(small_collection,
                                                 small_data):
    v, a = small_data
    s = small_collection._searcher()
    B, m = 8, a.shape[1]
    lo = np.full((B, m), -np.inf, np.float32)
    hi = np.full((B, m), np.inf, np.float32)
    est = s._estimate_selectivity(lo, hi)
    np.testing.assert_allclose(est, 1.0, atol=1e-6)   # fully open box
    t0 = float(np.quantile(a[:, 1], 0.75))
    lo[:, 1] = t0                                      # top quartile of ts
    est = s._estimate_selectivity(lo, hi)
    emp = (a[:, 1] >= t0).mean()
    assert np.abs(est - emp).max() < 0.05
