"""Grid partition invariants (paper Section 3.1) — property-based."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.core import grid


@given(st.integers(0, 2**31 - 1), st.sampled_from([(2,), (4,), (2, 2),
                                                   (4, 4), (2, 3, 2)]))
@settings(max_examples=20, deadline=None)
def test_partition_disjoint_cover_balanced(seed, seg_per_attr):
    rng = np.random.default_rng(seed)
    n, m = 2000, len(seg_per_attr) + 1
    attrs = rng.normal(size=(n, m))
    seg_bounds, cell_of, order, cell_start, cell_lo, cell_hi = \
        grid.build_grid(attrs, seg_per_attr)
    S = int(np.prod(seg_per_attr))
    # cover: every object in exactly one cell
    assert cell_of.shape == (n,)
    assert (cell_of >= 0).all() and (cell_of < S).all()
    # CSR offsets consistent
    counts = np.bincount(cell_of, minlength=S)
    np.testing.assert_array_equal(np.diff(cell_start), counts)
    # cardinality balance (continuous attrs -> near-perfect quantiles)
    assert counts.max() <= int(1.25 * n / S) + len(seg_per_attr) + 1


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_cells_for_box_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    n, p = 1000, 2
    attrs = rng.normal(size=(n, p))
    seg = (3, 3)
    seg_bounds, cell_of, order, cell_start, cell_lo, cell_hi = \
        grid.build_grid(attrs, seg)
    attrs_s = attrs[order]
    cell_of_s = cell_of[order]
    lo = rng.normal(size=(5, p)) - 0.5
    hi = lo + rng.uniform(0.2, 2.0, size=(5, p))
    mask = grid.cells_for_box(cell_lo, cell_hi, lo, hi)
    # any cell holding an in-range object must be selected
    for b in range(5):
        ok = ((attrs_s >= lo[b]) & (attrs_s <= hi[b])).all(axis=1)
        touched = np.unique(cell_of_s[ok])
        assert mask[b, touched].all(), (touched, np.nonzero(mask[b])[0])


def test_skewed_attr_segments_stay_monotone():
    rng = np.random.default_rng(0)
    vals = np.concatenate([np.zeros(500), rng.normal(size=500)])  # ties
    edges = grid.quantile_edges(vals, 4)
    assert (np.diff(edges) > 0).all()
    seg = grid.segment_of(vals, edges)
    assert (seg >= 0).all() and (seg <= 3).all()
