"""Size-aware cell-cache arena (ISSUE 4): skewed-size packing beats the
fixed-slot layout, eviction/compaction keep the id indirection exact,
and hit-rate statistics behave on repeated workloads."""

import numpy as np
import pytest

from repro.core.runtime import (
    ROW_QUANTUM, CellCache, cache_row_bytes, cache_slot_bytes,
    cell_alloc_rows, plan_cache_rows)
from repro.core.traversal import UNCACHED
from repro.core.types import GMGConfig, GMGIndex


def synth_index(sizes, deg=4, l=2, dim=8, seed=0):
    """Minimal GMGIndex with hand-chosen (skewed) cell sizes."""
    sizes = list(sizes)
    n, S = sum(sizes), len(sizes)
    rng = np.random.default_rng(seed)
    cell_start = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return GMGIndex(
        config=GMGConfig(seg_per_attr=(S,)),
        vectors=rng.normal(size=(n, dim)).astype(np.float32),
        attrs=rng.normal(size=(n, 1)).astype(np.float32),
        perm=np.arange(n),
        seg_bounds=[np.linspace(0, 1, S + 1).astype(np.float32)],
        cell_of=np.repeat(np.arange(S), sizes).astype(np.int32),
        cell_start=cell_start,
        cell_lo=np.zeros((S, 1), np.float32),
        cell_hi=np.ones((S, 1), np.float32),
        intra_adj=rng.integers(-1, n, (n, deg)).astype(np.int32),
        inter_adj=rng.integers(-1, n, (n, S, l)).astype(np.int32),
        centroids=np.zeros((2, dim), np.float32),
        hist=np.zeros((S, 2), np.float32))


def assert_consistent(cache, index):
    """Every resident cell's rows must read back exactly through the
    cell_base indirection; absent cells must be UNCACHED."""
    base = cache.cell_base()
    intra = np.asarray(cache.intra_buf)
    resident = cache.resident_cells()
    for c in range(index.n_cells):
        if c not in resident:
            assert base[c] == UNCACHED
            continue
        s, e = int(index.cell_start[c]), int(index.cell_start[c + 1])
        lo, hi = base[c] + s, base[c] + e
        assert 0 <= lo and hi <= intra.shape[0]
        np.testing.assert_array_equal(intra[lo:hi], index.intra_adj[s:e])


def test_skewed_sizes_fit_more_cells_than_fixed_slots():
    """One giant cell + many small ones: the arena keeps all the small
    cells resident in a budget where the fixed layout holds just two
    slots (every slot pays the giant cell's padding)."""
    idx = synth_index([40, 8, 8, 8, 8, 8])
    budget = 2 * cache_slot_bytes(idx)          # rows for 2 largest-cell slots
    fixed = CellCache(idx, budget_bytes=budget, policy="fixed")
    arena = CellCache(idx, budget_bytes=budget, policy="size_aware")
    assert fixed.n_slots == 2
    small = [1, 2, 3, 4, 5]
    with pytest.raises(ValueError):
        fixed.ensure(small)                     # 5 cells > 2 slots
    arena.ensure(small)                         # 5 * 8 = 40 of 80 rows
    assert arena.resident_cells() == frozenset(small)
    assert_consistent(arena, idx)
    # and the giant cell still fits alongside some of them
    arena.ensure([0, 4, 5])
    assert {0, 4, 5} <= arena.resident_cells()
    assert_consistent(arena, idx)


def test_eviction_keeps_ids_consistent():
    """Random ensure waves under a tight budget: after every call the
    cell_base indirection must read back the exact adjacency rows."""
    idx = synth_index([24, 16, 8, 32, 8, 16, 8, 24], seed=1)
    rows = cell_alloc_rows(idx)
    cap = int(rows.sum()) // 2
    cache = CellCache(idx, budget_bytes=cap * cache_row_bytes(idx))
    rng = np.random.default_rng(2)
    for _ in range(30):
        wave = []
        budget = cache.cap_rows
        for c in rng.permutation(idx.n_cells):
            if rows[c] <= budget:
                wave.append(int(c))
                budget -= int(rows[c])
        cache.ensure(wave)
        assert {int(c) for c in wave} <= cache.resident_cells()
        assert_consistent(cache, idx)
    assert cache.evictions > 0                  # the regime actually churns


def test_compaction_defragments_pinned_extents():
    """A wave whose cells are all wanted but fragmented around pinned
    extents triggers a compaction, not a failure."""
    idx = synth_index([16, 8, 24, 8, 16])
    cap_rows = 48
    cache = CellCache(idx, budget_bytes=cap_rows * cache_row_bytes(idx))
    assert cache.cap_rows == cap_rows
    cache.ensure([0, 1, 3])          # layout: 0@[0,16) 1@[16,24) 3@[24,32)
    cache.ensure([1, 3, 2])          # 2 needs 24 contiguous rows: evicting
    #                                  0 leaves (0,16)+(32,16) split ->
    #                                  compact 1,3 to the front, place 2
    assert cache.compactions == 1
    assert cache.resident_cells() == frozenset({1, 2, 3})
    assert_consistent(cache, idx)


def test_hit_rate_monotone_on_repeated_workload():
    """Re-ensuring a fitting wave is all hits: misses stop growing after
    the cold pass and the lifetime hit rate rises monotonically."""
    idx = synth_index([16, 8, 8, 16])
    cache = CellCache(idx, budget_bytes=None)   # everything fits
    wave = [0, 1, 2, 3]
    cache.ensure(wave)
    assert cache.hits == 0 and cache.misses == 4
    last = cache.hit_rate()
    for _ in range(5):
        got = cache.ensure(wave)
        assert got["misses"] == 0 and got["bytes"] == 0
        assert cache.hit_rate() >= last
        last = cache.hit_rate()
    assert cache.misses == 4
    assert last == pytest.approx(20 / 24)


def test_capacity_checks_and_policy_validation():
    idx = synth_index([16, 8, 8])
    with pytest.raises(ValueError):
        CellCache(idx, policy="bogus")
    cache = CellCache(idx, budget_bytes=1)      # clamps to the largest cell
    assert cache.cap_rows == max(cell_alloc_rows(idx))
    with pytest.raises(ValueError):
        cache.ensure([0, 1, 2])                 # 32 rows > 16-row arena
    assert plan_cache_rows(idx, None) == int(cell_alloc_rows(idx).sum())


def test_alloc_rows_quantized():
    idx = synth_index([13, 1, 8])
    rows = cell_alloc_rows(idx)
    assert rows.tolist() == [16, 8, 8]
    assert all(r % ROW_QUANTUM == 0 for r in rows)
