"""Observability layer (ISSUE 10): span tracing, the unified metrics
registry, Perfetto/Prometheus export, and the wiring contracts —
engine stats are registry views, straggler walls are span-derived,
and a traced sharded-hybrid search accounts for (almost) all of its
own wall clock.
"""

import json
import time

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, EngineStats
from repro.core.types import GMGConfig
from repro.dist.straggler import StragglerMonitor
from repro.obs.export import (chrome_trace_events, prometheus_text,
                              write_chrome_trace)
from repro.obs.metrics import MetricsRegistry, PassMetrics
from repro.obs.trace import (NOOP_SPAN, Tracer, active_tracer, local_trace,
                             span, sum_walls, tracing)
from repro.serve.frontend import VectorFrontend, VirtualClock


class FakeClock:
    """Deterministic clock: every read advances by ``step``."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_and_attrs():
    clock = VirtualClock()
    tr = Tracer(clock=clock)
    with tracing(tr):
        with span("search", rows=8) as root:
            clock.advance(1.0)
            with span("wave", wave=0) as w0:
                clock.advance(2.0)
            with span("wave", wave=1) as w1:
                clock.advance(3.0)
                w1.annotate(cells=4)
        with span("other"):
            clock.advance(0.5)
    assert active_tracer() is None
    # completion order: children before parents
    assert [s.name for s in tr.spans] == ["wave", "wave", "search", "other"]
    assert w0.parent is root and w1.parent is root and root.parent is None
    assert root.depth == 0 and w0.depth == 1
    assert root.duration == pytest.approx(6.0)
    assert w0.duration == pytest.approx(2.0)
    assert w1.duration == pytest.approx(3.0)
    assert w1.attrs == {"wave": 1, "cells": 4}
    assert tr.roots() == [root, tr.spans[-1]]
    assert tr.children_of(root) == [w0, w1]
    assert tr.by_name("wave") == [w0, w1]
    # child intervals sit inside the parent's
    for c in (w0, w1):
        assert root.t0 <= c.t0 and c.t1 <= root.t1


def test_mark_and_spans_since():
    tr = Tracer(clock=FakeClock())
    with tracing(tr):
        with span("a"):
            pass
        mark = tr.mark()
        with span("b"):
            pass
    assert [s.name for s in tr.spans_since(mark)] == ["b"]
    tr.clear()
    assert tr.spans == [] and tr.mark() == 0


def test_sum_walls_groups_by_attr():
    clock = VirtualClock()
    tr = Tracer(clock=clock)
    with tracing(tr):
        for sid, dt in ((0, 1.0), (1, 2.0), (0, 3.0)):
            with span("shard.search", shard=sid):
                clock.advance(dt)
        with span("unrelated"):
            clock.advance(9.0)
    walls = sum_walls(tr.spans, "shard")
    assert walls == {0: pytest.approx(4.0), 1: pytest.approx(2.0)}


def test_noop_fast_path():
    assert active_tracer() is None
    sp = span("anything", cells=3)
    assert sp is NOOP_SPAN
    payload = object()
    assert sp.attach(payload) is payload
    assert sp.annotate(x=1) is sp and sp.duration == 0.0
    # loose CI-safe bound: 200k disabled spans must be far under a second
    t0 = time.perf_counter()
    for _ in range(200_000):
        with span("hot.loop"):
            pass
    assert time.perf_counter() - t0 < 1.0


def test_virtualclock_tracer_compat():
    clock = VirtualClock(t0=100.0)
    tr = Tracer(clock=clock)
    with tracing(tr):
        with span("pass") as sp:
            clock.advance(0.25)
    assert sp.t0 == pytest.approx(100.0)
    assert sp.duration == pytest.approx(0.25)


def test_sync_close_blocks_on_payload():
    import jax.numpy as jnp
    tr = Tracer(sync=True)
    with tracing(tr):
        with span("launch") as sp:
            out = sp.attach(jnp.arange(8) * 2)
    assert sp.duration >= 0.0 and sp._payload is None
    np.testing.assert_array_equal(np.asarray(out), np.arange(8) * 2)


def test_local_trace_reuses_active_tracer():
    tr = Tracer(clock=FakeClock())
    with tracing(tr):
        with local_trace() as lt:
            assert lt is tr
            with span("inner"):
                pass
    assert [s.name for s in tr.spans] == ["inner"]
    # no active tracer: a temporary one collects, nothing leaks
    with local_trace() as lt2:
        assert lt2 is not tr and active_tracer() is lt2
        with span("tmp"):
            pass
    assert active_tracer() is None
    assert [s.name for s in lt2.spans] == ["tmp"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_kinds_and_snapshot_delta():
    reg = MetricsRegistry()
    c = reg.counter("n_waves")
    assert reg.counter("n_waves") is c
    c.inc(3)
    reg.gauge("hit_rate").set(0.5)
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    with pytest.raises(TypeError):
        reg.gauge("n_waves")
    assert reg.value("n_waves") == 3
    assert reg.value("lat") == 4           # histograms report sample count
    assert reg.value("missing", default=7) == 7
    assert "n_waves" in reg and "missing" not in reg
    snap = reg.snapshot()
    c.inc(2)
    h.observe(5.0)
    reg.gauge("hit_rate").set(0.75)
    dlt = reg.delta(snap)
    assert dlt["n_waves"] == 2 and dlt["lat"] == 1
    assert dlt["hit_rate"] == 0.75         # gauges report current value
    assert h.mean() == pytest.approx(3.0)
    assert h.percentile(50) == pytest.approx(3.0)


def test_histogram_ring_bound():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    h.maxlen = 8
    for v in range(100):
        h.observe(float(v))
    assert len(h.values) == 8 and h.count == 100
    assert h.values == [float(v) for v in range(92, 100)]


def test_pass_metrics_single_source():
    reg = MetricsRegistry()
    pm = PassMetrics(reg, static={"engine": "incore"})
    pm.count("n_rows", 4)
    pm.count("n_rows", 2)
    pm.set("hit_rate", 0.5)
    pm.put("cache_policy", "fixed")        # dict-only, no registry metric
    pm.update_counts({"n_dense": 1, "n_broad": 3})
    stats = pm.stats()
    assert stats["n_rows"] == 6 == reg.value("n_rows")
    assert stats["hit_rate"] == 0.5 == reg.value("hit_rate")
    assert stats["n_dense"] == 1 and reg.value("n_broad") == 3
    assert stats["engine"] == "incore" and "engine" not in reg
    assert stats["cache_policy"] == "fixed" and "cache_policy" not in reg
    # stats() is the live dict: later pm writes show through
    pm.count("n_rows", 1)
    assert stats["n_rows"] == 7


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_event_schema(tmp_path):
    clock = VirtualClock(t0=5.0)
    tr = Tracer(clock=clock)
    with tracing(tr):
        with span("hybrid.wave", cells=np.int32(4), note="x"):
            clock.advance(0.002)
            with span("cache.upload", bytes=1024):
                clock.advance(0.001)
    events = chrome_trace_events(tr)
    assert len(events) == 2
    for e in events:
        assert set(e) == {"name", "ph", "ts", "dur", "pid", "tid", "cat",
                          "args"}
        assert e["ph"] == "X" and e["ts"] >= 0.0
    # sorted by start; the parent (longer) first on ties; cat = prefix
    assert [e["name"] for e in events] == ["hybrid.wave", "cache.upload"]
    assert events[0]["cat"] == "hybrid" and events[1]["cat"] == "cache"
    assert events[0]["dur"] == pytest.approx(3000.0)   # µs
    assert events[0]["args"]["cells"] == 4             # numpy -> plain int
    assert isinstance(events[0]["args"]["cells"], int)
    path = tmp_path / "sub" / "t.trace.json"           # dirs auto-created
    assert write_chrome_trace(tr, str(path)) == str(path)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert doc["traceEvents"] == events
    assert chrome_trace_events(Tracer()) == []


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("n_waves").inc(3)
    reg.gauge("hit_rate").set(0.25)
    h = reg.histogram("latency_seconds")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    reg.counter("weird.name-1").inc()
    text = prometheus_text(reg, extra={"queue_depth": 2})
    lines = text.splitlines()
    assert "# TYPE repro_n_waves counter" in lines
    assert "repro_n_waves 3" in lines
    assert "# TYPE repro_hit_rate gauge" in lines
    assert "repro_hit_rate 0.25" in lines
    assert "# TYPE repro_latency_seconds summary" in lines
    assert 'repro_latency_seconds{quantile="0.5"}' in text
    assert "repro_latency_seconds_sum 1" in lines    # ints lose the .0
    assert "repro_latency_seconds_count 4" in lines
    assert "repro_weird_name_1 1" in lines             # sanitized
    assert "# TYPE repro_queue_depth gauge" in lines
    assert "repro_queue_depth 2" in lines
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# engine wiring: a 16-cell index so streamed modes multi-wave + prefetch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def obs_col(small_data):
    v, a = small_data
    cfg = GMGConfig(seg_per_attr=(4, 4), intra_degree=12, n_clusters=16,
                    build_ef=48, batch_cells=2, dense_threshold=256)
    return Collection.build(
        v, a, schema=AttrSchema(["price", "ts", "views", "duration"]),
        config=cfg, seed=0)


@pytest.fixture(scope="module")
def obs_queries(small_data):
    """16 broad 2-attr windows: every query touches many cells."""
    v, a = small_data
    rng = np.random.default_rng(0)
    lo = np.full((16, 4), -np.inf, np.float32)
    hi = np.full((16, 4), np.inf, np.float32)
    lo[:, :2] = np.quantile(a[:, :2], 0.15, axis=0)
    hi[:, :2] = np.quantile(a[:, :2], 0.85, axis=0)
    q = (v[rng.integers(0, len(v), 16)] + 0.01).astype(np.float32)
    return q, lo, hi


def _strip_timing(stats):
    """Drop wall-clock keys (the only legitimately nondeterministic
    stats) so two identical passes compare equal."""
    out = {}
    for k, v in stats.items():
        if "wall" in k or "seconds" in k:
            continue
        if k == "shards":
            out[k] = [{kk: vv for kk, vv in s.items()
                       if "wall" not in kk and "seconds" not in kk}
                      for s in v]
        else:
            out[k] = v
    return out


def test_traced_sharded_hybrid_coverage(obs_col, obs_queries, small_data,
                                        tmp_path):
    """The acceptance scenario: sharded hybrid search with a pending
    mutation buffer, traced end to end. Depth-1 child spans must cover
    >= 95% of the collection.search wall, cache prefetches must visibly
    overlap in-flight traversals, and the mid-stream buffer fold must
    appear as its own span."""
    v, a = small_data
    q, lo, hi = obs_queries
    budget = int(obs_col.hybrid_min_bytes() * 0.6)   # tight per-shard cache
    col = Collection(index=obs_col.index, schema=obs_col.schema,
                     shards=2, device_budget_bytes=budget)
    col.insert(v[:5] + 0.01, a[:5])                  # pending buffer
    col.search(q, filters=(lo, hi), k=10, engine="hybrid")   # warm compile
    path = tmp_path / "search.trace.json"
    with col.trace(str(path)) as tr:
        res = col.search(q, filters=(lo, hi), k=10, engine="hybrid")
    assert res.ids.shape == (16, 10)

    roots = [s for s in tr.roots() if s.name == "collection.search"]
    assert len(roots) == 1
    root = roots[0]
    kids = tr.children_of(root)
    names = [s.name for s in kids]
    assert names.count("shard.search") == 2
    assert "collection.plan" in names
    assert "collection.fold_buffer" in names         # mid-stream fold
    # union of depth-1 child intervals covers >= 95% of the search wall
    covered, cur = 0.0, None
    for t0, t1 in sorted(s.interval() for s in kids):
        if cur is None or t0 > cur[1]:
            if cur is not None:
                covered += cur[1] - cur[0]
            cur = [t0, t1]
        else:
            cur[1] = max(cur[1], t1)
    covered += cur[1] - cur[0]
    assert covered / root.duration >= 0.95
    # DMA/compute overlap: every prefetch upload sits inside an
    # in-flight traversal span (hybrid.traverse covers launch->prefetch)
    prefetches = tr.by_name("cache.prefetch")
    assert len(prefetches) >= 2                      # both shards multi-wave
    for pf in prefetches:
        anc = pf.parent
        while anc is not None and anc.name != "hybrid.traverse":
            anc = anc.parent
        assert anc is not None
        assert anc.t0 <= pf.t0 and pf.t1 <= anc.t1
    # the straggler monitor saw exactly the per-shard span walls the
    # stats report (satellite: no hand-threaded shard timing)
    eng = col._sharded
    assert sum(eng.straggler._count) > 0
    walls = sum_walls(tr.spans_since(0), "shard")
    for st in col.last_stats["shards"]:
        assert st["wall_seconds"] == pytest.approx(walls[st["shard"]])
    # the exported file is schema-valid Perfetto JSON of this tracer
    doc = json.loads(path.read_text())
    assert doc["traceEvents"] == chrome_trace_events(tr)
    assert len(doc["traceEvents"]) == len(tr.spans)


@pytest.mark.parametrize("mode", ["incore", "hybrid", "ooc"])
def test_counter_parity_with_registry(obs_col, obs_queries, mode):
    """Gate-tracked stats are registry views: after one pass on a fresh
    engine, every numeric stat whose name is registered reads the same
    from the stats dict and from the registry (PassMetrics writes both
    through one call — they cannot disagree)."""
    q, lo, hi = obs_queries
    col = Collection(index=obs_col.index, schema=obs_col.schema)
    col.search(q, filters=(lo, hi), k=10, engine=mode)
    eng = col._engine_for(mode)
    stats, reg = eng.stats, eng.metrics
    checked = 0
    for name, val in stats.items():
        if isinstance(val, (int, float)) and name in reg:
            assert reg.value(name) == pytest.approx(val), name
            checked += 1
    assert checked >= 5
    # the facade view reports exactly what the engine did
    assert col.last_stats
    if mode == "incore":
        assert col.last_stats["n_rows"] == 16


@pytest.mark.parametrize("mode", ["incore", "hybrid"])
def test_tracing_does_not_change_stats(obs_col, obs_queries, mode):
    """Overhead guard: the same pass traced and untraced reports
    value-identical gate metrics (tracing only observes)."""
    q, lo, hi = obs_queries
    col_a = Collection(index=obs_col.index, schema=obs_col.schema)
    col_b = Collection(index=obs_col.index, schema=obs_col.schema)
    for col in (col_a, col_b):                       # identical warm-up
        col.search(q, filters=(lo, hi), k=10, engine=mode)
    with col_a.trace():
        col_a.search(q, filters=(lo, hi), k=10, engine=mode)
    col_b.search(q, filters=(lo, hi), k=10, engine=mode)
    assert _strip_timing(col_a.last_stats) == _strip_timing(col_b.last_stats)


def test_engine_stats_raw_dict_roundtrip():
    assert EngineStats().raw_dict() == {}
    raw = {"engine": "incore", "n_rows": 4, "n_waves": 2}
    st = EngineStats.from_raw(raw)
    assert st.raw_dict() == raw
    assert "n_batches" not in st.raw_dict()          # unreported key absent


def test_straggler_ingest_from_spans():
    clock = VirtualClock()
    tr = Tracer(clock=clock)
    with tracing(tr):
        for sid, dt in ((0, 0.1), (1, 0.4)):
            with span("shard.search", shard=sid):
                clock.advance(dt)
    mon = StragglerMonitor(n_hosts=3)
    walls = mon.ingest(tr.spans, key="shard")
    assert walls == {0: pytest.approx(0.1), 1: pytest.approx(0.4)}
    assert mon._count == [1, 1, 0]                   # idle host 2 untouched
    assert mon._ewma[0] == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# build phases + serving export
# ---------------------------------------------------------------------------

def test_build_phase_spans(small_data):
    from repro.core.gmg import build_gmg, build_phase_seconds, build_timings
    v, a = small_data
    cfg = GMGConfig(seg_per_attr=(2,), intra_degree=8, n_clusters=8,
                    build_ef=32)
    t = build_timings(v[:512], a[:512], cfg, seed=0)
    phases = ("grid", "intra", "inter", "order", "quantize")
    assert all(t[f"{p}_seconds"] > 0.0 for p in phases)
    assert sum(t[f"{p}_seconds"] for p in phases) <= t["build_seconds"]
    # a user trace around a build sees the same phases as spans
    tr = Tracer()
    with tracing(tr):
        build_gmg(v[:512], a[:512], cfg, seed=0)
    got = build_phase_seconds(tr.spans)
    assert set(got) == set(phases)


def test_frontend_prometheus_export(obs_col, obs_queries):
    q, lo, hi = obs_queries
    col = Collection(index=obs_col.index, schema=obs_col.schema)
    fe = VectorFrontend(col, max_batch_queries=64, clock=VirtualClock())
    rids = [fe.submit(q[i:i + 1], filters=(lo[i:i + 1], hi[i:i + 1]), k=5)
            for i in range(4)]
    fe.drain()
    assert all(fe.take(r).result is not None for r in rids)
    assert isinstance(fe.metrics_registry, MetricsRegistry)
    m = fe.metrics()
    assert m["served"] == 4 and m["n_passes"] >= 1
    assert fe.n_served == 4                          # registry-backed props
    text = fe.prometheus()
    assert "# TYPE repro_serve_served counter" in text
    assert "repro_serve_served 4" in text
    assert "# TYPE repro_serve_ticks counter" in text
    assert 'repro_serve_latency_seconds{quantile="0.99"}' in text
    assert "repro_serve_queue_depth 0" in text


def test_frontend_tick_spans(obs_col, obs_queries):
    """A traced tick shows the sub-phase spans (admit/engine/fold)."""
    q, lo, hi = obs_queries
    col = Collection(index=obs_col.index, schema=obs_col.schema)
    fe = VectorFrontend(col, max_batch_queries=64, clock=VirtualClock())
    fe.submit(q[:2], filters=(lo[:2], hi[:2]), k=5)
    tr = Tracer()
    with tracing(tr):
        fe.tick()
    ticks = tr.by_name("tick")
    assert len(ticks) == 1
    kid_names = {s.name for s in tr.children_of(ticks[0])}
    assert "tick.admit" in kid_names and "tick.engine" in kid_names
    # the engine pass nests inside the tick
    searches = tr.by_name("collection.search_many")
    assert searches and searches[0].depth >= 1
