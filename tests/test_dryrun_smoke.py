"""Dry-run machinery smoke (deliverable e, reduced configs, subprocess —
the 512-device flag must not leak into this test process)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch import dryrun
for mesh in ("single", "multi"):
    rec = dryrun.run_cell("{arch}", "{shape}", mesh, reduced=True,
                          save=False)
    print(json.dumps({{"mesh": mesh, "status": rec["status"],
                       "err": rec.get("error", "")}}))
    assert rec["status"] == "ok", rec.get("error")
print("DONE")
"""


@pytest.mark.parametrize("arch,shape", [
    ("llama3.2-3b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k"),
    ("mamba2-1.3b", "long_500k"),
])
def test_dryrun_reduced_both_meshes(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    code = _SCRIPT.format(arch=arch, shape=shape)
    res = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert "DONE" in res.stdout, res.stdout[-2000:] + res.stderr[-2000:]


def test_production_mesh_shapes():
    """Mesh factory contract (uses however many devices exist by
    inspecting the spec only)."""
    from repro.launch import mesh as mesh_mod
    import inspect
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
