"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes/dtypes, plus hypothesis
property tests for the bitonic sort network."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.kernels import config as kcfg
from repro.kernels import ops, ref
from repro.kernels.sort_network import bitonic_sort, merge_topk


RNG = np.random.default_rng(0)


def _data(B, N, d, dtype=np.float32):
    q = RNG.normal(size=(B, d)).astype(dtype)
    v = RNG.normal(size=(N, d)).astype(dtype)
    return q, v


# ---------------------------------------------------------------------------
# pairwise_l2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d", [
    (8, 128, 32), (17, 200, 64), (128, 384, 128), (3, 50, 96),
])
def test_pairwise_l2_matches_ref(B, N, d):
    q, v = _data(B, N, d)
    want = np.asarray(ref.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pairwise_l2_bf16_inputs():
    q, v = _data(16, 128, 64)
    qb, vb = jnp.asarray(q, jnp.bfloat16), jnp.asarray(v, jnp.bfloat16)
    want = np.asarray(ref.pairwise_l2(qb, vb))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.pairwise_l2(qb, vb))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=1e-1)


# ---------------------------------------------------------------------------
# fused_topk
# ---------------------------------------------------------------------------

# Full-width (bn=128) fused_topk under interpret mode makes XLA:CPU
# unroll a 128-wide bitonic network per grid step — compile time explodes
# (minutes to hours). The kernel body is still validated off-TPU by
# test_fused_topk_small_tile_interpret below plus the sort-network
# property tests; the production tile runs compiled on real TPU.
_interpret_blowup = pytest.mark.skipif(
    jax.default_backend() != "tpu",
    reason="bn=128 pallas interpret compile is pathological on CPU XLA")


@_interpret_blowup
@pytest.mark.parametrize("B,N,d,k", [
    (8, 256, 32, 5), (16, 300, 64, 10), (4, 128, 16, 16), (9, 511, 48, 3),
])
def test_fused_topk_matches_ref(B, N, d, k):
    q, v = _data(B, N, d)
    rv, ri = ref.fused_topk(jnp.asarray(q), jnp.asarray(v), k)
    with kcfg.mode("pallas"):
        gv, gi = ops.topk_l2(jnp.asarray(q), jnp.asarray(v), k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               rtol=1e-5, atol=1e-4)
    # indices may differ on exact ties only; check distances of chosen ids
    d2 = np.asarray(ref.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    got_d = np.take_along_axis(d2, np.asarray(gi), axis=1)
    np.testing.assert_allclose(got_d, np.asarray(rv), rtol=1e-5, atol=1e-4)


@_interpret_blowup
def test_fused_topk_bias_filters():
    q, v = _data(4, 256, 32)
    bias = np.zeros(256, np.float32)
    bias[:200] = np.inf                      # only ids >= 200 allowed
    with kcfg.mode("pallas"):
        vals, idx = ops.topk_l2(jnp.asarray(q), jnp.asarray(v), 10,
                                bias=jnp.asarray(bias))
    assert (np.asarray(idx) >= 200).all()


def test_fused_topk_small_tile_interpret():
    """CPU-feasible kernel-body validation: a bn=16 tile keeps the
    interpreted bitonic network small enough to compile, and still
    exercises init/merge/flush across several grid steps + the bias
    mask."""
    from repro.kernels import fused_topk as ftk
    q, v = _data(8, 64, 128)
    bias = np.zeros((1, 64), np.float32)
    bias[0, :16] = np.inf                    # mask out the first tile
    vals, idx = ftk.fused_topk(jnp.asarray(q), jnp.asarray(v),
                               jnp.asarray(bias), 5, bq=8, bn=16)
    rv, ri = ref.fused_topk(jnp.asarray(q), jnp.asarray(v), 5,
                            bias=jnp.asarray(bias[0]))
    np.testing.assert_allclose(np.asarray(vals)[:, :5], np.asarray(rv),
                               rtol=1e-5, atol=1e-4)
    assert (np.asarray(idx)[:, :5] >= 16).all()


def test_topk_k_larger_than_n_pads():
    q, v = _data(4, 6, 16)
    vals, idx = ops.topk_l2(jnp.asarray(q), jnp.asarray(v), 10)
    assert idx.shape == (4, 10)
    assert (np.asarray(idx)[:, 6:] == -1).all()
    assert np.isinf(np.asarray(vals)[:, 6:]).all()


# ---------------------------------------------------------------------------
# int8_distance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d", [(8, 128, 32), (32, 256, 128), (5, 77, 64)])
def test_int8_distance_matches_ref(B, N, d):
    from repro.core.quantize import quantize
    q, v = _data(B, N, d)
    qq, qs = quantize(q)
    vq, vs = quantize(v)
    want = np.asarray(ref.int8_distance(
        jnp.asarray(qq), jnp.asarray(qs), jnp.asarray(vq), jnp.asarray(vs)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.int8_l2(
            jnp.asarray(qq), jnp.asarray(qs), jnp.asarray(vq),
            jnp.asarray(vs)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_int8_distance_approximates_exact():
    q, v = _data(8, 128, 64)
    from repro.core.quantize import quantize
    qq, qs = quantize(q)
    vq, vs = quantize(v)
    approx = np.asarray(ref.int8_distance(
        jnp.asarray(qq), jnp.asarray(qs), jnp.asarray(vq), jnp.asarray(vs)))
    exact = np.asarray(ref.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    # int8 symmetric quantization: relative error small on N(0,1) data
    rel = np.abs(approx - exact) / (exact + 1e-3)
    assert np.median(rel) < 0.02


# ---------------------------------------------------------------------------
# gather kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d,nb", [(4, 64, 32, 8), (7, 100, 64, 5)])
def test_gather_distance_matches_ref(B, N, d, nb):
    q, v = _data(B, N, d)
    idx = RNG.integers(-1, N, size=(B, nb)).astype(np.int32)
    want = np.asarray(ref.gather_distance(
        jnp.asarray(q), jnp.asarray(v), jnp.asarray(idx)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.gather_l2(
            jnp.asarray(q), jnp.asarray(v), jnp.asarray(idx)))
    mask = idx >= 0
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-5, atol=1e-4)
    assert np.isinf(got[~mask]).all()


def test_gather_int8_matches_ref():
    from repro.core.quantize import quantize
    q, v = _data(4, 64, 32)
    vq, vs = quantize(v)
    idx = RNG.integers(-1, 64, size=(4, 6)).astype(np.int32)
    want = np.asarray(ref.gather_int8_distance(
        jnp.asarray(q), jnp.asarray(vq), jnp.asarray(vs), jnp.asarray(idx)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.gather_l2_q(
            jnp.asarray(q), jnp.asarray(vq), jnp.asarray(vs),
            jnp.asarray(idx)))
    mask = idx >= 0
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-4, atol=1e-3)
    assert np.isinf(got[~mask]).all()


# ---------------------------------------------------------------------------
# sort network properties (hypothesis)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16, 64]))
@settings(max_examples=25, deadline=None)
def test_bitonic_sort_sorts(seed, width):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(3, width)).astype(np.float32))
    idxs = jnp.broadcast_to(jnp.arange(width, dtype=jnp.int32), (3, width))
    sv, si = bitonic_sort(vals, idxs)
    sv, si = np.asarray(sv), np.asarray(si)
    assert (np.diff(sv, axis=1) >= 0).all()
    # payload follows values
    np.testing.assert_allclose(np.take_along_axis(np.asarray(vals), si, 1),
                               sv)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 32]))
@settings(max_examples=25, deadline=None)
def test_merge_topk_is_best_k(seed, K):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.normal(size=(2, K)).astype(np.float32), axis=1)
    b = np.sort(rng.normal(size=(2, K)).astype(np.float32), axis=1)
    ia = rng.integers(0, 100, (2, K)).astype(np.int32)
    ib = rng.integers(100, 200, (2, K)).astype(np.int32)
    mv, mi = merge_topk(jnp.asarray(a), jnp.asarray(ia),
                        jnp.asarray(b), jnp.asarray(ib))
    want = np.sort(np.concatenate([a, b], axis=1), axis=1)[:, :K]
    np.testing.assert_allclose(np.asarray(mv), want)
