"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes/dtypes, plus hypothesis
property tests for the bitonic sort network."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.kernels import config as kcfg
from repro.kernels import ops, ref
from repro.kernels.sort_network import bitonic_sort, merge_topk


RNG = np.random.default_rng(0)


def _data(B, N, d, dtype=np.float32):
    q = RNG.normal(size=(B, d)).astype(dtype)
    v = RNG.normal(size=(N, d)).astype(dtype)
    return q, v


# ---------------------------------------------------------------------------
# pairwise_l2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d", [
    (8, 128, 32), (17, 200, 64), (128, 384, 128), (3, 50, 96),
])
def test_pairwise_l2_matches_ref(B, N, d):
    q, v = _data(B, N, d)
    want = np.asarray(ref.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pairwise_l2_bf16_inputs():
    q, v = _data(16, 128, 64)
    qb, vb = jnp.asarray(q, jnp.bfloat16), jnp.asarray(v, jnp.bfloat16)
    want = np.asarray(ref.pairwise_l2(qb, vb))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.pairwise_l2(qb, vb))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=1e-1)


# ---------------------------------------------------------------------------
# fused_topk
# ---------------------------------------------------------------------------

# Off-TPU these run the kernel under interpret mode with the roofline's
# interpret tile (bq=8, bn=max(16, K)) — a full-width bn=128 interpreted
# bitonic network used to explode XLA:CPU compile time (minutes+), which
# is why ops.topk_l2 asks launch/roofline.fused_topk_tiles for a
# compile-tractable tile instead of hardcoding the production one.
@pytest.mark.parametrize("B,N,d,k", [
    (8, 256, 32, 5), (16, 300, 64, 10), (4, 128, 16, 16), (9, 511, 48, 3),
])
def test_fused_topk_matches_ref(B, N, d, k):
    q, v = _data(B, N, d)
    rv, ri = ref.fused_topk(jnp.asarray(q), jnp.asarray(v), k)
    with kcfg.mode("pallas"):
        gv, gi = ops.topk_l2(jnp.asarray(q), jnp.asarray(v), k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               rtol=1e-5, atol=1e-4)
    # indices may differ on exact ties only; check distances of chosen ids
    d2 = np.asarray(ref.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    got_d = np.take_along_axis(d2, np.asarray(gi), axis=1)
    np.testing.assert_allclose(got_d, np.asarray(rv), rtol=1e-5, atol=1e-4)


def test_fused_topk_bias_filters():
    q, v = _data(4, 256, 32)
    bias = np.zeros(256, np.float32)
    bias[:200] = np.inf                      # only ids >= 200 allowed
    with kcfg.mode("pallas"):
        vals, idx = ops.topk_l2(jnp.asarray(q), jnp.asarray(v), 10,
                                bias=jnp.asarray(bias))
    assert (np.asarray(idx) >= 200).all()


def test_fused_topk_small_tile_interpret():
    """CPU-feasible kernel-body validation: a bn=16 tile keeps the
    interpreted bitonic network small enough to compile, and still
    exercises init/merge/flush across several grid steps + the bias
    mask."""
    from repro.kernels import fused_topk as ftk
    q, v = _data(8, 64, 128)
    bias = np.zeros((1, 64), np.float32)
    bias[0, :16] = np.inf                    # mask out the first tile
    vals, idx = ftk.fused_topk(jnp.asarray(q), jnp.asarray(v),
                               jnp.asarray(bias), 5, bq=8, bn=16)
    rv, ri = ref.fused_topk(jnp.asarray(q), jnp.asarray(v), 5,
                            bias=jnp.asarray(bias[0]))
    np.testing.assert_allclose(np.asarray(vals)[:, :5], np.asarray(rv),
                               rtol=1e-5, atol=1e-4)
    assert (np.asarray(idx)[:, :5] >= 16).all()


def test_topk_k_larger_than_n_pads():
    q, v = _data(4, 6, 16)
    vals, idx = ops.topk_l2(jnp.asarray(q), jnp.asarray(v), 10)
    assert idx.shape == (4, 10)
    assert (np.asarray(idx)[:, 6:] == -1).all()
    assert np.isinf(np.asarray(vals)[:, 6:]).all()


# ---------------------------------------------------------------------------
# int8_distance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d", [(8, 128, 32), (32, 256, 128), (5, 77, 64)])
def test_int8_distance_matches_ref(B, N, d):
    from repro.core.quantize import quantize
    q, v = _data(B, N, d)
    qq, qs = quantize(q)
    vq, vs = quantize(v)
    want = np.asarray(ref.int8_distance(
        jnp.asarray(qq), jnp.asarray(qs), jnp.asarray(vq), jnp.asarray(vs)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.int8_l2(
            jnp.asarray(qq), jnp.asarray(qs), jnp.asarray(vq),
            jnp.asarray(vs)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_int8_distance_approximates_exact():
    q, v = _data(8, 128, 64)
    from repro.core.quantize import quantize
    qq, qs = quantize(q)
    vq, vs = quantize(v)
    approx = np.asarray(ref.int8_distance(
        jnp.asarray(qq), jnp.asarray(qs), jnp.asarray(vq), jnp.asarray(vs)))
    exact = np.asarray(ref.pairwise_l2(jnp.asarray(q), jnp.asarray(v)))
    # int8 symmetric quantization: relative error small on N(0,1) data
    rel = np.abs(approx - exact) / (exact + 1e-3)
    assert np.median(rel) < 0.02


# ---------------------------------------------------------------------------
# gather kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d,nb", [(4, 64, 32, 8), (7, 100, 64, 5)])
def test_gather_distance_matches_ref(B, N, d, nb):
    q, v = _data(B, N, d)
    idx = RNG.integers(-1, N, size=(B, nb)).astype(np.int32)
    want = np.asarray(ref.gather_distance(
        jnp.asarray(q), jnp.asarray(v), jnp.asarray(idx)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.gather_l2(
            jnp.asarray(q), jnp.asarray(v), jnp.asarray(idx)))
    mask = idx >= 0
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-5, atol=1e-4)
    assert np.isinf(got[~mask]).all()


def test_gather_int8_matches_ref():
    from repro.core.quantize import quantize
    q, v = _data(4, 64, 32)
    vq, vs = quantize(v)
    idx = RNG.integers(-1, 64, size=(4, 6)).astype(np.int32)
    want = np.asarray(ref.gather_int8_distance(
        jnp.asarray(q), jnp.asarray(vq), jnp.asarray(vs), jnp.asarray(idx)))
    with kcfg.mode("pallas"):
        got = np.asarray(ops.gather_l2_q(
            jnp.asarray(q), jnp.asarray(vq), jnp.asarray(vs),
            jnp.asarray(idx)))
    mask = idx >= 0
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-4, atol=1e-3)
    assert np.isinf(got[~mask]).all()


# ---------------------------------------------------------------------------
# sort network properties (hypothesis)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16, 64]))
@settings(max_examples=25, deadline=None)
def test_bitonic_sort_sorts(seed, width):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(3, width)).astype(np.float32))
    idxs = jnp.broadcast_to(jnp.arange(width, dtype=jnp.int32), (3, width))
    sv, si = bitonic_sort(vals, idxs)
    sv, si = np.asarray(sv), np.asarray(si)
    assert (np.diff(sv, axis=1) >= 0).all()
    # payload follows values
    np.testing.assert_allclose(np.take_along_axis(np.asarray(vals), si, 1),
                               sv)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 32]))
@settings(max_examples=25, deadline=None)
def test_merge_topk_is_best_k(seed, K):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.normal(size=(2, K)).astype(np.float32), axis=1)
    b = np.sort(rng.normal(size=(2, K)).astype(np.float32), axis=1)
    ia = rng.integers(0, 100, (2, K)).astype(np.int32)
    ib = rng.integers(100, 200, (2, K)).astype(np.int32)
    mv, mi = merge_topk(jnp.asarray(a), jnp.asarray(ia),
                        jnp.asarray(b), jnp.asarray(ib))
    want = np.sort(np.concatenate([a, b], axis=1), axis=1)[:, :K]
    np.testing.assert_allclose(np.asarray(mv), want)


# ---------------------------------------------------------------------------
# traversal wave (one fused expansion step) — kernels/traversal_wave.py
# ---------------------------------------------------------------------------
#
# Parity policy: ids / expanded flags / visited words are EXACT (integer
# outputs must be bit-identical to the jnp oracle); distances are
# allclose(rtol=1e-6) only, because XLA contracts the fused
# dequant-sub-square-sum chain with different FMA groupings for the
# kernel's (1, d) rows vs the oracle's (B, nb, d) batch — last-ulp diffs
# that cannot flip an id except on exact distance ties.

from repro.kernels import traversal_wave as twave
from repro.kernels.sort_network import bitonic_sort_lex


def _wave_case(int8, B=4, nb=8, n=64, d=16, m=3, ef=8, k=4, entry_width=6,
               seed=0):
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(n, d)).astype(np.float32)
    vq = rng.integers(-127, 127, size=(n, d)).astype(np.int8)
    vscale = (rng.random(n).astype(np.float32) * 0.1 + 0.01)
    case = dict(
        q=rng.normal(size=(B, d)).astype(np.float32),
        vectors=None if int8 else table, vq=vq, vscale=vscale,
        attrs=rng.random((n, m)).astype(np.float32),
        lo=np.full((B, m), 0.1, np.float32),
        hi=np.full((B, m), 0.9, np.float32))
    cand = rng.integers(0, n, size=(B, nb)).astype(np.int32)
    cand[:, 1] = cand[:, 0]              # duplicate neighbor
    cand[:, 3] = -1                      # dead lane
    cand[0, :] = cand[0, 0]              # whole row duplicated
    active = np.array([True, True, False, True])[:B]
    case["cand"] = np.where(active[:, None], cand, -1)
    case["gids"] = np.maximum(cand, 0)
    case["visited"] = rng.integers(
        0, 2**32, size=(B, (n + 31) // 32), dtype=np.uint32)
    beam_d = np.sort(rng.random((B, ef)).astype(np.float32) * 4, axis=1)
    beam_d[:, ef - 2:] = np.inf          # open beam slots
    beam_ids = rng.integers(0, n, size=(B, ef)).astype(np.int32)
    beam_ids[beam_d == np.inf] = -1
    case.update(beam_ids=beam_ids, beam_d=beam_d,
                beam_exp=rng.integers(0, 2, size=(B, ef)).astype(bool))
    res_d = np.sort(rng.random((B, k)).astype(np.float32) * 4, axis=1)
    res_d[:, k - 1:] = np.inf
    res_ids = rng.integers(0, n, size=(B, k)).astype(np.int32)
    res_ids[res_d == np.inf] = -1
    case.update(res_ids=res_ids, res_d=res_d, active=active,
                entry_width=entry_width)
    return {kk: (vv if kk == "entry_width" or vv is None else
                 jnp.asarray(vv)) for kk, vv in case.items()}


_WAVE_OUTS = ["beam_ids", "beam_d", "beam_exp", "res_ids", "res_d",
              "visited"]


def _assert_wave_parity(ref_out, ker_out):
    for nm, r, g in zip(_WAVE_OUTS, ref_out, ker_out):
        r, g = np.asarray(r), np.asarray(g)
        if nm in ("beam_d", "res_d"):
            np.testing.assert_allclose(r, g, rtol=1e-6, atol=0, err_msg=nm)
        else:
            np.testing.assert_array_equal(r, g, err_msg=nm)


@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("g", [1, 2])
def test_wave_expand_matches_ref(int8, g):
    a = _wave_case(int8)
    args = (a["q"], a["vectors"], a["vq"], a["vscale"], a["attrs"],
            a["lo"], a["hi"], a["cand"], a["gids"], a["visited"],
            a["beam_ids"], a["beam_d"], a["beam_exp"],
            a["res_ids"], a["res_d"])
    want = ref.wave_expand(*args)
    with kcfg.mode("pallas"):
        got = twave.wave_expand(*args, g=g)
    _assert_wave_parity(want, got)


@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("g", [1, 2])
def test_wave_seed_matches_ref(int8, g):
    a = _wave_case(int8)
    args = (a["q"], a["vectors"], a["vq"], a["vscale"], a["attrs"],
            a["lo"], a["hi"], a["cand"], a["gids"], a["visited"],
            a["beam_ids"], a["beam_d"], a["res_ids"], a["res_d"],
            a["active"], a["entry_width"])
    want = ref.wave_seed(*args, a["cand"].shape[1])
    with kcfg.mode("pallas"):
        got = twave.wave_seed(*args, g=g)
    _assert_wave_parity(want, got)


def test_wave_candidate_padding_is_inert():
    """PAD_ID-padded candidate lanes (the kernel's pow2 padding) must not
    change any output vs the unpadded oracle call."""
    a = _wave_case(False, nb=8)
    cand_p = jnp.pad(a["cand"], ((0, 0), (0, 8)),
                     constant_values=ref.PAD_ID)
    gids_p = jnp.pad(a["gids"], ((0, 0), (0, 8)))
    base = ref.wave_expand(
        a["q"], a["vectors"], a["vq"], a["vscale"], a["attrs"], a["lo"],
        a["hi"], a["cand"], a["gids"], a["visited"], a["beam_ids"],
        a["beam_d"], a["beam_exp"], a["res_ids"], a["res_d"])
    padded = ref.wave_expand(
        a["q"], a["vectors"], a["vq"], a["vscale"], a["attrs"], a["lo"],
        a["hi"], cand_p, gids_p, a["visited"], a["beam_ids"],
        a["beam_d"], a["beam_exp"], a["res_ids"], a["res_d"])
    _assert_wave_parity(base, padded)


# ---------------------------------------------------------------------------
# packed-visited scatter — kernels/ref.set_packed_bits
# ---------------------------------------------------------------------------

def _set_packed_bits_loop(visited, ids, valid):
    """The former O(nb) fori_loop bit-set, as a numpy oracle: sequential
    read-then-set per candidate lane against the *batch-start* snapshot
    for ``seen`` and cumulative OR for the update."""
    visited = visited.copy()
    before = visited.copy()
    B, nb = ids.shape
    seen = np.zeros((B, nb), bool)
    for b in range(B):
        for j in range(nb):
            if not valid[b, j]:
                i = min(max(int(ids[b, j]), 0), visited.shape[1] * 32 - 1)
                seen[b, j] = (before[b, i >> 5] >> (i & 31)) & 1
                continue
            i = int(ids[b, j])
            seen[b, j] = (before[b, i >> 5] >> (i & 31)) & 1
            visited[b, i >> 5] |= np.uint32(1) << np.uint32(i & 31)
    return seen, visited


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_set_packed_bits_matches_loop(seed):
    rng = np.random.default_rng(seed)
    B, nb, n = 3, 12, 96
    ids = rng.integers(-1, n, size=(B, nb)).astype(np.int32)
    ids[:, 1] = ids[:, 0]                       # force duplicates
    valid = (ids >= 0) & (rng.random((B, nb)) > 0.2)
    visited = rng.integers(0, 2**32, size=(B, (n + 31) // 32),
                           dtype=np.uint32)
    want_seen, want_vis = _set_packed_bits_loop(visited, ids, valid)
    seen, vis = ref.set_packed_bits(
        jnp.asarray(visited), jnp.asarray(ids), jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(seen)[valid],
                                  want_seen[valid])
    np.testing.assert_array_equal(np.asarray(vis), want_vis)


# ---------------------------------------------------------------------------
# lexicographic sort network — sort_network.bitonic_sort_lex
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
@settings(max_examples=25, deadline=None)
def test_bitonic_sort_lex_is_stable_argsort(seed, width):
    """With k2 = original lane positions, the lex network reproduces a
    *stable* ascending sort by k1 — the dedup-by-id property the wave
    kernel's flush relies on."""
    rng = np.random.default_rng(seed)
    k1 = rng.integers(0, width // 2 + 1, size=(3, width)).astype(np.int32)
    pay = rng.normal(size=(3, width)).astype(np.float32)
    lane = np.broadcast_to(np.arange(width, dtype=np.int32), (3, width))
    s1, s2, (sp,) = bitonic_sort_lex(
        jnp.asarray(k1), jnp.asarray(lane), (jnp.asarray(pay),))
    order = np.argsort(k1, axis=1, kind="stable")
    np.testing.assert_array_equal(np.asarray(s1),
                                  np.take_along_axis(k1, order, 1))
    np.testing.assert_array_equal(np.asarray(s2), order.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(sp),
                                  np.take_along_axis(pay, order, 1))
