"""Cell scheduling (paper Alg. 5) properties + the paper's own example."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.core import scheduler


def test_paper_fig6_example():
    """4 queries, 4 cells, b=2: optimal schedule has 2 active per batch."""
    inc = np.zeros((4, 4), bool)
    inc[0, [0, 2]] = True
    inc[1, [0, 2]] = True
    inc[2, [1, 3]] = True
    inc[3, [1, 3]] = True
    naive = scheduler.naive_schedule(inc, 2)
    assert scheduler.total_active(inc, naive) == 8   # all 4 active twice
    best = scheduler.schedule_cells(inc, 2)
    assert scheduler.total_active(inc, best) == 4    # paper Fig. 6(b)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_schedule_capacity_and_coverage(seed, b):
    rng = np.random.default_rng(seed)
    m, n = rng.integers(2, 20), rng.integers(1, 12)
    inc = rng.random((m, n)) < 0.3
    batches = scheduler.schedule_cells(inc, b)
    flat = [c for batch in batches for c in batch]
    touched = [c for c in range(n) if inc[:, c].any()]
    assert sorted(flat) == sorted(touched)          # exactly-once coverage
    assert all(len(batch) <= b for batch in batches)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_greedy_no_worse_than_naive(seed):
    rng = np.random.default_rng(seed)
    inc = rng.random((16, 12)) < 0.25
    greedy = scheduler.total_active(inc, scheduler.schedule_cells(inc, 3))
    naive = scheduler.total_active(inc, scheduler.naive_schedule(inc, 3))
    # the greedy objective never exceeds naive by more than slack on
    # adversarial instances; on random ones it's consistently <=
    assert greedy <= naive + 2


def test_schedule_deterministic_under_ties():
    """Equal-gain placements resolve by the explicit lexicographic
    (added_active, current_active, batch_index) key, so identical
    incidence always yields the identical plan — including when the
    caller hands the cell list in a different order."""
    inc = np.ones((6, 9), bool)          # every placement ties on gain
    b1 = scheduler.schedule_cells(inc, 3)
    b2 = scheduler.schedule_cells(inc, 3)
    assert b1 == b2
    # all queries become active at the first placement; afterwards every
    # batch adds 0 active, so ties fill batch 0, then 1, then 2
    assert b1 == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    # cell-list order does not change the plan (ascending visit order)
    shuffled = scheduler.schedule_cells(inc, 3,
                                        cells=list(reversed(range(9))))
    assert shuffled == b1


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_schedule_reproducible_across_runs(seed):
    rng = np.random.default_rng(seed)
    inc = rng.random((12, 10)) < 0.3
    plans = [scheduler.schedule_cells(inc.copy(), 3) for _ in range(3)]
    assert plans[0] == plans[1] == plans[2]


def _reference_schedule_cells(incidence, batch_size, cells=None):
    """The pre-incremental implementation (recomputes each batch's
    active count with a full O(m) mask sum after every placement) —
    the oracle for the incremental-update bugfix."""
    m, n = incidence.shape
    if cells is None:
        cells = [c for c in range(n) if incidence[:, c].any()]
    cells = sorted(int(c) for c in cells)
    n_batches = max(1, -(-len(cells) // batch_size))
    batches = [[] for _ in range(n_batches)]
    active_mask = [np.zeros(m, dtype=bool) for _ in range(n_batches)]
    active_cnt = [0] * n_batches
    for c in cells:
        col = incidence[:, c]
        best_k, best_key = -1, None
        for k in range(n_batches):
            if len(batches[k]) >= batch_size:
                continue
            inc = int((col & ~active_mask[k]).sum())
            cand = (inc, active_cnt[k], k)
            if best_key is None or cand < best_key:
                best_k, best_key = k, cand
        batches[best_k].append(c)
        active_mask[best_k] |= col
        active_cnt[best_k] = int(active_mask[best_k].sum())
    return [b for b in batches if b]


@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
@settings(max_examples=40, deadline=None)
def test_incremental_active_count_byte_identical(seed, b):
    """The incremental active_cnt update (+= the placement's own gain)
    must reproduce the full-recompute schedule exactly, placement for
    placement."""
    rng = np.random.default_rng(seed)
    m, n = rng.integers(2, 24), rng.integers(1, 14)
    inc = rng.random((m, n)) < rng.uniform(0.1, 0.6)
    assert scheduler.schedule_cells(inc, b) == \
        _reference_schedule_cells(inc, b)


def test_resident_cells_prefer_earliest_wave():
    """Cache affinity: under equal gain a resident cell steers into the
    earliest wave (it executes before LRU eviction claims its rows),
    overriding the least-active tie-break; without a resident set the
    plan is byte-identical to pure Alg. 5."""
    inc = np.zeros((4, 3), bool)
    inc[0, 0] = inc[1, 0] = True      # two queries pin cell 0
    inc[2, 1] = True
    inc[3, 2] = True
    base = scheduler.schedule_cells(inc, 2)
    assert base == [[0], [1, 2]]      # cell 2 ties on gain, picks less
    #                                   active wave 1 (pure Alg. 5)
    aware = scheduler.schedule_cells(inc, 2, resident={2})
    assert aware == [[0, 2], [1]]     # resident cell 2 takes wave 0
    # an empty resident set must not perturb the plan at all
    assert scheduler.schedule_cells(inc, 2, resident=set()) == base


def test_coaccessed_neighbor_affinity():
    """A non-resident cell breaks a gain tie toward the wave whose
    resident members share its queries (co-accessed cells travel
    together), even against the least-active tie-break."""
    inc = np.zeros((3, 3), bool)
    inc[0, 0] = inc[1, 0] = True      # cell 0: queries 0, 1
    inc[2, 1] = True                  # cell 1: query 2
    inc[0, 2] = inc[2, 2] = True      # cell 2 co-accessed with cell 0
    blind = scheduler.schedule_cells(inc, 2)
    assert blind == [[0], [1, 2]]
    aware = scheduler.schedule_cells(inc, 2, resident={0})
    assert aware == [[0, 2], [1]]


def test_order_waves_runs_resident_first_and_keeps_objective():
    rng = np.random.default_rng(7)
    inc = rng.random((16, 12)) < 0.3
    waves = scheduler.schedule_cells(inc, 3)
    assert scheduler.order_waves(waves, None) == waves
    reordered = scheduler.order_waves(waves, resident=set(waves[-1]))
    assert reordered[0] == waves[-1]
    assert sorted(map(tuple, reordered)) == sorted(map(tuple, waves))
    # Eq. 3's objective is order-invariant — reordering is free
    assert scheduler.total_active(inc, reordered) == \
        scheduler.total_active(inc, waves)
    # rows-weighted residency: the wave with more resident *rows* wins
    w = np.arange(12) * 10 + 1
    hv = scheduler.order_waves([[0, 1], [11]], resident={1, 11}, weights=w)
    assert hv[0] == [11]


def test_weighted_capacity_packs_and_appends():
    """Arena rows as weights: waves never exceed the capacity, extra
    waves append deterministically, oversized single cells fail fast."""
    inc = np.ones((4, 5), bool)
    w = np.array([30, 30, 30, 30, 30])
    waves = scheduler.schedule_cells(inc, 5, weights=w, capacity=60)
    assert all(sum(w[c] for c in wave) <= 60 for wave in waves)
    assert sorted(c for wave in waves for c in wave) == list(range(5))
    assert len(waves) == 3            # 2 + 2 + 1
    with np.testing.assert_raises(ValueError):
        scheduler.schedule_cells(inc, 5, weights=w, capacity=20)
    with np.testing.assert_raises(ValueError):
        scheduler.schedule_cells(inc, 5, weights=w)   # capacity required


def test_multihost_plan_covers_cells():
    from repro.core.pipeline import multihost_plan
    rng = np.random.default_rng(0)
    inc = rng.random((24, 16)) < 0.3
    host_of, plans, totals = multihost_plan(inc, 4, 2)
    seen = set()
    for h, batches in enumerate(plans):
        for batch in batches:
            for c in batch:
                assert host_of[c] == h       # locality: own cells only
                seen.add(c)
    touched = {c for c in range(16) if inc[:, c].any()}
    assert seen == touched
