"""Cell scheduling (paper Alg. 5) properties + the paper's own example."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.core import scheduler


def test_paper_fig6_example():
    """4 queries, 4 cells, b=2: optimal schedule has 2 active per batch."""
    inc = np.zeros((4, 4), bool)
    inc[0, [0, 2]] = True
    inc[1, [0, 2]] = True
    inc[2, [1, 3]] = True
    inc[3, [1, 3]] = True
    naive = scheduler.naive_schedule(inc, 2)
    assert scheduler.total_active(inc, naive) == 8   # all 4 active twice
    best = scheduler.schedule_cells(inc, 2)
    assert scheduler.total_active(inc, best) == 4    # paper Fig. 6(b)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_schedule_capacity_and_coverage(seed, b):
    rng = np.random.default_rng(seed)
    m, n = rng.integers(2, 20), rng.integers(1, 12)
    inc = rng.random((m, n)) < 0.3
    batches = scheduler.schedule_cells(inc, b)
    flat = [c for batch in batches for c in batch]
    touched = [c for c in range(n) if inc[:, c].any()]
    assert sorted(flat) == sorted(touched)          # exactly-once coverage
    assert all(len(batch) <= b for batch in batches)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_greedy_no_worse_than_naive(seed):
    rng = np.random.default_rng(seed)
    inc = rng.random((16, 12)) < 0.25
    greedy = scheduler.total_active(inc, scheduler.schedule_cells(inc, 3))
    naive = scheduler.total_active(inc, scheduler.naive_schedule(inc, 3))
    # the greedy objective never exceeds naive by more than slack on
    # adversarial instances; on random ones it's consistently <=
    assert greedy <= naive + 2


def test_multihost_plan_covers_cells():
    from repro.core.pipeline import multihost_plan
    rng = np.random.default_rng(0)
    inc = rng.random((24, 16)) < 0.3
    host_of, plans, totals = multihost_plan(inc, 4, 2)
    seen = set()
    for h, batches in enumerate(plans):
        for batch in batches:
            for c in batch:
                assert host_of[c] == h       # locality: own cells only
                seen.add(c)
    touched = {c for c in range(16) if inc[:, c].any()}
    assert seen == touched
