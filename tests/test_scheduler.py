"""Cell scheduling (paper Alg. 5) properties + the paper's own example."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # minimal container: deterministic fallback
    from prop_fallback import given, settings, st

from repro.core import scheduler


def test_paper_fig6_example():
    """4 queries, 4 cells, b=2: optimal schedule has 2 active per batch."""
    inc = np.zeros((4, 4), bool)
    inc[0, [0, 2]] = True
    inc[1, [0, 2]] = True
    inc[2, [1, 3]] = True
    inc[3, [1, 3]] = True
    naive = scheduler.naive_schedule(inc, 2)
    assert scheduler.total_active(inc, naive) == 8   # all 4 active twice
    best = scheduler.schedule_cells(inc, 2)
    assert scheduler.total_active(inc, best) == 4    # paper Fig. 6(b)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_schedule_capacity_and_coverage(seed, b):
    rng = np.random.default_rng(seed)
    m, n = rng.integers(2, 20), rng.integers(1, 12)
    inc = rng.random((m, n)) < 0.3
    batches = scheduler.schedule_cells(inc, b)
    flat = [c for batch in batches for c in batch]
    touched = [c for c in range(n) if inc[:, c].any()]
    assert sorted(flat) == sorted(touched)          # exactly-once coverage
    assert all(len(batch) <= b for batch in batches)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_greedy_no_worse_than_naive(seed):
    rng = np.random.default_rng(seed)
    inc = rng.random((16, 12)) < 0.25
    greedy = scheduler.total_active(inc, scheduler.schedule_cells(inc, 3))
    naive = scheduler.total_active(inc, scheduler.naive_schedule(inc, 3))
    # the greedy objective never exceeds naive by more than slack on
    # adversarial instances; on random ones it's consistently <=
    assert greedy <= naive + 2


def test_schedule_deterministic_under_ties():
    """Equal-gain placements resolve by the explicit lexicographic
    (added_active, current_active, batch_index) key, so identical
    incidence always yields the identical plan — including when the
    caller hands the cell list in a different order."""
    inc = np.ones((6, 9), bool)          # every placement ties on gain
    b1 = scheduler.schedule_cells(inc, 3)
    b2 = scheduler.schedule_cells(inc, 3)
    assert b1 == b2
    # all queries become active at the first placement; afterwards every
    # batch adds 0 active, so ties fill batch 0, then 1, then 2
    assert b1 == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    # cell-list order does not change the plan (ascending visit order)
    shuffled = scheduler.schedule_cells(inc, 3,
                                        cells=list(reversed(range(9))))
    assert shuffled == b1


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_schedule_reproducible_across_runs(seed):
    rng = np.random.default_rng(seed)
    inc = rng.random((12, 10)) < 0.3
    plans = [scheduler.schedule_cells(inc.copy(), 3) for _ in range(3)]
    assert plans[0] == plans[1] == plans[2]


def test_multihost_plan_covers_cells():
    from repro.core.pipeline import multihost_plan
    rng = np.random.default_rng(0)
    inc = rng.random((24, 16)) < 0.3
    host_of, plans, totals = multihost_plan(inc, 4, 2)
    seen = set()
    for h, batches in enumerate(plans):
        for batch in batches:
            for c in batch:
                assert host_of[c] == h       # locality: own cells only
                seen.add(c)
    touched = {c for c in range(16) if inc[:, c].any()}
    assert seen == touched
