"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

CI installs real hypothesis (shrinking, example database, coverage-guided
generation); this fallback just re-runs each property ``max_examples``
times with fixed-seed pseudorandom draws so the properties still execute
in minimal containers. Only the subset used by this repo's tests is
provided: ``given``, ``settings(max_examples=, deadline=)``,
``st.integers(lo, hi)``, ``st.sampled_from(seq)``.
"""

from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _Strategies:
    @staticmethod
    def integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


st = _Strategies()


def given(*strats):
    def deco(f):
        max_examples = getattr(f, "_max_examples", 10)

        def runner():          # zero-arg: pytest must not see f's params
            rng = np.random.default_rng(0)
            for _ in range(max_examples):
                f(*(s.draw(rng) for s in strats))
        runner.__name__ = f.__name__
        runner.__doc__ = f.__doc__
        return runner
    return deco


def settings(max_examples: int = 10, deadline=None, **_):
    def deco(f):
        f._max_examples = max_examples
        return f
    return deco
