"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 device
(the dry-run sets its own flag in its own process)."""

import pytest

from repro.api import AttrSchema, Collection
from repro.core.types import GMGConfig
from repro.data import make_dataset, make_queries


@pytest.fixture(scope="session")
def small_data():
    """(vectors, attrs): 4k points, 64-dim, 4 attrs (uniform regime)."""
    v, a = make_dataset("deep", 4000, seed=0, m=4)
    return v, a


@pytest.fixture(scope="session")
def small_collection(small_data):
    """Built through the public Collection facade (named attributes)."""
    v, a = small_data
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=12, n_clusters=16,
                    build_ef=48, batch_cells=2, dense_threshold=256)
    return Collection.build(
        v, a, schema=AttrSchema(["price", "ts", "views", "duration"]),
        config=cfg, seed=0)


@pytest.fixture(scope="session")
def small_index(small_collection):
    """Engine-level view for tests that drive internals directly."""
    return small_collection.index


@pytest.fixture(scope="session")
def small_queries(small_data):
    v, a = small_data
    return make_queries(v, a, 32, 2, seed=3)


@pytest.fixture(scope="session")
def small_truth(small_data, small_queries):
    from repro.core.search import ground_truth
    v, a = small_data
    wl = small_queries
    ids, d = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    return ids, d
