"""Distributed substrate: sharding rules, ZeRO-1, checkpoint commit/
restore/reshard, straggler detection, gradient compression (error
feedback), train-loop crash/resume determinism."""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.dist import lm_rules
from repro.dist import sharding as shd
from repro.dist.straggler import StragglerMonitor
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_partition_spec_divisibility_fallback():
    mesh = _mesh11()
    # model axis size 1 -> always falls back to replication
    spec = shd.partition_spec((4096, 32), ("embed", "heads"), mesh,
                              lm_rules.TRAIN_RULES)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_partition_spec_shards_divisible_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate 16-way logic on shapes without a 16-device mesh by checking
    # the rule resolution path via a fake mesh with repeated axis... the
    # real 256/512-device checks happen in the dry-run subprocess test.
    spec = shd.partition_spec((40, 128), ("heads", "head_dim"), mesh,
                              lm_rules.TRAIN_RULES)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_zero1_sharding_prefers_largest_dim():
    mesh = _mesh11()
    s = shd.zero1_sharding((1024, 64), ("embed", None), mesh,
                           lm_rules.TRAIN_RULES)
    assert isinstance(s, jax.sharding.NamedSharding)


def test_batch_sharding_falls_back_for_odd_batches():
    mesh = _mesh11()
    s = shd.batch_sharding(mesh, 7)
    assert s.spec == jax.sharding.PartitionSpec()


# ---------------------------------------------------------------------------
# checkpoint: commit marker, restore, torn write, resume determinism
# ---------------------------------------------------------------------------

def _state(x=0.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))},
            "step": jnp.asarray(3, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, _state(1.5))
    got, step = restore_checkpoint(d, _state())
    assert step == 10
    np.testing.assert_allclose(np.asarray(got["params"]["w"]), 1.5)


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, _state(1.0))
    # torn write: step_20 exists but no COMMITTED marker
    os.makedirs(os.path.join(d, "step_20"))
    with open(os.path.join(d, "step_20", "shard_0.npz"), "wb") as f:
        f.write(b"garbage")
    assert latest_step(d) == 10
    got, step = restore_checkpoint(d, _state())
    assert step == 10


def test_restore_with_new_sharding(tmp_path):
    """Elastic reshard path: restore device_puts with provided shardings."""
    d = str(tmp_path)
    save_checkpoint(d, 5, _state(2.0))
    mesh = _mesh11()
    sh = jax.tree.map(lambda _: shd.replicated(mesh), _state())
    got, step = restore_checkpoint(d, _state(), shardings=sh)
    assert step == 5
    assert got["params"]["w"].sharding.mesh.shape == mesh.shape


def test_train_loop_crash_resume_bitexact(tmp_path):
    """Run A: 6 uninterrupted steps. Run B: crash at 3, resume, finish.
    Final params must match exactly (deterministic data + committed
    checkpoints)."""
    from repro.configs import get_reduced
    from repro.data.tokens import TokenPipeline
    from repro.train.loop import LoopConfig, run
    from repro.train.step import TrainConfig

    cfg = get_reduced("llama3.2-3b")
    tcfg = TrainConfig(remat=False)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=4, seq=16, seed=1)

    loop_a = LoopConfig(total_steps=6, ckpt_every=2,
                        ckpt_dir=str(tmp_path / "a"), log_every=0)
    state_a, _ = run(cfg, tcfg, loop_a, pipe, seed=0)

    loop_b = LoopConfig(total_steps=6, ckpt_every=2,
                        ckpt_dir=str(tmp_path / "b"), log_every=0)
    with pytest.raises(RuntimeError, match="injected crash"):
        run(cfg, tcfg, loop_b, pipe, seed=0, crash_at=3)
    state_b, _ = run(cfg, tcfg, loop_b, pipe, seed=0)   # resume from ckpt

    fa = jax.tree.leaves(state_a["params"])
    fb = jax.tree.leaves(state_b["params"])
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_detection_with_injected_delay():
    mon = StragglerMonitor(n_hosts=4, min_steps=3)
    for step in range(10):
        for h in range(4):
            t = 1.0 if h != 2 else 8.0       # host 2 is 8x slower
            mon.record(h, t + 0.01 * step)
    assert mon.is_straggler(2)
    assert not mon.is_straggler(0)


def test_straggler_recovers():
    mon = StragglerMonitor(n_hosts=2, min_steps=2, alpha=0.9)
    for _ in range(5):
        mon.record(0, 1.0)
        mon.record(1, 10.0)
    assert mon.is_straggler(1)
    for _ in range(30):
        mon.record(0, 1.0)
        mon.record(1, 1.0)
    assert not mon.is_straggler(1)


# ---------------------------------------------------------------------------
# gradient compression (multi-device: subprocess with forced host devices)
# ---------------------------------------------------------------------------

_COMPRESSION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.dist.compression import compressed_psum
mesh = jax.make_mesh((4,), ("pod",))
from jax.sharding import PartitionSpec as P
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:            # pre-0.5 jax keeps it in experimental
    from jax.experimental.shard_map import shard_map

@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
         out_specs=(P("pod"), P("pod")))
def sync(g, r):
    out, new_r = compressed_psum(g[0], r[0], "pod")
    return out[None], new_r[None]

rng = np.random.default_rng(0)
g_shards = rng.normal(size=(4, 64)).astype(np.float32)
r = np.zeros((4, 64), np.float32)
accum_true = np.zeros(64); accum_comp = np.zeros(64)
for step in range(20):
    g_shards = rng.normal(size=(4, 64)).astype(np.float32)
    out, r = sync(jnp.asarray(g_shards), jnp.asarray(r))
    accum_comp += np.asarray(out)[0]
    accum_true += g_shards.mean(axis=0)
err = np.abs(accum_comp - accum_true).max() / (np.abs(accum_true).max() + 1e-9)
print("REL_ERR", err)
assert err < 0.05, err
print("OK")
"""


def test_compressed_psum_error_feedback():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _COMPRESSION_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert "OK" in res.stdout, res.stdout + res.stderr


# ---------------------------------------------------------------------------
# constrain on a real (simulated) multi-device mesh
# ---------------------------------------------------------------------------

_CONSTRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist import sharding as shd
from repro.dist.lm_rules import TRAIN_RULES

mesh = jax.make_mesh((1, 4), ("data", "model"))

# outside an activation_rules context: identity, even on a mesh
x = jnp.ones((8, 64))
assert shd.constrain(x, ("batch", "heads")) is x

@jax.jit
def f(x):
    return shd.constrain(x * 2.0, (None, "heads"))

with shd.activation_rules(mesh, TRAIN_RULES):
    y = f(jnp.ones((8, 64)))
# "heads" -> "model" (size 4, divides 64): dim 1 actually sharded
spec = y.sharding.spec
assert tuple(spec) == (None, "model"), spec
assert len(y.sharding.device_set) == 4
np.testing.assert_allclose(np.asarray(y), 2.0)

# non-divisible dim falls back to replication, values unchanged
with shd.activation_rules(mesh, TRAIN_RULES):
    z = jax.jit(lambda x: shd.constrain(x, (None, "heads")))(jnp.ones((8, 65)))
assert tuple(z.sharding.spec) in ((), (None,), (None, None)), z.sharding.spec
print("OK")
"""


def test_constrain_pins_layout_on_simulated_mesh():
    """`constrain` was a PR-1 reconstruction that only ever ran on one
    device (where it lowers to the identity); validate it on a real
    simulated mesh: pins divisible dims, replicates the rest, and never
    changes values."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _CONSTRAIN_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert "OK" in res.stdout, res.stdout + res.stderr
