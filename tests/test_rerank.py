"""Device-side exact re-rank (ISSUE 4): the fused gather->distance->
k-select program must return bit-identical ids to the host numpy loop
across every engine mode, through the Collection facade, and under
disjunctive (qmap-folded) plans."""

import numpy as np
import pytest

from repro.api import Collection, F
from repro.core.hybrid import HybridEngine
from repro.core.pipeline import OutOfCoreEngine
from repro.core.types import SearchParams


@pytest.mark.parametrize("engine_cls", [HybridEngine, OutOfCoreEngine])
def test_device_host_rerank_bit_identical(engine_cls, small_index,
                                          small_queries):
    wl = small_queries
    params = SearchParams(k=10, ef=64)
    ids_h, d_h = engine_cls(small_index, rerank="host").search(
        wl.q, wl.lo, wl.hi, params)
    ids_d, d_d = engine_cls(small_index, rerank="device").search(
        wl.q, wl.lo, wl.hi, params)
    np.testing.assert_array_equal(ids_h, ids_d)
    finite = np.isfinite(d_h)
    np.testing.assert_array_equal(finite, np.isfinite(d_d))
    np.testing.assert_allclose(d_h[finite], d_d[finite],
                               rtol=1e-4, atol=1e-4)


def test_rerank_parity_through_collection_all_modes(small_collection,
                                                    small_queries):
    """Engine parity across the three modes: flipping the Collection's
    rerank knob never changes the returned ids (incore has no rerank
    stage — trivially identical — hybrid/ooc run the two paths)."""
    wl = small_queries
    col = small_collection
    budget = col.hybrid_min_bytes() + (1 << 18)
    for mode in ("incore", "hybrid", "ooc"):
        res = {}
        for rr in ("host", "device"):
            c = Collection(index=col.index, schema=col.schema,
                           device_budget_bytes=budget, mode=mode,
                           rerank=rr)
            res[rr] = c.search(wl.q, filters=(wl.lo, wl.hi),
                               params=SearchParams(k=10, ef=64))
            assert res[rr].engine == mode
        np.testing.assert_array_equal(res["host"].ids, res["device"].ids)


def test_rerank_parity_disjunctive(small_collection, small_data,
                                   small_queries):
    """The segment-aware top-k fold consumes rerank output — identical
    ids must survive a box-batched disjunctive pass too."""
    v, a = small_data
    wl = small_queries
    col = small_collection
    p10, p90 = np.quantile(a[:, 0], [0.10, 0.90])
    union = (F("price") < float(p10)) | (F("price") > float(p90))
    budget = col.hybrid_min_bytes() + (1 << 18)
    res = {}
    for rr in ("host", "device"):
        c = Collection(index=col.index, schema=col.schema,
                       device_budget_bytes=budget, mode="hybrid", rerank=rr)
        res[rr] = c.search(wl.q, filters=union, k=10, ef=64)
    np.testing.assert_array_equal(res["host"].ids, res["device"].ids)


def test_device_rerank_k_wider_than_pool(small_index, small_queries):
    """k > ef: the candidate pool is narrower than k — the device path
    must pad short rows with -1/inf exactly like the host loop instead
    of feeding an oversized k to lax.top_k."""
    wl = small_queries
    params = SearchParams(k=40, ef=24)
    ids_h, d_h = HybridEngine(small_index, rerank="host").search(
        wl.q, wl.lo, wl.hi, params)
    ids_d, d_d = HybridEngine(small_index, rerank="device").search(
        wl.q, wl.lo, wl.hi, params)
    assert ids_d.shape == (len(wl.q), 40)
    np.testing.assert_array_equal(ids_h, ids_d)
    assert (ids_d[:, 24:] == -1).all() and np.isinf(d_d[:, 24:]).all()


def test_rerank_rejects_unknown_path(small_index):
    with pytest.raises(ValueError):
        HybridEngine(small_index, rerank="gpu")
    with pytest.raises(ValueError):
        OutOfCoreEngine(small_index, rerank="gpu")


def test_knobs_save_load_round_trip(tmp_path, small_collection):
    """cache_policy / rerank ride through save -> load like mode does."""
    col = Collection(index=small_collection.index,
                     schema=small_collection.schema,
                     device_budget_bytes=1 << 26, mode="hybrid",
                     cache_policy="fixed", rerank="host")
    path = str(tmp_path / "col.npz")
    col.save(path)
    back = Collection.load(path)
    assert back.mode == "hybrid"
    assert back.cache_policy == "fixed"
    assert back.rerank == "host"
    assert back.device_budget_bytes == 1 << 26
    # overrides still win
    over = Collection.load(path, cache_policy="size_aware", rerank="device")
    assert over.cache_policy == "size_aware" and over.rerank == "device"
    # validation happens at construction
    with pytest.raises(ValueError):
        Collection(index=col.index, schema=col.schema, cache_policy="huge")
    with pytest.raises(ValueError):
        Collection(index=col.index, schema=col.schema, rerank="gpu")
