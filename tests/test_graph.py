"""Intra-cell graph construction: exactness, degree bound, connectivity."""

import numpy as np
import jax.numpy as jnp

from repro.core import graph
from repro.core.graph import _UnionFind
from repro.kernels import ref


def _components(adj):
    n = adj.shape[0]
    uf = _UnionFind(n)
    us, vs = np.nonzero(adj >= 0)
    for u, w in zip(us, adj[us, vs]):
        uf.union(int(u), int(w))
    return len({uf.find(i) for i in range(n)})


def test_exact_knn_matches_bruteforce():
    rng = np.random.default_rng(0)
    v = rng.normal(size=(300, 24)).astype(np.float32)
    knn = graph.exact_knn(v, 5)
    d2 = np.array(ref.pairwise_l2(jnp.asarray(v), jnp.asarray(v)))
    np.fill_diagonal(d2, np.inf)
    want = np.argsort(d2, axis=1)[:, :5]
    # sets match (ties may permute)
    got_d = np.take_along_axis(d2, knn, axis=1)
    want_d = np.take_along_axis(d2, want, axis=1)
    np.testing.assert_allclose(np.sort(got_d, 1), np.sort(want_d, 1),
                               rtol=1e-5)


def test_build_cell_graph_degree_and_connectivity():
    rng = np.random.default_rng(1)
    # adversarial: tight, well separated clusters (kNN graph fragments)
    centers = rng.normal(size=(8, 32)).astype(np.float32) * 10
    v = (centers[rng.integers(0, 8, 600)]
         + 0.1 * rng.normal(size=(600, 32)).astype(np.float32))
    adj = graph.build_cell_graph(v, degree=8, exact_threshold=10000)
    assert adj.shape == (600, 8)
    assert (adj < 600).all()
    assert not (adj == np.arange(600)[:, None]).any(), "self loop"
    assert _components(adj) == 1, "repair_connectivity must bridge"


def test_nn_descent_quality():
    rng = np.random.default_rng(2)
    v = rng.normal(size=(500, 16)).astype(np.float32)
    ids = graph.nn_descent(v, k=10, iters=8)
    d2 = np.array(ref.pairwise_l2(jnp.asarray(v), jnp.asarray(v)))
    np.fill_diagonal(d2, np.inf)
    gt = np.argsort(d2, axis=1)[:, :10]
    recall = np.mean([len(set(ids[i]) & set(gt[i])) / 10 for i in range(500)])
    assert recall > 0.85, recall


def test_tiny_cells_dont_crash():
    v = np.random.default_rng(3).normal(size=(1, 8)).astype(np.float32)
    adj = graph.build_cell_graph(v, degree=4)
    assert adj.shape == (1, 4)
    assert (adj == -1).all()
    v2 = np.random.default_rng(4).normal(size=(3, 8)).astype(np.float32)
    adj2 = graph.build_cell_graph(v2, degree=4)
    assert adj2.shape == (3, 4)
