"""Mesh-tier tests: placement, per-pass assignment, sharded-vs-single
parity, the redesigned Collection sharding + stats API, and the real
simulated-mesh run (subprocess with forced host devices).

Parity contract (repro.core.shard docstring): sharded incore pins the
partition-independent traversal profile (use_inter_edges=False,
adaptive_global=False) and reproduces single-device ids bit-for-bit;
hybrid/ooc follow the PR-6 recall-parity contract for streamed modes.
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import Collection, EngineStats, F, QueryResult, ShardSpec
from repro.api.planner import plan_queries, shard_routing
from repro.api.result import ShardStats
from repro.core.shard import (ShardedEngine, assign_cells, cell_weights,
                              plan_placement, shard_index)
from repro.core.types import SearchParams

# the partition-independent profile both sides of every id-parity check
# run under (the sharded engine coerces to it internally)
PP = SearchParams(k=10, use_inter_edges=False, adaptive_global=False)


def _sharded(col, shards):
    """A collection sharing ``col``'s built index, mesh tier enabled."""
    return Collection(index=col.index, schema=col.schema, shards=shards)


# ---------------------------------------------------------------------------
# placement + sub-index construction
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        ShardSpec(n_shards=0)
    with pytest.raises(ValueError):
        ShardSpec(n_shards=2, replicate_hot=-1)
    with pytest.raises(ValueError):
        ShardSpec(n_shards=2, balance_by="vibes")
    with pytest.raises(TypeError):
        ShardSpec.canon("two")
    assert ShardSpec.canon(None) is None
    assert ShardSpec.canon(4) == ShardSpec(n_shards=4)
    spec = ShardSpec(n_shards=2)
    assert ShardSpec.canon(spec) is spec


def test_collection_validates_shards(small_collection):
    S = small_collection.index.n_cells
    with pytest.raises(ValueError):
        _sharded(small_collection, S + 1)
    col = _sharded(small_collection, 2)
    assert col.shards == ShardSpec(n_shards=2)


def test_build_accepts_shards(small_data):
    v, a = small_data
    from repro.core.types import GMGConfig
    col = Collection.build(
        v, a, config=GMGConfig(seg_per_attr=(2, 2), intra_degree=12,
                               n_clusters=16),
        seed=0, shards=2)
    assert col.shards == ShardSpec(n_shards=2)
    res = col.search(v[:4] + 0.01, params=PP)
    assert res.stats.n_shards == 2


def test_placement_balanced_and_deterministic(small_index):
    spec = ShardSpec(n_shards=2)
    p1 = plan_placement(small_index, spec)
    p2 = plan_placement(small_index, spec)
    np.testing.assert_array_equal(p1.owner, p2.owner)
    assert p1.balance() <= 1.5
    # every cell owned exactly once; shard_cells = owned (no replication)
    assert sorted(np.concatenate(p1.shard_cells).tolist()) \
        == list(range(small_index.n_cells))
    # weights follow resident bytes: rows * per-row constant
    w = cell_weights(small_index, "bytes")
    rows = np.diff(small_index.cell_start)
    assert (w[np.argmax(rows)] == w.max())


def test_replicated_cells_resident_everywhere(small_index):
    spec = ShardSpec(n_shards=2, replicate_hot=2)
    pl = plan_placement(small_index, spec)
    hot = np.nonzero(pl.replicated)[0]
    assert len(hot) == 2
    for cells in pl.shard_cells:
        assert np.isin(hot, cells).all()
    # explicit hot_cells override the weight-derived pick
    pl2 = plan_placement(small_index, ShardSpec(n_shards=2, hot_cells=(0,)))
    assert pl2.replicated[0] and pl2.replicated.sum() == 1


def test_shard_index_roundtrip(small_index):
    pl = plan_placement(small_index, ShardSpec(n_shards=2))
    sub, rows, g2l = shard_index(small_index, pl.shard_cells[0])
    assert sub.n == len(rows)
    np.testing.assert_array_equal(sub.vectors, small_index.vectors[rows])
    np.testing.assert_array_equal(sub.perm, small_index.perm[rows])
    # intra edges stay within-cell, so the remap is lossless: every local
    # edge maps back to the original global edge
    li = np.arange(sub.n)
    for col_ in range(sub.intra_adj.shape[1]):
        e = sub.intra_adj[:, col_]
        ok = e >= 0
        np.testing.assert_array_equal(
            rows[e[ok]], small_index.intra_adj[rows[ok], col_])
    # cell CSR consistent
    assert sub.n_cells == len(pl.shard_cells[0])
    np.testing.assert_array_equal(np.diff(sub.cell_start),
                                  np.diff(small_index.cell_start)
                                  [pl.shard_cells[0]])


def test_assign_cells_rebalances_replicated(small_index):
    pl = plan_placement(small_index, ShardSpec(n_shards=2, replicate_hot=1))
    hot = int(np.nonzero(pl.replicated)[0][0])
    S = small_index.n_cells
    # every row wants only the hot cell -> it must go to the least-loaded
    # shard, and the assignment stays deterministic
    inc = np.zeros((8, S), bool)
    inc[:, hot] = True
    a1, hits1 = assign_cells(inc, pl)
    a2, hits2 = assign_cells(inc, pl)
    np.testing.assert_array_equal(a1, a2)
    assert hits1 == hits2
    # non-replicated cells always serve at home
    rest = ~pl.replicated
    np.testing.assert_array_equal(a1[rest], pl.owner[rest])


# ---------------------------------------------------------------------------
# id parity (incore) on 1/2/4 shards, one device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_incore_id_parity(small_collection, small_queries, n_shards):
    wl = small_queries
    ref = small_collection.search(wl.q, filters=(wl.lo, wl.hi), params=PP,
                                  engine="incore")
    col = _sharded(small_collection, n_shards)
    res = col.search(wl.q, filters=(wl.lo, wl.hi), params=PP,
                     engine="incore")
    np.testing.assert_array_equal(ref.ids, res.ids)
    np.testing.assert_allclose(ref.distances, res.distances)
    assert res.stats.sharded and res.stats.n_shards == n_shards
    assert len(res.stats.shards) == n_shards


def test_incore_parity_with_replication(small_collection, small_queries):
    wl = small_queries
    ref = small_collection.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    col = _sharded(small_collection,
                   ShardSpec(n_shards=2, replicate_hot=2))
    res = col.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    np.testing.assert_array_equal(ref.ids, res.ids)
    # the broad workload re-homes at least one replicated incidence
    assert res.stats.replicated_cells == 2
    assert res.stats.replica_hits >= 0
    assert sum(s.replica_hits for s in res.stats.shards) \
        == res.stats.replica_hits


def test_disjunctive_qmap_parity(small_collection, small_data):
    v, a = small_data
    med, hi_q = np.quantile(a[:, 0], (0.5, 0.8)).astype(np.float32)
    filt = (F("price") <= med) | (F("price") >= hi_q)
    q = v[:16] + 0.01
    ref = small_collection.search(q, filters=filt, params=PP)
    col = _sharded(small_collection, 4)
    res = col.search(q, filters=filt, params=PP)
    np.testing.assert_array_equal(ref.ids, res.ids)
    assert res.stats.planner["n_boxes"] >= len(q)
    assert res.stats["n_boxes"] == res.stats.planner["n_boxes"]


def test_search_many_parity(small_collection, small_queries):
    wl = small_queries
    reqs = [(wl.q[:4], (wl.lo[:4], wl.hi[:4]), 5),
            (wl.q[4:10], (wl.lo[4:10], wl.hi[4:10]), 10)]
    refs = small_collection.search_many(reqs, params=PP)
    outs = _sharded(small_collection, 2).search_many(reqs, params=PP)
    for r, o in zip(refs, outs):
        np.testing.assert_array_equal(r.ids, o.ids)


# ---------------------------------------------------------------------------
# recall parity (hybrid / ooc)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["hybrid", "ooc"])
def test_streamed_recall_parity(small_collection, small_queries,
                                small_truth, mode):
    wl = small_queries
    gt = small_truth[0]
    ref = small_collection.search(wl.q, filters=(wl.lo, wl.hi), k=10,
                                  engine=mode)
    col = _sharded(small_collection, 2)
    res = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, engine=mode)
    assert res.recall(gt) >= ref.recall(gt) - 0.02
    assert res.stats.engine == mode and res.stats.sharded
    assert res.stats.total_active > 0


# ---------------------------------------------------------------------------
# mutation reaches the owning shard
# ---------------------------------------------------------------------------

def test_mutation_reaches_owning_shard(small_collection, small_data):
    v, a = small_data
    col = _sharded(small_collection, 2)
    qv = v[7:8] + 0.001
    base = col.search(qv, k=3, params=PP)
    new_ids = col.insert(qv, a[7:8])          # buffered, searchable now
    res = col.search(qv, k=3, params=PP)
    assert new_ids[0] in res.ids[0]
    n_flushed = col.flush()                   # spliced into the owning cell
    assert n_flushed == 1
    res = col.search(qv, k=3, params=PP)
    assert new_ids[0] in res.ids[0]
    assert col.delete([int(new_ids[0])]) == 1  # tombstoned on every shard
    res = col.search(qv, k=3, params=PP)
    assert new_ids[0] not in res.ids[0]
    np.testing.assert_array_equal(res.ids, base.ids)


def test_straggler_monitor_wired(small_collection, small_queries):
    wl = small_queries
    col = _sharded(small_collection, 2)
    for _ in range(3):
        col.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    eng = col._sharded
    assert isinstance(eng, ShardedEngine)
    assert sum(eng.straggler._count) > 0      # per-shard walls recorded
    assert eng.stragglers() == []             # one host, no stragglers


# ---------------------------------------------------------------------------
# planner introspection
# ---------------------------------------------------------------------------

def test_shard_routing_introspection(small_collection, small_queries):
    wl = small_queries
    plan = plan_queries((wl.lo, wl.hi), small_collection.schema,
                        len(wl.q))
    info = shard_routing(plan, small_collection.index, 2)
    assert info["n_shards"] == 2 and info["n_boxes"] == len(wl.q)
    assert len(info["shards"]) == 2
    assert sum(s["total_active"] for s in info["shards"]) > 0
    assert info["balance"] >= 1.0


# ---------------------------------------------------------------------------
# API redesign: EngineStats, deprecated aliases, npz round-trip
# ---------------------------------------------------------------------------

def test_engine_stats_typed(small_collection, small_queries):
    wl = small_queries
    res = small_collection.search(wl.q, filters=(wl.lo, wl.hi))
    st = res.stats
    assert isinstance(st, EngineStats)
    assert st.engine == "incore" and st.n_rows == len(wl.q)
    assert st.n_dense + st.n_itinerary + st.n_global == len(wl.q)
    # mapping access stays alive through the transition
    assert st["engine"] == "incore"
    assert st.get("missing", 42) == 42
    assert "engine" in st and "cache" not in st
    d = st.to_dict()
    assert d["engine"] == "incore" and "hit_rate" not in d
    # raw dicts coerce on construction (engines hand Collection dicts)
    qr = QueryResult(ids=res.ids, distances=res.distances,
                     stats={"engine": "hybrid", "n_rows": 3,
                            "made_up_key": 7})
    assert qr.stats.engine == "hybrid"
    assert qr.stats.extras["made_up_key"] == 7
    assert qr.stats["made_up_key"] == 7


def test_engine_stats_sharded_fields(small_collection, small_queries):
    wl = small_queries
    col = _sharded(small_collection, ShardSpec(n_shards=2, replicate_hot=1))
    res = col.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    st = res.stats
    assert st.sharded and st.n_shards == 2
    assert all(isinstance(s, ShardStats) for s in st.shards)
    assert sum(s.total_active for s in st.shards) == st.total_active
    d = st.to_dict()
    assert d["sharded"] and len(d["shards"]) == 2
    assert isinstance(d["shards"][0], dict)


def test_legacy_mode_aliases_warn(small_collection, small_queries):
    wl = small_queries
    with pytest.warns(DeprecationWarning, match="in_core"):
        small_collection.search(wl.q[:2], engine="in_core")
    with pytest.warns(DeprecationWarning, match="out_of_core"):
        Collection(index=small_collection.index,
                   schema=small_collection.schema, mode="out_of_core")


def test_npz_v4_roundtrips_shard_spec(tmp_path, small_collection,
                                      small_queries):
    wl = small_queries
    spec = ShardSpec(n_shards=2, replicate_hot=1, balance_by="rows")
    col = Collection(index=small_collection.index,
                     schema=small_collection.schema, shards=spec)
    path = str(tmp_path / "sharded.npz")
    col.save(path)
    col2 = Collection.load(path)
    assert col2.shards == spec
    ref = col.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    res = col2.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    np.testing.assert_array_equal(ref.ids, res.ids)
    # explicit shards=None override disables the saved spec
    col3 = Collection.load(path, shards=None)
    assert col3.shards is None
    # and an int override re-shards
    col4 = Collection.load(path, shards=4)
    assert col4.shards == ShardSpec(n_shards=4)


def test_npz_v3_files_still_load(tmp_path, small_collection,
                                 small_queries):
    """Regression: a pre-mesh (format v3, no shards key) file loads with
    sharding disabled and identical results."""
    wl = small_queries
    path = str(tmp_path / "v3.npz")
    small_collection.save(path)
    with np.load(path, allow_pickle=False) as z:
        payload = {name: z[name] for name in z.files}
    meta = json.loads(bytes(payload["meta_json"].tobytes()).decode())
    assert meta["format_version"] == 4
    meta["format_version"] = 3
    meta.pop("shards", None)
    payload["meta_json"] = np.frombuffer(json.dumps(meta).encode(),
                                         dtype=np.uint8)
    np.savez(path, **payload)
    col = Collection.load(path)
    assert col.shards is None
    ref = small_collection.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    res = col.search(wl.q, filters=(wl.lo, wl.hi), params=PP)
    np.testing.assert_array_equal(ref.ids, res.ids)


# ---------------------------------------------------------------------------
# the real thing: 8 simulated devices (subprocess, forced host devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
assert len(jax.devices()) == 8, jax.devices()

from repro.api import AttrSchema, Collection, ShardSpec
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_dataset, make_queries

v, a = make_dataset("deep", 3000, seed=0, m=3)
cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=12, n_clusters=16,
                build_ef=48, dense_threshold=256)
col = Collection.build(v, a, schema=AttrSchema(["x", "y", "z"]),
                       config=cfg, seed=0)
wl = make_queries(v, a, 24, 2, seed=3)
pp = SearchParams(k=10, use_inter_edges=False, adaptive_global=False)
ref = col.search(wl.q, filters=(wl.lo, wl.hi), params=pp)

for n in (2, 4, 8):
    sh = Collection(index=col.index, schema=col.schema,
                    shards=ShardSpec(n_shards=n, replicate_hot=1))
    res = sh.search(wl.q, filters=(wl.lo, wl.hi), params=pp)
    assert np.array_equal(ref.ids, res.ids), f"id mismatch at n={n}"
    st = res.stats
    devices = {s.device for s in st.shards}
    assert len(devices) == n, (n, devices)   # each shard on its own device
    active = [s.total_active for s in st.shards if s.total_active]
    bal = max(active) / (sum(active) / len(active))
    print(f"n={n} balance={bal:.3f} replica_hits={st.replica_hits}")
print("OK")
"""


def test_mesh_parity_on_8_simulated_devices():
    """Acceptance: sharded ids bit-identical to single-device incore on
    2/4/8 simulated devices, each shard pinned to its own device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert "OK" in res.stdout, res.stdout + res.stderr
