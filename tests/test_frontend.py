"""Serving front-end (ISSUE 6): cross-request coalescing parity, SLO
scheduling, and deferred mutation maintenance."""

import collections

import numpy as np
import pytest

from repro.api import AttrSchema, Collection, F
from repro.api.planner import concat_plans, plan_queries
from repro.core.types import GMGConfig
from repro.serve.frontend import VectorFrontend, VirtualClock


@pytest.fixture(scope="module")
def serve_collection(small_data):
    """Fresh collection (tests here mutate it via inserts/flushes, so the
    session-scoped ``small_collection`` must stay untouched)."""
    v, a = small_data
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=12, n_clusters=16,
                    build_ef=48, batch_cells=2, dense_threshold=256)
    return Collection.build(
        v, a, schema=AttrSchema(["price", "ts", "views", "duration"]),
        config=cfg, seed=0)


@pytest.fixture(scope="module")
def qbatch(small_data):
    rng = np.random.default_rng(11)
    v, _ = small_data
    return rng.standard_normal((8, v.shape[1])).astype(np.float32)


def _mixed_requests(q):
    """Duplicated query vectors, mixed conjunctive/disjunctive filters,
    heterogeneous k — the cross-request qmap coverage the tentpole
    demands, all in one widened pass."""
    return [
        (q[:3], F("price").between(0.2, 0.8), 10),
        (q[3:5], (F("price") < 0.2) | (F("price") > 0.8), 5),
        (q[:3], F("ts") >= 0.5, 7),          # same vectors as request 0
        (q[5:], None, 12),
        (q[1:2], F("views").between(0.1, 0.4) & (F("ts") < 0.9), 3),
    ]


# -- cross-request qmap correctness ------------------------------------------

def test_concat_plans_offsets_and_qmap(serve_collection, qbatch):
    reqs = _mixed_requests(qbatch)
    plans = [plan_queries(f, serve_collection.schema, q.shape[0])
             for (q, f, _) in reqs]
    plan, offs = concat_plans(plans)
    assert offs.tolist() == [0, 3, 5, 8, 11, 12]
    assert plan.n_queries == 12
    assert plan.n_boxes == sum(p.n_boxes for p in plans)
    # every plan's qmap segment comes back shifted by its query offset
    start = 0
    for r, p in enumerate(plans):
        seg = plan.qmap[start:start + p.n_boxes]
        np.testing.assert_array_equal(seg, p.qmap + offs[r])
        start += p.n_boxes
    assert not plan.trivial          # request 1 is disjunctive
    assert plan.stats["n_requests"] == len(reqs)


def test_search_many_bit_identical_to_serial(serve_collection, qbatch):
    """The acceptance bar: one coalesced widened pass returns exactly the
    ids (and distances) each request's solo Collection.search gives."""
    col = serve_collection
    reqs = _mixed_requests(qbatch)
    many = col.search_many(reqs)
    assert len(many) == len(reqs)
    for (q, f, k), res in zip(reqs, many):
        solo = col.search(q, filters=f, k=k)
        assert res.k == k
        np.testing.assert_array_equal(res.ids, solo.ids)
        np.testing.assert_array_equal(res.distances, solo.distances)


def test_searcher_batch_composition_independence(serve_collection, qbatch):
    """A query's ids must not depend on who shares its batch — the engine
    contract the whole coalescing design rests on."""
    col = serve_collection
    for f in (None, F("price") <= 0.7,
              (F("ts") < 0.2) | (F("ts") > 0.8),
              F("price").between(0.48, 0.52) & F("ts").between(0.4, 0.6)):
        full = col.search(qbatch, filters=f, k=10)
        solo = col.search(qbatch[3], filters=f, k=10)
        np.testing.assert_array_equal(solo.ids[0], full.ids[3])
        sub = col.search(qbatch[2:6], filters=f, k=10)
        np.testing.assert_array_equal(sub.ids, full.ids[2:6])


def test_search_many_streamed_modes_recall_parity(serve_collection, qbatch):
    """Hybrid/ooc schedule waves over the whole tick's union incidence,
    so coalesced != serial id-for-id; assert recall parity instead."""
    col = serve_collection
    reqs = [(qbatch[:4], F("price").between(0.1, 0.9), 10),
            (qbatch[4:], None, 10)]
    for engine in ("hybrid", "ooc"):
        many = col.search_many(reqs, engine=engine)
        for (q, f, k), res in zip(reqs, many):
            truth = col.ground_truth(q, filters=f, k=k)
            solo = col.search(q, filters=f, k=k, engine=engine)
            assert res.recall(truth) >= solo.recall(truth) - 0.1


# -- observability ------------------------------------------------------------

def test_query_result_stats(serve_collection, qbatch):
    col = serve_collection
    res = col.search(qbatch, filters=F("price") <= 0.7, k=5)
    assert res.stats["engine"] == "incore"
    assert res.stats["n_rows"] == len(qbatch)
    assert (res.stats["n_dense"] + res.stats["n_global"]
            + res.stats["n_itinerary"]) == len(qbatch)
    hyb = col.search(qbatch, filters=F("price") <= 0.7, k=5,
                     engine="hybrid")
    for key in ("n_waves", "total_active", "hit_rate", "transfer_bytes"):
        assert key in hyb.stats
    assert hyb.stats["cache"]["capacity_bytes"] > 0
    dis = col.search(qbatch, filters=(F("ts") < 0.2) | (F("ts") > 0.8))
    assert dis.stats["planner"]["n_boxes"] >= len(qbatch)


# -- the frontend loop --------------------------------------------------------

def test_frontend_matches_direct_search(serve_collection, qbatch):
    col = serve_collection
    reqs = _mixed_requests(qbatch)
    fe = VectorFrontend(col, max_batch_queries=64, clock=VirtualClock())
    rids = [fe.submit(q, filters=f, k=k) for (q, f, k) in reqs]
    done = fe.drain()
    assert [r.rid for r in done] == rids
    for (q, f, k), rid in zip(reqs, rids):
        got = fe.take(rid)
        assert not got.shed and got.latency is not None
        solo = col.search(q, filters=f, k=k)
        np.testing.assert_array_equal(got.result.ids, solo.ids)
    m = fe.metrics()
    assert m["served"] == len(reqs) and m["shed"] == 0
    assert m["n_passes"] == 1        # everything coalesced into one pass


def test_frontend_parity_under_interleaved_inserts(serve_collection,
                                                   qbatch, small_data):
    col = serve_collection
    v, _ = small_data
    rng = np.random.default_rng(5)
    fe = VectorFrontend(col, max_batch_queries=64, flush_budget=1e9,
                        clock=VirtualClock())
    fe.insert(rng.standard_normal((16, v.shape[1])).astype(np.float32),
              rng.random((16, 4)).astype(np.float32))
    assert col._mut.pending_rows == 16
    reqs = _mixed_requests(qbatch)
    # serial expectations computed on the SAME pending-buffer state the
    # coalesced pass will see (search never mutates)
    serial = [col.search(q, filters=f, k=k) for (q, f, k) in reqs]
    rids = [fe.submit(q, filters=f, k=k) for (q, f, k) in reqs]
    fe.drain()
    for rid, solo in zip(rids, serial):
        np.testing.assert_array_equal(fe.take(rid).result.ids, solo.ids)
    # the deferred flush ran once the queue went idle
    assert fe.n_flushes == 1
    assert col._mut.pending_rows == 0
    # post-flush parity too: the spliced rows are now graph-resident
    post = col.search_many(reqs)
    for (q, f, k), res in zip(reqs, post):
        np.testing.assert_array_equal(
            res.ids, col.search(q, filters=f, k=k).ids)


def test_frontend_sheds_expired_requests(serve_collection, qbatch):
    clock = VirtualClock()
    fe = VectorFrontend(serve_collection, clock=clock)
    dead = fe.submit(qbatch[:1], k=5, timeout=0.5)
    live = fe.submit(qbatch[1:2], k=5)
    clock.advance(1.0)
    fe.tick()
    assert fe.take(dead).shed
    got = fe.take(live)
    assert not got.shed and got.result is not None
    m = fe.metrics()
    assert m["shed"] == 1 and 0 < m["shed_rate"] < 1


def test_frontend_edf_admission(serve_collection, qbatch):
    clock = VirtualClock()
    fe = VectorFrontend(serve_collection, max_batch_queries=1, clock=clock)
    late = fe.submit(qbatch[:1], k=5, deadline=100.0)
    early = fe.submit(qbatch[1:2], k=5, deadline=1.0)
    none = fe.submit(qbatch[2:3], k=5)           # no deadline: last
    fe.tick()
    assert early in fe.completed
    assert late not in fe.completed and none not in fe.completed
    fe.tick()
    assert late in fe.completed and none not in fe.completed
    fe.tick()
    assert none in fe.completed


def test_frontend_microbatch_wait(serve_collection, qbatch):
    clock = VirtualClock()
    fe = VectorFrontend(serve_collection, max_batch_queries=8,
                        max_wait=0.5, clock=clock)
    rid = fe.submit(qbatch[:1], k=5)
    stats = fe.tick()
    assert stats["waited"] and rid not in fe.completed
    # a full batch does not wait
    fe.submit(qbatch[1:], k=5)
    stats = fe.tick()
    assert not stats["waited"] and rid in fe.completed
    # an under-full queue executes once the wait budget elapses
    rid2 = fe.submit(qbatch[:1], k=5)
    assert fe.tick()["waited"]
    clock.advance(0.6)
    assert not fe.tick()["waited"]
    assert rid2 in fe.completed


def test_frontend_tick_exports_engine_stats(serve_collection, qbatch):
    fe = VectorFrontend(serve_collection, clock=VirtualClock())
    fe.submit(qbatch, filters=F("price") <= 0.7, k=5)
    stats = fe.tick()
    assert stats["admitted"] == 1
    assert stats["engine"]["engine"] == "incore"
    assert 0 < stats["occupancy"] <= 1
    m = fe.metrics()
    assert m["p99_latency"] >= m["p50_latency"] > 0


def test_frontend_queue_is_deque(serve_collection):
    # satellite: serving queues are deques (no O(n) head pops); the LM
    # engine's queue is asserted in test_serve.py where one is built
    fe = VectorFrontend(serve_collection)
    assert isinstance(fe.queue, collections.deque)
