"""Disjunctive range filters end-to-end: the ``|`` operator, the DNF
query planner (canonicalization + box batching), and the equivalent —
but slower — per-branch loop with a host-side merge.

    PYTHONPATH=src python examples/disjunctive_filters.py
"""

import numpy as np

from repro.api import AttrSchema, Collection, F, plan_queries
from repro.core.types import GMGConfig
from repro.data import make_dataset


def main():
    print("1. dataset: 8k vectors, price in [0, 100), ts in [0, 1)")
    vectors, attrs = make_dataset("deep", 8000, seed=0, m=2)
    attrs = attrs.copy()
    attrs[:, 0] *= 100.0
    schema = AttrSchema(["price", "ts"])
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16, n_clusters=32)
    col = Collection.build(vectors, attrs, schema=schema, config=cfg, seed=0)

    rng = np.random.default_rng(1)
    q = vectors[rng.integers(0, len(vectors), 32)] \
        + rng.normal(0, 0.3, (32, vectors.shape[1])).astype(np.float32)

    print("2. union of two price tails: (price < 10) | (price > 90)")
    tails = (F("price") < 10) | (F("price") > 90)
    plan = plan_queries(tails, schema, len(q))
    print(f"   plan: {plan.stats['n_dnf_branches']} DNF branches -> "
          f"{plan.stats['n_boxes']} boxes for {len(q)} queries, "
          f"fanout {plan.stats['max_fanout']}")
    res = col.search(q, filters=tails, k=10, ef=64)
    truth = col.ground_truth(q, filters=tails, k=10)
    print(f"   one box-batched engine pass, recall@10 = "
          f"{res.recall(truth):.4f}")
    assert res.recall(truth) >= 0.95

    print("3. canonicalization: overlapping branches collapse")
    overlapping = ((F("price") < 40) | (F("price") >= 25)) & (F("ts") <= 0.5)
    plan2 = plan_queries(overlapping, schema, len(q))
    print(f"   {plan2.stats['n_dnf_branches']} branches merged into "
          f"{plan2.stats['max_fanout']} box per query "
          "(intervals overlap on 'price')")
    assert plan2.stats["max_fanout"] == 1

    print("4. nested and/or: tails restricted to early timestamps")
    nested = tails & (F("ts") <= 0.5)
    res_n = col.search(q, filters=nested, k=10, ef=64)
    truth_n = col.ground_truth(q, filters=nested, k=10)
    print(f"   recall@10 = {res_n.recall(truth_n):.4f}")

    print("5. per-branch loop + QueryResult.merge gives the same answer")
    r_lo = col.search(q, filters=F("price") < 10, k=10, ef=64)
    r_hi = col.search(q, filters=F("price") > 90, k=10, ef=64)
    merged = r_lo.merge(r_hi)
    print(f"   merged recall@10 = {merged.recall(truth):.4f} "
          "(two engine passes instead of one)")
    assert merged.recall(truth) >= 0.95
    print("OK")


if __name__ == "__main__":
    main()
