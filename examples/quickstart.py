"""Quickstart: the `Collection` API end-to-end — build a range-filtered
ANN collection with named attributes, query it with composable filter
expressions, persist it, and check recall against the exact answer.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.api import AttrSchema, Collection, F
from repro.core.types import GMGConfig
from repro.data import make_dataset, make_queries


def main():
    print("1. synthesizing 10k vectors x 128d with 4 named attributes")
    vectors, attrs = make_dataset("sift", 10000, seed=0)
    schema = AttrSchema(["price", "ts", "views", "duration"])

    print("2. building the collection (2x2 grid, degree-16 CAGRA cells)")
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16, n_clusters=32)
    col = Collection.build(vectors, attrs, schema=schema, config=cfg, seed=0)
    sizes = col.index.nbytes()
    print(f"   index {sizes['index_bytes'] / 1e6:.1f}MB on "
          f"{sizes['vector_bytes'] / 1e6:.1f}MB of vectors "
          f"({col.index.n_cells} cells)")

    print("3. querying: 64 queries, range predicates on 2 attributes")
    wl = make_queries(vectors, attrs, 64, 2, seed=1)
    res = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    print(f"   engine={res.engine}, mean valid results "
          f"{res.valid_counts.mean():.1f}/10")

    print("4. exact ground truth + recall")
    true_ids = col.ground_truth(wl.q, filters=(wl.lo, wl.hi), k=10)
    rec = res.recall(true_ids)
    print(f"   recall@10 = {rec:.4f}")
    assert rec > 0.9

    print("5. named one-sided filter == hand-built ±inf arrays")
    t0 = float(np.quantile(attrs[:, 1], 0.5))
    res_expr = col.search(wl.q, filters=F("ts") >= t0, k=10, ef=64)
    lo = np.full((64, 4), -np.inf, np.float32)
    hi = np.full((64, 4), np.inf, np.float32)
    lo[:, 1] = t0
    res_raw = col.search(wl.q, filters=(lo, hi), k=10, ef=64)
    assert np.array_equal(res_expr.ids, res_raw.ids)
    print("   identical ids for F('ts') >= t0")

    print("6. disjunctive filter: price tails, one box-batched pass")
    p10, p90 = np.quantile(attrs[:, 0], [0.10, 0.90])
    union = (F("price") < float(p10)) | (F("price") > float(p90))
    res_or = col.search(wl.q, filters=union, k=10, ef=64)
    true_or = col.ground_truth(wl.q, filters=union, k=10)
    rec_or = res_or.recall(true_or)
    print(f"   planner ran {col.last_stats['planner']['n_boxes']} boxes "
          f"for {len(wl.q)} queries in one engine pass; "
          f"recall@10 = {rec_or:.4f}")
    assert rec_or > 0.9

    print("7. engine modes: one traversal core, three residency tiers")
    #   mode    | vectors       | graph          | seeding
    #   --------+---------------+----------------+--------------
    #   incore  | fp32 resident | fully resident | fresh beam
    #   hybrid  | int8 +rerank  | LRU cell cache | carried pool
    #   ooc     | int8 +rerank  | streamed batch | carried pool
    # mode="auto" (the default) picks from device_budget_bytes; an
    # explicit mode (or search(engine=...)) forces a tier.
    col.device_budget_bytes = col.hybrid_min_bytes() + (256 << 10)
    print(f"   budget {col.device_budget_bytes / 1e6:.1f}MB -> "
          f"{col.plan()['engine']} "
          f"(in-core would need {col.in_core_bytes() / 1e6:.1f}MB)")
    res_h = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    print(f"   hybrid recall@10 = {res_h.recall(true_ids):.4f} "
          f"({col.last_stats['cache_misses']} cell-cache misses)")

    # a second, warm batch: the LRU cell cache kept the hot graph cells
    # device-resident and the cache-aware wave order runs them first, so
    # repeated workloads stop paying transfer — watch `Collection.last_stats`
    col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    warm = col.last_stats
    print(f"   warm batch: hit_rate={warm['hit_rate']:.2f}, "
          f"transfer_bytes={warm['transfer_bytes']} "
          f"(rerank={warm['rerank']}, {warm['cache_policy']} cache)")
    assert warm["hit_rate"] > 0
    col.device_budget_bytes = None          # back to in-core

    print("8. streaming updates: insert -> search -> delete -> compact")
    extra_v = vectors[:4] + 0.01
    new_ids = col.insert(extra_v, attrs[:4])
    res_new = col.search(extra_v, k=1)
    assert np.array_equal(res_new.ids[:, 0], new_ids)   # buffered, found
    col.delete(new_ids[:2])
    res_del = col.search(extra_v[:2], k=1)
    assert not np.isin(res_del.ids, new_ids[:2]).any()  # tombstoned
    col.compact()                                       # reclaim + fold
    print(f"   inserted {len(new_ids)}, deleted 2, compacted to "
          f"{col.n} rows "
          f"(pending={col.plan()['pending_rows']}, "
          f"deleted={col.plan()['deleted_rows']})")

    print("9. serving frontend: submit -> tick -> drain, one widened pass")
    from repro.serve.frontend import VectorFrontend
    fe = VectorFrontend(col, max_batch_queries=64)
    rid_a = fe.submit(wl.q[:3], filters=F("ts") >= t0, k=10)
    rid_b = fe.submit(wl.q[3:5], filters=union, k=5)    # mixed filters/k
    fe.tick()               # both requests coalesce into ONE engine pass
    got_a, got_b = fe.take(rid_a), fe.take(rid_b)
    assert np.array_equal(got_a.result.ids,
                          col.search(wl.q[:3], filters=F("ts") >= t0,
                                     k=10).ids)          # bit-identical
    m = fe.metrics()
    print(f"   served {m['served']} requests in {m['n_passes']} pass, "
          f"p99 latency {m['p99_latency'] * 1e3:.1f}ms "
          f"(occupancy {m['mean_batch_occupancy']:.2f})")

    print("10. mesh tier: cells sharded across devices, bit-identical ids")
    from repro.api import ShardSpec
    from repro.core.types import SearchParams
    # the partition-independent profile (no inter-cell edges / global
    # fallback — those are inherently cross-shard); the sharded incore
    # tier coerces it, the reference must opt in for the comparison
    pp = SearchParams(k=10, ef=64, use_inter_edges=False,
                      adaptive_global=False)
    ref = col.search(wl.q, filters=(wl.lo, wl.hi), params=pp)
    shc = Collection(index=col.index, schema=schema,
                     shards=ShardSpec(n_shards=2, replicate_hot=1))
    res_sh = shc.search(wl.q, filters=(wl.lo, wl.hi), params=pp)
    assert np.array_equal(ref.ids, res_sh.ids)          # bit parity
    st = res_sh.stats
    print(f"   {st.n_shards} shards, per-shard work "
          f"{[s.total_active for s in st.shards]} "
          f"(replica hits {st.replica_hits}); ids identical to 1 device")

    print("11. save -> load -> search round-trip (mode rides along)")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "collection.npz")
        col.save(path)
        col2 = Collection.load(path)
        res2 = col2.search(wl.q, filters=F("ts") >= t0, k=10, ef=64)
    assert col2.mode == col.mode
    res_expr2 = col.search(wl.q, filters=F("ts") >= t0, k=10, ef=64)
    assert np.array_equal(res_expr2.ids, res2.ids)
    print("   identical results after reload")
    print("OK")


if __name__ == "__main__":
    main()
