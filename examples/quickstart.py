"""Quickstart: build a GMG index, run multi-attribute range-filtered
ANN queries, check recall against the exact answer.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import gmg
from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_dataset, make_queries


def main():
    print("1. synthesizing 10k vectors x 128d with 4 numeric attributes")
    vectors, attrs = make_dataset("sift", 10000, seed=0)

    print("2. building the GMG index (2x2 grid, degree-16 CAGRA cells)")
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16, n_clusters=32)
    index = gmg.build_gmg(vectors, attrs, cfg, seed=0)
    sizes = index.nbytes()
    print(f"   index {sizes['index_bytes'] / 1e6:.1f}MB on "
          f"{sizes['vector_bytes'] / 1e6:.1f}MB of vectors "
          f"({index.n_cells} cells)")

    print("3. querying: 64 queries, range predicates on 2 attributes")
    wl = make_queries(vectors, attrs, 64, 2, seed=1)
    searcher = Searcher(index)
    ids, dists = searcher.search(wl.q, wl.lo, wl.hi,
                                 SearchParams(k=10, ef=64))

    print("4. exact ground truth + recall")
    true_ids, _ = ground_truth(vectors, attrs, wl.q, wl.lo, wl.hi, 10)
    rec = recall_at_k(ids, true_ids)
    print(f"   recall@10 = {rec:.4f}")
    assert rec > 0.9
    print("OK")


if __name__ == "__main__":
    main()
