"""Out-of-core RFANNS (paper Section 5) through the `Collection` API:
declare a device-memory budget and the collection dispatches to the
streaming engine (int8 vectors resident, graph streamed in scheduled
cell batches, exact host re-rank).

    PYTHONPATH=src python examples/out_of_core.py
"""

from repro.api import AttrSchema, Collection
from repro.core.pipeline import multihost_plan
from repro.core.types import GMGConfig, SearchParams
from repro.core import select as sel
from repro.data import make_dataset, make_queries


def main():
    vectors, attrs = make_dataset("sift", 12000, seed=0)
    cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=16, n_clusters=32,
                    batch_cells=3)
    col = Collection.build(
        vectors, attrs,
        schema=AttrSchema(["price", "ts", "views", "duration"]),
        config=cfg, seed=0)

    # a budget below the in-core footprint forces the streaming engine,
    # with the leftover (after the int8 residents) as the graph window
    col.device_budget_bytes = col.out_of_core_resident_bytes() + (512 << 10)
    plan = col.plan()
    print(f"in-core needs {plan['in_core_bytes'] / 1e6:.1f}MB; "
          f"budget {plan['device_budget_bytes'] / 1e6:.1f}MB -> "
          f"engine={plan['engine']}")
    print(f"cells/batch under 512KB graph window: "
          f"{plan['cells_per_batch']}")

    wl = make_queries(vectors, attrs, 48, 2, seed=1)
    res = col.search(wl.q, filters=(wl.lo, wl.hi),
                     params=SearchParams(k=10))
    assert res.engine == "out_of_core"
    print("pipeline stats:", col.last_stats)

    true_ids = col.ground_truth(wl.q, filters=(wl.lo, wl.hi), k=10)
    print(f"recall@10 = {res.recall(true_ids):.4f}")

    # fleet-scale plan: cells sharded over 4 hosts, Alg. 5 per host
    idx = col.index
    inc = sel.incidence_numpy(wl.lo, wl.hi, idx.cell_lo, idx.cell_hi)
    host_of, plans, totals = multihost_plan(inc, n_hosts=4, batch_size=2)
    print(f"multi-host active-query totals per host: {totals}")


if __name__ == "__main__":
    main()
