"""Out-of-core RFANNS (paper Section 5): int8 vectors resident, graph
streamed in scheduled cell batches, exact host re-rank.

    PYTHONPATH=src python examples/out_of_core.py
"""

import numpy as np

from repro.core import gmg
from repro.core.pipeline import OutOfCoreEngine, multihost_plan
from repro.core.search import ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.core import select as sel
from repro.data import make_dataset, make_queries


def main():
    vectors, attrs = make_dataset("sift", 12000, seed=0)
    cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=16, n_clusters=32,
                    batch_cells=3)
    index = gmg.build_gmg(vectors, attrs, cfg, seed=0)

    # stream under an explicit HBM budget
    engine = OutOfCoreEngine(index, hbm_budget_bytes=2 << 20)
    print(f"cells/batch under 2MB graph window: {engine.cells_per_batch()}")

    wl = make_queries(vectors, attrs, 48, 2, seed=1)
    ids, dists = engine.search(wl.q, wl.lo, wl.hi, SearchParams(k=10))
    print("pipeline stats:", {k: v for k, v in engine.stats.items()})

    true_ids, _ = ground_truth(vectors, attrs, wl.q, wl.lo, wl.hi, 10)
    print(f"recall@10 = {recall_at_k(ids, true_ids):.4f}")

    # fleet-scale plan: cells sharded over 4 hosts, Alg. 5 per host
    inc = sel.incidence_numpy(wl.lo, wl.hi, index.cell_lo, index.cell_hi)
    host_of, plans, totals = multihost_plan(inc, n_hosts=4, batch_size=2)
    print(f"multi-host active-query totals per host: {totals}")


if __name__ == "__main__":
    main()
