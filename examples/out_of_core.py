"""Memory-bounded RFANNS (paper Section 5) through the `Collection` API:
declare a device-memory budget and the collection walks the engine-mode
matrix — the same traversal core under three residency regimes:

  mode    | vectors       | graph              | seeding
  --------+---------------+--------------------+--------------
  incore  | fp32 resident | fully resident     | fresh beam
  hybrid  | int8 +rerank  | LRU cell cache     | carried pool
  ooc     | int8 +rerank  | streamed batches   | carried pool

    PYTHONPATH=src python examples/out_of_core.py
"""

from repro.api import AttrSchema, Collection
from repro.core.pipeline import multihost_plan
from repro.core.types import GMGConfig, SearchParams
from repro.core import select as sel
from repro.data import make_dataset, make_queries


def main():
    vectors, attrs = make_dataset("sift", 12000, seed=0)
    cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=16, n_clusters=32,
                    batch_cells=3)
    col = Collection.build(
        vectors, attrs,
        schema=AttrSchema(["price", "ts", "views", "duration"]),
        config=cfg, seed=0)
    wl = make_queries(vectors, attrs, 48, 2, seed=1)
    true_ids = col.ground_truth(wl.q, filters=(wl.lo, wl.hi), k=10)

    # 1. a budget that holds the int8 residents + a graph cache -> hybrid:
    # hot cells stay device-resident across query batches, misses stream
    # (sized here so the whole touched graph fits the cache; a smaller
    # cache still works, it just keeps streaming the overflow)
    from repro.core.runtime import cache_slot_bytes
    col.device_budget_bytes = (col.out_of_core_resident_bytes()
                               + cache_slot_bytes(col.index)
                               * col.index.n_cells + (64 << 10))
    assert col.device_budget_bytes < col.in_core_bytes()
    plan = col.plan()
    print(f"in-core needs {col.in_core_bytes() / 1e6:.1f}MB; "
          f"budget {plan['device_budget_bytes'] / 1e6:.1f}MB -> "
          f"engine={plan['engine']} "
          f"({plan['cache_slots']} cache slots)")
    res = col.search(wl.q, filters=(wl.lo, wl.hi),
                     params=SearchParams(k=10))
    assert res.engine == "hybrid"
    print(f"  cold pass: {col.last_stats['cache_misses']} cache misses, "
          f"{col.last_stats['transfer_bytes'] / 1e6:.2f}MB streamed, "
          f"recall@10 = {res.recall(true_ids):.4f}")
    res = col.search(wl.q, filters=(wl.lo, wl.hi),
                     params=SearchParams(k=10))
    print(f"  warm pass: {col.last_stats['cache_hits']} hits "
          f"(hit_rate {col.last_stats['hit_rate']:.2f}), "
          f"{col.last_stats['transfer_bytes']}B streamed, "
          f"rerank={col.last_stats['rerank']}")

    # 2. a budget barely above the residents -> the streaming engine,
    # with the leftover as the (re-uploaded every call) graph window
    col.device_budget_bytes = (col.out_of_core_resident_bytes()
                               + col.hybrid_min_bytes()) // 2
    plan = col.plan()
    print(f"budget {plan['device_budget_bytes'] / 1e6:.1f}MB -> "
          f"engine={plan['engine']}, "
          f"cells/batch={plan['cells_per_batch']}")
    res = col.search(wl.q, filters=(wl.lo, wl.hi),
                     params=SearchParams(k=10))
    assert res.engine == "ooc"
    print("  pipeline stats:", col.last_stats)
    print(f"  recall@10 = {res.recall(true_ids):.4f}")

    # 3. fleet-scale plan: cells sharded over 4 hosts, Alg. 5 per host
    idx = col.index
    inc = sel.incidence_numpy(wl.lo, wl.hi, idx.cell_lo, idx.cell_hi)
    host_of, plans, totals = multihost_plan(inc, n_hosts=4, batch_size=2)
    print(f"multi-host active-query totals per host: {totals}")


if __name__ == "__main__":
    main()
