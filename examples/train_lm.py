"""End-to-end driver (deliverable b): train a ~100M-param llama-family
model for a few hundred steps on the synthetic token pipeline, with
checkpoint/restart fault tolerance. Loss must drop (the pipeline has
learnable bigram/copy structure).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 768]
"""

import argparse
import tempfile

from repro.models.attention import AttnConfig
from repro.models.lm import LayerSpec, LMConfig
from repro.models.common import count_params
from repro.models import lm as lm_mod
from repro.data.tokens import TokenPipeline
from repro.train.loop import LoopConfig, run
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = LMConfig(
        name="llama-demo-100m",
        n_layers=args.layers, d_model=args.d_model, vocab=32768,
        d_ff=args.d_model * 8 // 3 // 128 * 128,
        pattern=(LayerSpec("attn", ffn="dense"),),
        attn=AttnConfig(d_model=args.d_model,
                        n_heads=args.d_model // 64,
                        n_kv_heads=max(args.d_model // 256, 1),
                        d_head=64),
        tie_embeddings=True,
    )
    n = count_params(lm_mod.lm_specs(cfg))
    print(f"model: {n / 1e6:.1f}M params")

    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         seed=0)
    tcfg = TrainConfig(remat=False, peak_lr=1e-3, warmup=20,
                       total_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt:
        loop = LoopConfig(total_steps=args.steps, ckpt_every=100,
                          ckpt_dir=ckpt, log_every=10)
        import logging
        logging.basicConfig(level=logging.INFO,
                            format="%(asctime)s %(message)s")
        state, hist = run(cfg, tcfg, loop, pipe, seed=0)
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"loss: first10={first:.4f} last10={last:.4f}")
    assert last < first, "loss did not drop"
    print("OK")


if __name__ == "__main__":
    main()
