"""Streaming mutability: a live collection ingesting writes while it
serves reads — insert -> search -> delete -> compact, with persistence
of the in-flight state.

    PYTHONPATH=src python examples/streaming_updates.py
"""

import os
import tempfile

import numpy as np

from repro.api import AttrSchema, Collection, F
from repro.core.types import GMGConfig
from repro.data import make_dataset, make_queries
from repro.core.search import ground_truth, recall_at_k


def main():
    print("1. build on the first 80% of a 6k corpus (price, ts attrs)")
    v, a = make_dataset("deep", 6000, seed=0, m=2)
    n80 = 4800
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=12, n_clusters=16)
    col = Collection.build(v[:n80], a[:n80],
                           schema=AttrSchema(["price", "ts"]),
                           config=cfg, seed=0)
    print(f"   {col.n} rows indexed")

    print("2. stream in the remaining 20% via Collection.insert")
    # keep this batch in the append buffers to show the buffered regime;
    # past this bound a cell flushes itself (cell maintenance)
    col.buffer_rows_per_cell = 1024
    ids = col.insert(v[n80:], a[n80:])
    plan = col.plan()
    print(f"   ids {ids[0]}..{ids[-1]} assigned; "
          f"{plan['pending_rows']} rows buffered (searchable already)")

    print("3. buffered rows fold into every query's top-k")
    wl = make_queries(v, a, 32, 1, seed=4)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    res = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    print(f"   recall@10 vs the full corpus = {res.recall(tids):.4f} "
          f"({col.last_stats['buffered_rows']} buffered rows scanned)")
    assert res.recall(tids) > 0.9

    print("4. flush: splice buffers into the cell-contiguous index "
          "(local graph link + cross-cell repair)")
    n_flushed = col.flush()
    res = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    print(f"   flushed {n_flushed} rows; recall@10 = {res.recall(tids):.4f}")
    assert col.plan()["pending_rows"] == 0

    print("5. delete 5%: tombstones AND into the filter mask, engines "
          "stay warm")
    rng = np.random.default_rng(1)
    dead = rng.choice(6000, 300, replace=False)
    col.delete(dead)
    res = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    leaked = np.intersect1d(res.ids[res.ids >= 0], dead).size
    print(f"   live rows {col.live_count()}; deleted ids in results: "
          f"{leaked}")
    assert leaked == 0
    # disjunctive plans honor tombstones through the qmap fold too
    p25, p75 = np.quantile(a[:, 0], [0.25, 0.75])
    union = (F("price") < float(p25)) | (F("price") > float(p75))
    res_or = col.search(wl.q, filters=union, k=10, ef=64)
    assert np.intersect1d(res_or.ids[res_or.ids >= 0], dead).size == 0

    print("6. the in-flight state persists: save -> load keeps buffers "
          "+ tombstones")
    col.insert(v[:8] + 0.03, a[:8])            # leave something pending
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "live.npz")
        col.save(path)
        col2 = Collection.load(path)
    p2 = col2.plan()
    print(f"   reloaded: pending={p2['pending_rows']} "
          f"deleted={p2['deleted_rows']} epoch={p2['mutation_epoch']}")
    assert p2["pending_rows"] == 8 and p2["deleted_rows"] == 300

    print("7. compact: reclaim tombstones, fold buffers — equivalent to "
          "a fresh build on the survivors")
    stats = col.compact()
    res = col.search(wl.q, filters=(wl.lo, wl.hi), k=10, ef=64)
    live_ids = np.setdiff1d(np.arange(col._mut.next_id), dead)
    truth = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)  # full-corpus ref
    print(f"   {stats['reclaimed']} reclaimed, {stats['flushed']} folded, "
          f"{stats['rows']} rows live; recall@10 = "
          f"{recall_at_k(res.ids, truth[0]):.4f} (vs pre-delete truth)")
    assert len(live_ids) >= stats["rows"]
    print("OK")


if __name__ == "__main__":
    main()
