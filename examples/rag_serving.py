"""RAG serving: an LM embeds queries, Garfield retrieves range-filtered
documents through the `Collection` API, the serving engine generates
with batched requests. The corpus is ingested *incrementally* — a
serving deployment never gets to rebuild from scratch: documents stream
in through ``Collection.insert`` while queries run, and the cell
maintenance machinery (auto-flush of overflowing append buffers) keeps
the index healthy underneath.

    PYTHONPATH=src python examples/rag_serving.py
"""

import numpy as np
import jax

from repro.api import AttrSchema, Collection, F
from repro.configs import get_reduced
from repro.core.types import GMGConfig
from repro.data import make_dataset
from repro.models import lm
from repro.models.common import init_params
from repro.serve.engine import Engine, Request
from repro.serve.rag import RagPipeline


def main():
    print("1. seed corpus: 6k of 8k docs with (year, views) attributes")
    vectors, attrs = make_dataset("dblp", 8000, seed=0, m=2)
    n_seed = 6000
    col = Collection.build(
        vectors[:n_seed], attrs[:n_seed],
        schema=AttrSchema(["year", "views"]),
        config=GMGConfig(seg_per_attr=(2, 2), intra_degree=12,
                         n_clusters=16),
        seed=0)

    print("2. live ingest: the remaining 2k docs arrive in batches "
          "through Collection.insert")
    col.buffer_rows_per_cell = 300        # overflowing cells self-flush
    for s in range(n_seed, 8000, 500):
        col.insert(vectors[s:s + 500], attrs[s:s + 500])
    plan = col.plan()
    print(f"   {col.live_count()} docs live "
          f"({plan['pending_rows']} still buffered after "
          f"{plan['mutation_epoch']} maintenance flushes) — "
          "all searchable")
    assert col.live_count() == 8000

    print("3. reduced llama3.2 as the embedder/generator")
    cfg = get_reduced("llama3.2-3b")
    params = init_params(lm.lm_specs(cfg), jax.random.PRNGKey(0))
    rag = RagPipeline(params=params, cfg=cfg, collection=col)

    print("4. retrieval with a year-range filter (buffered docs fold in)")
    rng = np.random.default_rng(0)
    queries = rng.integers(1, cfg.vocab, size=(4, 12))
    recent = float(np.quantile(attrs[:, 0], 0.5))     # recent half only
    res = rag.retrieve(queries, filters=F("year") >= recent, k=3)
    print("   retrieved doc ids per query:", res.ids.tolist())

    print("5. batched generation over the retrieved context")
    eng = Engine(params, cfg, lanes=4, max_seq=64)
    for i in range(4):
        ids = res.ids[i]
        prompt = np.concatenate([queries[i], ids[ids >= 0] % cfg.vocab])
        eng.submit(Request(rid=i, prompt=prompt.astype(np.int64),
                           max_new=8))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"   request {r.rid}: generated {r.out}")
    print("OK")


if __name__ == "__main__":
    main()
