"""RAG serving: an LM embeds queries, Garfield retrieves range-filtered
documents through the `Collection` API, the serving engine generates
with batched requests.

    PYTHONPATH=src python examples/rag_serving.py
"""

import numpy as np
import jax

from repro.api import AttrSchema, Collection, F
from repro.configs import get_reduced
from repro.core.types import GMGConfig
from repro.data import make_dataset
from repro.models import lm
from repro.models.common import init_params
from repro.serve.engine import Engine, Request
from repro.serve.rag import RagPipeline


def main():
    print("1. corpus: 8k docs with (year, views) attributes")
    vectors, attrs = make_dataset("dblp", 8000, seed=0, m=2)
    col = Collection.build(
        vectors, attrs, schema=AttrSchema(["year", "views"]),
        config=GMGConfig(seg_per_attr=(2, 2), intra_degree=12,
                         n_clusters=16),
        seed=0)

    print("2. reduced llama3.2 as the embedder/generator")
    cfg = get_reduced("llama3.2-3b")
    params = init_params(lm.lm_specs(cfg), jax.random.PRNGKey(0))
    rag = RagPipeline(params=params, cfg=cfg, collection=col)

    print("3. retrieval with a year-range filter")
    rng = np.random.default_rng(0)
    queries = rng.integers(1, cfg.vocab, size=(4, 12))
    recent = float(np.quantile(attrs[:, 0], 0.5))     # recent half only
    res = rag.retrieve(queries, filters=F("year") >= recent, k=3)
    print("   retrieved doc ids per query:", res.ids.tolist())

    print("4. batched generation over the retrieved context")
    eng = Engine(params, cfg, lanes=4, max_seq=64)
    for i in range(4):
        ids = res.ids[i]
        prompt = np.concatenate([queries[i], ids[ids >= 0] % cfg.vocab])
        eng.submit(Request(rid=i, prompt=prompt.astype(np.int64),
                           max_new=8))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"   request {r.rid}: generated {r.out}")
    print("OK")


if __name__ == "__main__":
    main()
