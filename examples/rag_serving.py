"""RAG serving: an LM embeds queries, Garfield retrieves range-filtered
documents through the serving front-end, the LM engine generates with
batched requests. The corpus is ingested *concurrently* — a serving
deployment never gets to rebuild from scratch: document batches stream
in through ``VectorFrontend.insert`` while queries are submitted and
ticked, landing in append buffers (searchable at once) with the
expensive graph splice deferred until the query queue goes idle.

    PYTHONPATH=src python examples/rag_serving.py
"""

import numpy as np
import jax

from repro.api import AttrSchema, Collection, F
from repro.configs import get_reduced
from repro.core.types import GMGConfig
from repro.data import make_dataset
from repro.models import lm
from repro.models.common import init_params
from repro.serve.engine import Engine, Request
from repro.serve.frontend import VectorFrontend
from repro.serve.rag import RagPipeline


def main():
    print("1. seed corpus: 6k of 8k docs with (year, views) attributes")
    vectors, attrs = make_dataset("dblp", 8000, seed=0, m=2)
    n_seed = 6000
    col = Collection.build(
        vectors[:n_seed], attrs[:n_seed],
        schema=AttrSchema(["year", "views"]),
        config=GMGConfig(seg_per_attr=(2, 2), intra_degree=12,
                         n_clusters=16),
        seed=0)

    print("2. reduced llama3.2 as the embedder/generator")
    cfg = get_reduced("llama3.2-3b")
    params = init_params(lm.lm_specs(cfg), jax.random.PRNGKey(0))
    rag = RagPipeline(params=params, cfg=cfg, collection=col)

    print("3. serve + ingest concurrently: queries coalesce into widened "
          "passes while the remaining 2k docs ride the same loop")
    fe = VectorFrontend(col, max_batch_queries=16, flush_budget=1e9)
    rng = np.random.default_rng(0)
    queries = rng.integers(1, cfg.vocab, size=(4, 12))
    qvec = rag.embed(queries)                 # (4, dim) query embeddings
    recent = float(np.quantile(attrs[:, 0], 0.5))     # recent half only
    rids = []
    for i, s in enumerate(range(n_seed, 8000, 500)):
        fe.insert(vectors[s:s + 500], attrs[s:s + 500])   # background write
        rids.append(fe.submit(qvec[i:i + 1],
                              filters=F("year") >= recent, k=3))
        fe.tick()      # buffered docs are already searchable in this pass
    fe.drain()         # queue idle -> the deferred graph splice runs here
    m = fe.metrics()
    print(f"   {col.live_count()} docs live after {m['n_flushes']} "
          f"deferred flush(es); served {m['served']} requests in "
          f"{m['n_passes']} passes (p99 {m['p99_latency'] * 1e3:.1f}ms)")
    assert col.live_count() == 8000
    assert col.plan()["pending_rows"] == 0

    print("4. retrieved doc ids per query (writes never stalled reads)")
    ids = np.stack([fe.take(rid).result.ids[0] for rid in rids])
    print("  ", ids.tolist())
    # frontend answers == direct Collection.search on the same state
    post = col.search(qvec, filters=F("year") >= recent, k=3)
    assert post.ids.shape == ids.shape

    print("5. batched generation over the retrieved context")
    eng = Engine(params, cfg, lanes=4, max_seq=64)
    for i in range(4):
        got = ids[i]
        prompt = np.concatenate([queries[i], got[got >= 0] % cfg.vocab])
        eng.submit(Request(rid=i, prompt=prompt.astype(np.int64),
                           max_new=8))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"   request {r.rid}: generated {r.out}")
    print("OK")


if __name__ == "__main__":
    main()
