"""Kernel microbench: ref (XLA) path wall-time on CPU + interpret-mode
validation cost. On TPU the pallas path would time here instead; on CPU
the ref path *is* the production path, so the numbers are real.

Also emits the traversal-wave fusion counters the CI perf gate tracks:
``per_hop_programs`` — the number of launch-grade ops (pallas_call /
sort / top_k / gather / scatter) one expansion step traces to. The
fused wave must stay at exactly 1 (one pallas_call per hop); the
unfused jnp composition is the >= 3 baseline it replaced. These are
jaxpr-structural counts, deterministic and wall-clock-free."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import config as kcfg
from repro.kernels import ops, ref
from repro.kernels import traversal_wave as twave

# primitives that lower to their own expensive launch/pass (vs cheap
# pointwise/reshape glue): what "one kernel per hop" counts
_HEAVY = {"pallas_call", "sort", "top_k", "gather", "scatter",
          "scatter-add"}


def _count_programs(fn, *args) -> int:
    """Launch-grade ops in fn's jaxpr, recursing into sub-jaxprs except
    a pallas_call's own body (its internal ops are fused in one launch).
    """
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _HEAVY:
                n += 1
            if eqn.primitive.name == "pallas_call":
                continue    # one launch regardless of body size
            for v in eqn.params.values():
                for item in (v if isinstance(v, (list, tuple)) else [v]):
                    if hasattr(item, "eqns"):
                        n += walk(item)
                    elif hasattr(item, "jaxpr"):
                        n += walk(item.jaxpr)
        return n

    return walk(closed.jaxpr)


def _wave_rows(rows):
    rng = np.random.default_rng(1)
    B, nb, n, d, m, ef, k = 8, 16, 4096, 128, 2, 32, 10
    table = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    attrs = jnp.asarray(rng.random((n, m)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    lo = jnp.zeros((B, m), jnp.float32)
    hi = jnp.ones((B, m), jnp.float32)
    cand = jnp.asarray(rng.integers(0, n, (B, nb)).astype(np.int32))
    visited = jnp.zeros((B, (n + 31) // 32), jnp.uint32)
    beam_ids = jnp.full((B, ef), -1, jnp.int32)
    beam_d = jnp.full((B, ef), jnp.inf, jnp.float32)
    beam_exp = jnp.ones((B, ef), bool)
    res_ids = jnp.full((B, k), -1, jnp.int32)
    res_d = jnp.full((B, k), jnp.inf, jnp.float32)
    args = (q, table, None, None, attrs, lo, hi, cand, cand, visited,
            beam_ids, beam_d, beam_exp, res_ids, res_d)

    with kcfg.mode("pallas"):
        n_fused = _count_programs(twave.wave_expand, *args)
    n_unfused = _count_programs(ref.wave_expand, *args)
    assert n_fused == 1, (
        f"the fused traversal wave must issue exactly ONE kernel per "
        f"expansion step, traced {n_fused}")
    assert n_unfused >= 3, (
        f"unfused baseline unexpectedly cheap: {n_unfused} programs")

    # analytic per-hop gather traffic: neighbor rows + their attr rows
    gather_f32 = B * nb * (d * 4 + m * 4)
    gather_int8 = B * nb * (d * 1 + 4 + m * 4)

    qps, dt = common.timed_qps(
        lambda: ref.wave_expand(*args)[0].block_until_ready(), B)
    rows.append(dict(bench="kernels", kernel="traversal_wave",
                     variant="unfused", B=B, nb=nb, d=d,
                     ms=round(dt * 1e3, 3),
                     per_hop_programs=n_unfused,
                     hop_gather_bytes=gather_f32))
    rows.append(dict(bench="kernels", kernel="traversal_wave",
                     variant="fused", B=B, nb=nb, d=d,
                     per_hop_programs=n_fused,
                     hop_gather_bytes=gather_f32,
                     hop_gather_bytes_int8=gather_int8))


def run(scale: str = "smoke"):
    rng = np.random.default_rng(0)
    sizes = [(128, 4096, 128), (256, 16384, 128)] \
        if scale == "smoke" else [(128, 4096, 128), (256, 65536, 128),
                                  (512, 65536, 768)]
    rows = []
    for B, N, d in sizes:
        q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
        qps, dt = common.timed_qps(
            lambda: ops.pairwise_l2(q, v).block_until_ready(), B)
        flops = 2.0 * B * N * d
        rows.append(dict(bench="kernels", kernel="pairwise_l2",
                         B=B, N=N, d=d, ms=round(dt * 1e3, 2),
                         gflops=round(flops / dt / 1e9, 1)))
        qps, dt = common.timed_qps(
            lambda: ops.topk_l2(q, v, 10)[0].block_until_ready(), B)
        rows.append(dict(bench="kernels", kernel="fused_topk",
                         B=B, N=N, d=d, ms=round(dt * 1e3, 2),
                         gflops=round(flops / dt / 1e9, 1)))
        idx = jnp.asarray(rng.integers(0, N, size=(B, 16)).astype(np.int32))
        qps, dt = common.timed_qps(
            lambda: ops.gather_l2(q, v, idx).block_until_ready(), B)
        rows.append(dict(bench="kernels", kernel="gather_distance",
                         B=B, N=N, d=d, ms=round(dt * 1e3, 2),
                         gflops=round(2.0 * B * 16 * d / dt / 1e9, 2)))
    _wave_rows(rows)
    return rows
