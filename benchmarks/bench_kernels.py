"""Kernel microbench: ref (XLA) path wall-time on CPU + interpret-mode
validation cost. On TPU the pallas path would time here instead; on CPU
the ref path *is* the production path, so the numbers are real."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops


def run(scale: str = "smoke"):
    rng = np.random.default_rng(0)
    sizes = [(128, 4096, 128), (256, 16384, 128)] \
        if scale == "smoke" else [(128, 4096, 128), (256, 65536, 128),
                                  (512, 65536, 768)]
    rows = []
    for B, N, d in sizes:
        q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
        qps, dt = common.timed_qps(
            lambda: ops.pairwise_l2(q, v).block_until_ready(), B)
        flops = 2.0 * B * N * d
        rows.append(dict(bench="kernels", kernel="pairwise_l2",
                         B=B, N=N, d=d, ms=round(dt * 1e3, 2),
                         gflops=round(flops / dt / 1e9, 1)))
        qps, dt = common.timed_qps(
            lambda: ops.topk_l2(q, v, 10)[0].block_until_ready(), B)
        rows.append(dict(bench="kernels", kernel="fused_topk",
                         B=B, N=N, d=d, ms=round(dt * 1e3, 2),
                         gflops=round(flops / dt / 1e9, 1)))
        idx = jnp.asarray(rng.integers(0, N, size=(B, 16)).astype(np.int32))
        qps, dt = common.timed_qps(
            lambda: ops.gather_l2(q, v, idx).block_until_ready(), B)
        rows.append(dict(bench="kernels", kernel="gather_distance",
                         B=B, N=N, d=d, ms=round(dt * 1e3, 2),
                         gflops=round(2.0 * B * 16 * d / dt / 1e9, 2)))
    return rows
