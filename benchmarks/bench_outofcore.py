"""Paper Figure 14 + Table 3: out-of-core pipeline (overlap) and cell
scheduling (active-query minimization)."""

from __future__ import annotations

from benchmarks import common
from repro.core.pipeline import OutOfCoreEngine
from repro.core.search import recall_at_k
from repro.core.types import SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    from repro.core import gmg
    from repro.core.types import GMGConfig
    cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=16, n_clusters=32,
                    batch_cells=3)
    idx = gmg.build_gmg(v, a, cfg, seed=0)
    eng = OutOfCoreEngine(idx)
    rows = []
    for m in (1, 2):
        wl = make_queries(v, a, nq, m, seed=110 + m)
        from repro.core.search import ground_truth
        tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
        p = SearchParams(k=10, ef=64)
        for sched in (True, False):
            ids, _ = eng.search(wl.q, wl.lo, wl.hi, p, use_schedule=sched)
            stats = dict(eng.stats)
            qps, _ = common.timed_qps(
                lambda: eng.search(wl.q, wl.lo, wl.hi, p,
                                   use_schedule=sched), nq, warmup=0,
                iters=2)
            rows.append(dict(
                bench="outofcore", m=m,
                schedule="greedy" if sched else "naive",
                recall=round(recall_at_k(ids, tids), 4),
                qps=round(qps, 1),
                total_active=stats["total_active"],
                n_batches=stats["n_batches"],
                transfer_mb=round(stats["transfer_bytes"] / 1e6, 2)))
    return rows
