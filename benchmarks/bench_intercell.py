"""Paper Figure 12: impact of the number of inter-cell edges l."""

from __future__ import annotations

from benchmarks import common
from repro.core import gmg
from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    wl = make_queries(v, a, nq, 2, seed=95)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    rows = []
    for l in (1, 2, 4):
        cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16,
                        inter_degree=l, n_clusters=32)
        idx = gmg.build_gmg(v, a, cfg, seed=0)
        s = Searcher(idx)
        p = SearchParams(k=10, ef=64)
        ids, _ = s.search(wl.q, wl.lo, wl.hi, p)
        qps, _ = common.timed_qps(lambda: s.search(wl.q, wl.lo, wl.hi, p),
                                  nq)
        rows.append(dict(bench="intercell", l=l,
                         recall=round(recall_at_k(ids, tids), 4),
                         qps=round(qps, 1),
                         inter_bytes=idx.inter_adj.nbytes))
    return rows
