"""Paper Figure 11: impact of the number of cells S."""

from __future__ import annotations

from benchmarks import common
from repro.core import gmg
from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    wl = make_queries(v, a, nq, 2, seed=90)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    rows = []
    for seg in ((2, 2), (4, 2), (4, 4), (4, 6)):
        cfg = GMGConfig(seg_per_attr=seg, intra_degree=16, n_clusters=32)
        idx = gmg.build_gmg(v, a, cfg, seed=0)
        s = Searcher(idx)
        p = SearchParams(k=10, ef=64)
        ids, _ = s.search(wl.q, wl.lo, wl.hi, p)
        qps, _ = common.timed_qps(lambda: s.search(wl.q, wl.lo, wl.hi, p),
                                  nq)
        rows.append(dict(bench="cells", S=cfg.n_cells,
                         recall=round(recall_at_k(ids, tids), 4),
                         qps=round(qps, 1),
                         index_bytes=idx.nbytes()["index_bytes"]))
    return rows
