"""Paper Table 2: index build time + size, Garfield vs baselines.

Columns mirror the paper: build seconds, index bytes; plus the analytic
sizes of iRangeGraph/UNIFY-style structures at the same (n, M) for the
inflation-ratio comparison (those systems are CPU C++ codebases; their
*sizes* follow from their published space complexities — O(nM log n) and
O(nMS) — which is the paper's own Table 2 story)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.api import AttrSchema, Collection
from repro.core.baselines import FlatBaseline
from repro.core.types import GMGConfig


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    rows = []
    for ds in sc["datasets"]:
        n = sc["n"]
        v, a = common.dataset(ds, n)
        cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16, n_clusters=32)

        t0 = time.perf_counter()
        col = Collection.build(v, a, schema=AttrSchema.generic(a.shape[1]),
                               config=cfg, seed=0)
        t_gmg = time.perf_counter() - t0
        nb = col.index.nbytes()
        common._CACHE[("collection", ds, n, cfg.seg_per_attr,
                       cfg.intra_degree, cfg.inter_degree, 0)] = col

        t0 = time.perf_counter()
        flat = FlatBaseline.build(v, a, degree=16)
        t_flat = time.perf_counter() - t0
        common._CACHE[("flat", ds, n)] = flat

        M = 16
        irange_bytes = n * M * int(np.log2(n)) * 4       # O(nM log n)
        unify_bytes = n * M * cfg.n_cells * 4            # O(nMS)
        rows.append(dict(
            bench="build", dataset=ds, n=n,
            gmg_build_s=round(t_gmg, 2),
            gmg_index_bytes=nb["index_bytes"],
            flat_build_s=round(t_flat, 2),
            flat_index_bytes=flat.nbytes()["graph_bytes"],
            irangegraph_bytes_analytic=irange_bytes,
            unify_bytes_analytic=unify_bytes,
            inflation_vs_irange=round(irange_bytes / nb["index_bytes"], 2),
            inflation_vs_unify=round(unify_bytes / nb["index_bytes"], 2),
        ))
    return rows
