"""Shared benchmark scaffolding.

Each bench_* module exposes ``run(scale) -> list[dict]`` rows; run.py
aggregates to CSV. Scales: "smoke" (CI-size) and "full" (paper-shaped,
minutes). Rows carry (bench, dataset, config..., metric columns) —
one bench per paper table/figure, see DESIGN.md §7.

Index construction routes through the public ``repro.api.Collection``
facade; ``built_index``/``searcher_for`` expose the underlying engine
objects for ablation benches that poke engine-level knobs directly.
"""

from __future__ import annotations

import time

from repro.api import AttrSchema, Collection
from repro.core.search import Searcher, ground_truth, recall_at_k  # noqa: F401
from repro.core.types import GMGConfig, SearchParams  # noqa: F401
from repro.data import make_dataset, make_queries  # noqa: F401

_CACHE: dict = {}

SCALES = {
    "smoke": dict(n=8000, n_queries=32, datasets=("sift",)),
    "full": dict(n=60000, n_queries=128, datasets=("sift", "dblp")),
}


def dataset(name: str, n: int, seed: int = 0):
    key = ("data", name, n, seed)
    if key not in _CACHE:
        _CACHE[key] = make_dataset(name, n, seed=seed)
    return _CACHE[key]


def built_collection(name: str, n: int, cfg: GMGConfig | None = None,
                     seed: int = 0) -> Collection:
    cfg = cfg or GMGConfig(seg_per_attr=(2, 2), intra_degree=16,
                           n_clusters=32)
    key = ("collection", name, n, cfg.seg_per_attr, cfg.intra_degree,
           cfg.inter_degree, seed)
    if key not in _CACHE:
        v, a = dataset(name, n, seed)
        _CACHE[key] = Collection.build(
            v, a, schema=AttrSchema.generic(a.shape[1]), config=cfg,
            seed=seed)
    return _CACHE[key]


def built_index(name: str, n: int, cfg: GMGConfig | None = None,
                seed: int = 0):
    """Engine-level view (GMGIndex) of the cached collection."""
    return built_collection(name, n, cfg, seed).index


def searcher_for(index) -> Searcher:
    """The collection's in-core engine for benches that drive it raw."""
    for v in _CACHE.values():
        if isinstance(v, Collection) and v.index is index:
            return v._searcher()
    key = ("searcher", id(index))
    if key not in _CACHE:
        _CACHE[key] = Searcher(index)
    return _CACHE[key]


def truth(name: str, n: int, wl, k: int = 10, seed: int = 0):
    key = ("truth", name, n, id(wl), k)
    if key not in _CACHE:
        v, a = dataset(name, n, seed)
        _CACHE[key] = ground_truth(v, a, wl.q, wl.lo, wl.hi, k)
    return _CACHE[key]


def timed_qps(fn, n_queries: int, warmup: int = 1, iters: int = 3):
    """Wall-time QPS of a batched search callable (end-to-end latency,
    matching the paper's metric)."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    dt = (time.perf_counter() - t0) / iters
    return n_queries / dt, dt


def pretty_bytes(b: int) -> str:
    return f"{b / (1 << 20):.1f}MB"
