"""Streaming mutability bench (ISSUE 5): insert throughput, recall vs
fraction inserted, delete correctness, compaction cost.

Acceptance regime, asserted here so the rows cannot silently stop
meaning anything (the CI gate additionally tracks the recall columns
against the committed baseline):

  - build on 80% of the 5k smoke dataset, insert the remaining 20%
    through ``Collection.insert`` + ``flush``: recall@10 within 0.02 of
    a from-scratch full rebuild at identical SearchParams, in all three
    engine modes;
  - delete a random 5% of ids: zero deleted ids across >= 1k filtered
    queries, conjunctive AND disjunctive (the tombstone mask must hold
    under qmap folding), across all three modes;
  - ``compact()``: behaviorally identical to a fresh build on the
    surviving rows (recall parity asserted).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.api import AttrSchema, Collection, F
from repro.core.search import ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_dataset, make_queries

PARITY_TOL = 0.02


def run(scale: str = "smoke"):
    n, nq = (5000, 32) if scale == "smoke" else (20000, 64)
    ds = "sift"
    v, a = make_dataset(ds, n, seed=3)
    schema = AttrSchema.generic(a.shape[1])
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16, n_clusters=32)
    n80 = int(0.8 * n)
    rows = []

    wl = make_queries(v, a, nq, 2, seed=77)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    p = SearchParams(k=10, ef=96)

    col = Collection.build(v[:n80], a[:n80], schema=schema, config=cfg,
                           seed=0)
    col.buffer_rows_per_cell = 10 ** 9      # measure the buffered regime
    full = Collection.build(v, a, schema=schema, config=cfg, seed=0)

    # -- insert throughput + recall vs fraction inserted (buffered) ----------
    chunk = max((n - n80) // 4, 1)
    inserted = 0
    t_insert = 0.0
    while inserted < n - n80:
        s = n80 + inserted
        e = min(s + chunk, n)
        t0 = time.perf_counter()
        col.insert(v[s:e], a[s:e])
        t_insert += time.perf_counter() - t0
        inserted = e - n80
        res = col.search(wl.q, filters=(wl.lo, wl.hi), params=p)
        rows.append(dict(
            bench="updates", dataset=ds, phase="recall_vs_fraction",
            fraction=round(inserted / n, 3),
            recall=round(recall_at_k(res.ids, tids), 4)))
    rows.append(dict(
        bench="updates", dataset=ds, phase="insert_throughput",
        n_inserted=inserted,
        rows_per_s=round(inserted / max(t_insert, 1e-9), 1)))

    # -- flush + per-mode recall parity vs the full rebuild ------------------
    t0 = time.perf_counter()
    col.flush()
    t_flush = time.perf_counter() - t0
    rows.append(dict(bench="updates", dataset=ds, phase="flush",
                     n_flushed=inserted, seconds=round(t_flush, 3)))
    for mode in ("incore", "hybrid", "ooc"):
        res_i = col.search(wl.q, filters=(wl.lo, wl.hi), params=p,
                           engine=mode)
        qps, _ = common.timed_qps(
            lambda: col.search(wl.q, filters=(wl.lo, wl.hi), params=p,
                               engine=mode), nq, warmup=0, iters=2)
        res_f = full.search(wl.q, filters=(wl.lo, wl.hi), params=p,
                            engine=mode)
        r_inc = recall_at_k(res_i.ids, tids)
        r_full = recall_at_k(res_f.ids, tids)
        assert r_full - r_inc <= PARITY_TOL, (
            f"incremental {mode} recall {r_inc:.4f} fell more than "
            f"{PARITY_TOL} below the full rebuild's {r_full:.4f}")
        rows.append(dict(
            bench="updates", dataset=ds, phase="incremental", mode=mode,
            recall=round(r_inc, 4), recall_full=round(r_full, 4),
            qps=round(qps, 1)))

    # -- deletes: zero tombstoned ids across >= 1k filtered queries ----------
    rng = np.random.default_rng(5)
    dead = rng.choice(n, n // 20, replace=False)
    t0 = time.perf_counter()
    col.delete(dead)
    t_del = time.perf_counter() - t0
    nq_del = 512
    wl_d = make_queries(v, a, nq_del, 1, seed=78)
    p10, p90 = np.quantile(a[:, 0], [0.10, 0.90])
    expr = (F("attr0") < float(p10)) | (F("attr0") > float(p90))
    for mode in ("incore", "hybrid", "ooc"):
        hits = 0
        res = col.search(wl_d.q, filters=(wl_d.lo, wl_d.hi),
                         params=p, engine=mode)
        hits += np.intersect1d(res.ids[res.ids >= 0], dead).size
        res = col.search(wl_d.q, filters=expr, params=p, engine=mode)
        hits += np.intersect1d(res.ids[res.ids >= 0], dead).size
        assert hits == 0, (
            f"{mode}: {hits} deleted ids surfaced across "
            f"{2 * nq_del} filtered queries")
        rows.append(dict(
            bench="updates", dataset=ds, phase="delete", mode=mode,
            n_queries=2 * nq_del, n_deleted=len(dead), deleted_hits=hits,
            delete_seconds=round(t_del, 4)))

    # -- compaction: cost + parity with a fresh build on the survivors -------
    live_v, live_a, live_ids = col._live_view()
    t0 = time.perf_counter()
    col.compact(seed=0)
    t_comp = time.perf_counter() - t0
    fresh = Collection.build(live_v, live_a, schema=schema, config=cfg,
                             seed=0)
    t_pos, _ = ground_truth(live_v, live_a, wl.q, wl.lo, wl.hi, 10)
    t_live = np.where(t_pos >= 0, live_ids[np.maximum(t_pos, 0)], -1)
    res_c = col.search(wl.q, filters=(wl.lo, wl.hi), params=p)
    res_f = fresh.search(wl.q, filters=(wl.lo, wl.hi), params=p)
    mapped = np.where(res_f.ids >= 0,
                      live_ids[np.maximum(res_f.ids, 0)], -1)
    assert np.array_equal(res_c.ids, mapped), (
        "compact() must behave identically to a fresh build on the "
        "surviving rows")
    rows.append(dict(
        bench="updates", dataset=ds, phase="compact",
        seconds=round(t_comp, 2), rows_after=col.n,
        recall=round(recall_at_k(res_c.ids, t_live), 4),
        recall_fresh=round(recall_at_k(mapped, t_live), 4)))
    return rows
