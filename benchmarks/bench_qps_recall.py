"""Paper Figure 7: QPS vs recall across m ∈ {1, 2, 4} filtering
attributes, Garfield vs GPU-Pre / CAGRA-Post / inline-filter."""

from __future__ import annotations


from benchmarks import common
from repro.core.baselines import (inline_filter_search, postfilter_search,
                                  prefilter_search)
from repro.core.search import recall_at_k
from repro.core.types import SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    rows = []
    for ds in sc["datasets"]:
        n, nq = sc["n"], sc["n_queries"]
        v, a = common.dataset(ds, n)
        idx = common.built_index(ds, n)
        s = common.searcher_for(idx)
        flat = common._CACHE.get(("flat", ds, n))
        if flat is None:
            from repro.core.baselines import FlatBaseline
            flat = FlatBaseline.build(v, a, degree=16)
            common._CACHE[("flat", ds, n)] = flat

        for m in (1, 2, 4):
            wl = make_queries(v, a, nq, m, seed=40 + m)
            tids, _ = common.truth(ds, n, wl)

            for ef in (32, 64, 128):
                p = SearchParams(k=10, ef=ef)
                ids, _ = s.search(wl.q, wl.lo, wl.hi, p)   # compile warm
                qps, _ = common.timed_qps(
                    lambda: s.search(wl.q, wl.lo, wl.hi, p), nq)
                rows.append(dict(bench="qps_recall", dataset=ds, m=m,
                                 method="garfield", ef=ef,
                                 recall=round(recall_at_k(ids, tids), 4),
                                 qps=round(qps, 1)))

            ids, _ = prefilter_search(flat, wl.q, wl.lo, wl.hi, 10)
            qps, _ = common.timed_qps(
                lambda: prefilter_search(flat, wl.q, wl.lo, wl.hi, 10), nq)
            rows.append(dict(bench="qps_recall", dataset=ds, m=m,
                             method="gpu_pre", ef=0,
                             recall=round(recall_at_k(ids, tids), 4),
                             qps=round(qps, 1)))

            for expand in (2, 4):
                ids, _ = postfilter_search(flat, wl.q, wl.lo, wl.hi, 10,
                                           expand=expand)
                qps, _ = common.timed_qps(
                    lambda: postfilter_search(flat, wl.q, wl.lo, wl.hi, 10,
                                              expand=expand), nq)
                rows.append(dict(bench="qps_recall", dataset=ds, m=m,
                                 method="cagra_post", ef=expand * 10,
                                 recall=round(recall_at_k(ids, tids), 4),
                                 qps=round(qps, 1)))

            ids, _ = inline_filter_search(flat, wl.q, wl.lo, wl.hi, 10)
            qps, _ = common.timed_qps(
                lambda: inline_filter_search(flat, wl.q, wl.lo, wl.hi, 10),
                nq)
            rows.append(dict(bench="qps_recall", dataset=ds, m=m,
                             method="inline_filter", ef=64,
                             recall=round(recall_at_k(ids, tids), 4),
                             qps=round(qps, 1)))
    return rows
