"""Mesh-tier sweep: sharded vs single-device serving (ISSUE 9).

Sweeps the cell-sharded engine across 1/2/4/8 shards on whatever
devices are present (each shard pins to ``devices[s % n_devices]``, so
the same sweep runs on one CPU device in the harness and on a real
simulated mesh in the CI job with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Asserted here (hard bench failures, not just tracked drift):
  - id parity: sharded incore results are bit-identical to the
    single-device run under the partition-independent profile;
  - work-partition balance: per-shard served-incidence max/mean <= 1.5.

Tracked by the gate (deterministic host-side counters): recall,
``active_balance`` and ``replica_hits`` per shard count.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (SCALES, built_collection, dataset,
                               make_queries, recall_at_k, timed_qps, truth)
from repro.api import Collection, ShardSpec
from repro.core.types import GMGConfig, SearchParams

# balanced placement is only demonstrable with enough cells to spread:
# 16 cells support the full 1/2/4/8 sweep
_CFG = GMGConfig(seg_per_attr=(4, 4), intra_degree=16, n_clusters=32,
                 dense_threshold=256)

BALANCE_CAP = 1.5          # acceptance: max/mean served incidences

# the partition-independent traversal profile (the sharded incore tier
# always runs it; the reference must too for bit-parity)
_PP = SearchParams(k=10, use_inter_edges=False, adaptive_global=False)


def run(scale: str):
    import jax
    p = SCALES[scale]
    rows = []
    for name in p["datasets"]:
        col = built_collection(name, p["n"], _CFG)
        v, a = dataset(name, p["n"])
        wl = make_queries(v, a, p["n_queries"], 2, seed=3)
        gt, _ = truth(name, p["n"], wl, 10)
        ref = col.search(wl.q, filters=(wl.lo, wl.hi), params=_PP,
                         engine="incore")
        for n_shards in (1, 2, 4, 8):
            sh = Collection(index=col.index, schema=col.schema,
                            shards=ShardSpec(n_shards=n_shards,
                                             replicate_hot=2))
            res = sh.search(wl.q, filters=(wl.lo, wl.hi), params=_PP,
                            engine="incore")
            assert np.array_equal(ref.ids, res.ids), \
                f"sharded ids diverged at n_shards={n_shards}"
            st = res.stats
            active = [s.total_active for s in st.shards]
            mean = sum(active) / max(len(active), 1)
            balance = max(active) / max(mean, 1e-12)
            assert balance <= BALANCE_CAP, \
                (f"work-partition balance {balance:.2f} > {BALANCE_CAP} "
                 f"at n_shards={n_shards}: {active}")
            qps, _ = timed_qps(
                lambda: sh.search(wl.q, filters=(wl.lo, wl.hi),
                                  params=_PP, engine="incore"),
                p["n_queries"])
            rows.append({
                "dataset": name,
                "n_shards": n_shards,
                "n_devices": len(jax.devices()),
                "replicate_hot": 2,
                "qps": round(qps, 1),
                "recall": round(recall_at_k(res.ids, gt), 4),
                "active_balance": round(balance, 4),
                "total_active": int(st.total_active),
                "replica_hits": int(st.replica_hits),
                "replicated_cells": int(st.replicated_cells),
                "parity": "exact",
            })
    return rows
