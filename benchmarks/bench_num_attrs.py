"""Paper Figure 9: total filtered attributes m from 2 to 10 (p=4 indexed;
the rest are scalar checks during traversal)."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import gmg
from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_dataset, make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    n, nq = sc["n"], sc["n_queries"]
    rows = []
    # dataset with 10 attributes; index partitions the first p=2 (smoke)
    v, a = make_dataset("sift", n, seed=0, m=10)
    cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16, n_clusters=32)
    idx = gmg.build_gmg(v, a, cfg, seed=0)
    s = Searcher(idx)
    for m in (2, 4, 6, 8, 10):
        wl = make_queries(v, a, nq, m, seed=70 + m,
                          sel_range=(0.3, 1.0))
        tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
        p = SearchParams(k=10, ef=64)
        ids, _ = s.search(wl.q, wl.lo, wl.hi, p)
        qps, _ = common.timed_qps(lambda: s.search(wl.q, wl.lo, wl.hi, p),
                                  nq)
        rows.append(dict(bench="num_attrs", m=m,
                         recall=round(recall_at_k(ids, tids), 4),
                         qps=round(qps, 1),
                         mean_selectivity=float(np.mean(wl.sel))))
    return rows
