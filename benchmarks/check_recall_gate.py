"""CI quality + perf gate: smoke-bench metrics vs. the committed baseline.

Reads the per-bench JSON written by ``python -m benchmarks.run --scale
smoke`` (results/bench/*.json) and tracks two metric families:

  quality — recall of Garfield's QPS/recall sweep rows, the disjunctive
      box-batched rows, the engine-mode memory-budget sweep (incore /
      hybrid / ooc) and the cost-model selectivity sweep (cost-on
      recall per regime x mode, plus its on/off speedup under the loose
      wall-clock rule). Fails when a recall drops more than
      ``tolerance`` below baseline.
  perf — the streamed engines' scheduling/transfer counters from
      ``bench_memory_budget``: ``total_active`` (Alg. 5's objective),
      cache ``hit_rate`` and warm ``transfer_bytes``. These are
      deterministic host-side counters (no wall-clock flakiness), so the
      gate holds them to tight direction-aware tolerances: lower-is-
      better counters fail on growth beyond a relative slack,
      ``hit_rate`` fails on an absolute drop. A cache-layout or
      scheduling change that silently re-inflates transfer can no
      longer pass CI. ``bench_sharding``'s mesh-tier counters
      (``active_balance`` work-partition skew, ``replica_hits``
      hot-replica routing) ride the same deterministic rules.
  serving — ``bench_serving``'s frontend rows: recall, batching speedup
      over the serial loop, p99 latency and shed rate. These carry
      wall-clock, so their limits are deliberately loose (order-of-
      magnitude guards, not runner-jitter traps).

All families fail the job too when a tracked metric disappears entirely
(a silently-skipped bench must not pass the gate).

After an *intentional* quality/perf change, regenerate the baseline::

    PYTHONPATH=src python -m benchmarks.run --scale smoke
    PYTHONPATH=src python -m benchmarks.check_recall_gate --write-baseline

and commit the updated baseline file alongside the change.

In CI the comparison table is also appended as markdown to
``$GITHUB_STEP_SUMMARY`` (or any path passed via ``--summary``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_RESULTS = os.path.join(_REPO, "results", "bench")
DEFAULT_BASELINE = os.path.join(_REPO, "benchmarks", "baselines",
                                "smoke_recall.json")
DEFAULT_TOLERANCE = 0.03   # CPU-jax jitter headroom across versions/runners

# perf counters tracked per memory-budget row; key suffix ->
# (direction, kind, tolerance, absolute slack). Deterministic counters,
# so the slack only absorbs benign plan shifts (e.g. one extra wave).
PERF_METRICS = {
    "transfer_bytes": ("lower", "rel", 0.10, 4096),
    "total_active": ("lower", "rel", 0.10, 2),
    "hit_rate": ("higher", "abs", 0.05, 0.0),
    # double-buffered streaming (hybrid wave loop): prefetches must keep
    # landing ahead of their wave and keep being useful
    "prefetch_hits": ("higher", "rel", 0.50, 0.0),
    "prefetch_hit_rate": ("higher", "abs", 0.10, 0.0),
    # traversal-wave fusion counters (bench_kernels, jaxpr-structural):
    # an expansion step regrowing extra launches fails the gate — the
    # fused path is pinned at exactly 1 program per hop
    "per_hop_programs": ("lower", "abs", 0, 2),
    "hop_gather_bytes": ("lower", "rel", 0.10, 0.0),
    # serving rows are wall-clock (virtual-time arrivals, real service
    # cost), so the latency limit is deliberately loose — it catches
    # order-of-magnitude scheduler regressions, not runner jitter.
    "p99_ms": ("lower", "rel", 1.00, 50.0),
    "shed_rate": ("lower", "abs", 0.10, 0.0),
    # batching throughput advantage over the serial loop; the bench
    # itself asserts >= 3x, the gate holds the measured ratio loosely.
    "speedup": ("higher", "rel", 0.50, 0.0),
    # mesh tier (bench_sharding): deterministic host-side placement/
    # routing counters. The bench hard-asserts balance <= 1.5; the gate
    # additionally pins drift so a placement change that quietly skews
    # work toward one shard (or stops exercising replicas) fails CI.
    "active_balance": ("lower", "abs", 0.15, 0.0),
    "replica_hits": ("higher", "rel", 0.50, 0.0),
}


def _load_rows(results_dir: str, bench: str):
    path = os.path.join(results_dir, f"{bench}.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if data.get("status") != "ok":
        return []   # errored bench: its metrics go "missing" -> gate fails
    return data.get("rows", [])


def tracked_metrics(results_dir: str) -> dict:
    """key -> value for every metric the gate watches.

    Recall rows with recall == 0 are skipped as degenerate: at smoke
    scale some workloads (e.g. m=4 conjunctions) leave empty ground-truth
    sets and score 0/1 regardless of search quality, so a 0.0 floor could
    never fail and would only pretend to guard anything. Perf counters
    ride on the same (non-degenerate) memory-budget rows.
    """
    out = {}
    for r in _load_rows(results_dir, "bench_qps_recall"):
        if r.get("method") == "garfield" and float(r.get("recall", 0)) > 0:
            key = f"qps_recall:{r['dataset']}:m={r['m']}:ef={r['ef']}"
            out[key] = float(r["recall"])
    for r in _load_rows(results_dir, "bench_disjunction"):
        if (r.get("method") == "box_batched"
                and float(r.get("recall", 0)) > 0):
            key = f"disjunction:{r['dataset']}:branches={r['n_branches']}"
            out[key] = float(r["recall"])
    for r in _load_rows(results_dir, "bench_memory_budget"):
        if float(r.get("recall", 0)) > 0:
            base = f"memory_budget:{r['dataset']}:{r['budget']}:{r['mode']}"
            out[base] = float(r["recall"])
            for suffix in PERF_METRICS:
                if suffix in r:
                    out[f"{base}:{suffix}"] = float(r[suffix])
    for r in _load_rows(results_dir, "bench_selectivity"):
        # cost-model sweep: cost-on recall per (selectivity, mode) regime
        # plus the on/off speedup ratio (held to the loose wall-clock
        # rule shared with serving — the bench's own asserts are the
        # tight per-regime gate, this tracks drift across commits)
        base = f"selectivity:{r['dataset']}:sel={r['sel']}:{r['mode']}"
        if float(r.get("recall", 0)) > 0:
            out[base] = float(r["recall"])
        if "speedup" in r:
            out[f"{base}:speedup"] = float(r["speedup"])
    for r in _load_rows(results_dir, "bench_updates"):
        # the streaming-mutability regressions worth holding: incremental
        # (insert 20% then flush) and post-compaction recall per mode
        if r.get("phase") == "incremental" and float(r.get("recall", 0)) > 0:
            out[f"updates:{r['dataset']}:incremental:{r['mode']}"] = \
                float(r["recall"])
        if r.get("phase") == "compact" and float(r.get("recall", 0)) > 0:
            out[f"updates:{r['dataset']}:compact"] = float(r["recall"])
    for r in _load_rows(results_dir, "bench_kernels"):
        # traversal-wave fusion counters: deterministic jaxpr-structural
        # counts (no wall-clock), tracked per variant so the fused path
        # staying at 1 program/hop is a committed, gated fact
        if r.get("kernel") != "traversal_wave":
            continue
        base = f"kernels:traversal_wave:{r['variant']}"
        for suffix in ("per_hop_programs", "hop_gather_bytes"):
            if suffix in r and r[suffix] is not None:
                out[f"{base}:{suffix}"] = float(r[suffix])
    for r in _load_rows(results_dir, "bench_sharding"):
        # mesh tier: the bench itself asserts exact id parity and the
        # 1.5x balance cap; here we track recall plus the deterministic
        # placement/routing counters per shard count so drift is visible
        base = f"sharding:{r['dataset']}:shards={r['n_shards']}"
        if float(r.get("recall", 0)) > 0:
            out[base] = float(r["recall"])
        for suffix in ("active_balance", "replica_hits"):
            if suffix in r:
                out[f"{base}:{suffix}"] = float(r[suffix])
    for r in _load_rows(results_dir, "bench_serving"):
        # frontend rows only: the serial row is the calibration baseline
        # (its open-loop latencies are the backlog being demonstrated)
        if r.get("mode") not in ("frontend", "frontend_ingest"):
            continue
        base = f"serving:{r['dataset']}:{r['mode']}"
        if float(r.get("recall", 0)) > 0:
            out[base] = float(r["recall"])
        for suffix in ("p99_ms", "shed_rate", "speedup"):
            if suffix in r:
                out[f"{base}:{suffix}"] = float(r[suffix])
    return out


def metric_rule(key: str, recall_tol: float):
    """(direction, kind, tolerance, abs_slack) for a tracked key."""
    suffix = key.rsplit(":", 1)[-1]
    if suffix in PERF_METRICS:
        return PERF_METRICS[suffix]
    return ("higher", "abs", recall_tol, 0.0)


def check_one(key: str, got: float, base: float, recall_tol: float):
    """Returns (ok, limit) — the boundary value the metric must respect."""
    direction, kind, tol, slack = metric_rule(key, recall_tol)
    if direction == "higher":
        limit = base - tol if kind == "abs" else base * (1 - tol)
        return got >= limit, limit
    limit = (base + tol if kind == "abs" else base * (1 + tol)) + slack
    return got <= limit, limit


def _fmt(v: float) -> str:
    return f"{v:.4f}" if abs(v) < 100 else f"{v:.0f}"


def write_summary(path: str, lines: list[tuple], failures, missing) -> None:
    """Markdown table for $GITHUB_STEP_SUMMARY."""
    with open(path, "a") as f:
        f.write("### Bench gate (quality + perf)\n\n")
        f.write("| metric | baseline | current | limit | status |\n")
        f.write("|---|---:|---:|---:|---|\n")
        for key, base, got, limit, status in lines:
            mark = {"ok": "✅", "FAIL": "❌", "new": "🆕"}.get(status, "")
            f.write(f"| `{key}` | {_fmt(base) if base is not None else '—'} "
                    f"| {_fmt(got)} | "
                    f"{_fmt(limit) if limit is not None else '—'} "
                    f"| {mark} {status} |\n")
        for key in missing:
            f.write(f"| `{key}` | — | *missing* | — | ❌ missing |\n")
        verdict = "**FAIL**" if (failures or missing) else "**OK**"
        f.write(f"\n{verdict}: {len(lines)} tracked, "
                f"{len(failures)} regressed, {len(missing)} missing\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=DEFAULT_RESULTS,
                    help="directory holding the per-bench JSON files")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current results as the new baseline")
    ap.add_argument("--summary", default=os.environ.get(
        "GITHUB_STEP_SUMMARY"),
        help="append a markdown summary table to this file "
             "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)

    got = tracked_metrics(args.results)
    if not got:
        print(f"bench gate: no tracked bench results under {args.results} "
              "(run `python -m benchmarks.run --scale smoke` first)")
        return 1

    if args.write_baseline:
        payload = {"tolerance": DEFAULT_TOLERANCE,
                   "metrics": {k: got[k] for k in sorted(got)}}
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"bench gate: wrote {len(got)} metrics to {args.baseline}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    tol = float(base.get("tolerance", DEFAULT_TOLERANCE))
    lines, failures, missing = [], [], []
    for key, floor in sorted(base["metrics"].items()):
        if key not in got:
            missing.append(key)
            continue
        ok, limit = check_one(key, got[key], floor, tol)
        status = "ok" if ok else "FAIL"
        print(f"  [{status}] {key}: {_fmt(got[key])} "
              f"(baseline {_fmt(floor)}, limit {_fmt(limit)})")
        lines.append((key, floor, got[key], limit, status))
        if not ok:
            failures.append(key)
    for key in sorted(set(got) - set(base["metrics"])):
        print(f"  [new]  {key}: {_fmt(got[key])} (not in baseline yet)")
        lines.append((key, None, got[key], None, "new"))

    if args.summary:
        write_summary(args.summary, lines, failures, missing)
    if missing:
        print(f"bench gate: {len(missing)} tracked metric(s) missing from "
              f"results: {missing}")
    if failures:
        print(f"bench gate: FAIL — {len(failures)} metric(s) regressed "
              f"past their limit: {failures}")
    if missing or failures:
        return 1
    print(f"bench gate: OK ({len(got)} metrics within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
