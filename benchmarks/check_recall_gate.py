"""CI recall gate: smoke-bench recall vs. the committed baseline.

Reads the per-bench JSON written by ``python -m benchmarks.run --scale
smoke`` (results/bench/*.json), extracts the tracked recall metrics —
Garfield's QPS/recall sweep rows, the disjunctive box-batched rows and
the engine-mode memory-budget sweep (incore / hybrid / ooc) —
and exits non-zero if any drops more than ``tolerance`` below its value
in benchmarks/baselines/smoke_recall.json, or if a tracked metric
disappeared entirely (a silently-skipped bench must not pass the gate).

After an *intentional* quality change, regenerate the baseline with::

    PYTHONPATH=src python -m benchmarks.run --scale smoke
    PYTHONPATH=src python -m benchmarks.check_recall_gate --write-baseline

and commit the updated baseline file alongside the change.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_RESULTS = os.path.join(_REPO, "results", "bench")
DEFAULT_BASELINE = os.path.join(_REPO, "benchmarks", "baselines",
                                "smoke_recall.json")
DEFAULT_TOLERANCE = 0.03   # CPU-jax jitter headroom across versions/runners


def _load_rows(results_dir: str, bench: str):
    path = os.path.join(results_dir, f"{bench}.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if data.get("status") != "ok":
        return []   # errored bench: its metrics go "missing" -> gate fails
    return data.get("rows", [])


def tracked_metrics(results_dir: str) -> dict:
    """key -> recall for every row the gate watches.

    Rows with recall == 0 are skipped as degenerate: at smoke scale some
    workloads (e.g. m=4 conjunctions) leave empty ground-truth sets and
    score 0/1 regardless of search quality, so a 0.0 floor could never
    fail and would only pretend to guard anything.
    """
    out = {}
    for r in _load_rows(results_dir, "bench_qps_recall"):
        if r.get("method") == "garfield" and float(r.get("recall", 0)) > 0:
            key = f"qps_recall:{r['dataset']}:m={r['m']}:ef={r['ef']}"
            out[key] = float(r["recall"])
    for r in _load_rows(results_dir, "bench_disjunction"):
        if (r.get("method") == "box_batched"
                and float(r.get("recall", 0)) > 0):
            key = f"disjunction:{r['dataset']}:branches={r['n_branches']}"
            out[key] = float(r["recall"])
    for r in _load_rows(results_dir, "bench_memory_budget"):
        if float(r.get("recall", 0)) > 0:
            key = (f"memory_budget:{r['dataset']}:{r['budget']}:"
                   f"{r['mode']}")
            out[key] = float(r["recall"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=DEFAULT_RESULTS,
                    help="directory holding the per-bench JSON files")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current results as the new baseline")
    args = ap.parse_args(argv)

    got = tracked_metrics(args.results)
    if not got:
        print(f"recall gate: no tracked bench results under {args.results} "
              "(run `python -m benchmarks.run --scale smoke` first)")
        return 1

    if args.write_baseline:
        payload = {"tolerance": DEFAULT_TOLERANCE,
                   "metrics": {k: got[k] for k in sorted(got)}}
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"recall gate: wrote {len(got)} metrics to {args.baseline}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    tol = float(base.get("tolerance", DEFAULT_TOLERANCE))
    failures, missing = [], []
    for key, floor in sorted(base["metrics"].items()):
        if key not in got:
            missing.append(key)
            continue
        status = "FAIL" if got[key] < floor - tol else "ok"
        print(f"  [{status}] {key}: {got[key]:.4f} "
              f"(baseline {floor:.4f}, tolerance {tol})")
        if status == "FAIL":
            failures.append(key)
    for key in sorted(set(got) - set(base["metrics"])):
        print(f"  [new]  {key}: {got[key]:.4f} (not in baseline yet)")

    if missing:
        print(f"recall gate: {len(missing)} tracked metric(s) missing from "
              f"results: {missing}")
    if failures:
        print(f"recall gate: FAIL — {len(failures)} metric(s) regressed "
              f"below baseline - {tol}: {failures}")
    if missing or failures:
        return 1
    print(f"recall gate: OK ({len(got)} metrics within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
