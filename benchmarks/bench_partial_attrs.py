"""Paper Figure 10: queries filtering a subset of the indexed attributes
on an index built for p attributes, vs dedicated indexes per subset."""

from __future__ import annotations

from benchmarks import common
from repro.core import gmg
from repro.core.search import Searcher, ground_truth, recall_at_k
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    # index over p=2 attributes
    full_idx = common.built_index(ds, n)
    s_full = Searcher(full_idx)
    rows = []
    for subset in ([0], [1], [0, 1]):
        wl = make_queries(v, a, nq, len(subset), seed=80,
                          attr_subset=subset)
        tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
        p = SearchParams(k=10, ef=64)
        ids, _ = s_full.search(wl.q, wl.lo, wl.hi, p)
        qps_full, _ = common.timed_qps(
            lambda: s_full.search(wl.q, wl.lo, wl.hi, p), nq)
        # dedicated index over exactly the filtered subset (the paper's
        # "ideal" baseline)
        ded_cfg = GMGConfig(seg_per_attr=(4,) * len(subset),
                            intra_degree=16, n_clusters=32)
        a_sub = a[:, subset]
        ded = gmg.build_gmg(v, a_sub, ded_cfg, seed=0)
        s_ded = Searcher(ded)
        wl_sub_lo = wl.lo[:, subset]
        wl_sub_hi = wl.hi[:, subset]
        ids_d, _ = s_ded.search(wl.q, wl_sub_lo, wl_sub_hi, p)
        qps_ded, _ = common.timed_qps(
            lambda: s_ded.search(wl.q, wl_sub_lo, wl_sub_hi, p), nq)
        # dedicated truth == same truth (subset predicates identical)
        rows.append(dict(bench="partial_attrs",
                         subset="+".join(map(str, subset)),
                         recall_full=round(recall_at_k(ids, tids), 4),
                         qps_full=round(qps_full, 1),
                         recall_dedicated=round(recall_at_k(ids_d, tids), 4),
                         qps_dedicated=round(qps_ded, 1)))
    return rows
